"""L2: the agent model and local update graphs of Alg. 1 (build-time JAX).

Everything here is lowered **once** by ``aot.py`` to HLO text and executed
from the Rust coordinator through PJRT; Python never runs on the request
path.

Model state crosses the PJRT boundary as a single flat ``f32[P]`` vector
(the ABI documented in DESIGN.md §4).  The MLP architecture is a list of
layer widths ``[d, h1, ..., c]``; parameters are packed
``[W1, b1, W2, b2, ...]`` row-major.

Local update graphs:

* ``local_admm``     — S proximal-SGD steps on
  ``f_i(x) + rho/2 |x - zhat + u|^2`` (Alg. 1 agent step; also FedADMM,
  FedProx via ``u = 0, rho = mu``, FedAvg via ``rho = 0``).
* ``local_scaffold`` — S corrected-SGD steps ``p -= lr (g + c - c_i)``.
* ``predict`` / ``loss`` / ``grad`` — evaluation heads.

Each graph exists in a Pallas (L1 kernels) and a pure-jnp reference
variant; pytest pins them equal and ``aot.py`` emits both.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.linear import dense
from compile.kernels.prox import prox_sgd_update
from compile.kernels import ref


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------

def param_shapes(layers):
    """[(W shape, b shape), ...] for an MLP with the given widths."""
    return [((din, dout), (dout,))
            for din, dout in zip(layers[:-1], layers[1:])]


def param_offsets(layers):
    """Flat-vector offsets: list of (start, end, shape) in pack order."""
    offs, pos = [], 0
    for wshape, bshape in param_shapes(layers):
        for shape in (wshape, bshape):
            size = 1
            for s in shape:
                size *= s
            offs.append((pos, pos + size, shape))
            pos += size
    return offs, pos


def param_len(layers) -> int:
    return param_offsets(layers)[1]


def unpack(flat, layers):
    """Flat f32[P] -> [(W1, b1), (W2, b2), ...]."""
    offs, total = param_offsets(layers)
    assert flat.shape == (total,), (flat.shape, total)
    tensors = [flat[a:b].reshape(shape) for a, b, shape in offs]
    return list(zip(tensors[0::2], tensors[1::2]))


def pack(pairs):
    """[(W, b), ...] -> flat f32[P]."""
    parts = []
    for w, b in pairs:
        parts.append(w.reshape(-1))
        parts.append(b.reshape(-1))
    return jnp.concatenate(parts)


def init_params(layers, key):
    """He-init packed parameter vector (matches rust/src/model native init)."""
    pairs = []
    for din, dout in zip(layers[:-1], layers[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
        pairs.append((w, jnp.zeros((dout,), jnp.float32)))
    return pack(pairs)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _forward(flat, x, layers, use_pallas: bool):
    layer = dense if use_pallas else ref.dense_ref
    pairs = unpack(flat, layers)
    h = x
    for li, (w, b) in enumerate(pairs):
        is_last = li == len(pairs) - 1
        h = layer(h, w, b, not is_last)
    return h  # logits


def predict(flat, x, *, layers, use_pallas=True):
    """Logits ``f32[B, C]`` for a batch ``x: f32[B, D]``."""
    return _forward(flat, x, layers, use_pallas)


def loss(flat, x, y_onehot, *, layers, use_pallas=True):
    """Mean softmax cross-entropy; ``y_onehot: f32[B, C]``."""
    logits = _forward(flat, x, layers, use_pallas)
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logz, axis=-1))


def grad(flat, x, y_onehot, *, layers, use_pallas=True):
    """dloss/dparams, flat ``f32[P]``."""
    return jax.grad(loss)(flat, x, y_onehot, layers=layers,
                          use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Local update graphs
# ---------------------------------------------------------------------------

def _prox_step(p, g, anchor, corr, lr, rho, use_pallas):
    if use_pallas:
        return prox_sgd_update(p, g, anchor, corr, lr, rho)
    return ref.prox_sgd_update_ref(p, g, anchor, corr, lr, rho)


def local_admm(params, zhat, u, xs, ys, lr, rho, *, layers, use_pallas=True):
    """S proximal-SGD steps of the Alg. 1 agent update.

    ``xs: f32[S, B, D]``, ``ys: f32[S, B, C]`` — one minibatch per step,
    sampled by the Rust coordinator.  ``lr``/``rho`` are runtime scalars so a
    single artifact serves hyperparameter sweeps.
    """
    steps = xs.shape[0]
    anchor = zhat - u
    zero = jnp.zeros_like(params)

    def body(s, p):
        g = grad(p, xs[s], ys[s], layers=layers, use_pallas=use_pallas)
        return _prox_step(p, g, anchor, zero, lr, rho, use_pallas)

    return lax.fori_loop(0, steps, body, params)


def local_scaffold(params, corr, xs, ys, lr, *, layers, use_pallas=True):
    """S corrected-SGD steps (SCAFFOLD): ``p -= lr (g + corr)`` with
    ``corr = c - c_i`` computed by the coordinator.  Reuses the fused prox
    kernel with ``rho = 0`` and the correction as the additive term."""
    steps = xs.shape[0]
    zero = jnp.zeros_like(params)

    def body(s, p):
        g = grad(p, xs[s], ys[s], layers=layers, use_pallas=use_pallas)
        return _prox_step(p, g, zero, corr, lr, 0.0, use_pallas)

    return lax.fori_loop(0, steps, body, params)
