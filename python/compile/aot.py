"""AOT pipeline: lower every L2 graph to HLO text + write the manifest.

Run as ``python -m compile.aot --out ../artifacts`` (see Makefile target
``artifacts``).  Python runs ONCE here; the Rust coordinator is
self-contained afterwards.

Interchange format is **HLO text** — jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``<config>.<graph>.<variant>.hlo.txt``  — one per (config, graph, variant)
* ``manifest.json``                       — shapes, parameter ABI, file map
* ``testvec.json``                        — pinned inputs/outputs of the tiny
  config for Rust differential tests
* ``.stamp``                              — source hash for incremental skips
"""

import argparse
import hashlib
import json
import os
import sys
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------------
# Model configs (see DESIGN.md §3 for the dataset substitutions)
# ---------------------------------------------------------------------------
# layers include input dim and class count; batch = minibatch size per SGD
# step; steps = SGD steps per round (the paper's "5 steps" / "3 local
# epochs" budgets).

CONFIGS = {
    # fast config for unit/integration tests and quickstart
    "tiny": dict(layers=[8, 16, 4], batch=4, steps=2),
    # MNIST-surrogate: paper's MLP [400, 200, 10] on 8x8 synthetic digits
    "mnist": dict(layers=[64, 400, 200, 10], batch=64, steps=5),
    # CIFAR-surrogate: wider MLP on 3x8x8 synthetic images
    "cifar": dict(layers=[192, 512, 256, 10], batch=20, steps=6),
}

GRAPHS = ("local_admm", "local_scaffold", "predict", "loss", "grad")
VARIANTS = ("pallas", "ref")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def graph_fn(graph: str, layers, use_pallas: bool):
    """The jittable function + its example arg specs for one artifact."""
    P = model.param_len(layers)
    d, c = layers[0], layers[-1]

    if graph == "local_admm":
        def fn(params, zhat, u, xs, ys, lr, rho):
            return (model.local_admm(params, zhat, u, xs, ys, lr, rho,
                                     layers=layers, use_pallas=use_pallas),)
        def specs(batch, steps):
            return [_spec((P,))] * 3 + [_spec((steps, batch, d)),
                                        _spec((steps, batch, c)),
                                        _spec(()), _spec(())]
    elif graph == "local_scaffold":
        def fn(params, corr, xs, ys, lr):
            return (model.local_scaffold(params, corr, xs, ys, lr,
                                         layers=layers,
                                         use_pallas=use_pallas),)
        def specs(batch, steps):
            return [_spec((P,))] * 2 + [_spec((steps, batch, d)),
                                        _spec((steps, batch, c)), _spec(())]
    elif graph == "predict":
        def fn(params, x):
            return (model.predict(params, x, layers=layers,
                                  use_pallas=use_pallas),)
        def specs(batch, steps):
            return [_spec((P,)), _spec((batch, d))]
    elif graph == "loss":
        def fn(params, x, y):
            return (model.loss(params, x, y, layers=layers,
                               use_pallas=use_pallas),)
        def specs(batch, steps):
            return [_spec((P,)), _spec((batch, d)), _spec((batch, c))]
    elif graph == "grad":
        def fn(params, x, y):
            return (model.grad(params, x, y, layers=layers,
                               use_pallas=use_pallas),)
        def specs(batch, steps):
            return [_spec((P,)), _spec((batch, d)), _spec((batch, c))]
    else:
        raise ValueError(graph)
    return fn, specs


def source_hash() -> str:
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _dirs, files in os.walk(base):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    h.update(json.dumps(CONFIGS, sort_keys=True).encode())
    return h.hexdigest()


def emit_testvec(outdir: str):
    """Pinned tiny-config inputs/outputs for Rust differential tests."""
    cfg = CONFIGS["tiny"]
    layers, batch, steps = cfg["layers"], cfg["batch"], cfg["steps"]
    P = model.param_len(layers)
    d, c = layers[0], layers[-1]
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 8)
    params = model.init_params(layers, ks[0])
    zhat = params * 0.9
    u = 0.01 * jax.random.normal(ks[1], (P,))
    corr = 0.02 * jax.random.normal(ks[2], (P,))
    xs = jax.random.normal(ks[3], (steps, batch, d))
    labels = jax.random.randint(ks[4], (steps, batch), 0, c)
    ys = jax.nn.one_hot(labels, c).astype(jnp.float32)
    lr, rho = 0.1, 1.0

    out = {
        "config": "tiny",
        "lr": lr,
        "rho": rho,
        "params": params.tolist(),
        "zhat": zhat.tolist(),
        "u": u.tolist(),
        "corr": corr.tolist(),
        "xs": xs.reshape(-1).tolist(),
        "ys": ys.reshape(-1).tolist(),
    }
    out["local_admm"] = model.local_admm(
        params, zhat, u, xs, ys, lr, rho, layers=layers,
        use_pallas=False).tolist()
    out["local_scaffold"] = model.local_scaffold(
        params, corr, xs, ys, lr, layers=layers, use_pallas=False).tolist()
    out["predict"] = model.predict(
        params, xs[0], layers=layers, use_pallas=False).reshape(-1).tolist()
    out["loss"] = float(model.loss(params, xs[0], ys[0], layers=layers,
                                   use_pallas=False))
    out["grad"] = model.grad(params, xs[0], ys[0], layers=layers,
                             use_pallas=False).tolist()
    with open(os.path.join(outdir, "testvec.json"), "w") as f:
        json.dump(out, f)
    print(f"  testvec.json ({len(out['params'])}-param tiny config)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default=",".join(CONFIGS),
                    help="comma-separated subset of configs to emit")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)
    stamp_path = os.path.join(outdir, ".stamp")
    stamp = source_hash() + ":" + args.configs
    if not args.force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read() == stamp and os.path.exists(
                    os.path.join(outdir, "manifest.json")):
                print("artifacts up to date (stamp match); skipping")
                return

    manifest = {"abi": "flat f32[P]; pack order [W1,b1,W2,b2,...] row-major",
                "configs": {}}
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        layers, batch, steps = cfg["layers"], cfg["batch"], cfg["steps"]
        P = model.param_len(layers)
        offsets = [
            {"start": a, "end": b, "shape": list(shape)}
            for a, b, shape in model.param_offsets(layers)[0]
        ]
        entry = {
            "layers": layers, "batch": batch, "steps": steps,
            "classes": layers[-1], "input_dim": layers[0],
            "param_len": P, "offsets": offsets, "artifacts": {},
        }
        for graph in GRAPHS:
            for variant in VARIANTS:
                fn, specs = graph_fn(graph, layers, variant == "pallas")
                lowered = jax.jit(fn).lower(*specs(batch, steps))
                text = to_hlo_text(lowered)
                fname = f"{name}.{graph}.{variant}.hlo.txt"
                with open(os.path.join(outdir, fname), "w") as f:
                    f.write(text)
                entry["artifacts"][f"{graph}_{variant}"] = fname
                print(f"  {fname}: {len(text)} chars")
        manifest["configs"][name] = entry

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    emit_testvec(outdir)
    with open(stamp_path, "w") as f:
        f.write(stamp)
    print(f"wrote manifest for configs: {args.configs} -> {outdir}")


if __name__ == "__main__":
    main()
