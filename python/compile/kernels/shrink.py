"""Soft-threshold (l1 proximal) kernel (L1).

The z-update of Alg. 1 with ``g(z) = lambda |z|_1`` is the shrinkage
operator ``S_tau(v) = sign(v) * max(|v| - tau, 0)`` — the workhorse of the
paper's LASSO experiments (App. G.1/G.2).  Fused single-pass kernel over a
1-D VMEM-tiled grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import os

_BLOCK = int(os.environ.get("DELA_PALLAS_VBLOCK", "65536"))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _shrink_kernel(v_ref, tau_ref, o_ref):
    v = v_ref[...]
    tau = tau_ref[0]
    o_ref[...] = jnp.sign(v) * jnp.maximum(jnp.abs(v) - tau, 0.0)


def soft_threshold(v, tau, *, block: int = _BLOCK):
    """``sign(v) * max(|v| - tau, 0)`` over a flat f32 vector."""
    (n,) = v.shape
    bs = min(block, _round_up(n, 8))
    npad = _round_up(n, bs)
    vp = jnp.pad(v, (0, npad - n)) if npad != n else v
    tau1 = jnp.asarray(tau, jnp.float32).reshape((1,))
    vec = pl.BlockSpec((bs,), lambda i: (i,))
    out = pl.pallas_call(
        _shrink_kernel,
        grid=(npad // bs,),
        in_specs=[vec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(vp, tau1)
    return out[:n]
