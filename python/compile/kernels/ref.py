"""Pure-jnp correctness oracles for every L1 kernel.

pytest asserts ``allclose`` between each Pallas kernel and its oracle over
exact paper shapes and hypothesis-driven shape/value sweeps.  The ``*_ref``
artifact variants emitted by ``aot.py`` are built exclusively from these.
"""

import jax.numpy as jnp


def matmul_ref(x, w, *, bias=None, relu=False, trans_x=False, trans_w=False):
    a = x.T if trans_x else x
    b = w.T if trans_w else w
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.reshape(1, -1)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def dense_ref(x, w, b, relu=False):
    return matmul_ref(x, w, bias=b, relu=relu)


def prox_sgd_update_ref(p, g, anchor, corr, lr, rho):
    lr = jnp.asarray(lr, jnp.float32).reshape(())
    rho = jnp.asarray(rho, jnp.float32).reshape(())
    return p - lr * (g + corr + rho * (p - anchor))


def soft_threshold_ref(v, tau):
    tau = jnp.asarray(tau, jnp.float32).reshape(())
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - tau, 0.0)
