"""L1 Pallas kernels for DELA.

All kernels are authored with TPU-shaped tiling (BlockSpec-expressed
HBM<->VMEM schedules, MXU-friendly block shapes) and lowered with
``interpret=True`` so the CPU PJRT plugin can execute the resulting HLO.
Correctness oracles live in :mod:`compile.kernels.ref`.
"""

from compile.kernels.linear import matmul, dense
from compile.kernels.prox import prox_sgd_update
from compile.kernels.shrink import soft_threshold

__all__ = ["matmul", "dense", "prox_sgd_update", "soft_threshold"]
