"""Tiled Pallas matmul / dense-layer kernels (L1).

The paper's compute hot-spot is the local proximal step of Alg. 1, which is
dominated by the dense-layer matmuls of the agent model.  On a GPU the paper
relies on cuBLAS; here the insight is re-expressed for TPU idiom:

* the grid iterates ``(M/bm, N/bn, K/bk)`` and each step keeps one
  ``(bm, bk)`` x-tile, one ``(bk, bn)`` w-tile and the ``(bm, bn)``
  accumulator resident in VMEM (the BlockSpecs below *are* the HBM<->VMEM
  schedule a CUDA kernel would express with threadblocks + shared memory);
* the contraction runs on the MXU via ``dot_general`` with an f32
  accumulator that is revisited across the sequential K axis;
* bias add + ReLU are fused into the final K step so the activation never
  round-trips through HBM.

``interpret=True`` lowers the kernel to plain HLO so the CPU PJRT client can
execute it; on a real TPU the same source compiles to Mosaic.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default tile edge.  128 is the MXU-native edge a real-TPU build would
# use; the CPU interpret path amortizes its per-grid-step overhead with a
# larger default (4x128 = still MXU-aligned, 3 x 512^2 x 4B = 3 MB << 16 MB
# VMEM).  Overridable for experiments via DELA_PALLAS_TILE (read at
# AOT-lowering time; see EXPERIMENTS.md §Perf for the measured effect).
import os

_TILE = int(os.environ.get("DELA_PALLAS_TILE", "512"))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, tile: int = _TILE) -> int:
    """Pick a block edge: full MXU tile when the dim is big enough,
    otherwise the next multiple of 8 covering the dim (single block)."""
    if dim >= tile:
        return tile
    return _round_up(dim, 8)


def _pad2(a, rows: int, cols: int):
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk, trans_x, trans_w, relu,
               has_bias):
    """One (i, j, k) grid step: accumulate an MXU tile; fuse bias/ReLU on
    the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Contraction dims depend on the (trans_x, trans_w) layout:
    #   x tile: (bm, bk) normally, (bk, bm) when trans_x
    #   w tile: (bk, bn) normally, (bn, bk) when trans_w
    cx = 0 if trans_x else 1
    cw = 1 if trans_w else 0
    acc = lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((cx,), (cw,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if trans_x:
        # dot_general yields (bk-free?, ...): with contraction on x dim0 the
        # remaining x dim is dim1 -> rows are already bm. Nothing to do.
        pass
    o_ref[...] += acc

    @pl.when(k == nk - 1)
    def _finish():
        out = o_ref[...]
        if has_bias:
            out = out + b_ref[...]
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def matmul(x, w, *, bias=None, relu: bool = False,
           trans_x: bool = False, trans_w: bool = False,
           tile: int = _TILE):
    """``op(x) @ op(w) (+ bias) (-> relu)`` as a tiled Pallas kernel.

    ``trans_x`` contracts over ``x``'s leading dim (i.e. computes
    ``x.T @ w``); ``trans_w`` contracts over ``w``'s trailing dim
    (``x @ w.T``).  Shapes follow numpy semantics of the *logical* product.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape}, {w.shape}")
    m = x.shape[1] if trans_x else x.shape[0]
    kx = x.shape[0] if trans_x else x.shape[1]
    kw = w.shape[1] if trans_w else w.shape[0]
    n = w.shape[0] if trans_w else w.shape[1]
    if kx != kw:
        raise ValueError(f"contraction mismatch: {x.shape} vs {w.shape}")
    kdim = kx

    bm, bn, bk = _pick_block(m, tile), _pick_block(n, tile), _pick_block(kdim, tile)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(kdim, bk)

    xp = _pad2(x, kp if trans_x else mp, mp if trans_x else kp)
    wp = _pad2(w, np_ if trans_w else kp, kp if trans_w else np_)
    has_bias = bias is not None
    bp = (_pad2(bias.reshape(1, -1), 1, np_) if has_bias
          else jnp.zeros((1, bn), jnp.float32))

    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    x_spec = pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)) if trans_x \
        else pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)) if trans_w \
        else pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    b_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))

    out = pl.pallas_call(
        partial(_mm_kernel, nk=nk, trans_x=trans_x, trans_w=trans_w,
                relu=relu, has_bias=has_bias),
        grid=grid,
        in_specs=[x_spec, w_spec, b_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Dense layer with a custom VJP so jax.grad pulls gradients through the
# Pallas kernels (forward *and* backward run on the L1 path).
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu: bool = False):
    """``relu?(x @ w + b)`` with Pallas forward and backward."""
    return matmul(x, w, bias=b, relu=relu)


def _dense_fwd(x, w, b, relu):
    out = matmul(x, w, bias=b, relu=relu)
    return out, (x, w, out)


def _dense_bwd(relu, res, dy):
    x, w, out = res
    if relu:
        dy = jnp.where(out > 0.0, dy, 0.0)
    dx = matmul(dy, w, trans_w=True)           # dY @ W^T
    dw = matmul(x, dy, trans_x=True)           # X^T @ dY
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
