"""Fused proximal-SGD update kernel (L1).

One step of the local minimization of Alg. 1 replaces
``argmin_x f_i(x) + rho/2 |x - zhat + u|^2`` with (stochastic) gradient
steps

    p <- p - lr * (g + corr + rho * (p - (zhat - u)))

where ``g`` is the data gradient, ``corr`` an optional additive correction
(SCAFFOLD's ``c - c_i``; zero for ADMM) and ``anchor = zhat - u``.  Written
naively in jnp this is four elementwise HBM round-trips over the full
parameter vector; the kernel fuses them into one pass, tiled over a 1-D
grid so each block lives in VMEM.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import os

# 1-D tile; 64k f32 x 6 operands = 1.5 MB of VMEM per step.
_BLOCK = int(os.environ.get("DELA_PALLAS_VBLOCK", "65536"))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _prox_kernel(p_ref, g_ref, a_ref, c_ref, lr_ref, rho_ref, o_ref):
    lr = lr_ref[0]
    rho = rho_ref[0]
    p = p_ref[...]
    o_ref[...] = p - lr * (g_ref[...] + c_ref[...] + rho * (p - a_ref[...]))


def prox_sgd_update(p, g, anchor, corr, lr, rho, *, block: int = _BLOCK):
    """Fused ``p - lr*(g + corr + rho*(p - anchor))`` over flat f32 vectors.

    ``lr`` and ``rho`` are traced scalars (rank-0 or shape-(1,) arrays).
    """
    (n,) = p.shape
    bs = min(block, _round_up(n, 8))
    npad = _round_up(n, bs)

    def pad(v):
        return jnp.pad(v, (0, npad - n)) if npad != n else v

    lr1 = jnp.asarray(lr, jnp.float32).reshape((1,))
    rho1 = jnp.asarray(rho, jnp.float32).reshape((1,))
    vec = pl.BlockSpec((bs,), lambda i: (i,))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    out = pl.pallas_call(
        _prox_kernel,
        grid=(npad // bs,),
        in_specs=[vec, vec, vec, vec, scal, scal],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(pad(p), pad(g), pad(anchor), pad(corr), lr1, rho1)
    return out[:n]
