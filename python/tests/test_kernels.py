"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.linear import matmul, dense
from compile.kernels.prox import prox_sgd_update
from compile.kernels.shrink import soft_threshold
from compile.kernels import ref

ATOL = 2e-4  # f32 accumulation over <=512-length contractions


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# matmul — plain, bias, relu, transposes, tile-boundary shapes
# ---------------------------------------------------------------------------

MM_SHAPES = [
    (1, 1, 1), (3, 5, 7), (8, 8, 8), (64, 64, 64),
    (128, 128, 128), (129, 127, 130),  # crosses the 128 tile on all axes
    (64, 400, 200),                    # paper MLP interior layer
    (20, 192, 512),                    # cifar-surrogate entry layer
    (5, 200, 10),                      # tiny head
]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
def test_matmul_plain(m, k, n):
    k1, k2 = keys(2, seed=m * 1000 + n)
    x, w = _rand(k1, (m, k)), _rand(k2, (k, n))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                               atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("m,k,n", MM_SHAPES[:6])
def test_matmul_bias_relu(m, k, n):
    k1, k2, k3 = keys(3, seed=m + n)
    x, w, b = _rand(k1, (m, k)), _rand(k2, (k, n)), _rand(k3, (n,))
    got = matmul(x, w, bias=b, relu=True)
    want = ref.matmul_ref(x, w, bias=b, relu=True)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)
    assert float(jnp.min(got)) >= 0.0


@pytest.mark.parametrize("m,k,n", MM_SHAPES[:6])
def test_matmul_trans_x(m, k, n):
    k1, k2 = keys(2, seed=m * 7 + n)
    x, w = _rand(k1, (k, m)), _rand(k2, (k, n))
    np.testing.assert_allclose(matmul(x, w, trans_x=True),
                               ref.matmul_ref(x, w, trans_x=True),
                               atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("m,k,n", MM_SHAPES[:6])
def test_matmul_trans_w(m, k, n):
    k1, k2 = keys(2, seed=m * 11 + n)
    x, w = _rand(k1, (m, k)), _rand(k2, (n, k))
    np.testing.assert_allclose(matmul(x, w, trans_w=True),
                               ref.matmul_ref(x, w, trans_w=True),
                               atol=ATOL, rtol=1e-4)


def test_matmul_rejects_bad_shapes():
    x, w = jnp.zeros((3, 4)), jnp.zeros((5, 6))
    with pytest.raises(ValueError):
        matmul(x, w)
    with pytest.raises(ValueError):
        matmul(jnp.zeros((3,)), w)


def test_matmul_zero_inputs():
    out = matmul(jnp.zeros((9, 17)), jnp.zeros((17, 3)))
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_matmul_identity():
    x = _rand(keys(1)[0], (12, 12))
    np.testing.assert_allclose(matmul(x, jnp.eye(12)), x, atol=ATOL)


def test_matmul_custom_tile():
    k1, k2 = keys(2, seed=3)
    x, w = _rand(k1, (33, 47)), _rand(k2, (47, 21))
    for tile in (8, 16, 32):
        np.testing.assert_allclose(matmul(x, w, tile=tile),
                                   ref.matmul_ref(x, w), atol=ATOL, rtol=1e-4)


# ---------------------------------------------------------------------------
# dense + custom VJP: gradients flow through the Pallas backward kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("relu", [False, True])
def test_dense_forward(relu):
    k1, k2, k3 = keys(3, seed=5)
    x, w, b = _rand(k1, (6, 9)), _rand(k2, (9, 4)), _rand(k3, (4,))
    np.testing.assert_allclose(dense(x, w, b, relu),
                               ref.dense_ref(x, w, b, relu),
                               atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("relu", [False, True])
def test_dense_grad_matches_ref_autodiff(relu):
    k1, k2, k3 = keys(3, seed=6)
    x, w, b = _rand(k1, (6, 9)), _rand(k2, (9, 4)), _rand(k3, (4,))

    def f(x, w, b):
        return jnp.sum(jnp.tanh(dense(x, w, b, relu)))

    def fr(x, w, b):
        return jnp.sum(jnp.tanh(ref.dense_ref(x, w, b, relu)))

    gp = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, atol=ATOL, rtol=1e-4)


def test_dense_grad_large_shape():
    k1, k2, k3 = keys(3, seed=7)
    x, w, b = _rand(k1, (64, 130)), _rand(k2, (130, 140)), _rand(k3, (140,))
    gp = jax.grad(lambda *a: jnp.sum(dense(*a, True)), (0, 1, 2))(x, w, b)
    gr = jax.grad(lambda *a: jnp.sum(ref.dense_ref(*a, True)), (0, 1, 2))(x, w, b)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# prox_sgd_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 212, 8192, 8193, 100_000])
def test_prox_sgd(n):
    k1, k2, k3, k4 = keys(4, seed=n)
    p, g, a, c = (_rand(k1, (n,)), _rand(k2, (n,)), _rand(k3, (n,)),
                  _rand(k4, (n,)))
    got = prox_sgd_update(p, g, a, c, 0.05, 2.0)
    want = ref.prox_sgd_update_ref(p, g, a, c, 0.05, 2.0)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


def test_prox_sgd_zero_rho_is_sgd():
    k1, k2 = keys(2, seed=9)
    p, g = _rand(k1, (500,)), _rand(k2, (500,))
    z = jnp.zeros((500,))
    got = prox_sgd_update(p, g, z, z, 0.1, 0.0)
    np.testing.assert_allclose(got, p - 0.1 * g, atol=1e-7)


def test_prox_sgd_pulls_toward_anchor():
    # With g = corr = 0 the update is a contraction toward the anchor.
    p = jnp.ones((100,)) * 5.0
    a = jnp.zeros((100,))
    z = jnp.zeros((100,))
    out = prox_sgd_update(p, z, a, z, 0.1, 1.0)
    assert float(jnp.max(jnp.abs(out))) < 5.0


def test_prox_sgd_traced_scalars():
    # lr/rho must be usable as traced runtime values (the artifact ABI).
    k1, k2 = keys(2, seed=10)
    p, g = _rand(k1, (64,)), _rand(k2, (64,))
    z = jnp.zeros((64,))
    f = jax.jit(lambda lr, rho: prox_sgd_update(p, g, z, z, lr, rho))
    np.testing.assert_allclose(
        f(jnp.float32(0.2), jnp.float32(3.0)),
        ref.prox_sgd_update_ref(p, g, z, z, 0.2, 3.0), atol=1e-6)


# ---------------------------------------------------------------------------
# soft_threshold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 50, 8192, 8200])
def test_soft_threshold(n):
    v = _rand(keys(1, seed=n)[0], (n,)) * 3.0
    np.testing.assert_allclose(soft_threshold(v, 0.7),
                               ref.soft_threshold_ref(v, 0.7), atol=1e-7)


def test_soft_threshold_zeroes_small_entries():
    v = jnp.array([-0.5, -0.1, 0.0, 0.1, 0.5])
    out = soft_threshold(v, 0.2)
    np.testing.assert_allclose(out, jnp.array([-0.3, 0.0, 0.0, 0.0, 0.3]),
                               atol=1e-7)


def test_soft_threshold_is_prox_of_l1():
    # prox_{tau|.|_1}(v) minimizes tau|z|_1 + 0.5|z-v|^2: check first-order
    # optimality via subgradient containment on random points.
    v = _rand(keys(1, seed=3)[0], (200,)) * 2.0
    tau = 0.4
    z = soft_threshold(v, tau)
    # where z != 0: z - v + tau*sign(z) == 0
    nz = jnp.abs(z) > 0
    resid = jnp.where(nz, z - v + tau * jnp.sign(z), 0.0)
    assert float(jnp.max(jnp.abs(resid))) < 1e-6
    # where z == 0: |v| <= tau
    assert float(jnp.max(jnp.where(nz, 0.0, jnp.abs(v)))) <= tau + 1e-6
