"""Hypothesis sweeps: Pallas kernels vs oracles over random shapes/values.

Per the reproduction contract, hypothesis drives the L1 kernels across
shape/value space and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.linear import matmul
from compile.kernels.prox import prox_sgd_update
from compile.kernels.shrink import soft_threshold
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)

dims = st.integers(min_value=1, max_value=160)
small_dims = st.integers(min_value=1, max_value=64)
vec_lens = st.integers(min_value=1, max_value=20_000)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
scalars = st.floats(min_value=0.0, max_value=10.0, allow_nan=False,
                    width=32)


def _rand(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape,
                                     jnp.float32)


@given(m=dims, k=dims, n=dims, seed=seeds, relu=st.booleans(),
       bias=st.booleans())
@settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n, seed, relu, bias):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,)) if bias else None
    got = matmul(x, w, bias=b, relu=relu)
    want = ref.matmul_ref(x, w, bias=b, relu=relu)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


@given(m=small_dims, k=small_dims, n=small_dims, seed=seeds,
       tx=st.booleans(), tw=st.booleans())
@settings(**SETTINGS)
def test_matmul_transposes_match_ref(m, k, n, seed, tx, tw):
    x = _rand(seed, (k, m) if tx else (m, k))
    w = _rand(seed + 1, (n, k) if tw else (k, n))
    got = matmul(x, w, trans_x=tx, trans_w=tw)
    want = ref.matmul_ref(x, w, trans_x=tx, trans_w=tw)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


@given(n=vec_lens, seed=seeds, lr=scalars, rho=scalars)
@settings(**SETTINGS)
def test_prox_matches_ref(n, seed, lr, rho):
    p = _rand(seed, (n,))
    g = _rand(seed + 1, (n,))
    a = _rand(seed + 2, (n,))
    c = _rand(seed + 3, (n,))
    got = prox_sgd_update(p, g, a, c, lr, rho)
    want = ref.prox_sgd_update_ref(p, g, a, c, lr, rho)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@given(n=vec_lens, seed=seeds, tau=scalars)
@settings(**SETTINGS)
def test_shrink_matches_ref(n, seed, tau):
    v = _rand(seed, (n,), scale=3.0)
    got = soft_threshold(v, tau)
    want = ref.soft_threshold_ref(v, tau)
    np.testing.assert_allclose(got, want, atol=1e-6)
    # shrinkage never increases magnitude
    assert float(jnp.max(jnp.abs(got) - jnp.abs(v))) <= 1e-6
