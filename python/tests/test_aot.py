"""AOT pipeline tests: manifest consistency + HLO text well-formedness.

These run against the emitted ``artifacts/`` (built by ``make artifacts``);
they skip gracefully when artifacts are absent so `pytest` can run before
the first build.
"""

import json
import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_configs():
    m = _manifest()
    assert set(aot.CONFIGS) <= set(m["configs"])


def test_manifest_param_lens_match_model():
    m = _manifest()
    for name, entry in m["configs"].items():
        assert entry["param_len"] == model.param_len(entry["layers"])
        assert entry["classes"] == entry["layers"][-1]
        assert entry["input_dim"] == entry["layers"][0]


def test_manifest_offsets_are_contiguous():
    m = _manifest()
    for entry in m["configs"].values():
        pos = 0
        for off in entry["offsets"]:
            assert off["start"] == pos
            size = 1
            for s in off["shape"]:
                size *= s
            assert off["end"] - off["start"] == size
            pos = off["end"]
        assert pos == entry["param_len"]


def test_all_artifacts_exist_and_parse_as_hlo():
    m = _manifest()
    for entry in m["configs"].values():
        for key, fname in entry["artifacts"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), fname
            with open(path) as f:
                text = f.read()
            # well-formed HLO text: module header + ENTRY computation
            assert text.startswith("HloModule"), fname
            assert "ENTRY" in text, fname
            assert "ROOT" in text, fname


def test_both_variants_emitted_per_graph():
    m = _manifest()
    for entry in m["configs"].values():
        for graph in aot.GRAPHS:
            assert f"{graph}_pallas" in entry["artifacts"]
            assert f"{graph}_ref" in entry["artifacts"]


def test_testvec_shapes():
    m = _manifest()
    path = os.path.join(ART, "testvec.json")
    assert os.path.exists(path)
    with open(path) as f:
        tv = json.load(f)
    entry = m["configs"][tv["config"]]
    P = entry["param_len"]
    S, B = entry["steps"], entry["batch"]
    D, C = entry["input_dim"], entry["classes"]
    for key in ("params", "zhat", "u", "corr", "local_admm",
                "local_scaffold", "grad"):
        assert len(tv[key]) == P, key
    assert len(tv["xs"]) == S * B * D
    assert len(tv["ys"]) == S * B * C
    assert len(tv["predict"]) == B * C
    assert isinstance(tv["loss"], float)


def test_stamp_skips_rebuild(tmp_path, capsys):
    # second invocation with identical sources must be a no-op
    h1 = aot.source_hash()
    h2 = aot.source_hash()
    assert h1 == h2
