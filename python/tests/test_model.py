"""L2 model tests: packing ABI, forward/loss, local update graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

LAYERS = [8, 16, 4]
P = model.param_len(LAYERS)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _data(seed=0, steps=2, batch=4):
    k1, k2 = keys(2, seed)
    xs = jax.random.normal(k1, (steps, batch, LAYERS[0]))
    labels = jax.random.randint(k2, (steps, batch), 0, LAYERS[-1])
    ys = jax.nn.one_hot(labels, LAYERS[-1]).astype(jnp.float32)
    return xs, ys


# ---------------------------------------------------------------------------
# Packing ABI
# ---------------------------------------------------------------------------

def test_param_len():
    # 8*16+16 + 16*4+4 = 212
    assert P == 212


def test_param_len_paper_configs():
    assert model.param_len([64, 400, 200, 10]) == 64 * 400 + 400 + \
        400 * 200 + 200 + 200 * 10 + 10
    assert model.param_len([192, 512, 256, 10]) == 192 * 512 + 512 + \
        512 * 256 + 256 + 256 * 10 + 10


def test_pack_unpack_roundtrip():
    flat = model.init_params(LAYERS, keys(1)[0])
    np.testing.assert_array_equal(model.pack(model.unpack(flat, LAYERS)), flat)


def test_offsets_cover_vector_contiguously():
    offs, total = model.param_offsets(LAYERS)
    pos = 0
    for a, b, shape in offs:
        assert a == pos
        size = int(np.prod(shape))
        assert b - a == size
        pos = b
    assert pos == total


def test_unpack_shapes():
    flat = jnp.arange(P, dtype=jnp.float32)
    pairs = model.unpack(flat, LAYERS)
    assert [((w.shape), (b.shape)) for w, b in pairs] == \
        [((8, 16), (16,)), ((16, 4), (4,))]
    # W1 occupies the first 128 entries row-major
    np.testing.assert_array_equal(pairs[0][0].reshape(-1),
                                  jnp.arange(128, dtype=jnp.float32))


def test_unpack_rejects_wrong_len():
    with pytest.raises(AssertionError):
        model.unpack(jnp.zeros((P + 1,)), LAYERS)


# ---------------------------------------------------------------------------
# Forward / loss / grad — pallas variant == ref variant
# ---------------------------------------------------------------------------

def test_predict_variants_match():
    flat = model.init_params(LAYERS, keys(1)[0])
    xs, _ = _data()
    a = model.predict(flat, xs[0], layers=LAYERS, use_pallas=True)
    b = model.predict(flat, xs[0], layers=LAYERS, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-5)


def test_loss_finite_and_near_log_c_at_init():
    # With random init the expected CE is ~log(C).
    flat = model.init_params(LAYERS, keys(1, seed=2)[0]) * 0.01
    xs, ys = _data(seed=3)
    val = float(model.loss(flat, xs[0], ys[0], layers=LAYERS,
                           use_pallas=False))
    assert np.isfinite(val)
    assert abs(val - np.log(LAYERS[-1])) < 0.5


def test_grad_variants_match():
    flat = model.init_params(LAYERS, keys(1, seed=4)[0])
    xs, ys = _data(seed=5)
    ga = model.grad(flat, xs[0], ys[0], layers=LAYERS, use_pallas=True)
    gb = model.grad(flat, xs[0], ys[0], layers=LAYERS, use_pallas=False)
    np.testing.assert_allclose(ga, gb, atol=5e-5, rtol=1e-4)


def test_grad_descends_loss():
    flat = model.init_params(LAYERS, keys(1, seed=6)[0])
    xs, ys = _data(seed=7)
    g = model.grad(flat, xs[0], ys[0], layers=LAYERS, use_pallas=False)
    l0 = model.loss(flat, xs[0], ys[0], layers=LAYERS, use_pallas=False)
    l1 = model.loss(flat - 0.05 * g, xs[0], ys[0], layers=LAYERS,
                    use_pallas=False)
    assert float(l1) < float(l0)


# ---------------------------------------------------------------------------
# Local update graphs
# ---------------------------------------------------------------------------

def test_local_admm_variants_match():
    flat = model.init_params(LAYERS, keys(1, seed=8)[0])
    xs, ys = _data(seed=9)
    zhat, u = flat * 0.9, flat * 0.01
    a = model.local_admm(flat, zhat, u, xs, ys, 0.1, 1.0, layers=LAYERS,
                         use_pallas=True)
    b = model.local_admm(flat, zhat, u, xs, ys, 0.1, 1.0, layers=LAYERS,
                         use_pallas=False)
    np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-4)


def test_local_admm_reduces_augmented_objective():
    flat = model.init_params(LAYERS, keys(1, seed=10)[0])
    xs, ys = _data(seed=11, steps=8)
    zhat, u = jnp.zeros((P,)), jnp.zeros((P,))
    out = model.local_admm(flat, zhat, u, xs, ys, 0.05, 0.5, layers=LAYERS,
                           use_pallas=False)

    def aug(p):
        return float(model.loss(p, xs[0], ys[0], layers=LAYERS,
                                use_pallas=False)
                     + 0.25 * jnp.sum((p - zhat + u) ** 2))
    assert aug(out) < aug(flat)


def test_local_admm_rho_zero_is_fedavg_sgd():
    """With rho=0 the graph degenerates to plain SGD (the FedAvg local
    step), independent of zhat/u."""
    flat = model.init_params(LAYERS, keys(1, seed=12)[0])
    xs, ys = _data(seed=13)
    junk1, junk2 = keys(2, seed=14)
    z1 = jax.random.normal(junk1, (P,))
    z2 = jax.random.normal(junk2, (P,))
    a = model.local_admm(flat, z1, z2, xs, ys, 0.1, 0.0, layers=LAYERS,
                         use_pallas=False)
    # manual SGD
    p = flat
    for s in range(xs.shape[0]):
        p = p - 0.1 * model.grad(p, xs[s], ys[s], layers=LAYERS,
                                 use_pallas=False)
    np.testing.assert_allclose(a, p, atol=1e-6)


def test_local_admm_strong_rho_pins_to_anchor():
    flat = model.init_params(LAYERS, keys(1, seed=15)[0])
    xs, ys = _data(seed=16, steps=20)
    zhat = jnp.zeros((P,))
    u = jnp.zeros((P,))
    # lr*rho = 0.5 < 1 keeps the proximal pull a contraction.
    out = model.local_admm(flat, zhat, u, xs, ys, 0.05, 10.0, layers=LAYERS,
                           use_pallas=False)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(flat))


def test_local_scaffold_variants_match():
    flat = model.init_params(LAYERS, keys(1, seed=17)[0])
    xs, ys = _data(seed=18)
    corr = 0.02 * jax.random.normal(keys(1, seed=19)[0], (P,))
    a = model.local_scaffold(flat, corr, xs, ys, 0.1, layers=LAYERS,
                             use_pallas=True)
    b = model.local_scaffold(flat, corr, xs, ys, 0.1, layers=LAYERS,
                             use_pallas=False)
    np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-4)


def test_local_scaffold_zero_corr_is_sgd():
    flat = model.init_params(LAYERS, keys(1, seed=20)[0])
    xs, ys = _data(seed=21)
    corr = jnp.zeros((P,))
    a = model.local_scaffold(flat, corr, xs, ys, 0.1, layers=LAYERS,
                             use_pallas=False)
    b = model.local_admm(flat, jnp.zeros((P,)), jnp.zeros((P,)), xs, ys,
                         0.1, 0.0, layers=LAYERS, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=1e-6)
