// Fixture: .unwrap() in a library path must produce exactly one
// panic-in-library finding.
pub fn head(xs: &[u64]) -> u64 {
    *xs.first().unwrap()
}
