// Fixture: journaling a send is NOT accounting for it.  A transport
// write that emits an observability event but never charges WireStats
// must still produce exactly one unaccounted-send finding — the event
// journal mirrors the byte books, it does not replace them.
pub struct FakeObs {
    pub lines: Vec<String>,
}

impl FakeObs {
    pub fn emit(&mut self, line: &str) {
        self.lines.push(line.to_string());
    }
}

pub fn push_journaled(
    w: &mut impl std::io::Write,
    obs: &mut FakeObs,
    buf: &[u8],
) -> std::io::Result<()> {
    obs.emit("msg_sent");
    w.write_all(buf)
}
