// Fixture: a raw socket write in a restricted module with no WireStats
// charging must produce exactly one unaccounted-send finding (the
// transport's framed writes charge via LossyLink before the bytes hit
// the socket).
pub fn push(w: &mut impl std::io::Write, buf: &[u8]) -> std::io::Result<()> {
    w.write_all(buf)
}
