// Fixture: a HashMap in a restricted module (analyzed under a virtual
// rust/src/sim/ path) must produce exactly one nondet-iteration finding.
pub fn order(map: &std::collections::HashMap<u32, u32>) -> u32 {
    map.values().sum()
}
