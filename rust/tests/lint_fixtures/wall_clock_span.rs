//! Fixture: a span-shaped timing helper that reads the wall clock
//! directly instead of routing through `obs::clock::Stopwatch` — the
//! mistake the per-file allowance exists to catch.  Exactly one
//! `wall-clock` finding.

/// A would-be span that bypasses the clock module.
pub struct RogueSpan {
    t0: std::time::Instant,
}

impl RogueSpan {
    pub fn open() -> RogueSpan {
        RogueSpan { t0: std::time::Instant::now() }
    }

    pub fn close(self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }
}
