// Fixture: a well-formed suppression with a justification silences the
// finding on the next line — this file must produce zero findings.
pub fn head(xs: &[u64]) -> u64 {
    // lint:allow(panic-in-library): fixture demonstrating a justified suppression
    *xs.first().unwrap()
}
