// Fixture: Instant::now() outside benchlib/metrics must produce exactly
// one wall-clock finding (the bare `Instant` in the return type is not
// flagged; only the `Instant::now` call is).
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
