// Fixture: constructing RNG state from ambient entropy (RandomState)
// outside rng/ must produce exactly one ambient-rng finding.
pub fn entropy_hasher() -> impl std::hash::BuildHasher {
    std::collections::hash_map::RandomState::new()
}
