// Fixture: a suppression without a justification is malformed — it does
// NOT silence anything and additionally reports bad-suppression, so this
// file must produce exactly two findings.
pub fn head(xs: &[u64]) -> u64 {
    // lint:allow(panic-in-library)
    *xs.first().unwrap()
}
