// Fixture: a channel send in a restricted module with no WireStats
// charging must produce exactly one unaccounted-send finding.
pub fn push(tx: &std::sync::mpsc::Sender<u64>, v: u64) {
    let _ = tx.send(v);
}
