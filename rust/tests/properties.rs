//! Property-based tests (mini-proptest harness) for the coordinator
//! invariants: trigger semantics, estimate consistency, Prop. 2.1 bounds,
//! reset synchronization, partitioners, linalg and graph structure.

use deluxe::comm::delta_norm;
use deluxe::prelude::{Estimate, LossyLink, Pcg64, Rng, Trigger, TriggerState};
use deluxe::data::partition::{dirichlet_split, single_class_split};
use deluxe::data::synth::{generate, SynthSpec};
use deluxe::linalg::{soft_threshold, Cholesky, Matrix};
use deluxe::proptest::forall;
use deluxe::topology::Graph;

// ---------------------------------------------------------------------------
// Trigger / protocol invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_vanilla_trigger_fires_iff_deviation_exceeds_delta() {
    forall(
        "vanilla trigger boundary",
        |rng| {
            let dim = 1 + rng.below(8);
            let delta = rng.range(0.01, 2.0);
            let vals: Vec<Vec<f64>> = (0..20)
                .map(|_| (0..dim).map(|_| 3.0 * rng.normal()).collect())
                .collect();
            (delta, vals)
        },
        |(delta, vals)| {
            let dim = vals[0].len();
            let mut st: TriggerState<f64> =
                TriggerState::new(Trigger::vanilla(*delta), vec![0.0; dim]);
            let mut rng = Pcg64::seed(0);
            for v in vals {
                let dev_before = st.deviation(v);
                let fired = st.offer(v, &mut rng).is_some();
                if fired != (dev_before > *delta) {
                    return Err(format!(
                        "fired={fired} but deviation {dev_before} vs delta {delta}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_estimate_equals_last_sent_on_reliable_link() {
    forall(
        "estimate consistency",
        |rng| {
            let dim = 1 + rng.below(6);
            let delta = rng.range(0.0, 1.0);
            let steps = 5 + rng.below(40);
            let walk: Vec<Vec<f64>> = {
                let mut v = vec![0.0; dim];
                (0..steps)
                    .map(|_| {
                        for x in &mut v {
                            *x += 0.3 * rng.normal();
                        }
                        v.clone()
                    })
                    .collect()
            };
            (delta, walk)
        },
        |(delta, walk)| {
            let dim = walk[0].len();
            let mut tx: TriggerState<f64> =
                TriggerState::new(Trigger::vanilla(*delta), vec![0.0; dim]);
            let mut rx = Estimate::new(vec![0.0; dim]);
            let mut rng = Pcg64::seed(1);
            for v in walk {
                if let Some(d) = tx.offer(v, &mut rng) {
                    rx.apply(&d);
                }
                let err = delta_norm(rx.get(), tx.last_sent());
                if err > 1e-9 {
                    return Err(format!("estimate drifted by {err}"));
                }
                // and the receiver error vs the true value is <= delta
                let err_true = delta_norm(rx.get(), v);
                if err_true > *delta + 1e-9 {
                    return Err(format!(
                        "receiver error {err_true} > delta {delta}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop21_error_bounded_by_delta_plus_drop_accumulation() {
    // With drops, the estimate error is bounded by Δ + (accumulated χ
    // since last reset); the reset clamps the accumulation (Prop. 2.1).
    forall(
        "prop 2.1 with drops + reset",
        |rng| {
            let delta = rng.range(0.05, 0.5);
            let drop = rng.range(0.0, 0.6);
            let reset_t = 3 + rng.below(8);
            let seed = rng.next_u64();
            (delta, drop, reset_t, seed)
        },
        |&(delta, drop, reset_t, seed)| {
            let dim = 3;
            let mut rng = Pcg64::seed(seed);
            let mut tx: TriggerState<f64> =
                TriggerState::new(Trigger::vanilla(delta), vec![0.0; dim]);
            let mut rx = Estimate::new(vec![0.0; dim]);
            let mut ch = LossyLink::new(drop);
            let mut v = vec![0.0; dim];
            let mut chi_accum = 0.0f64; // Σ|χ| since last reset
            for k in 0..100 {
                for x in &mut v {
                    *x += 0.2 * rng.normal();
                }
                if let Some(d) = tx.offer(&v, &mut rng) {
                    let mag =
                        d.iter().map(|x| x * x).sum::<f64>().sqrt();
                    match ch.transmit(d, &mut rng) {
                        Some(d) => rx.apply(&d),
                        None => chi_accum += mag,
                    }
                }
                if (k + 1) % reset_t == 0 {
                    tx.reset(&v);
                    rx.reset_to(&v);
                    chi_accum = 0.0;
                }
                let err = delta_norm(rx.get(), &v);
                if err > delta + chi_accum + 1e-9 {
                    return Err(format!(
                        "err {err} > delta {delta} + chi {chi_accum}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_randomized_trigger_fires_superset_of_vanilla() {
    forall(
        "randomized ⊇ vanilla",
        |rng| (rng.range(0.1, 1.0), rng.next_u64()),
        |&(delta, seed)| {
            let mut rng = Pcg64::seed(seed);
            let mut van: TriggerState<f64> =
                TriggerState::new(Trigger::vanilla(delta), vec![0.0]);
            let mut rand: TriggerState<f64> = TriggerState::new(
                Trigger::randomized(delta, 0.3),
                vec![0.0],
            );
            let mut v = vec![0.0];
            for _ in 0..60 {
                v[0] += 0.3 * rng.normal();
                let f_v = van.offer(&v, &mut rng).is_some();
                let f_r = rand.offer(&v, &mut rng).is_some();
                // whenever the two share a reference point and vanilla
                // fires, randomized must fire too (deterministic branch)
                if van.last_sent() == rand.last_sent() && f_v && !f_r {
                    return Err("vanilla fired but randomized didn't".into());
                }
                // keep reference points aligned for the next step
                if f_v != f_r {
                    let sync = v.clone();
                    van.reset(&sync);
                    rand.reset(&sync);
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Data partitioners
// ---------------------------------------------------------------------------

#[test]
fn prop_dirichlet_split_partitions_exactly() {
    forall(
        "dirichlet split partition",
        |rng| (2 + rng.below(10), rng.range(0.05, 2.0), rng.next_u64()),
        |&(agents, beta, seed)| {
            let mut rng = Pcg64::seed(seed);
            let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
            let shards = dirichlet_split(&train, agents, beta, &mut rng);
            let total: usize = shards.iter().map(|s| s.len()).sum();
            if total != train.len() {
                return Err(format!("lost samples: {total} vs {}", train.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_class_split_is_pure() {
    forall(
        "single-class purity",
        |rng| 1 + rng.below(12),
        |&agents| {
            let mut rng = Pcg64::seed(3);
            let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
            let shards = single_class_split(&train, agents);
            for (a, s) in shards.iter().enumerate() {
                if !s.labels.iter().all(|&l| l == a % train.classes) {
                    return Err(format!("shard {a} impure"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Linalg
// ---------------------------------------------------------------------------

#[test]
fn prop_cholesky_solve_inverts_spd_systems() {
    forall(
        "cholesky roundtrip",
        |rng| {
            let n = 2 + rng.below(12);
            let seed = rng.next_u64();
            (n, seed)
        },
        |&(n, seed)| {
            let mut rng = Pcg64::seed(seed);
            let a = Matrix::randn(n + 4, n, &mut rng);
            let mut g = a.gram();
            g.add_diag(0.3);
            let chol = Cholesky::factor(&g).ok_or("not PD")?;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = g.matvec(&x);
            let xs = chol.solve(&b);
            let err = deluxe::linalg::dist2(&x, &xs);
            if err > 1e-7 {
                return Err(format!("solve error {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_soft_threshold_is_nonexpansive() {
    forall(
        "shrinkage nonexpansive",
        |rng| {
            let n = 1 + rng.below(50);
            let tau = rng.range(0.0, 2.0);
            let a: Vec<f64> = (0..n).map(|_| 3.0 * rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| 3.0 * rng.normal()).collect();
            (tau, a, b)
        },
        |(tau, a, b)| {
            let sa = soft_threshold(a, *tau);
            let sb = soft_threshold(b, *tau);
            let d_out = deluxe::linalg::dist2(&sa, &sb);
            let d_in = deluxe::linalg::dist2(a, b);
            if d_out > d_in + 1e-12 {
                return Err(format!("expansive: {d_out} > {d_in}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Graph structure
// ---------------------------------------------------------------------------

#[test]
fn prop_random_graph_connected_with_exact_edges() {
    forall(
        "random graph structure",
        |rng| {
            let n = 3 + rng.below(20);
            let max = n * (n - 1) / 2;
            let m = (n - 1) + rng.below(max - (n - 1) + 1);
            (n, m, rng.next_u64())
        },
        |&(n, m, seed)| {
            let mut rng = Pcg64::seed(seed);
            let g = Graph::random_connected(n, m, &mut rng);
            if g.edges.len() != m {
                return Err(format!("edges {} != {m}", g.edges.len()));
            }
            if !g.is_connected() {
                return Err("disconnected".into());
            }
            // handshake lemma
            let degsum: usize = (0..n).map(|v| g.degree(v)).sum();
            if degsum != 2 * m {
                return Err(format!("degree sum {degsum} != {}", 2 * m));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_incidence_matches_edges() {
    forall(
        "incidence structure",
        |rng| (4 + rng.below(10), rng.next_u64()),
        |&(n, seed)| {
            let mut rng = Pcg64::seed(seed);
            let m = n + rng.below(n);
            let g = Graph::random_connected(n, m.min(n * (n - 1) / 2), &mut rng);
            let (at, ar) = g.incidence();
            for (e, &(i, j)) in g.edges.iter().enumerate() {
                let ti = at.row(e).iter().position(|&v| v == 1.0).ok_or("no tx")?;
                let ri = ar.row(e).iter().position(|&v| v == 1.0).ok_or("no rx")?;
                if (ti.min(ri), ti.max(ri)) != (i, j) {
                    return Err(format!("edge {e} mismatch"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// ADMM fixed point = KKT point
// ---------------------------------------------------------------------------

#[test]
fn prop_consensus_admm_fixed_point_is_global_optimum() {
    use deluxe::admm::{ConsensusAdmm, ConsensusConfig};
    use deluxe::solver::{IdentityProx, LocalSolver};

    struct Quad {
        w: Vec<f64>,
        c: Vec<f64>,
    }
    impl LocalSolver<f64> for Quad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _r: &mut Pcg64,
        ) -> Vec<f64> {
            vec![
                (self.w[agent] * self.c[agent] + rho * anchor[0])
                    / (self.w[agent] + rho),
            ]
        }
        fn dim(&self) -> usize {
            1
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    forall(
        "ADMM fixed point = weighted mean",
        |rng| {
            let n = 2 + rng.below(6);
            let w: Vec<f64> = (0..n).map(|_| rng.range(0.2, 3.0)).collect();
            let c: Vec<f64> = (0..n).map(|_| 5.0 * rng.normal()).collect();
            let rho = rng.range(0.3, 3.0);
            (w, c, rho)
        },
        |(w, c, rho)| {
            let opt = w.iter().zip(c).map(|(a, b)| a * b).sum::<f64>()
                / w.iter().sum::<f64>();
            let n = w.len();
            let mut solver = Quad { w: w.clone(), c: c.clone() };
            let cfg = ConsensusConfig { rho: *rho, rounds: 2000, ..Default::default() };
            let mut eng = ConsensusAdmm::new(cfg, n, vec![0.0]);
            let mut prox = IdentityProx;
            let mut rng = Pcg64::seed(9);
            for _ in 0..2000 {
                eng.round(&mut solver, &mut prox, &mut rng);
            }
            let err = (eng.z[0] - opt).abs();
            if err > 1e-6 {
                return Err(format!("z {} vs opt {opt} (err {err})", eng.z[0]));
            }
            Ok(())
        },
    );
}
