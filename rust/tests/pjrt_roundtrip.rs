//! Integration: PJRT artifacts vs pinned Python outputs vs the native twin.
//!
//! `testvec.json` (emitted by `aot.py`) pins inputs and the JAX-computed
//! outputs of every graph for the `tiny` config; these tests run the same
//! inputs through (a) the compiled artifacts via PJRT and (b) the native
//! Rust MLP, and require all three to agree.  This is the strongest
//! correctness signal across the L1/L2/L3 boundary.
//!
//! Skips (with a note) when artifacts have not been built.

use deluxe::config::default_artifacts_dir;
use deluxe::jsonio::read_json;
use deluxe::model::MlpSpec;
use deluxe::runtime::{PjrtRuntime, Variant};

struct TestVec {
    params: Vec<f32>,
    zhat: Vec<f32>,
    u: Vec<f32>,
    corr: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<f32>,
    lr: f32,
    rho: f32,
    local_admm: Vec<f32>,
    local_scaffold: Vec<f32>,
    predict: Vec<f32>,
    loss: f32,
    grad: Vec<f32>,
}

fn load() -> Option<(PjrtRuntime, TestVec)> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() || !dir.join("testvec.json").exists() {
        eprintln!("artifacts not built; skipping PJRT round-trip tests");
        return None;
    }
    let rt = PjrtRuntime::load(&dir).expect("load runtime");
    let j = read_json(&dir.join("testvec.json")).expect("testvec");
    let get = |k: &str| -> Vec<f32> {
        j.get(k).and_then(|v| v.as_f32_vec()).unwrap_or_else(|| panic!("missing {k}"))
    };
    let tv = TestVec {
        params: get("params"),
        zhat: get("zhat"),
        u: get("u"),
        corr: get("corr"),
        xs: get("xs"),
        ys: get("ys"),
        lr: j.get("lr").unwrap().as_f64().unwrap() as f32,
        rho: j.get("rho").unwrap().as_f64().unwrap() as f32,
        local_admm: get("local_admm"),
        local_scaffold: get("local_scaffold"),
        predict: get("predict"),
        loss: j.get("loss").unwrap().as_f64().unwrap() as f32,
        grad: get("grad"),
    };
    Some((rt, tv))
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst <= tol, "{what}: max abs diff {worst} > {tol}");
}

#[test]
fn local_admm_pallas_matches_python() {
    let Some((rt, tv)) = load() else { return };
    let out = rt
        .local_admm(
            "tiny", Variant::Pallas, &tv.params, &tv.zhat, &tv.u, &tv.xs,
            &tv.ys, tv.lr, tv.rho,
        )
        .unwrap();
    assert_close(&out, &tv.local_admm, 2e-5, "local_admm pallas");
}

#[test]
fn local_admm_ref_matches_python() {
    let Some((rt, tv)) = load() else { return };
    let out = rt
        .local_admm(
            "tiny", Variant::Ref, &tv.params, &tv.zhat, &tv.u, &tv.xs, &tv.ys,
            tv.lr, tv.rho,
        )
        .unwrap();
    assert_close(&out, &tv.local_admm, 1e-6, "local_admm ref");
}

#[test]
fn local_scaffold_matches_python() {
    let Some((rt, tv)) = load() else { return };
    for variant in [Variant::Pallas, Variant::Ref] {
        let out = rt
            .local_scaffold(
                "tiny", variant, &tv.params, &tv.corr, &tv.xs, &tv.ys, tv.lr,
            )
            .unwrap();
        assert_close(&out, &tv.local_scaffold, 2e-5, "local_scaffold");
    }
}

#[test]
fn predict_loss_grad_match_python() {
    let Some((rt, tv)) = load() else { return };
    let cfg = rt.config("tiny").unwrap().clone();
    let x1 = &tv.xs[..cfg.batch * cfg.input_dim];
    let y1 = &tv.ys[..cfg.batch * cfg.classes];
    for variant in [Variant::Pallas, Variant::Ref] {
        let logits = rt.predict("tiny", variant, &tv.params, x1).unwrap();
        assert_close(&logits, &tv.predict, 2e-5, "predict");
        let loss = rt.loss("tiny", variant, &tv.params, x1, y1).unwrap();
        assert!((loss - tv.loss).abs() < 2e-5, "loss {loss} vs {}", tv.loss);
        let grad = rt.grad("tiny", variant, &tv.params, x1, y1).unwrap();
        assert_close(&grad, &tv.grad, 2e-5, "grad");
    }
}

#[test]
fn native_twin_matches_python() {
    // No PJRT needed, but uses the same pinned vectors.
    let Some((rt, tv)) = load() else { return };
    let cfg = rt.config("tiny").unwrap().clone();
    let spec = MlpSpec::new(cfg.layers.clone());
    let out = spec.local_admm(
        &tv.params, &tv.zhat, &tv.u, &tv.xs, &tv.ys, tv.lr, tv.rho,
        cfg.steps, cfg.batch,
    );
    assert_close(&out, &tv.local_admm, 5e-5, "native local_admm");
    let out2 = spec.local_scaffold(
        &tv.params, &tv.corr, &tv.xs, &tv.ys, tv.lr, cfg.steps, cfg.batch,
    );
    assert_close(&out2, &tv.local_scaffold, 5e-5, "native local_scaffold");
    let x1 = &tv.xs[..cfg.batch * cfg.input_dim];
    let y1 = &tv.ys[..cfg.batch * cfg.classes];
    let logits = spec.forward(&tv.params, x1, cfg.batch);
    assert_close(&logits, &tv.predict, 5e-5, "native predict");
    let (loss, grad) = spec.loss_grad(&tv.params, x1, y1, cfg.batch);
    assert!((loss - tv.loss).abs() < 5e-5);
    assert_close(&grad, &tv.grad, 5e-5, "native grad");
}

#[test]
fn manifest_param_lens_match_native_spec() {
    let Some((rt, _)) = load() else { return };
    for (name, cfg) in &rt.manifest.configs {
        let spec = MlpSpec::new(cfg.layers.clone());
        assert_eq!(
            spec.param_len(),
            cfg.param_len,
            "config {name}: ABI mismatch"
        );
    }
}

#[test]
fn accuracy_helper_consistent_with_native() {
    let Some((rt, tv)) = load() else { return };
    let cfg = rt.config("tiny").unwrap().clone();
    let spec = MlpSpec::new(cfg.layers.clone());
    // build a tiny labelled set from the pinned xs
    let n = cfg.batch * cfg.steps;
    let xs = &tv.xs[..n * cfg.input_dim];
    let labels: Vec<usize> = (0..n).map(|i| i % cfg.classes).collect();
    let a_native = spec.accuracy(&tv.params, xs, &labels);
    let a_pjrt = rt
        .accuracy("tiny", Variant::Ref, &tv.params, xs, &labels)
        .unwrap();
    assert!(
        (a_native - a_pjrt).abs() < 1e-9,
        "accuracy mismatch: native {a_native} vs pjrt {a_pjrt}"
    );
}
