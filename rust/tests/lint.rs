//! Integration: the `deluxe lint` pass against its fixture corpus, and
//! the repo-is-clean gate.
//!
//! Each fixture under `rust/tests/lint_fixtures/` isolates one rule; the
//! tests analyze it under a *virtual* restricted-module path (the corpus
//! directory itself is skipped by the tree walk) and pin the exact
//! finding set.  `lint_self_clean` then asserts the crate's own tree
//! produces zero findings — the adoption contract of DESIGN.md §11.

use std::path::Path;
use std::process::Command;

use deluxe::analysis::{analyze_source, classify, run_on_tree, FileKind};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", p.display()))
}

fn rules_of(path: &str, src: &str) -> Vec<String> {
    analyze_source(path, src)
        .into_iter()
        .map(|f| f.rule)
        .collect()
}

// ---------------------------------------------------------------------------
// one fixture per rule
// ---------------------------------------------------------------------------

#[test]
fn fixture_nondet_iteration_fires_in_restricted_module() {
    let src = fixture("nondet_iteration.rs");
    assert_eq!(
        rules_of("rust/src/sim/fixture.rs", &src),
        vec!["nondet-iteration"]
    );
    // ...but not in an unrestricted library module
    assert!(rules_of("rust/src/model/fixture.rs", &src).is_empty());
    // ...and not in tests
    assert!(rules_of("rust/tests/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_wall_clock_fires_outside_benchlib() {
    let src = fixture("wall_clock.rs");
    assert_eq!(
        rules_of("rust/src/sim/fixture.rs", &src),
        vec!["wall-clock"]
    );
    // benchlib and metrics measure real time by design
    assert!(rules_of("rust/src/benchlib/fixture.rs", &src).is_empty());
    assert!(rules_of("rust/src/metrics/fixture.rs", &src).is_empty());
    assert!(rules_of("rust/benches/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_ambient_rng_fires_outside_rng_module() {
    let src = fixture("ambient_rng.rs");
    assert_eq!(
        rules_of("rust/src/sim/fixture.rs", &src),
        vec!["ambient-rng"]
    );
    // the seeded-RNG module itself is the one place entropy words appear
    assert!(rules_of("rust/src/rng/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_panic_in_library_fires_everywhere_but_cli_and_tests() {
    let src = fixture("panic_in_library.rs");
    assert_eq!(
        rules_of("rust/src/model/fixture.rs", &src),
        vec!["panic-in-library"]
    );
    assert!(rules_of("rust/src/main.rs", &src).is_empty());
    assert!(rules_of("rust/tests/fixture.rs", &src).is_empty());
    assert!(rules_of("examples/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_unaccounted_send_fires_in_restricted_module() {
    let src = fixture("unaccounted_send.rs");
    assert_eq!(
        rules_of("rust/src/coordinator/fixture.rs", &src),
        vec!["unaccounted-send"]
    );
    // transport joined the restricted set with the socket runtime
    assert_eq!(
        rules_of("rust/src/transport/fixture.rs", &src),
        vec!["unaccounted-send"]
    );
    assert!(rules_of("rust/src/solver/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_unaccounted_write_all_fires_in_transport_module() {
    let src = fixture("unaccounted_send_write.rs");
    assert_eq!(
        rules_of("rust/src/transport/fixture.rs", &src),
        vec!["unaccounted-send"]
    );
    // unrestricted library modules may write raw bytes freely
    assert!(rules_of("rust/src/model/fixture.rs", &src).is_empty());
    // ...and so may tests
    assert!(rules_of("rust/tests/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_journaled_write_all_still_trips_unaccounted_send() {
    // an obs journal line next to the write does not satisfy the byte
    // books — only WireStats charging does
    let src = fixture("unaccounted_send_journaled.rs");
    assert_eq!(
        rules_of("rust/src/transport/fixture.rs", &src),
        vec!["unaccounted-send"]
    );
    assert!(rules_of("rust/src/model/fixture.rs", &src).is_empty());
}

#[test]
fn obs_is_a_restricted_module() {
    // journal emission order feeds the determinism tests, so obs joins
    // the restricted set: nondet iteration and raw sends fire there
    let src = fixture("nondet_iteration.rs");
    assert_eq!(
        rules_of("rust/src/obs/fixture.rs", &src),
        vec!["nondet-iteration"]
    );
    let src = fixture("unaccounted_send_write.rs");
    assert_eq!(
        rules_of("rust/src/obs/fixture.rs", &src),
        vec!["unaccounted-send"]
    );
}

#[test]
fn wall_clock_allowed_only_in_the_obs_timing_sampler() {
    let src = fixture("wall_clock.rs");
    // the scoped allowance covers exactly rust/src/obs/clock.rs ...
    assert!(rules_of("rust/src/obs/clock.rs", &src).is_empty());
    // ... not the rest of the obs module, and not like-named files
    // elsewhere in restricted modules
    assert_eq!(rules_of("rust/src/obs/mod.rs", &src), vec!["wall-clock"]);
    assert_eq!(
        rules_of("rust/src/transport/clock.rs", &src),
        vec!["wall-clock"]
    );
}

#[test]
fn span_code_must_route_timing_through_the_clock_module() {
    // the span layer carries the dual-time discipline (DESIGN.md §14):
    // wall-clock enters spans only via obs/clock.rs::Stopwatch, so a
    // raw Instant::now in span-shaped code is a finding...
    let src = fixture("wall_clock_span.rs");
    assert_eq!(
        rules_of("rust/src/obs/span.rs", &src),
        vec!["wall-clock"]
    );
    // ...while the one allowed sampler file stays clean
    assert!(rules_of("rust/src/obs/clock.rs", &src).is_empty());
}

// ---------------------------------------------------------------------------
// suppression semantics
// ---------------------------------------------------------------------------

#[test]
fn fixture_justified_suppression_silences_finding() {
    let src = fixture("suppressed_ok.rs");
    assert!(rules_of("rust/src/model/fixture.rs", &src).is_empty());
}

#[test]
fn fixture_unjustified_suppression_is_itself_a_finding() {
    let src = fixture("bad_suppression.rs");
    let mut got = rules_of("rust/src/model/fixture.rs", &src);
    got.sort();
    assert_eq!(got, vec!["bad-suppression", "panic-in-library"]);
}

#[test]
fn trailing_suppression_covers_its_own_line() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    \
               x.unwrap() // lint:allow(panic-in-library): trailing form covers this line\n}\n";
    assert!(rules_of("rust/src/model/fixture.rs", src).is_empty());
}

#[test]
fn suppression_of_unknown_rule_is_rejected() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    \
               // lint:allow(no-such-rule): bogus\n    x.unwrap()\n}\n";
    let mut got = rules_of("rust/src/model/fixture.rs", src);
    got.sort();
    assert_eq!(got, vec!["bad-suppression", "panic-in-library"]);
}

#[test]
fn suppression_on_wrong_rule_does_not_silence() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    \
               // lint:allow(wall-clock): names the wrong rule\n    x.unwrap()\n}\n";
    assert_eq!(
        rules_of("rust/src/model/fixture.rs", src),
        vec!["panic-in-library"]
    );
}

#[test]
fn cfg_test_items_are_exempt_inside_library_files() {
    let src = "pub fn lib_fn() -> u8 { 1 }\n\
               #[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
               let x: Option<u8> = Some(1);\n        assert_eq!(x.unwrap(), 1);\n    }\n}\n";
    assert!(rules_of("rust/src/model/fixture.rs", src).is_empty());
}

#[test]
fn classification_matches_design_doc() {
    assert_eq!(
        classify("rust/src/wire/codec.rs"),
        Some((FileKind::Library, "wire".to_string()))
    );
    assert_eq!(classify("rust/src/main.rs"), Some((FileKind::Cli, String::new())));
    assert_eq!(classify("rust/vendor/anyhow/src/lib.rs"), None);
    assert_eq!(classify("rust/tests/lint_fixtures/panic_in_library.rs"), None);
}

// ---------------------------------------------------------------------------
// the adoption gate: the crate's own tree must be clean
// ---------------------------------------------------------------------------

#[test]
fn lint_self_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = run_on_tree(root).expect("tree walk");
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(
        findings.is_empty(),
        "the repo tree has {} lint finding(s); fix or justify them \
         (see DESIGN.md §11)",
        findings.len()
    );
}

// ---------------------------------------------------------------------------
// CLI exit codes (`deluxe lint` is the CI gate)
// ---------------------------------------------------------------------------

#[test]
fn cli_exits_zero_on_clean_tree_and_nonzero_on_violation() {
    let exe = env!("CARGO_BIN_EXE_deluxe");

    // clean: the repo itself
    let out = Command::new(exe)
        .args(["lint", "--root", env!("CARGO_MANIFEST_DIR")])
        .output()
        .expect("run deluxe lint");
    assert!(
        out.status.success(),
        "expected exit 0 on the repo tree:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );

    // violation: a synthetic tree with one restricted-module HashMap
    let tmp = std::env::temp_dir()
        .join(format!("dela_lint_cli_{}", std::process::id()));
    let src_dir = tmp.join("rust/src/sim");
    std::fs::create_dir_all(&src_dir).expect("mk temp tree");
    std::fs::write(
        src_dir.join("bad.rs"),
        "pub fn f(m: &std::collections::HashMap<u8, u8>) -> usize {\n    m.len()\n}\n",
    )
    .expect("write violation");
    let out = Command::new(exe)
        .args(["lint", "--json", "--root"])
        .arg(&tmp)
        .output()
        .expect("run deluxe lint on temp tree");
    assert!(!out.status.success(), "expected nonzero exit on a violation");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let j = deluxe::jsonio::Json::parse(&stdout).expect("valid --json output");
    assert_eq!(j.get("count").and_then(deluxe::jsonio::Json::as_f64), Some(1.0));
    let arr = j
        .get("findings")
        .and_then(deluxe::jsonio::Json::as_arr)
        .expect("findings array");
    assert_eq!(
        arr[0].get("rule").and_then(deluxe::jsonio::Json::as_str),
        Some("nondet-iteration")
    );
    std::fs::remove_dir_all(&tmp).ok();
}
