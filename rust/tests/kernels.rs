//! The fused-kernel contract (DESIGN.md §15):
//!
//! * **bit-exactness** — every blocked kernel equals its unblocked
//!   scalar reference twin bit-for-bit over randomized shapes, because
//!   blocking never reassociates a per-element fold (property tests);
//! * **arena equivalence** — the `*_into` scratch-arena entry points
//!   return exactly what the allocating wrappers return, with one
//!   `Scratch` reused across heterogeneous shapes;
//! * **Cholesky-cache semantics** — `ExactQuadratic`'s shared cache is
//!   keyed by `(gram digest, ρ bits)`: identical blocks share one
//!   factorization, hit/miss books are exact, and caching never changes
//!   solve values;
//! * **fused-batch determinism** — `NativeSgd::solve_batch[_into]`
//!   (chunk-stacked minibatch arenas) is bit-identical to per-agent
//!   sequential `solve` calls and across worker counts 1/4.

use deluxe::admm::core::solve_rngs;
use deluxe::admm::WorkerPool;
use deluxe::data::partition::iid_split;
use deluxe::data::regress::{generate, RegressSpec};
use deluxe::data::synth::{self, SynthSpec};
use deluxe::kernels::{self, reference, Scratch};
use deluxe::model::MlpSpec;
use deluxe::proptest::forall;
use deluxe::rng::{Pcg64, Rng};
use deluxe::solver::{ExactQuadratic, LocalSolver, NativeSgd};

fn randv32(n: usize, rng: &mut Pcg64) -> Vec<f32> {
    (0..n).map(|_| rng.f32n()).collect()
}

fn randv64(n: usize, rng: &mut Pcg64) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// kernel == reference, bit-exactly, over randomized shapes
// ---------------------------------------------------------------------------

#[test]
fn prop_layer_forward_matches_reference_bitwise() {
    forall(
        "blocked layer_forward == scalar reference (bitwise)",
        |rng| {
            let n = 1 + rng.below(33);
            let din = 1 + rng.below(37);
            let dout = 1 + rng.below(29);
            let fuse = rng.bernoulli(0.5);
            (
                randv32(n * din, rng),
                randv32(din * dout, rng),
                randv32(dout, rng),
                n,
                din,
                dout,
                fuse,
            )
        },
        |(inp, w, bias, n, din, dout, fuse)| {
            let mut got = vec![0.0f32; n * dout];
            let mut want = vec![0.0f32; n * dout];
            kernels::layer_forward(inp, w, bias, &mut got, *n, *din, *dout, *fuse);
            reference::layer_forward(inp, w, bias, &mut want, *n, *din, *dout, *fuse);
            if bits32(&got) == bits32(&want) {
                Ok(())
            } else {
                Err(format!("n={n} din={din} dout={dout} fuse={fuse}"))
            }
        },
    );
}

#[test]
fn prop_backprop_kernels_match_reference_bitwise() {
    forall(
        "accum_outer + backprop_dot == scalar references (bitwise)",
        |rng| {
            let n = 1 + rng.below(25);
            let din = 1 + rng.below(21);
            let dout = 1 + rng.below(19);
            (
                randv32(n * din, rng),
                randv32(n * dout, rng),
                randv32(din * dout, rng),
                n,
                din,
                dout,
            )
        },
        |(inp, delta, w, n, din, dout)| {
            let mut gw_got = vec![0.25f32; din * dout];
            let mut gw_want = gw_got.clone();
            kernels::accum_outer(inp, delta, &mut gw_got, *n, *din, *dout);
            reference::accum_outer(inp, delta, &mut gw_want, *n, *din, *dout);
            if bits32(&gw_got) != bits32(&gw_want) {
                return Err(format!("accum_outer n={n} din={din} dout={dout}"));
            }
            let mut di_got = vec![0.0f32; n * din];
            let mut di_want = vec![0.0f32; n * din];
            kernels::backprop_dot(w, delta, &mut di_got, *n, *din, *dout);
            reference::backprop_dot(w, delta, &mut di_want, *n, *din, *dout);
            if bits32(&di_got) != bits32(&di_want) {
                return Err(format!("backprop_dot n={n} din={din} dout={dout}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_f64_gemm_and_matvec_match_reference_bitwise() {
    forall(
        "gemm_acc_f64 + mat_vec_f64 == scalar references (bitwise)",
        |rng| {
            let m = 1 + rng.below(13);
            let k = 1 + rng.below(17);
            let n = 1 + rng.below(11);
            // sprinkle exact zeros: the historical zero-skip's territory
            let mut a = randv64(m * k, rng);
            for v in a.iter_mut() {
                if rng.bernoulli(0.3) {
                    *v = 0.0;
                }
            }
            (a, randv64(k * n, rng), m, k, n)
        },
        |(a, b, m, k, n)| {
            let mut c_got = vec![0.5f64; m * n];
            let mut c_want = c_got.clone();
            kernels::gemm_acc_f64(a, b, &mut c_got, *m, *k, *n);
            reference::gemm_acc_f64(a, b, &mut c_want, *m, *k, *n);
            if bits64(&c_got) != bits64(&c_want) {
                return Err(format!("gemm m={m} k={k} n={n}"));
            }
            let mut y_got = vec![0.0f64; *m];
            let mut y_want = vec![0.0f64; *m];
            let x = &b[..*k];
            kernels::mat_vec_f64(a, x, &mut y_got, *m, *k);
            reference::mat_vec_f64(a, x, &mut y_want, *m, *k);
            if bits64(&y_got) != bits64(&y_want) {
                return Err(format!("matvec rows={m} cols={k}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// arena entry points == allocating wrappers, scratch reused across shapes
// ---------------------------------------------------------------------------

#[test]
fn scratch_entry_points_match_allocating_wrappers_across_shapes() {
    let mut rng = Pcg64::seed(11);
    let mut scratch = Scratch::new();
    // one retained scratch driven across two different architectures and
    // batch sizes — resizing must never change values
    for arch in [vec![8, 16, 4], vec![6, 10, 10, 3]] {
        let spec = MlpSpec::new(arch);
        let params = spec.init(&mut rng);
        for n in [1usize, 5, 12] {
            let xs = randv32(n * spec.input_dim(), &mut rng);
            let ys: Vec<f32> = {
                let mut y = vec![0.0f32; n * spec.classes()];
                for r in 0..n {
                    y[r * spec.classes() + r % spec.classes()] = 1.0;
                }
                y
            };
            let (loss_a, grad_a) = spec.loss_grad(&params, &xs, &ys, n);
            let loss_b =
                spec.loss_grad_into(&params, &xs, &ys, n, &mut scratch);
            assert_eq!(loss_a.to_bits(), loss_b.to_bits());
            assert_eq!(bits32(&grad_a), bits32(&scratch.grad));
        }
    }
}

#[test]
fn local_admm_anchor_equals_zero_dual_path_bitwise() {
    let mut rng = Pcg64::seed(12);
    let spec = MlpSpec::new(vec![8, 12, 4]);
    let params = spec.init(&mut rng);
    let anchor = randv32(params.len(), &mut rng);
    let zeros = vec![0.0f32; params.len()];
    let (steps, batch) = (3usize, 5usize);
    let xs = randv32(steps * batch * spec.input_dim(), &mut rng);
    let mut ys = vec![0.0f32; steps * batch * spec.classes()];
    for r in 0..steps * batch {
        ys[r * spec.classes() + r % spec.classes()] = 1.0;
    }
    let via_u = spec.local_admm(
        &params, &anchor, &zeros, &xs, &ys, 0.07, 0.9, steps, batch,
    );
    let via_anchor = spec.local_admm_anchor(
        &params, &anchor, &xs, &ys, 0.07, 0.9, steps, batch,
    );
    assert_eq!(bits32(&via_u), bits32(&via_anchor));
}

// ---------------------------------------------------------------------------
// shared Cholesky cache: keying, hit/miss books, value-neutrality
// ---------------------------------------------------------------------------

#[test]
fn chol_cache_shares_factorizations_and_counts_exactly() {
    let mut rng = Pcg64::seed(21);
    let (blocks3, _) = generate(
        &RegressSpec {
            n_agents: 3,
            rows_per_agent: 9,
            dim: 5,
            ..Default::default()
        },
        &mut rng,
    );
    // agents 0 and 1 share a bit-identical block -> one shared factor
    let blocks = vec![
        blocks3[0].clone(),
        blocks3[0].clone(),
        blocks3[1].clone(),
        blocks3[2].clone(),
    ];
    let mut solver = ExactQuadratic::new(&blocks);
    let anchors: Vec<Vec<f64>> =
        (0..4).map(|_| randv64(5, &mut rng)).collect();
    let agents = [0usize, 1, 2, 3];
    let pool = WorkerPool::new(2);
    let mut rngs = solve_rngs(&Pcg64::seed(1), 0, 4);

    let xs1 = solver.solve_batch(&agents, &anchors, 0.7, &mut rngs, &pool);
    // 3 distinct gram digests -> 3 misses; the duplicate is a hit
    assert_eq!(solver.cache_stats(), (1, 3, 3));

    // same rho again: all four hit, no new entries
    let xs2 = solver.solve_batch(&agents, &anchors, 0.7, &mut rngs, &pool);
    assert_eq!(solver.cache_stats(), (5, 3, 3));
    for (a, b) in xs1.iter().zip(&xs2) {
        assert_eq!(bits64(a), bits64(b), "cache hits must not change values");
    }

    // new rho: three fresh factorizations alongside the old ones
    let _ = solver.solve_batch(&agents, &anchors, 1.3, &mut rngs, &pool);
    assert_eq!(solver.cache_stats(), (6, 6, 6));

    // sequential solve() books into the same cache
    let _ = solver.solve(3, &anchors[3], 0.7, &mut rngs[3]);
    assert_eq!(solver.cache_stats(), (7, 6, 6));

    // caching is value-neutral: a fresh solver solving sequentially,
    // agent by agent, produces the same bits the pooled batch produced
    let mut fresh = ExactQuadratic::new(&blocks);
    for (j, &agent) in agents.iter().enumerate() {
        let x = fresh.solve(agent, &anchors[j], 0.7, &mut rngs[j]);
        assert_eq!(bits64(&x), bits64(&xs1[j]), "agent {agent}");
    }
    // identical duplicated blocks with identical anchors would also be a
    // trivial equality; make sure anchors actually differed
    assert_ne!(bits64(&xs1[0]), bits64(&xs1[1]));
}

// ---------------------------------------------------------------------------
// fused NativeSgd batch: == sequential solve, == across worker counts,
// and the _into path reuses buffers without changing values
// ---------------------------------------------------------------------------

fn tiny_sgd(seed: u64, n: usize) -> (NativeSgd, Vec<f32>) {
    let mut rng = Pcg64::seed(seed);
    let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
    let shards = iid_split(&train, n, &mut rng);
    let spec = MlpSpec::new(vec![8, 16, 4]);
    let init = spec.init(&mut rng);
    (NativeSgd::new(spec, shards, 0.1, 2, 4, &init), init)
}

#[test]
fn native_sgd_fused_batch_is_bit_identical_to_sequential_solves() {
    let n = 4;
    let rounds = 3;
    let run = |workers: usize, use_into: bool| {
        let (mut solver, init) = tiny_sgd(31, n);
        let pool = if workers <= 1 {
            WorkerPool::sequential()
        } else {
            WorkerPool::new(workers)
        };
        let agents: Vec<usize> = (0..n).collect();
        let mut anchors = vec![init; n];
        let base = Pcg64::seed(32);
        let mut outs: Vec<Vec<f32>> = Vec::new();
        let mut trace: Vec<u32> = Vec::new();
        for round in 0..rounds {
            let mut rngs = solve_rngs(&base, round, n);
            if use_into {
                solver.solve_batch_into(
                    &agents, &anchors, 0.8, &mut rngs, &pool, &mut outs,
                );
            } else {
                outs = solver.solve_batch(
                    &agents, &anchors, 0.8, &mut rngs, &pool,
                );
            }
            for (anchor, x) in anchors.iter_mut().zip(&outs) {
                trace.extend(bits32(x));
                anchor.clone_from(x);
            }
        }
        for a in 0..n {
            trace.extend(bits32(&solver.xs[a]));
        }
        trace
    };
    // per-agent sequential solve() through the same forked streams — the
    // trait-default shape the fused path must reproduce observably
    let reference = {
        let (mut solver, init) = tiny_sgd(31, n);
        let base = Pcg64::seed(32);
        let mut anchors = vec![init; n];
        let mut trace: Vec<u32> = Vec::new();
        for round in 0..rounds {
            let mut rngs = solve_rngs(&base, round, n);
            for a in 0..n {
                let x = solver.solve(a, &anchors[a], 0.8, &mut rngs[a]);
                trace.extend(bits32(&x));
                anchors[a].clone_from(&x);
            }
        }
        for a in 0..n {
            trace.extend(bits32(&solver.xs[a]));
        }
        trace
    };
    assert_eq!(run(1, false), reference, "fused w=1 != sequential solves");
    assert_eq!(run(1, true), reference, "fused _into w=1 != sequential");
    assert_eq!(run(4, false), reference, "fused w=4 != sequential solves");
    assert_eq!(run(4, true), reference, "fused _into w=4 != sequential");
    // worker count beyond the batch, and a non-dividing chunk width
    assert_eq!(run(3, true), reference, "fused w=3 != sequential");
    assert_eq!(run(16, true), reference, "fused w=16 != sequential");
}
