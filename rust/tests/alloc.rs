//! Zero-alloc pin for the fused solve phase (DESIGN.md §15).
//!
//! Installs [`CountingAlloc`] as this binary's global allocator and
//! asserts that after warmup rounds, a full `NativeSgd::solve_batch_into`
//! round — minibatch sampling, forward, backprop, prox steps, warm-iterate
//! update — performs **zero heap allocations** on the driving thread.
//!
//! The assertion runs with `WorkerPool::sequential()` so the entire hot
//! path executes inline on the counted thread (the counter is
//! thread-local by design; pooled workers allocate their own arenas
//! during warmup and that is fine).  The per-round RNG forks are
//! pre-built outside the measured region: `solve_rngs` allocates its
//! `Vec<Pcg64>` by contract, and the engines hold it round-local.

use deluxe::admm::core::solve_rngs;
use deluxe::admm::WorkerPool;
use deluxe::benchlib::alloc::{self, CountingAlloc};
use deluxe::data::partition::iid_split;
use deluxe::data::synth::{self, SynthSpec};
use deluxe::model::MlpSpec;
use deluxe::rng::Pcg64;
use deluxe::solver::{LocalSolver, NativeSgd};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn fused_solve_round_is_allocation_free_after_warmup() {
    let n = 3;
    let mut rng = Pcg64::seed(77);
    let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
    let shards = iid_split(&train, n, &mut rng);
    let spec = MlpSpec::new(vec![8, 16, 4]);
    let init = spec.init(&mut rng);
    let mut solver = NativeSgd::new(spec, shards, 0.1, 2, 4, &init);

    let pool = WorkerPool::sequential();
    let agents: Vec<usize> = (0..n).collect();
    let anchors = vec![init; n];
    let base = Pcg64::seed(78);
    let mut outs: Vec<Vec<f32>> = Vec::new();

    // Warmup: arenas size themselves to the (spec, batch) shape and the
    // outs buffers reach their final lengths.
    for round in 0..3u64 {
        let mut rngs = solve_rngs(&base, round, n);
        solver.solve_batch_into(&agents, &anchors, 0.8, &mut rngs, &pool, &mut outs);
    }

    // Measured round: same shapes, retained buffers — must not allocate.
    let mut rngs = solve_rngs(&base, 3, n);
    let ((), count, bytes) = alloc::measure(|| {
        solver.solve_batch_into(&agents, &anchors, 0.8, &mut rngs, &pool, &mut outs);
    });
    assert_eq!(
        (count, bytes),
        (0, 0),
        "fused solve round allocated {count} times ({bytes} bytes) after warmup"
    );

    // The measured round still did real work: outputs changed state.
    assert!(outs.iter().all(|x| !x.is_empty()));
}

#[test]
fn counting_allocator_actually_intercepts() {
    // sanity: with CountingAlloc installed, an obvious allocation shows
    // up — guards against the zero-alloc test passing vacuously.
    let ((), count, bytes) = alloc::measure(|| {
        let v: Vec<u8> = Vec::with_capacity(4096);
        std::hint::black_box(&v);
    });
    assert!(count >= 1, "expected at least one allocation, saw none");
    assert!(bytes >= 4096, "expected >= 4096 bytes, saw {bytes}");
}
