//! End-to-end socket-transport tests: a real TCP (or UDS) leader with
//! agent sessions driven over loopback, one thread standing in for each
//! agent process (the threads run the exact `deluxe agent` code path —
//! [`run_tcp_agent`] — so the two-terminal deployment is what's tested).
//!
//! The keystone property: under no loss, a TCP cohort replays the
//! in-proc trajectory bit-for-bit — reliable links draw nothing from
//! the leader RNG, replies apply in agent order, and every byte is
//! charged through the same `LossyLink` books.

use std::thread;

use deluxe::data::partition::single_class_split;
use deluxe::data::synth::{generate, ClassDataset, SynthSpec};
use deluxe::model::MlpSpec;
use deluxe::prelude::{
    make_endpoints, run_tcp_agent, AgentOpts, Coordinator, Pcg64, RunConfig,
    SessionEnd, SocketOpts, Tcp, Trigger,
};

/// The shared 4-agent workload: tiny synthetic classes, single-class
/// shards, an 8-16-4 MLP.
fn workload(seed: u64) -> (ClassDataset, ClassDataset, MlpSpec, Vec<f32>) {
    let mut rng = Pcg64::seed(seed);
    let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
    let spec = MlpSpec::new(vec![8, 16, 4]);
    let init = spec.init(&mut rng);
    (train, test, spec, init)
}

/// Spawn one session thread per endpoint against `addr`, each running
/// the real client driver.
fn spawn_agents(
    addr: &str,
    endpoints: Vec<deluxe::prelude::AgentEndpoint>,
    digest: u64,
    opts_for: impl Fn(usize) -> AgentOpts,
) -> Vec<thread::JoinHandle<SessionEnd>> {
    endpoints
        .into_iter()
        .enumerate()
        .map(|(i, mut ep)| {
            let addr = addr.to_string();
            let opts = opts_for(i);
            thread::Builder::new()
                .name(format!("test-agent-{i}"))
                .spawn(move || {
                    run_tcp_agent(&addr, &mut ep, digest, &opts)
                        .expect("agent session")
                })
                .expect("spawn test agent")
        })
        .collect()
}

#[test]
fn tcp_loopback_matches_inproc_bitwise() {
    let (train, _, spec, init) = workload(31);
    let cfg = RunConfig::default()
        .with_steps(2)
        .with_batch(4)
        .with_trigger_d(Trigger::vanilla(0.05))
        .with_trigger_z(Trigger::vanilla(0.05))
        .with_seed(23);

    // reference trajectory: the in-proc thread runtime
    let mut a = Coordinator::spawn(
        cfg.clone(),
        spec.clone(),
        single_class_split(&train, 4),
        init.clone(),
    );

    // TCP loopback cohort on an ephemeral port
    let digest = cfg.digest(init.len(), 4);
    let mut tp =
        Tcp::bind("127.0.0.1:0", 4, digest, init.len(), SocketOpts::default())
            .expect("bind leader");
    let addr = tp.local_addr().to_string();
    let endpoints =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init);
    let joins = spawn_agents(&addr, endpoints, digest, |_| AgentOpts::default());
    tp.await_cohort().expect("cohort formation");
    let mut b = Coordinator::over(tp, cfg, spec, init);

    for r in 0..10 {
        a.round();
        b.round();
        assert_eq!(a.z, b.z, "z diverged from in-proc at round {r}");
    }
    // byte books are bit-identical too: same LossyLink charging rules on
    // both transports, cumulative uplink counters reported by identical
    // endpoints
    assert_eq!(a.downlink_bytes(), b.downlink_bytes());
    assert_eq!(a.uplink_bytes(), b.uplink_bytes());
    let (wa, wb) = (a.wire_stats(), b.wire_stats());
    assert_eq!(wa.uplink_bytes(), wb.uplink_bytes());
    assert_eq!(wa.downlink_bytes(), wb.downlink_bytes());

    a.shutdown();
    b.shutdown();
    for j in joins {
        assert_eq!(j.join().expect("agent thread"), SessionEnd::Stopped);
    }
}

#[test]
fn tcp_journal_matches_inproc_on_deterministic_fields() {
    use deluxe::obs::{strip_wall, Obs};

    let (train, _, spec, init) = workload(61);
    let cfg = RunConfig::default()
        .with_steps(2)
        .with_batch(4)
        .with_trigger_d(Trigger::vanilla(0.05))
        .with_trigger_z(Trigger::vanilla(0.05))
        .with_seed(59);

    let mut a = Coordinator::spawn(
        cfg.clone(),
        spec.clone(),
        single_class_split(&train, 4),
        init.clone(),
    );
    a.obs = Obs::in_memory();

    let digest = cfg.digest(init.len(), 4);
    let mut tp =
        Tcp::bind("127.0.0.1:0", 4, digest, init.len(), SocketOpts::default())
            .expect("bind leader");
    let addr = tp.local_addr().to_string();
    let endpoints =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init);
    let joins = spawn_agents(&addr, endpoints, digest, |_| AgentOpts::default());
    tp.await_cohort().expect("cohort formation");
    let mut b = Coordinator::over(tp, cfg, spec, init);
    b.obs = Obs::in_memory();

    for _ in 0..10 {
        a.round();
        b.round();
    }
    // the deterministic journal fields (everything but "wall_us") are
    // bit-identical between the in-proc and TCP transports: triggers,
    // byte deltas and round books come from identical LossyLink state,
    // and uplink events are journaled in agent order at apply time
    let strip = |o: &Obs| -> Vec<String> {
        o.mem_lines()
            .iter()
            .map(|l| {
                let j = deluxe::jsonio::Json::parse(l).expect("journal line");
                strip_wall(&j).to_string()
            })
            .collect()
    };
    let (ja, jb) = (strip(&a.obs), strip(&b.obs));
    assert!(!ja.is_empty(), "journal recorded events");
    assert_eq!(ja, jb, "journals diverged between in-proc and TCP");
    // the span layer (DESIGN.md §14) rides the same contract: span
    // open/close lines are deterministic fields, so the ja == jb pin
    // above already covers them bit-for-bit — here we assert they are
    // actually present and balanced on both transports
    let count = |lines: &[String], ev: &str| {
        lines
            .iter()
            .filter(|l| l.contains(&format!("\"ev\":\"{ev}\"")))
            .count()
    };
    let opened = count(&ja, "span_open");
    assert!(opened > 0, "rounds must emit spans");
    assert_eq!(opened, count(&ja, "span_close"), "every span closes");
    assert_eq!(opened, count(&jb, "span_open"));
    assert_eq!(opened, count(&jb, "span_close"));
    // the journal reconciles exactly with the engine books (the
    // ISSUE's acceptance criterion): per-line sums equal the wire
    // stats the coordinator kept independently
    let sum_bytes = |lines: &[String], ev: &str, line: &str| -> u64 {
        lines
            .iter()
            .map(|l| deluxe::jsonio::Json::parse(l).expect("line"))
            .filter(|j| {
                j.get("ev").and_then(|v| v.as_str()) == Some(ev)
                    && (line.is_empty()
                        || j.get("line").and_then(|v| v.as_str())
                            == Some(line))
            })
            .map(|j| {
                j.get("bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
            })
            .sum()
    };
    assert_eq!(
        sum_bytes(&jb, "msg_sent", "up"),
        b.uplink_bytes(),
        "journaled uplink bytes must equal the cumulative Reply books"
    );
    assert_eq!(
        sum_bytes(&jb, "msg_sent", "down")
            + sum_bytes(&jb, "reset_sync", ""),
        b.downlink_bytes(),
        "journaled downlink + reset bytes must equal the wire books"
    );

    a.shutdown();
    b.shutdown();
    for j in joins {
        assert_eq!(j.join().expect("agent thread"), SessionEnd::Stopped);
    }
}

#[test]
fn status_probe_round_trips_over_tcp() {
    use deluxe::jsonio::Json;
    use deluxe::transport::frame::{read_frame, write_frame, Frame};

    let (train, _, spec, init) = workload(67);
    let cfg = RunConfig::default()
        .with_steps(2)
        .with_batch(4)
        .with_trigger_d(Trigger::vanilla(0.05))
        .with_trigger_z(Trigger::vanilla(0.05))
        .with_seed(71);
    let digest = cfg.digest(init.len(), 4);
    let mut tp =
        Tcp::bind("127.0.0.1:0", 4, digest, init.len(), SocketOpts::default())
            .expect("bind leader");
    let addr = tp.local_addr().to_string();
    let endpoints =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init);
    let joins = spawn_agents(&addr, endpoints, digest, |_| AgentOpts::default());
    tp.await_cohort().expect("cohort formation");
    let mut coord = Coordinator::over(tp, cfg, spec, init);
    coord.obs = deluxe::obs::Obs::new();

    let rounds = 6u64;
    for _ in 0..rounds {
        coord.round();
    }

    // one-shot probe connection: StatusReq instead of Hello, answered
    // by the acceptor from the published snapshot (the `deluxe status`
    // code path)
    let mut probe =
        std::net::TcpStream::connect(&addr).expect("probe connect");
    write_frame(&mut probe, &Frame::StatusReq).expect("send StatusReq");
    let json = match read_frame(&mut probe).expect("read Status") {
        Frame::Status { json } => json,
        other => panic!("expected Status, got {}", other.kind()),
    };
    let st = Json::parse(&json).expect("status JSON parses");
    assert_eq!(
        st.get("round").and_then(|j| j.as_f64()),
        Some(rounds as f64)
    );
    assert_eq!(st.get("agents").and_then(|j| j.as_f64()), Some(4.0));
    let live = st.get("live").and_then(|j| j.as_arr()).expect("live array");
    assert_eq!(live.len(), 4);
    assert!(live.iter().all(|l| l.as_bool() == Some(true)));
    // per-agent books and the metrics snapshot ride along
    let upb = st
        .get("uplink_bytes")
        .and_then(|j| j.as_arr())
        .expect("uplink_bytes");
    assert_eq!(upb.len(), 4);
    let metrics = st.get("metrics").expect("metrics snapshot");
    assert_eq!(
        metrics
            .get("counters")
            .and_then(|c| c.get("rounds"))
            .and_then(|v| v.as_f64()),
        Some(rounds as f64)
    );
    // the probe was not a failed handshake
    assert_eq!(coord.transport().rejected_handshakes(), 0);

    coord.shutdown();
    for j in joins {
        assert_eq!(j.join().expect("agent thread"), SessionEnd::Stopped);
    }
}

#[test]
fn tcp_survives_agent_crash_with_rejoin_resync() {
    let (train, test, spec, init) = workload(37);
    let cfg = RunConfig::default()
        .with_steps(3)
        .with_batch(8)
        .with_trigger_d(Trigger::vanilla(0.05))
        .with_trigger_z(Trigger::vanilla(0.05))
        .with_seed(29);
    let acc0 = spec.accuracy(&init, &test.xs, &test.labels);
    let digest = cfg.digest(init.len(), 4);
    let opts = SocketOpts { read_timeout_ms: 3_000, ..Default::default() };
    let mut tp = Tcp::bind("127.0.0.1:0", 4, digest, init.len(), opts)
        .expect("bind leader");
    let addr = tp.local_addr().to_string();

    // agent 2 silently drops its connection after serving 3 rounds — a
    // process crash without a goodbye
    let endpoints =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init);
    let joins = spawn_agents(&addr, endpoints, digest, |i| {
        if i == 2 {
            AgentOpts { crash_after_rounds: Some(3), ..Default::default() }
        } else {
            AgentOpts::default()
        }
    });
    tp.await_cohort().expect("cohort formation");
    let mut coord =
        Coordinator::over(tp, cfg.clone(), spec.clone(), init.clone());

    for _ in 0..5 {
        coord.round();
    }
    assert!(
        coord.live_count() < 4,
        "agent 2's crash should have surfaced by round 5"
    );

    // a replacement process takes over shard 2: fresh endpoint state
    // from init, resynced by the leader's reliable Reset on rejoin
    let mut replacement =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init)
            .remove(2);
    let addr2 = addr.clone();
    let rejoin = thread::spawn(move || {
        run_tcp_agent(&addr2, &mut replacement, digest, &AgentOpts::default())
            .expect("replacement session")
    });
    for _ in 0..15 {
        coord.round();
    }
    assert_eq!(coord.rejoin_resyncs, 1, "exactly one rejoin-resync");
    assert_eq!(coord.live_count(), 4, "replacement restored the cohort");
    // the resync was charged: agent 2's downlink books carry at least
    // one reliable dense sync on top of any triggered payloads
    let dense =
        deluxe::wire::WireMessage::<f32>::dense_bytes(coord.z.len()) as u64;
    assert!(
        coord.wire_stats().downlink[2].bytes >= dense,
        "rejoin Reset must be charged as one dense transfer"
    );
    // and the run still converges (the paper's drop-tolerance covers
    // the crashed agent's missing rounds)
    let acc = spec.accuracy(&coord.z, &test.xs, &test.labels);
    assert!(acc > acc0, "accuracy {acc0:.3} -> {acc:.3} should improve");

    coord.shutdown();
    let mut ends: Vec<SessionEnd> =
        joins.into_iter().map(|j| j.join().expect("agent thread")).collect();
    ends.push(rejoin.join().expect("replacement thread"));
    assert_eq!(
        ends.iter().filter(|e| **e == SessionEnd::Crashed).count(),
        1,
        "exactly the crashed session reports Crashed"
    );
}

#[cfg(unix)]
#[test]
fn uds_loopback_matches_inproc_bitwise() {
    use deluxe::coordinator::run_uds_agent;
    use deluxe::transport::Uds;

    let (train, _, spec, init) = workload(41);
    let cfg = RunConfig::default()
        .with_steps(2)
        .with_batch(4)
        .with_trigger_d(Trigger::vanilla(0.05))
        .with_trigger_z(Trigger::vanilla(0.05))
        .with_seed(43);

    let mut a = Coordinator::spawn(
        cfg.clone(),
        spec.clone(),
        single_class_split(&train, 4),
        init.clone(),
    );

    let digest = cfg.digest(init.len(), 4);
    let path = std::env::temp_dir()
        .join(format!("dela_uds_e2e_{}.sock", std::process::id()));
    let path_str = path.to_string_lossy().to_string();
    let mut tp =
        Uds::bind(&path_str, 4, digest, init.len(), SocketOpts::default())
            .expect("bind uds leader");
    let endpoints =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init);
    let joins: Vec<_> = endpoints
        .into_iter()
        .map(|mut ep| {
            let p = path_str.clone();
            thread::spawn(move || {
                run_uds_agent(&p, &mut ep, digest, &AgentOpts::default())
                    .expect("uds agent session")
            })
        })
        .collect();
    tp.await_cohort().expect("uds cohort formation");
    let mut b = Coordinator::over(tp, cfg, spec, init);

    for r in 0..8 {
        a.round();
        b.round();
        assert_eq!(a.z, b.z, "z diverged from in-proc at round {r}");
    }
    assert_eq!(a.uplink_bytes(), b.uplink_bytes());
    assert_eq!(a.downlink_bytes(), b.downlink_bytes());
    a.shutdown();
    b.shutdown();
    for j in joins {
        assert_eq!(j.join().expect("uds agent thread"), SessionEnd::Stopped);
    }
    assert!(!path.exists(), "leader shutdown removes the socket file");
}

#[test]
fn handshake_rejects_wrong_digest_and_duplicate_slot() {
    let (train, _, spec, init) = workload(47);
    let cfg = RunConfig::default().with_seed(53);
    let digest = cfg.digest(init.len(), 4);
    let mut tp =
        Tcp::bind("127.0.0.1:0", 4, digest, init.len(), SocketOpts::default())
            .expect("bind leader");
    let addr = tp.local_addr().to_string();

    // an agent built from a different protocol config never joins the
    // cohort: its Hello digest mismatches and the handshake is refused
    let bad_cfg = cfg.clone().with_delta(9.0);
    let bad_digest = bad_cfg.digest(init.len(), 4);
    assert_ne!(digest, bad_digest, "digest must separate the configs");
    let mut bad =
        make_endpoints(&bad_cfg, &spec, single_class_split(&train, 4), &init)
            .remove(0);
    let bad_opts = AgentOpts {
        reconnect_attempts: 0,
        backoff_ms: 10,
        ..Default::default()
    };
    let addr2 = addr.clone();
    let rejected = thread::spawn(move || {
        run_tcp_agent(&addr2, &mut bad, bad_digest, &bad_opts)
    });
    assert!(
        rejected.join().expect("rejected thread").is_err(),
        "mismatched digest must fail the session"
    );

    // the real cohort still forms afterwards
    let endpoints =
        make_endpoints(&cfg, &spec, single_class_split(&train, 4), &init);
    let joins = spawn_agents(&addr, endpoints, digest, |_| AgentOpts::default());
    tp.await_cohort().expect("cohort formation");
    assert!(tp.rejected_handshakes() >= 1, "the bad hello was counted");
    let coord = Coordinator::over(tp, cfg, spec, init);
    coord.shutdown();
    for j in joins {
        assert_eq!(j.join().expect("agent thread"), SessionEnd::Stopped);
    }
}
