//! End-to-end integration over the full stack: Alg. 1 with PJRT-backed
//! local solves (tiny artifacts), plus PJRT-vs-native differential runs
//! under identical seeds.

use deluxe::config::default_artifacts_dir;
use deluxe::experiments::nn::{run_algo, Algo, Backend, NnExperimentConfig, NnWorkload};
use deluxe::runtime::{PjrtRuntime, Variant};

fn runtime() -> Option<PjrtRuntime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping e2e stack tests");
        return None;
    }
    Some(PjrtRuntime::load(&dir).expect("runtime"))
}

#[test]
fn tiny_alg1_learns_through_pjrt_pallas() {
    let Some(rt) = runtime() else { return };
    let w = NnWorkload::tiny(5);
    let cfg = NnExperimentConfig { rounds: 25, eval_every: 5, seed: 5, ..Default::default() };
    let rec = run_algo(
        &w,
        Algo::Alg1Vanilla { delta_d: 0.05, delta_z: 0.05 },
        &cfg,
        &Backend::Pjrt(&rt, Variant::Pallas),
    );
    let acc = rec.last("accuracy").unwrap();
    assert!(acc > 0.5, "pjrt-pallas accuracy {acc}");
}

#[test]
fn pjrt_variants_agree_with_native_under_same_seed() {
    // Same workload + seed: the sequence of minibatches is identical, so
    // the three backends must produce closely matching trajectories
    // (small f32 divergence amplified over rounds is tolerated).
    let Some(rt) = runtime() else { return };
    let seed = 9;
    let cfg = NnExperimentConfig { rounds: 6, eval_every: 6, seed, ..Default::default() };
    let algo = Algo::Alg1Vanilla { delta_d: 0.05, delta_z: 0.05 };

    let w = NnWorkload::tiny(seed);
    let rec_native = run_algo(&w, algo, &cfg, &Backend::Native);
    let rec_pallas =
        run_algo(&w, algo, &cfg, &Backend::Pjrt(&rt, Variant::Pallas));
    let rec_ref = run_algo(&w, algo, &cfg, &Backend::Pjrt(&rt, Variant::Ref));

    let a_native = rec_native.last("accuracy").unwrap();
    let a_pallas = rec_pallas.last("accuracy").unwrap();
    let a_ref = rec_ref.last("accuracy").unwrap();
    assert!(
        (a_native - a_pallas).abs() < 0.15,
        "native {a_native} vs pallas {a_pallas}"
    );
    assert!(
        (a_ref - a_pallas).abs() < 0.15,
        "ref {a_ref} vs pallas {a_pallas}"
    );
    // event counts must match exactly when trajectories align:
    // allow small slack for f32-induced trigger flips
    let e_native = rec_native.last("events").unwrap();
    let e_pallas = rec_pallas.last("events").unwrap();
    assert!(
        (e_native - e_pallas).abs() <= 8.0,
        "event counts diverged: native {e_native} vs pallas {e_pallas}"
    );
}

#[test]
fn scaffold_runs_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let w = NnWorkload::tiny(11);
    let cfg = NnExperimentConfig { rounds: 10, eval_every: 5, seed: 11, ..Default::default() };
    let rec = run_algo(
        &w,
        Algo::Scaffold { part: 1.0 },
        &cfg,
        &Backend::Pjrt(&rt, Variant::Pallas),
    );
    assert!(rec.last("accuracy").unwrap() > 0.3);
    // SCAFFOLD's doubled packages: load == 2.0 at full participation
    assert!((rec.last("load").unwrap() - 2.0).abs() < 1e-9);
}

#[test]
fn fedavg_and_fedprox_run_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let w = NnWorkload::tiny(12);
    let cfg = NnExperimentConfig { rounds: 8, eval_every: 4, seed: 12, ..Default::default() };
    for algo in [
        Algo::FedAvg { part: 1.0 },
        Algo::FedProx { part: 1.0, mu: 0.1 },
        Algo::FedAdmm { part: 0.7 },
    ] {
        let rec = run_algo(&w, algo, &cfg, &Backend::Pjrt(&rt, Variant::Ref));
        assert!(
            rec.last("accuracy").unwrap() > 0.2,
            "{} failed to produce a sane model",
            algo.label()
        );
    }
}
