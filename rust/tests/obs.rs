//! Integration tests for the observability subsystem (DESIGN.md §13):
//! journal determinism across worker counts, flight-recorder eviction,
//! journal↔books reconciliation at the round-core level, and the
//! metrics registry snapshot shape.
//!
//! The house rule under test: every *deterministic* journal field
//! (round, agent, line, bytes, events, vtime) is bit-identical for any
//! `--workers` value; only `"wall_us"` values may differ, and
//! [`strip_wall`] removes exactly those.  The span layer (DESIGN.md
//! §14) rides the same rule: span open/close lines are deterministic,
//! every opened span closes, solve spans nest inside their local_solve
//! phase, and `profile::analyze` reconciles a 16-agent coordinator run
//! with zero violations.

use deluxe::admm::{EventLine, RoundCore};
use deluxe::comm::Trigger;
use deluxe::jsonio::Json;
use deluxe::obs::{parse_journal, strip_wall, Event, Line, Obs};
use deluxe::prelude::Pcg64;
use deluxe::rng::Rng;
use deluxe::wire::CompressorCfg;

/// Drive a miniature triggered engine — per-agent uplink [`EventLine`]s
/// plus the [`RoundCore`] solve phase — for `rounds` rounds at the given
/// worker count, journaling into an in-memory [`Obs`].  Returns the
/// journal lines and the final per-agent channel books.
fn drive_core(workers: usize, rounds: usize) -> (Vec<String>, Vec<(u64, u64)>) {
    let n = 6;
    let dim = 24;
    let mut core = RoundCore::<f32>::new(n, dim, &CompressorCfg::Identity, workers);
    let mut lines: Vec<EventLine<f32>> = (0..n)
        .map(|_| EventLine::new(Trigger::vanilla(0.4), vec![0.0; dim], 0.3))
        .collect();
    // one deterministic comm-phase RNG per agent, drawn in agent order
    let mut rngs: Vec<Pcg64> =
        (0..n).map(|i| Pcg64::seed_stream(99, i as u64)).collect();
    let mut obs = Obs::in_memory();
    let mut states: Vec<Vec<f32>> = vec![vec![0.0; dim]; n];

    for _ in 0..rounds {
        let round = core.round_idx as u64;
        obs.emit(Event::RoundStart { round });
        // phase 2: parallel local solves, journaled post-barrier in
        // agent order regardless of worker scheduling
        let solve_rngs = core.round_solve_rngs(&Pcg64::seed(7));
        let mut items: Vec<(Vec<f32>, Pcg64)> =
            states.iter().cloned().zip(solve_rngs).collect();
        core.solve_timed(
            &mut items,
            |_i, (x, r)| {
                for v in x.iter_mut() {
                    *v += r.f64() as f32 - 0.4;
                }
            },
            &mut obs,
        );
        for (s, (x, _)) in states.iter_mut().zip(items) {
            *s = x;
        }
        // phase 3: sequential comm in agent order
        let mut scratch = Vec::new();
        for i in 0..n {
            let comp = core.comp.as_ref();
            let _ = lines[i].offer_send_obs(
                &states[i],
                comp,
                &mut rngs[i],
                &mut scratch,
                &mut obs,
                round,
                i,
                Line::Up,
            );
        }
        if core.finish_round(4) {
            for i in 0..n {
                lines[i].resync_obs(&states[i], &mut obs, round, i);
            }
        }
    }
    let books = lines
        .iter()
        .map(|l| (l.stats().sent_bytes, l.events()))
        .collect();
    (obs.mem_lines().to_vec(), books)
}

fn strip(lines: &[String]) -> Vec<String> {
    lines
        .iter()
        .map(|l| strip_wall(&Json::parse(l).expect("journal line")).to_string())
        .collect()
}

#[test]
fn journal_deterministic_fields_identical_across_worker_counts() {
    let (j1, b1) = drive_core(1, 9);
    let (j4, b4) = drive_core(4, 9);
    assert_eq!(b1, b4, "channel books must be workers-invariant");
    let (s1, s4) = (strip(&j1), strip(&j4));
    assert!(!s1.is_empty());
    assert_eq!(s1, s4, "stripped journals diverged between workers 1 and 4");
    // the raw journals DO differ in wall_us (or at least may) — what
    // matters is that stripping is the only normalization needed, i.e.
    // wall_us is the only nondeterministic key.  Verify strip removed
    // something real: solve_done events carry wall_us.
    let solves = j1
        .iter()
        .filter(|l| l.contains("\"ev\":\"solve_done\""))
        .count();
    assert_eq!(solves, 9 * 6, "one solve_done per agent per round");
    assert!(
        j1.iter().any(|l| l.contains("wall_us")),
        "solve timings are journaled under wall_us"
    );
    assert!(
        s1.iter().all(|l| !l.contains("wall_us")),
        "strip_wall must remove every wall_us key"
    );
}

#[test]
fn journal_sums_reconcile_with_channel_books_exactly() {
    let (lines, books) = drive_core(2, 12);
    let events = parse_journal(&lines.join("\n")).expect("parse journal");
    let num = |j: &Json, k: &str| {
        j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
    };
    let n = books.len();
    let mut sent = vec![0u64; n];
    let mut trig = vec![0u64; n];
    for j in &events {
        let agent = num(j, "agent") as usize;
        match j.get("ev").and_then(|v| v.as_str()) {
            Some("msg_sent") | Some("reset_sync") => {
                sent[agent] += num(j, "bytes");
            }
            Some("trigger_fired") => trig[agent] += 1,
            _ => {}
        }
    }
    for (i, &(book_bytes, book_events)) in books.iter().enumerate() {
        assert_eq!(
            sent[i], book_bytes,
            "agent {i}: Σ msg_sent + Σ reset_sync must equal sent_bytes"
        );
        // a resync counts one trigger event in the books but journals as
        // reset_sync, so: trigger_fired + reset_sync == trig.events
        let resyncs = events
            .iter()
            .filter(|j| {
                j.get("ev").and_then(|v| v.as_str()) == Some("reset_sync")
                    && num(j, "agent") as usize == i
            })
            .count() as u64;
        assert_eq!(
            trig[i] + resyncs,
            book_events,
            "agent {i}: trigger_fired + reset_sync must equal trig.events"
        );
    }
}

#[test]
fn flight_recorder_ring_eviction_is_pinned() {
    use deluxe::obs::FlightRecorder;
    let mut fr = FlightRecorder::new(4);
    for r in 0..11u64 {
        fr.push(Event::RoundStart { round: r });
    }
    assert_eq!(fr.len(), 4);
    assert_eq!(fr.capacity(), 4);
    assert_eq!(fr.evicted(), 7);
    let rounds: Vec<u64> = fr
        .events()
        .map(|e| match e {
            Event::RoundStart { round } => *round,
            other => panic!("unexpected event {other:?}"),
        })
        .collect();
    assert_eq!(rounds, vec![7, 8, 9, 10], "oldest events evicted first");
    let dump = fr.dump_json();
    assert_eq!(dump.get("evicted").and_then(|j| j.as_f64()), Some(7.0));
    assert_eq!(
        dump.get("events").and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(4)
    );
}

#[test]
fn metrics_snapshot_has_stable_shape_and_counts() {
    let mut obs = Obs::new();
    obs.emit(Event::Meta { agents: 3, dim: 10, dense_bytes: 49 });
    for r in 0..5u64 {
        obs.emit(Event::RoundStart { round: r });
        obs.emit(Event::TriggerFired { round: r, agent: 0, line: Line::Up });
        obs.emit(Event::MessageSent {
            round: r,
            agent: 0,
            line: Line::Up,
            bytes: 100,
        });
        obs.emit(Event::SolveDone { round: r, agent: 0, micros: 1 << r });
        obs.emit(Event::RoundEnd {
            round: r,
            events: r + 1,
            up_bytes: 100 * (r + 1),
            down_bytes: 0,
            vtime_us: None,
            wall_us: Some(10),
        });
    }
    let m = &obs.metrics;
    assert_eq!(m.counter("rounds"), 5);
    assert_eq!(m.counter("trigger_up"), 5);
    assert_eq!(m.counter("msgs_up"), 5);
    assert_eq!(m.counter("bytes_up"), 500);
    let h = m.hist("solve_us").expect("solve_us histogram");
    assert_eq!(h.count(), 5);
    assert_eq!(h.sum(), 1 + 2 + 4 + 8 + 16);
    let snap = obs.metrics.snapshot();
    for key in ["counters", "gauges", "hists"] {
        assert!(snap.get(key).is_some(), "snapshot must carry {key}");
    }
    // snapshot serialization is deterministic (BTreeMap ordering)
    assert_eq!(snap.to_string(), obs.metrics.snapshot().to_string());
}

#[test]
fn core_spans_pair_up_and_solves_nest_inside_local_solve() {
    let rounds = 5usize;
    let (lines, _) = drive_core(3, rounds);
    let events: Vec<Json> = lines
        .iter()
        .map(|l| Json::parse(l).expect("journal line"))
        .collect();
    let num = |j: &Json, k: &str| {
        j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
    };
    let mut stack: Vec<(u64, String)> = Vec::new();
    let (mut opened, mut closed) = (0usize, 0usize);
    for j in &events {
        match j.get("ev").and_then(|v| v.as_str()) {
            Some("span_open") => {
                opened += 1;
                let id = num(j, "span");
                let kind = j
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .expect("span kind")
                    .to_string();
                let parent =
                    j.get("parent").and_then(|v| v.as_f64()).map(|p| p as u64);
                if kind == "solve" {
                    // solve spans nest inside their local_solve phase
                    let top = stack.last().expect("solve span has a parent");
                    assert_eq!(top.1, "local_solve");
                    assert_eq!(parent, Some(top.0));
                } else {
                    // the core harness has no coordinator round around
                    // it, so the local_solve phase is a root span
                    assert_eq!(kind, "local_solve");
                    assert_eq!(parent, None);
                }
                stack.push((id, kind));
            }
            Some("span_close") => {
                closed += 1;
                let id = num(j, "span");
                let (top_id, _) = stack.pop().expect("close matches an open");
                assert_eq!(top_id, id, "spans close LIFO");
            }
            _ => {}
        }
    }
    assert!(stack.is_empty(), "every opened span closes");
    assert_eq!(opened, closed);
    // per round: one local_solve phase holding one solve span per agent
    assert_eq!(opened, rounds * (1 + 6));
    let p = deluxe::obs::profile::analyze(&events);
    assert_eq!(p.violations, Vec::<String>::new());
    assert_eq!(p.spans_opened, opened as u64);
    assert_eq!(p.solve_hist.len(), 6, "one solve histogram per agent");
}

#[test]
fn span_streams_are_bit_identical_across_worker_counts() {
    // the span layer obeys the same house rule as the classic events:
    // strip_wall is the only normalization between workers 1 and 4
    let (j1, _) = drive_core(1, 7);
    let (j4, _) = drive_core(4, 7);
    let spans = |lines: &[String]| -> Vec<String> {
        strip(lines)
            .into_iter()
            .filter(|l| {
                l.contains("\"ev\":\"span_open\"")
                    || l.contains("\"ev\":\"span_close\"")
            })
            .collect()
    };
    let (s1, s4) = (spans(&j1), spans(&j4));
    assert_eq!(s1.len(), 2 * 7 * (1 + 6));
    assert_eq!(s1, s4, "span streams diverged between workers 1 and 4");
}

#[test]
fn coordinator_profile_reconciles_on_a_16_agent_run() {
    use deluxe::data::partition::single_class_split;
    use deluxe::data::synth::{generate, SynthSpec};
    use deluxe::model::MlpSpec;
    use deluxe::prelude::{Coordinator, RunConfig};

    let run = |workers: usize| -> Vec<Json> {
        let mut rng = Pcg64::seed(41);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let cfg = RunConfig::default()
            .with_steps(2)
            .with_batch(4)
            .with_trigger_d(Trigger::vanilla(0.05))
            .with_trigger_z(Trigger::vanilla(0.05))
            .with_reset_period(3)
            .with_workers(workers)
            .with_seed(43);
        let mut c = Coordinator::spawn(
            cfg,
            spec,
            single_class_split(&train, 16),
            init,
        );
        c.obs = Obs::in_memory();
        for _ in 0..6 {
            c.round();
        }
        let lines = c.obs.mem_lines().to_vec();
        c.shutdown();
        lines
            .iter()
            .map(|l| Json::parse(l).expect("journal line"))
            .collect()
    };
    let events = run(1);
    let p = deluxe::obs::profile::analyze(&events);
    // the `deluxe profile --check` contract: phase durations and bytes
    // reconcile with the round span and the WireStats books
    assert_eq!(p.violations, Vec::<String>::new());
    assert_eq!(p.rounds.len(), 6);
    for r in &p.rounds {
        for phase in ["broadcast", "gather", "apply"] {
            assert!(
                r.phases.contains_key(phase),
                "round {} missing phase {phase}",
                r.round
            );
        }
    }
    assert!(
        p.rounds.iter().any(|r| r.critical.is_some()),
        "critical-path attribution names an agent/link"
    );
    // the stripped profile is bit-identical across worker counts
    let stripped_profile = |events: &[Json]| -> String {
        let stripped: Vec<Json> = events.iter().map(strip_wall).collect();
        deluxe::obs::profile::analyze(&stripped).to_json().to_string()
    };
    assert_eq!(stripped_profile(&events), stripped_profile(&run(4)));
}

#[test]
fn journal_parses_back_and_off_handle_is_silent() {
    let mut obs = Obs::in_memory();
    obs.emit(Event::Meta { agents: 2, dim: 4, dense_bytes: 21 });
    obs.emit(Event::AgentJoined { agent: 0 });
    obs.emit(Event::Rejoin { round: 3, agent: 1 });
    obs.emit(Event::ReconnectAttempt { agent: 1, attempt: 2 });
    obs.emit(Event::FrameTimeout { round: 3 });
    let parsed =
        parse_journal(&obs.mem_lines().join("\n")).expect("roundtrip");
    assert_eq!(parsed.len(), 5);
    assert_eq!(
        parsed[0].get("ev").and_then(|j| j.as_str()),
        Some("meta")
    );

    let mut off = Obs::off();
    off.emit(Event::RoundStart { round: 0 });
    assert!(off.mem_lines().is_empty());
    assert!(!off.on());
    assert_eq!(off.metrics.counter("rounds"), 0);
    assert!(off.flight.is_empty());
}
