//! The unified round core's determinism contract (DESIGN.md §10):
//!
//! * **workers-invariance** — every engine (Alg. 1 consensus, Alg. 2
//!   general, graph, sharing) and every baseline (FedAvg, FedProx,
//!   SCAFFOLD, FedADMM) produces bit-identical trajectories — trace
//!   hash over the full per-round iterate/counter stream plus exact
//!   final iterates — for `workers = 1` and `workers = N`, under spicy
//!   configurations (stochastic triggers, drops, resets, compression,
//!   RNG-consuming SGD solvers);
//! * **pinned pre-refactor counters** — on deterministic
//!   configurations the unified core reproduces the closed-form
//!   event/drop/byte books the four hand-rolled engines produced
//!   before the unification.

use deluxe::admm::{
    ConsensusAdmm, ConsensusConfig, GeneralAdmm, GeneralConfig, GraphAdmm,
    GraphConfig, QuadraticF, SharingAdmm, SharingConfig, ZProx,
};
use deluxe::admm::sharing::SharingG;
use deluxe::baselines::{AvgFamily, FedAdmm, NativeFed, Scaffold};
use deluxe::comm::Trigger;
use deluxe::data::partition::iid_split;
use deluxe::data::regress::{generate, RegressSpec};
use deluxe::data::synth::{self, SynthSpec};
use deluxe::linalg::Matrix;
use deluxe::model::MlpSpec;
use deluxe::rng::{Pcg64, Rng};
use deluxe::sim::TraceHash;
use deluxe::solver::{ExactQuadratic, IdentityProx, NativeSgd};
use deluxe::wire::{CompressorCfg, WireMessage};

/// Fold a float slice into a trace hash bit-exactly.
fn mix_slice(h: &mut TraceHash, xs: &[f64]) {
    for &x in xs {
        h.mix(x.to_bits());
    }
}

fn mix_slice_f32(h: &mut TraceHash, xs: &[f32]) {
    for &x in xs {
        h.mix(x.to_bits() as u64);
    }
}

const WORKER_GRID: [usize; 3] = [1, 3, 8];

// ---------------------------------------------------------------------------
// workers-invariance: the four engines
// ---------------------------------------------------------------------------

#[test]
fn consensus_engine_is_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let mut rng = Pcg64::seed(71);
        let (blocks, _) = generate(
            &RegressSpec {
                n_agents: 12,
                rows_per_agent: 6,
                dim: 7,
                ..Default::default()
            },
            &mut rng,
        );
        let cfg = ConsensusConfig {
            rounds: 60,
            alpha: 1.3,
            trigger_d: Trigger::randomized(1e-3, 0.2),
            trigger_z: Trigger::vanilla(1e-4),
            drop_up: 0.2,
            drop_down: 0.1,
            reset_period: 9,
            compressor: CompressorCfg::Quant { bits: 10 },
            workers,
            ..Default::default()
        };
        let mut engine = ConsensusAdmm::new(cfg, 12, vec![0.0; 7]);
        let mut solver = ExactQuadratic::new(&blocks);
        let mut prox = IdentityProx;
        let mut h = TraceHash::new();
        for _ in 0..60 {
            engine.round(&mut solver, &mut prox, &mut rng);
            mix_slice(&mut h, &engine.z);
            h.mix(engine.total_events());
            let (ub, db) = engine.bytes_split();
            h.mix(ub);
            h.mix(db);
        }
        for i in 0..12 {
            mix_slice(&mut h, engine.agent_x(i));
            mix_slice(&mut h, engine.agent_u(i));
        }
        let (du, dd) = engine.drops_split();
        (h.value(), du, dd)
    };
    let base = run(WORKER_GRID[0]);
    for &w in &WORKER_GRID[1..] {
        assert_eq!(run(w), base, "consensus diverged at workers = {w}");
    }
}

#[test]
fn general_engine_is_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let mut rng = Pcg64::seed(72);
        let d = Matrix::randn(20, 5, &mut rng);
        let xtrue: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let b = d.matvec(&xtrue);
        let f = QuadraticF::least_squares(&d, &b);
        let cfg = GeneralConfig {
            rounds: 80,
            drop_rate: 0.2,
            reset_period: 7,
            compressor: CompressorCfg::Quant { bits: 9 },
            workers,
            ..Default::default()
        }
        .with_uniform_delta(1e-4);
        let mut eng = GeneralAdmm::new(
            cfg,
            Matrix::eye(5),
            vec![0.0; 5],
            f,
            ZProx::diag(-1.0, 0.1),
            vec![0.0; 5],
            vec![0.0; 5],
        );
        let mut h = TraceHash::new();
        for _ in 0..80 {
            eng.round(&mut rng);
            mix_slice(&mut h, &eng.x);
            mix_slice(&mut h, &eng.u);
            h.mix(eng.total_events());
            h.mix(eng.total_wire_bytes());
        }
        h.value()
    };
    let base = run(1);
    assert_eq!(run(4), base, "general engine diverged across workers");
}

#[test]
fn graph_engine_is_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let mut rng = Pcg64::seed(73);
        let graph = deluxe::topology::Graph::random_connected(10, 18, &mut rng);
        let (blocks, _) = generate(
            &RegressSpec {
                n_agents: 10,
                rows_per_agent: 6,
                dim: 4,
                ..Default::default()
            },
            &mut rng,
        );
        let cfg = GraphConfig {
            rounds: 50,
            trigger_x: Trigger::randomized(1e-3, 0.15),
            drop_rate: 0.25,
            reset_period: 8,
            compressor: CompressorCfg::Quant { bits: 8 },
            workers,
            ..Default::default()
        };
        let mut eng = GraphAdmm::new(cfg, graph, vec![0.0; 4]);
        let mut solver = ExactQuadratic::new(&blocks);
        let mut h = TraceHash::new();
        for _ in 0..50 {
            eng.round(&mut solver, &mut rng);
            mix_slice(&mut h, &eng.mean_x());
            h.mix(eng.total_events());
            h.mix(eng.total_wire_bytes());
        }
        for i in 0..10 {
            mix_slice(&mut h, eng.agent_x(i));
        }
        h.value()
    };
    let base = run(WORKER_GRID[0]);
    for &w in &WORKER_GRID[1..] {
        assert_eq!(run(w), base, "graph engine diverged at workers = {w}");
    }
}

#[test]
fn sharing_engine_is_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let mut rng = Pcg64::seed(74);
        let (blocks, _) = generate(
            &RegressSpec {
                n_agents: 8,
                rows_per_agent: 5,
                dim: 3,
                ..Default::default()
            },
            &mut rng,
        );
        let cfg = SharingConfig {
            rounds: 70,
            trigger_x: Trigger::randomized(1e-3, 0.2),
            trigger_h: Trigger::vanilla(1e-4),
            drop_rate: 0.2,
            reset_period: 6,
            g: SharingG::Quad { gamma: 0.4 },
            workers,
            ..Default::default()
        };
        let mut eng = SharingAdmm::new(cfg, 8, 3);
        let mut solver = ExactQuadratic::new(&blocks);
        let mut h = TraceHash::new();
        for _ in 0..70 {
            eng.round(&mut solver, &mut rng);
            mix_slice(&mut h, &eng.z);
            mix_slice(&mut h, &eng.aggregate());
            h.mix(eng.total_events());
            let (ub, db) = eng.bytes_split();
            h.mix(ub);
            h.mix(db);
        }
        h.value()
    };
    let base = run(1);
    assert_eq!(run(5), base, "sharing engine diverged across workers");
}

// ---------------------------------------------------------------------------
// workers-invariance: the four baselines (RNG-consuming SGD solvers)
// ---------------------------------------------------------------------------

fn tiny_fed(seed: u64) -> (NativeFed, Vec<f32>) {
    let mut rng = Pcg64::seed(seed);
    let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
    let shards = iid_split(&train, 6, &mut rng);
    let spec = MlpSpec::new(vec![8, 16, 4]);
    let init = spec.init(&mut rng);
    (NativeFed::new(spec, shards, 0.1, 3, 8), init)
}

#[test]
fn fedavg_and_fedprox_are_bit_identical_across_worker_counts() {
    for mu in [0.0, 0.5] {
        let run = |workers: usize| {
            let (mut local, init) = tiny_fed(81);
            let mut eng = if mu > 0.0 {
                AvgFamily::fedprox(init, 0.6, mu)
            } else {
                AvgFamily::fedavg(init, 0.6)
            }
            .with_workers(workers);
            let mut rng = Pcg64::seed(82);
            let mut h = TraceHash::new();
            for _ in 0..15 {
                eng.round(&mut local, &mut rng);
                mix_slice_f32(&mut h, &eng.z);
                h.mix(eng.events);
                h.mix(eng.wire.total());
            }
            h.value()
        };
        let base = run(1);
        for &w in &WORKER_GRID[1..] {
            assert_eq!(
                run(w),
                base,
                "avg-family (mu = {mu}) diverged at workers = {w}"
            );
        }
    }
}

#[test]
fn scaffold_is_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let (mut local, init) = tiny_fed(83);
        let mut eng = Scaffold::new(init, 6, 0.7).with_workers(workers);
        let mut rng = Pcg64::seed(84);
        let mut h = TraceHash::new();
        for _ in 0..12 {
            eng.round(&mut local, &mut rng);
            mix_slice_f32(&mut h, &eng.z);
            mix_slice_f32(&mut h, &eng.c);
            h.mix(eng.events);
        }
        h.value()
    };
    let base = run(1);
    for &w in &WORKER_GRID[1..] {
        assert_eq!(run(w), base, "scaffold diverged at workers = {w}");
    }
}

#[test]
fn fedadmm_with_sgd_solver_is_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let mut rng = Pcg64::seed(85);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards = iid_split(&train, 4, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver =
            NativeSgd::new(spec, shards, 0.1, 2, 4, &init);
        let mut eng =
            FedAdmm::<f32>::with_workers(4, init, 1.0, 0.6, 12, workers);
        let mut prox = IdentityProx;
        let mut h = TraceHash::new();
        for _ in 0..12 {
            eng.round(&mut solver, &mut prox, &mut rng);
            mix_slice_f32(&mut h, eng.z());
            h.mix(eng.total_events());
        }
        h.value()
    };
    let base = run(1);
    for &w in &WORKER_GRID[1..] {
        assert_eq!(run(w), base, "fedadmm diverged at workers = {w}");
    }
}

// ---------------------------------------------------------------------------
// workers-invariance: the async sim engine's batched compute phase
// ---------------------------------------------------------------------------

#[test]
fn async_sim_engine_is_bit_identical_across_worker_counts() {
    use deluxe::sim::{AsyncConsensus, Scenario};
    let run = |workers: usize| {
        let mut rng = Pcg64::seed(91);
        let (blocks, _) = generate(
            &RegressSpec {
                n_agents: 8,
                rows_per_agent: 6,
                dim: 5,
                ..Default::default()
            },
            &mut rng,
        );
        let mut scn = Scenario::ideal("det", 8, 40);
        scn.seed = 91;
        scn.trigger_d = Trigger::vanilla(1e-3);
        scn.trigger_z = Trigger::vanilla(1e-4);
        scn.participation = 0.6;
        scn.reset_period = 10;
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0; 5])
            .with_workers(workers);
        let mut solver = ExactQuadratic::new(&blocks);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        let mut h = TraceHash::new();
        mix_slice(&mut h, &sim.z);
        for i in 0..8 {
            mix_slice(&mut h, sim.agent_x(i));
            mix_slice(&mut h, sim.agent_u(i));
        }
        h.mix(sim.trace_hash());
        h.mix(sim.total_events());
        let (ub, db) = sim.bytes_split();
        h.mix(ub);
        h.mix(db);
        h.value()
    };
    let base = run(1);
    for &w in &WORKER_GRID[1..] {
        assert_eq!(run(w), base, "async engine diverged at workers = {w}");
    }
}

#[test]
fn async_sim_matches_sync_engine_with_rng_consuming_solver() {
    // The §9 sync-equivalence contract extended by the round core's fork
    // protocol: under an ideal scenario, the async engine must reproduce
    // ConsensusAdmm bit-for-bit *including the per-agent solver RNG
    // streams* — pinned here with NativeSgd, whose minibatch draws come
    // entirely from the forked streams.
    use deluxe::sim::{AsyncConsensus, Scenario};
    let n = 4;
    let rounds = 10;
    let mk_solver = || {
        let mut rng = Pcg64::seed(95);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards = iid_split(&train, n, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        (NativeSgd::new(spec, shards, 0.1, 2, 4, &init), init)
    };

    let mut scn = Scenario::ideal("nn-equiv", n, rounds);
    scn.seed = 96;
    scn.rho = 2.0;
    scn.trigger_d = Trigger::vanilla(1e-3);
    scn.trigger_z = Trigger::vanilla(1e-4);
    let (mut solver_a, init) = mk_solver();
    let mut sim = AsyncConsensus::<f32>::new(scn, init.clone());
    let mut prox_a = IdentityProx;
    sim.run(&mut solver_a, &mut prox_a);

    let cfg = ConsensusConfig {
        rho: 2.0,
        rounds,
        trigger_d: Trigger::vanilla(1e-3),
        trigger_z: Trigger::vanilla(1e-4),
        workers: 1,
        ..Default::default()
    };
    let (mut solver_b, _) = mk_solver();
    let mut sync = ConsensusAdmm::new(cfg, n, init);
    let mut prox_b = IdentityProx;
    let mut rng = Pcg64::seed(96);
    for _ in 0..rounds {
        sync.round(&mut solver_b, &mut prox_b, &mut rng);
    }

    assert_eq!(sim.z, sync.z, "z diverged under NativeSgd");
    for i in 0..n {
        assert_eq!(sim.agent_x(i), sync.agent_x(i), "x[{i}]");
        assert_eq!(sim.agent_u(i), sync.agent_u(i), "u[{i}]");
    }
    assert_eq!(sim.total_events(), sync.total_events());
    assert_eq!(sim.bytes_split(), sync.bytes_split());
}

// ---------------------------------------------------------------------------
// pinned pre-refactor counters (closed-form books on deterministic configs)
// ---------------------------------------------------------------------------

#[test]
fn graph_engine_reproduces_pre_refactor_counters() {
    // complete(4): degree 3 everywhere, Always triggers, reliable links,
    // resets every 5 of 20 rounds.  Broadcast events: 20 + 4 resets per
    // agent; link bytes: one dense dim-2 message per link event plus one
    // dense sync per link per reset — exactly the hand-rolled engine's
    // books.
    struct Pull;
    impl deluxe::solver::LocalSolver<f64> for Pull {
        fn solve(
            &mut self,
            _a: usize,
            anchor: &[f64],
            _rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            anchor.iter().map(|v| 0.5 * v + 1.0).collect()
        }
        fn dim(&self) -> usize {
            2
        }
        fn n_agents(&self) -> usize {
            4
        }
    }
    let g = deluxe::topology::Graph::complete(4);
    let cfg = GraphConfig {
        rounds: 20,
        reset_period: 5,
        ..Default::default()
    };
    let mut eng = GraphAdmm::new(cfg, g, vec![0.0; 2]);
    let mut rng = Pcg64::seed(5);
    for _ in 0..20 {
        eng.round(&mut Pull, &mut rng);
    }
    let per_agent: u64 = 20 + 4;
    assert_eq!(eng.total_events(), 4 * per_agent);
    assert_eq!(eng.total_link_events(), 4 * per_agent * 3);
    let dense = WireMessage::<f64>::dense_bytes(2) as u64;
    assert_eq!(eng.total_wire_bytes(), 4 * per_agent * 3 * dense);
}

#[test]
fn general_engine_reproduces_pre_refactor_counters() {
    let mut rng = Pcg64::seed(11);
    let d = Matrix::randn(20, 5, &mut rng);
    let xtrue: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
    let b = d.matvec(&xtrue);
    let f = QuadraticF::least_squares(&d, &b);
    let cfg = GeneralConfig {
        rounds: 30,
        reset_period: 10,
        ..Default::default()
    };
    let mut eng = GeneralAdmm::new(
        cfg,
        Matrix::eye(5),
        vec![0.0; 5],
        f,
        ZProx::diag(-1.0, 0.0),
        vec![0.0; 5],
        vec![0.0; 5],
    );
    for _ in 0..30 {
        eng.round(&mut rng);
    }
    // 6 lines x (30 triggered + 3 reset) events, each one dense dim-5
    // transfer on a reliable link
    assert_eq!(eng.total_events(), 6 * 33);
    let dense = WireMessage::<f64>::dense_bytes(5) as u64;
    assert_eq!(eng.total_wire_bytes(), 6 * 33 * dense);
    for (_, st) in eng.line_stats() {
        assert_eq!(st.sent, 33);
        assert_eq!(st.dropped, 0);
    }
}

#[test]
fn sharing_engine_reproduces_pre_refactor_event_counters() {
    // Always triggers, reliable links, resets every 4 of 16 rounds: the
    // event books match the pre-refactor engine; the byte books are new
    // (the old sharing engine had no wire accounting) and must equal
    // one dense dim-2 transfer per event.
    struct Pull;
    impl deluxe::solver::LocalSolver<f64> for Pull {
        fn solve(
            &mut self,
            _a: usize,
            anchor: &[f64],
            _rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            anchor.iter().map(|v| 0.9 * v + 0.1).collect()
        }
        fn dim(&self) -> usize {
            2
        }
        fn n_agents(&self) -> usize {
            3
        }
    }
    let cfg = SharingConfig {
        rounds: 16,
        reset_period: 4,
        ..Default::default()
    };
    let mut eng = SharingAdmm::new(cfg, 3, 2);
    let mut rng = Pcg64::seed(6);
    for _ in 0..16 {
        eng.round(&mut Pull, &mut rng);
    }
    let per_line: u64 = 16 + 4;
    assert_eq!(eng.total_events(), 2 * 3 * per_line);
    let dense = WireMessage::<f64>::dense_bytes(2) as u64;
    assert_eq!(
        eng.bytes_split(),
        (3 * per_line * dense, 3 * per_line * dense)
    );
    let ws = eng.wire_stats();
    assert_eq!(ws.uplink.len(), 3);
    for l in ws.uplink.iter().chain(&ws.downlink) {
        assert_eq!(l.msgs, per_line);
        assert_eq!(l.dropped_msgs, 0);
    }
}
