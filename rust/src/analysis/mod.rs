//! `deluxe lint` — a house-invariant static-analysis pass.
//!
//! The repo's determinism story (bit-exact identity compression,
//! async≡sync, workers-invariance, per-(round, agent) forked RNG
//! streams, the sim's integer-µs virtual clock) is enforced by tests
//! after the fact, but nothing stops a future change from silently
//! breaking it with a `HashMap` iteration, an ambient RNG or a
//! wall-clock read.  This module makes those contracts machine-checked
//! at CI time: a hand-rolled lexer ([`lexer`]), five syntactic rules
//! plus suppression handling ([`rules`]), and a tree walker — all
//! dependency-free, since the offline environment has no `syn`.
//!
//! The rule catalogue, the per-module scoping and the suppression
//! grammar (`lint:allow(<rule>): <justification>`, justification
//! mandatory) are documented in `DESIGN.md` §11.  The pass runs as
//! `deluxe lint [--json] [--root DIR]` and exits nonzero on findings;
//! `rust/tests/lint.rs` pins each rule against a fixture corpus and
//! asserts the repo tree itself is clean.

pub mod lexer;
pub mod rules;

use anyhow::Context;
use std::path::Path;

use crate::jsonio::Json;

/// The five enforceable rules, in catalogue order.
pub const RULES: [&str; 5] = [
    "nondet-iteration",
    "wall-clock",
    "ambient-rng",
    "panic-in-library",
    "unaccounted-send",
];

/// Pseudo-rule reported for broken suppression comments; it cannot
/// itself be suppressed.
pub const BAD_SUPPRESSION: &str = "bad-suppression";

/// Library modules whose iteration order / sends feed trajectories.
/// `kernels` is restricted because its accumulation order *is* the
/// bit-exactness contract (DESIGN.md §15): a nondeterministic iteration
/// or ambient draw there would corrupt every solve trajectory.
pub const RESTRICTED: [&str; 10] = [
    "admm",
    "sim",
    "comm",
    "wire",
    "baselines",
    "coordinator",
    "runtime",
    "transport",
    "obs",
    "kernels",
];

/// Modules allowed to read the wall clock (they measure, not simulate).
pub const WALL_CLOCK_ALLOW: [&str; 2] = ["benchlib", "metrics"];

/// File-scoped wall-clock allowance: `obs` is a restricted module (its
/// journal feeds trajectories in tests), but its timing sampler is the
/// one place the observability layer may read the clock.  Keeping the
/// allowance per-file rather than per-module means a stray `Instant`
/// anywhere else in `obs` still fires — deliberately including the
/// span layer (`obs/span.rs`), whose `TimedSpan` must route every
/// timing read through [`obs::clock::Stopwatch`] so wall-clock stays
/// confined to `"wall_us"` keys; a raw `Instant::now` in span-shaped
/// code is pinned as a finding by the `wall_clock_span.rs` fixture.
pub const WALL_CLOCK_ALLOW_FILES: [&str; 1] = ["rust/src/obs/clock.rs"];

/// Identifiers that construct RNG state from ambient entropy.
pub const RNG_IDENTS: [&str; 5] =
    ["thread_rng", "from_entropy", "OsRng", "RandomState", "getrandom"];

/// Diverging macros covered by `panic-in-library` (when followed by `!`).
pub const PANIC_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];

/// What a file is, which decides the rule set applied to it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileKind {
    /// `rust/src/**` except the CLI entry points: all rules apply.
    Library,
    /// `rust/src/main.rs` / `rust/src/cli.rs`: exempt (a CLI may panic).
    Cli,
    /// `rust/tests/**`: exempt.
    Test,
    /// `rust/benches/**`: exempt (benches legitimately read the clock).
    Bench,
    /// `examples/**`: exempt.
    Example,
}

/// One lint finding at a repo-relative `/`-separated path.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub path: String,
    pub rule: String,
    pub line: usize,
    pub col: usize,
    pub message: String,
}

impl Finding {
    pub(crate) fn new(
        rule: &str,
        line: usize,
        col: usize,
        message: String,
    ) -> Finding {
        Finding { path: String::new(), rule: rule.to_string(), line, col, message }
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}:{}: [{}] {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }
}

/// Classify a repo-relative path into its [`FileKind`] and module (the
/// first path component under `rust/src/`, or `""` for root files).
/// Returns `None` for paths the pass skips entirely (vendored crates,
/// the lint fixture corpus, non-Rust files, everything outside the
/// source roots).
pub fn classify(path: &str) -> Option<(FileKind, String)> {
    let p = path.replace('\\', "/");
    if !p.ends_with(".rs") {
        return None;
    }
    if p.contains("/vendor/")
        || p.starts_with("rust/vendor/")
        || p.contains("lint_fixtures")
    {
        return None;
    }
    if let Some(rest) = p.strip_prefix("rust/src/") {
        if rest == "main.rs" || rest == "cli.rs" {
            return Some((FileKind::Cli, String::new()));
        }
        let module = match rest.find('/') {
            Some(idx) => rest[..idx].to_string(),
            None => String::new(),
        };
        return Some((FileKind::Library, module));
    }
    if p.starts_with("rust/tests/") {
        return Some((FileKind::Test, String::new()));
    }
    if p.starts_with("rust/benches/") {
        return Some((FileKind::Bench, String::new()));
    }
    if p.starts_with("examples/") {
        return Some((FileKind::Example, String::new()));
    }
    None
}

/// Analyze one file's source under its repo-relative path.  Findings
/// come back sorted by (line, col, rule) with `path` filled in.
pub fn analyze_source(path: &str, src: &str) -> Vec<Finding> {
    let (kind, module) = match classify(path) {
        Some(c) => c,
        None => return Vec::new(),
    };
    let (toks, sups) = lexer::lex(src);
    let mask = rules::cfg_test_mask(&toks);
    let mut raw = rules::scan_rules(kind, &module, &toks, &mask);
    let rel = path.replace('\\', "/");
    if WALL_CLOCK_ALLOW_FILES.contains(&rel.as_str()) {
        raw.retain(|f| f.rule != "wall-clock");
    }
    let mut findings = rules::apply_suppressions(raw, &sups);
    for f in &mut findings {
        f.path = path.to_string();
    }
    findings
}

/// Walk the repo tree under `root` (the four source roots, skipping
/// `vendor/` and `lint_fixtures/`) and analyze every `.rs` file.  The
/// walk sorts directory entries so finding order is deterministic.
pub fn run_on_tree(root: &Path) -> anyhow::Result<Vec<Finding>> {
    let mut files: Vec<String> = Vec::new();
    for top in ["rust/src", "rust/benches", "rust/tests", "examples"] {
        let base = root.join(top);
        if base.is_dir() {
            collect_rs(root, &base, &mut files)?;
        }
    }
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading {rel}"))?;
        findings.extend(analyze_source(rel, &src));
    }
    Ok(findings)
}

fn collect_rs(
    root: &Path,
    dir: &Path,
    files: &mut Vec<String>,
) -> anyhow::Result<()> {
    let mut entries: Vec<std::path::PathBuf> = Vec::new();
    let iter = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for entry in iter {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        let name = match path.file_name().and_then(|s| s.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if path.is_dir() {
            if name == "vendor" || name == "lint_fixtures" {
                continue;
            }
            collect_rs(root, &path, files)?;
        } else if name.ends_with(".rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path);
            files.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

/// JSON export of a finding list (the `deluxe lint --json` payload).
pub fn findings_to_json(findings: &[Finding]) -> Json {
    Json::obj(vec![
        ("findings", Json::Arr(
            findings
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("path", Json::Str(f.path.clone())),
                        ("line", Json::Num(f.line as f64)),
                        ("col", Json::Num(f.col as f64)),
                        ("rule", Json::Str(f.rule.clone())),
                        ("message", Json::Str(f.message.clone())),
                    ])
                })
                .collect(),
        )),
        ("count", Json::Num(findings.len() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_kinds() {
        assert_eq!(
            classify("rust/src/admm/core.rs"),
            Some((FileKind::Library, "admm".to_string()))
        );
        assert_eq!(
            classify("rust/src/lib.rs"),
            Some((FileKind::Library, String::new()))
        );
        assert_eq!(
            classify("rust/src/main.rs"),
            Some((FileKind::Cli, String::new()))
        );
        assert_eq!(
            classify("rust/src/cli.rs"),
            Some((FileKind::Cli, String::new()))
        );
        assert_eq!(
            classify("rust/tests/determinism.rs"),
            Some((FileKind::Test, String::new()))
        );
        assert_eq!(
            classify("rust/benches/microbench.rs"),
            Some((FileKind::Bench, String::new()))
        );
        assert_eq!(
            classify("examples/quickstart.rs"),
            Some((FileKind::Example, String::new()))
        );
    }

    #[test]
    fn classify_skips() {
        assert_eq!(classify("rust/vendor/anyhow/src/lib.rs"), None);
        assert_eq!(classify("rust/tests/lint_fixtures/panic.rs"), None);
        assert_eq!(classify("python/export.py"), None);
        assert_eq!(classify("DESIGN.md"), None);
    }

    #[test]
    fn findings_sorted_and_pathed() {
        let src = "pub fn f(m: &std::collections::HashMap<u8, u8>) -> u8 {\n    *m.values().next().unwrap()\n}\n";
        let fs = analyze_source("rust/src/sim/x.rs", src);
        assert_eq!(fs.len(), 2);
        assert_eq!(fs[0].rule, "nondet-iteration");
        assert_eq!(fs[1].rule, "panic-in-library");
        assert!(fs.iter().all(|f| f.path == "rust/src/sim/x.rs"));
        assert!(fs[0].line <= fs[1].line);
    }

    #[test]
    fn json_export_shape() {
        let src = "pub fn f() { let x: Option<u8> = None; x.unwrap(); }\n";
        let fs = analyze_source("rust/src/model/x.rs", src);
        let j = findings_to_json(&fs);
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(1.0));
        let arr = j.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(
            arr[0].get("rule").and_then(Json::as_str),
            Some("panic-in-library")
        );
    }
}
