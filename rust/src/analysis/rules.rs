//! The per-file rule engine: `#[cfg(test)]` masking, the five
//! house-invariant rules, and suppression application.
//!
//! Rules operate on the token stream from [`crate::analysis::lexer`]; no
//! type information exists, so each rule is a conservative syntactic
//! pattern tuned against this crate (see `DESIGN.md` §11 for the
//! catalogue and the reasoning behind each pattern).

use crate::analysis::lexer::{Suppression, TokKind, Token};
use crate::analysis::{
    FileKind, Finding, BAD_SUPPRESSION, PANIC_MACROS, RESTRICTED,
    RNG_IDENTS, RULES, WALL_CLOCK_ALLOW,
};

/// Mark every token covered by a `#[cfg(test)]`-gated item (the
/// attribute itself, any stacked attributes, and the item body through
/// its matching `}` or a top-level `;`).  `#[cfg(not(test))]` and other
/// predicates are left unmasked.
pub fn cfg_test_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let is_attr = toks[i].kind == TokKind::Punct
            && toks[i].text == "#"
            && i + 1 < toks.len()
            && toks[i + 1].text == "[";
        if !is_attr {
            i += 1;
            continue;
        }
        // find the attribute's matching `]`, collecting its idents
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut idents: Vec<&str> = Vec::new();
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct && t.text == "[" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "]" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t.kind == TokKind::Ident {
                idents.push(&t.text);
            }
            j += 1;
        }
        if j >= toks.len() {
            break;
        }
        let gated = idents.iter().any(|s| *s == "cfg")
            && idents.iter().any(|s| *s == "test")
            && !idents.iter().any(|s| *s == "not");
        if !gated {
            i = j + 1;
            continue;
        }
        // skip further stacked attributes
        let mut k = j + 1;
        while k + 1 < toks.len()
            && toks[k].text == "#"
            && toks[k + 1].text == "["
        {
            let mut d2 = 0i32;
            k += 1;
            while k < toks.len() {
                if toks[k].text == "[" {
                    d2 += 1;
                } else if toks[k].text == "]" {
                    d2 -= 1;
                    if d2 == 0 {
                        break;
                    }
                }
                k += 1;
            }
            k += 1;
        }
        // walk to the item's end: first `;` at brace depth 0, or the
        // matching `}` of the first `{`
        let mut bd = 0i32;
        let mut end = k;
        while end < toks.len() {
            let t = &toks[end];
            if t.kind == TokKind::Punct && t.text == "{" {
                bd += 1;
            } else if t.kind == TokKind::Punct && t.text == "}" {
                bd -= 1;
                if bd == 0 {
                    break;
                }
            } else if t.kind == TokKind::Punct && t.text == ";" && bd == 0 {
                break;
            }
            end += 1;
        }
        let stop = (end + 1).min(toks.len());
        for m in mask.iter_mut().take(stop).skip(i) {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Run the five rules over an (unmasked) token stream.
pub fn scan_rules(
    kind: FileKind,
    module: &str,
    toks: &[Token],
    mask: &[bool],
) -> Vec<Finding> {
    let mut out = Vec::new();
    let lib = kind == FileKind::Library;
    let restricted = lib && RESTRICTED.contains(&module);
    let get = |k: usize| toks.get(k);

    for (i, tok) in toks.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let is_dot = tok.kind == TokKind::Punct && tok.text == ".";
        if tok.kind != TokKind::Ident && !is_dot {
            continue;
        }
        // nondet-iteration
        if restricted
            && tok.kind == TokKind::Ident
            && (tok.text == "HashMap" || tok.text == "HashSet")
        {
            out.push(Finding::new(
                "nondet-iteration",
                tok.line,
                tok.col,
                format!(
                    "{} in `{}/` — iteration order is nondeterministic \
                     and feeds trajectories; use BTreeMap or an indexed \
                     Vec",
                    tok.text, module
                ),
            ));
            continue;
        }
        // wall-clock
        if lib
            && !WALL_CLOCK_ALLOW.contains(&module)
            && tok.kind == TokKind::Ident
        {
            if tok.text == "SystemTime" {
                out.push(Finding::new(
                    "wall-clock",
                    tok.line,
                    tok.col,
                    "SystemTime in library code; the sim's integer-µs \
                     virtual clock is the only admissible time source"
                        .to_string(),
                ));
                continue;
            }
            if tok.text == "Instant" {
                if let (Some(a), Some(b), Some(c)) =
                    (get(i + 1), get(i + 2), get(i + 3))
                {
                    if a.text == ":"
                        && b.text == ":"
                        && c.kind == TokKind::Ident
                        && c.text == "now"
                    {
                        out.push(Finding::new(
                            "wall-clock",
                            tok.line,
                            tok.col,
                            "Instant::now in library code; the sim's \
                             integer-µs virtual clock is the only \
                             admissible time source"
                                .to_string(),
                        ));
                        continue;
                    }
                }
            }
        }
        // ambient-rng
        if lib
            && module != "rng"
            && tok.kind == TokKind::Ident
            && RNG_IDENTS.contains(&tok.text.as_str())
        {
            out.push(Finding::new(
                "ambient-rng",
                tok.line,
                tok.col,
                format!(
                    "`{}` constructs RNG state from ambient entropy; all \
                     streams must flow through Pcg64::fork(round, agent)",
                    tok.text
                ),
            ));
            continue;
        }
        // `.method(` patterns: panic-in-library and unaccounted-send
        if lib && is_dot {
            if let (Some(m), Some(p)) = (get(i + 1), get(i + 2)) {
                if m.kind == TokKind::Ident
                    && (m.text == "unwrap" || m.text == "expect")
                    && p.kind == TokKind::Punct
                    && p.text == "("
                {
                    out.push(Finding::new(
                        "panic-in-library",
                        m.line,
                        m.col,
                        format!(
                            "`.{}()` in a library path; propagate with \
                             anyhow::Result instead",
                            m.text
                        ),
                    ));
                }
                if restricted
                    && m.kind == TokKind::Ident
                    && (m.text == "send"
                        || m.text == "try_send"
                        || m.text == "write_all")
                    && p.kind == TokKind::Punct
                    && p.text == "("
                {
                    out.push(Finding::new(
                        "unaccounted-send",
                        m.line,
                        m.col,
                        format!(
                            "raw `.{}()` bypasses WireStats byte \
                             accounting; charge via \
                             LossyLink::transmit_bytes / \
                             ChannelStats::record_reliable or justify",
                            m.text
                        ),
                    ));
                }
                if restricted
                    && m.kind == TokKind::Ident
                    && m.text == "transmit"
                    && p.kind == TokKind::Punct
                    && p.text == "("
                {
                    // scan the balanced argument list for a *bytes* ident
                    let mut depth = 0i32;
                    let mut k = i + 2;
                    let mut has_bytes = false;
                    while k < toks.len() {
                        let tk = &toks[k];
                        if tk.kind == TokKind::Punct && tk.text == "(" {
                            depth += 1;
                        } else if tk.kind == TokKind::Punct && tk.text == ")"
                        {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        } else if tk.kind == TokKind::Ident
                            && (tk.text == "bytes"
                                || tk.text.ends_with("_bytes"))
                        {
                            has_bytes = true;
                        }
                        k += 1;
                    }
                    if !has_bytes {
                        out.push(Finding::new(
                            "unaccounted-send",
                            m.line,
                            m.col,
                            "`.transmit()` without a byte-size argument \
                             charges zero wire bytes; use transmit_bytes \
                             or justify"
                                .to_string(),
                        ));
                    }
                }
            }
            continue;
        }
        // panic!/unreachable!/todo!/unimplemented!
        if lib
            && tok.kind == TokKind::Ident
            && PANIC_MACROS.contains(&tok.text.as_str())
        {
            if let Some(nxt) = get(i + 1) {
                if nxt.kind == TokKind::Punct && nxt.text == "!" {
                    out.push(Finding::new(
                        "panic-in-library",
                        tok.line,
                        tok.col,
                        format!(
                            "`{}!` in a library path; propagate with \
                             anyhow::Result instead",
                            tok.text
                        ),
                    ));
                }
            }
            continue;
        }
    }
    out
}

/// Drop findings covered by a well-formed suppression on the same line
/// (trailing) or the line above (standalone), then append
/// `bad-suppression` findings for malformed directives and unknown rule
/// names.  `bad-suppression` itself cannot be suppressed.
pub fn apply_suppressions(
    raw: Vec<Finding>,
    sups: &[Suppression],
) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    for f in raw {
        let covered = sups.iter().any(|s| {
            s.malformed.is_none()
                && ((s.trailing && s.line == f.line)
                    || (!s.trailing && s.line + 1 == f.line))
                && s.rules.iter().any(|r| r == &f.rule)
        });
        if !covered {
            out.push(f);
        }
    }
    for s in sups {
        if let Some(msg) = &s.malformed {
            out.push(Finding::new(BAD_SUPPRESSION, s.line, s.col, msg.clone()));
        } else {
            for r in &s.rules {
                if !RULES.contains(&r.as_str()) {
                    out.push(Finding::new(
                        BAD_SUPPRESSION,
                        s.line,
                        s.col,
                        format!("suppression names unknown rule `{r}`"),
                    ));
                }
            }
        }
    }
    out.sort_by(|a, b| {
        (a.line, a.col, &a.rule).cmp(&(b.line, b.col, &b.rule))
    });
    out
}
