//! Hand-rolled Rust lexer for the lint pass.
//!
//! The offline environment has no crates.io access, so there is no `syn`
//! to lean on; the rules only need a token stream with line/column
//! positions plus the set of suppression comments, and that much of Rust
//! lexes with ~200 lines: line/block comments (nested), strings with
//! escapes (including backslash-newline continuations, which still count
//! their newline), raw/byte strings, char-vs-lifetime disambiguation,
//! numbers, identifiers (incl. `r#raw`), and single-character punctuation.
//! Literal *contents* are deliberately dropped (`text` is empty for
//! strings) so rule keywords inside messages never trigger findings.

/// Lexical class of a [`Token`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Lit,
    Lifetime,
}

/// One lexed token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
    pub col: usize,
}

/// A parsed suppression comment.
///
/// `trailing` marks a comment that shares its line with code (it then
/// covers that same line); a standalone comment covers the next line
/// only.  `malformed` carries the diagnostic for syntactically broken
/// directives, which become `bad-suppression` findings downstream.
#[derive(Clone, Debug)]
pub struct Suppression {
    pub line: usize,
    pub col: usize,
    pub trailing: bool,
    pub rules: Vec<String>,
    pub malformed: Option<String>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Parse a line comment (text includes the leading `//`) into a
/// [`Suppression`] if it carries a `lint:` directive.  Doc comments
/// (`///`, `//!`) are never directives.
pub fn parse_suppression(
    text: &str,
    line: usize,
    col: usize,
    trailing: bool,
) -> Option<Suppression> {
    let body = &text[2..];
    if body.starts_with('/') || body.starts_with('!') {
        return None;
    }
    let body = body.trim();
    if !body.starts_with("lint:") {
        return None;
    }
    let broken = |rules: Vec<String>, msg: &str| {
        Some(Suppression {
            line,
            col,
            trailing,
            rules,
            malformed: Some(msg.to_string()),
        })
    };
    if !body.starts_with("lint:allow") {
        return broken(
            Vec::new(),
            "unknown lint directive; expected lint:allow(<rule>): \
             <justification>",
        );
    }
    let rest = &body["lint:allow".len()..];
    if !rest.starts_with('(') {
        return broken(
            Vec::new(),
            "malformed suppression; expected lint:allow(<rule>): \
             <justification>",
        );
    }
    let close = match rest.find(')') {
        Some(c) => c,
        None => {
            return broken(
                Vec::new(),
                "malformed suppression; expected lint:allow(<rule>): \
                 <justification>",
            )
        }
    };
    let rules: Vec<String> =
        rest[1..close].split(',').map(|r| r.trim().to_string()).collect();
    if rules.iter().any(String::is_empty) {
        return broken(Vec::new(), "empty rule name in suppression");
    }
    let tail = rest[close + 1..].trim_start();
    if !tail.starts_with(':') || tail[1..].trim().is_empty() {
        return broken(
            rules,
            "suppression is missing its mandatory justification \
             (lint:allow(<rule>): <justification>)",
        );
    }
    Some(Suppression { line, col, trailing, rules, malformed: None })
}

/// Column one past a just-consumed span that may contain newlines.
fn col_after_span(span: &[char], start_col: usize) -> usize {
    match span.iter().rposition(|&ch| ch == '\n') {
        Some(idx) => span.len() - idx,
        None => start_col + span.len(),
    }
}

/// Lex `src` into tokens plus the suppression comments encountered.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Suppression>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut col = 1usize;
    let mut toks: Vec<Token> = Vec::new();
    let mut sups: Vec<Suppression> = Vec::new();
    let peek = |k: usize| if k < n { chars[k] } else { '\0' };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            col = 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            col += 1;
            continue;
        }
        // line comment (the only place suppressions live)
        if c == '/' && peek(i + 1) == '/' {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            let trailing = matches!(toks.last(), Some(t) if t.line == line);
            if let Some(s) = parse_suppression(&text, line, col, trailing) {
                sups.push(s);
            }
            col += j - i;
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && peek(i + 1) == '*' {
            let mut depth = 1i32;
            i += 2;
            col += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && peek(i + 1) == '*' {
                    depth += 1;
                    i += 2;
                    col += 2;
                } else if chars[i] == '*' && peek(i + 1) == '/' {
                    depth -= 1;
                    i += 2;
                    col += 2;
                } else if chars[i] == '\n' {
                    line += 1;
                    col = 1;
                    i += 1;
                } else {
                    i += 1;
                    col += 1;
                }
            }
            continue;
        }
        // raw strings / byte strings / raw identifiers
        if c == 'r' || c == 'b' {
            // raw identifier r#name
            if c == 'r' && peek(i + 1) == '#' && is_ident_start(peek(i + 2)) {
                let start_col = col;
                i += 2;
                col += 2;
                let mut j = i;
                while j < n && is_ident_char(chars[j]) {
                    j += 1;
                }
                toks.push(Token {
                    kind: TokKind::Ident,
                    text: chars[i..j].iter().collect(),
                    line,
                    col: start_col,
                });
                col += j - i;
                i = j;
                continue;
            }
            let raw_str = (c == 'r'
                && (peek(i + 1) == '"' || peek(i + 1) == '#'))
                || (c == 'b'
                    && peek(i + 1) == 'r'
                    && (peek(i + 2) == '"' || peek(i + 2) == '#'));
            if raw_str {
                let start_col = col;
                let mut p = i + if c == 'b' { 2 } else { 1 };
                let mut nh = 0usize;
                while peek(p) == '#' {
                    nh += 1;
                    p += 1;
                }
                if peek(p) == '"' {
                    p += 1;
                    while p < n {
                        if chars[p] == '"'
                            && p + 1 + nh <= n
                            && chars[p + 1..p + 1 + nh]
                                .iter()
                                .all(|&h| h == '#')
                        {
                            p += 1 + nh;
                            break;
                        }
                        if chars[p] == '\n' {
                            line += 1;
                        }
                        p += 1;
                    }
                    col = col_after_span(&chars[i..p], start_col);
                    toks.push(Token {
                        kind: TokKind::Lit,
                        text: String::new(),
                        line,
                        col: start_col,
                    });
                    i = p;
                    continue;
                }
                // not actually a raw string: fall through to ident
            }
            // byte string b"..."
            if c == 'b' && peek(i + 1) == '"' {
                let start_col = col;
                let mut p = i + 2;
                while p < n {
                    if chars[p] == '\\' {
                        if peek(p + 1) == '\n' {
                            line += 1;
                        }
                        p += 2;
                        continue;
                    }
                    if chars[p] == '"' {
                        p += 1;
                        break;
                    }
                    if chars[p] == '\n' {
                        line += 1;
                    }
                    p += 1;
                }
                col = col_after_span(&chars[i..p.min(n)], start_col);
                toks.push(Token {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    col: start_col,
                });
                i = p;
                continue;
            }
            // byte char literal b'x' / b'\n'
            if c == 'b' && peek(i + 1) == '\'' {
                let start_col = col;
                let mut p = i + 2;
                if peek(p) == '\\' {
                    p += 2;
                } else {
                    p += 1;
                }
                if peek(p) == '\'' {
                    p += 1;
                }
                toks.push(Token {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    col: start_col,
                });
                col += p - i;
                i = p;
                continue;
            }
            // plain identifier starting with r/b: fall through
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_char(chars[j]) {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Ident,
                text: chars[i..j].iter().collect(),
                line,
                col,
            });
            col += j - i;
            i = j;
            continue;
        }
        // string literal (escapes may hide quotes and span lines)
        if c == '"' {
            let start_col = col;
            let mut p = i + 1;
            while p < n {
                if chars[p] == '\\' {
                    // a backslash-newline continuation still advances the
                    // line counter even though the newline is "escaped"
                    if peek(p + 1) == '\n' {
                        line += 1;
                    }
                    p += 2;
                    continue;
                }
                if chars[p] == '"' {
                    p += 1;
                    break;
                }
                if chars[p] == '\n' {
                    line += 1;
                }
                p += 1;
            }
            col = col_after_span(&chars[i..p.min(n)], start_col);
            toks.push(Token {
                kind: TokKind::Lit,
                text: String::new(),
                line,
                col: start_col,
            });
            i = p;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let n1 = peek(i + 1);
            let n2 = peek(i + 2);
            if n1 == '\\' {
                // escaped char literal: scan to the closing quote
                let start_col = col;
                let mut p = i + 2;
                if p < n {
                    p += 1;
                }
                while p < n && chars[p] != '\'' {
                    p += 1;
                }
                p += 1;
                toks.push(Token {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    col: start_col,
                });
                col += p - i;
                i = p;
                continue;
            }
            if n2 == '\'' && n1 != '\0' {
                toks.push(Token {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    col,
                });
                col += 3;
                i += 3;
                continue;
            }
            // lifetime
            let start_col = col;
            let mut j = i + 1;
            while j < n && is_ident_char(chars[j]) {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Lifetime,
                text: chars[i + 1..j].iter().collect(),
                line,
                col: start_col,
            });
            col += j - i;
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let start_col = col;
            let mut j = i;
            loop {
                if j >= n {
                    break;
                }
                let cj = chars[j];
                let cont = is_ident_char(cj)
                    || (cj == '.'
                        && j + 1 < n
                        && chars[j + 1].is_ascii_digit()
                        && !(j > i && chars[j - 1] == '.'));
                if !cont {
                    break;
                }
                if cj == '.' {
                    j += 1;
                }
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Lit,
                text: chars[i..j].iter().collect(),
                line,
                col: start_col,
            });
            col += j - i;
            i = j;
            continue;
        }
        toks.push(Token {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
            col,
        });
        col += 1;
        i += 1;
    }
    (toks, sups)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_hide_their_contents() {
        let toks = lex("let s = \"HashMap panic! unwrap\";").0;
        assert_eq!(idents("let s = \"HashMap panic! unwrap\";"), ["let", "s"]);
        assert!(toks
            .iter()
            .any(|t| t.kind == TokKind::Lit && t.text.is_empty()));
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        assert_eq!(idents(r#"let s = "a\"HashMap"; x"#), ["let", "s", "x"]);
    }

    #[test]
    fn backslash_newline_continuation_counts_its_line() {
        let src = "let s = \"a\\\n   b\";\nfoo();";
        let toks = lex(src).0;
        let foo = toks.iter().find(|t| t.text == "foo").unwrap();
        assert_eq!(foo.line, 3);
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        assert_eq!(idents(r##"let x = r#"HashMap"#; y"##), ["let", "x", "y"]);
        assert_eq!(idents("let r#type = 1;"), ["let", "type"]);
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = lex("let c = 'x'; fn f<'a>(v: &'a str) {}").0;
        let lits: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Lit).collect();
        assert_eq!(lits.len(), 1);
        let lifes: Vec<_> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lifes, ["a", "a"]);
    }

    #[test]
    fn nested_block_comments() {
        assert_eq!(idents("/* a /* b */ HashMap */ x"), ["x"]);
    }

    #[test]
    fn line_and_col_positions() {
        let toks = lex("ab cd\n  ef").0;
        assert_eq!(
            toks.iter()
                .map(|t| (t.text.as_str(), t.line, t.col))
                .collect::<Vec<_>>(),
            [("ab", 1, 1), ("cd", 1, 4), ("ef", 2, 3)]
        );
    }

    #[test]
    fn suppression_trailing_vs_standalone() {
        let src = "\
let a = 1; // lint:allow(wall-clock): trailing covers this line
// lint:allow(ambient-rng): standalone covers the next line
let b = 2;
";
        let sups = lex(src).1;
        assert_eq!(sups.len(), 2);
        assert!(sups[0].trailing);
        assert_eq!(sups[0].rules, ["wall-clock"]);
        assert!(!sups[1].trailing);
        assert_eq!(sups[1].rules, ["ambient-rng"]);
        assert!(sups.iter().all(|s| s.malformed.is_none()));
    }

    #[test]
    fn suppression_requires_justification() {
        let s = parse_suppression("// lint:allow(wall-clock)", 1, 1, false)
            .unwrap();
        assert!(s.malformed.is_some());
        let s2 =
            parse_suppression("// lint:allow(wall-clock):   ", 1, 1, false)
                .unwrap();
        assert!(s2.malformed.is_some());
    }

    #[test]
    fn doc_comments_are_not_directives() {
        assert!(parse_suppression("/// lint:allow(x): y", 1, 1, false)
            .is_none());
        assert!(parse_suppression("//! lint:allow(x): y", 1, 1, false)
            .is_none());
    }

    #[test]
    fn multi_rule_suppression_parses() {
        let s = parse_suppression(
            "// lint:allow(wall-clock, ambient-rng): both justified here",
            4,
            9,
            true,
        )
        .unwrap();
        assert_eq!(s.rules, ["wall-clock", "ambient-rng"]);
        assert!(s.malformed.is_none());
        assert_eq!((s.line, s.col, s.trailing), (4, 9, true));
    }
}
