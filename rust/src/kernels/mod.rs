//! SIMD-friendly microkernels + per-worker scratch arenas (DESIGN.md §15).
//!
//! The solve phase is dominated by small dense GEMM-shaped loops: the
//! MLP forward/backward in [`crate::model`], the prox/corrected SGD
//! parameter updates, and the f64 Gram/matmul/matvec paths in
//! [`crate::linalg`] behind [`crate::solver::ExactQuadratic`].  This
//! module centralizes those inner loops as blocked,
//! autovectorization-friendly kernels (`chunks_exact` bodies with
//! fixed-width accumulators — no intrinsics, no `unsafe`) plus the
//! [`Scratch`] arena that makes the hot path allocation-free after
//! warmup (pinned by `rust/tests/alloc.rs`).
//!
//! # Accumulation-order contract
//!
//! Every kernel computes each output element as **exactly one** of:
//!
//! * an *axpy-style fold*: `out[j] (+)= Σ_k a_k · b_{k,j}` accumulated
//!   in strictly ascending `k`, one accumulator per element
//!   ([`axpy`], [`layer_forward`], [`accum_outer`], [`gemm_acc_f64`],
//!   [`syrk_upper_acc_f64`]);
//! * a *dot-style fold*: `out = Σ_j a_j · b_j` accumulated in strictly
//!   ascending `j`, one scalar accumulator ([`backprop_dot`],
//!   [`mat_vec_f64`]).
//!
//! Lane-blocking is only ever applied across **independent output
//! elements** (the `chunks_exact` width in axpy kernels, the [`KB`]
//! register block in dot kernels), never across the reduction index —
//! so no per-element sum is reassociated and every kernel is
//! **bit-identical** to its naive [`reference`] twin and to the scalar
//! loops it replaced.  That is what lets PR 10 rewire the solve phase
//! without re-pinning any golden trajectory: the house invariant
//! (bit-identical across `--workers` and transports) holds with kernels
//! on because the kernels are value-preserving, not just
//! tolerance-close.
//!
//! The one deliberate value-affecting change lives in
//! [`crate::linalg`]: `Matrix::matmul`/`gram` used to skip exactly-zero
//! multiplicands; the kernels include those terms (adding `±0.0`,
//! which can only flip a `-0.0` sum to `+0.0` or surface a `NaN` from
//! `0 · ∞` — neither occurs for the finite data these paths carry).

/// f32 lane width the axpy-style kernels block by (AVX2-sized; the
/// compiler narrows transparently on smaller ISAs).
pub const LANES: usize = 8;

/// Row-block size for batched layer kernels — streams each weight
/// matrix once per `RB` batch rows instead of once per row.
pub const RB: usize = 8;

/// Register block for dot-style kernels: [`KB`] independent
/// accumulators over [`KB`] *output* elements (the reduction order of
/// each element is untouched).
pub const KB: usize = 4;

// ---------------------------------------------------------------------------
// Elementwise f32 kernels
// ---------------------------------------------------------------------------

/// `y[i] += a * x[i]` — the axpy fold step shared by every f32 GEMM
/// kernel here.  Blocked by [`LANES`]; per-element order unchanged.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let head = y.len() - y.len() % LANES;
    let (yh, yt) = y.split_at_mut(head);
    let (xh, xt) = x.split_at(head);
    for (yc, xc) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            yc[i] += a * xc[i];
        }
    }
    for (yv, &xv) in yt.iter_mut().zip(xt) {
        *yv += a * xv;
    }
}

/// `y[i] += x[i]`.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let head = y.len() - y.len() % LANES;
    let (yh, yt) = y.split_at_mut(head);
    let (xh, xt) = x.split_at(head);
    for (yc, xc) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            yc[i] += xc[i];
        }
    }
    for (yv, &xv) in yt.iter_mut().zip(xt) {
        *yv += xv;
    }
}

/// In-place ReLU (`v < 0 → 0`; `-0.0` passes, matching the model's
/// historical strict `< 0.0` comparison).
#[inline]
pub fn relu(v: &mut [f32]) {
    for o in v {
        if *o < 0.0 {
            *o = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// Batched layer kernels (f32 GEMM shapes of the MLP)
// ---------------------------------------------------------------------------

/// One dense layer forward over a batch: `out[r,·] = bias + inp[r,·] W`
/// (`W` row-major `din x dout`), optional fused ReLU.  Row-blocked by
/// [`RB`] with a k-outer axpy inner loop — each `out[r,j]` is a
/// k-ascending fold seeded with `bias[j]`.
pub fn layer_forward(
    inp: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    fuse_relu: bool,
) {
    debug_assert_eq!(inp.len(), n * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(bias.len(), dout);
    debug_assert_eq!(out.len(), n * dout);
    let mut rb = 0;
    while rb < n {
        let rend = (rb + RB).min(n);
        for r in rb..rend {
            out[r * dout..(r + 1) * dout].copy_from_slice(bias);
        }
        for k in 0..din {
            let wrow = &w[k * dout..(k + 1) * dout];
            for r in rb..rend {
                // no zero-skip: the branch mispredicts on ~50%-zero ReLU
                // activations and blocks vectorization (§Perf)
                axpy(&mut out[r * dout..(r + 1) * dout], inp[r * din + k], wrow);
            }
        }
        if fuse_relu {
            relu(&mut out[rb * dout..rend * dout]);
        }
        rb = rend;
    }
}

/// Weight-gradient accumulation `gw += inpᵀ delta` (`gw` row-major
/// `din x dout`).  Row-blocked; each `gw[k,j]` accumulates in strictly
/// ascending batch-row order.
pub fn accum_outer(
    inp: &[f32],
    delta: &[f32],
    gw: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(inp.len(), n * din);
    debug_assert_eq!(delta.len(), n * dout);
    debug_assert_eq!(gw.len(), din * dout);
    let mut rb = 0;
    while rb < n {
        let rend = (rb + RB).min(n);
        for k in 0..din {
            let grow = &mut gw[k * dout..(k + 1) * dout];
            for r in rb..rend {
                axpy(grow, inp[r * din + k], &delta[r * dout..(r + 1) * dout]);
            }
        }
        rb = rend;
    }
}

/// Bias-gradient accumulation `gb[j] += Σ_r delta[r,j]` in ascending
/// `r`.
pub fn accum_bias(delta: &[f32], gb: &mut [f32], n: usize, dout: usize) {
    debug_assert_eq!(delta.len(), n * dout);
    debug_assert_eq!(gb.len(), dout);
    for r in 0..n {
        add_assign(gb, &delta[r * dout..(r + 1) * dout]);
    }
}

/// Input-gradient `dinp[r,k] = Σ_j delta[r,j] W[k,j]` — a j-ascending
/// dot per element, register-blocked by [`KB`] across the independent
/// `k` outputs ([`KB`] separate accumulators, reduction order of each
/// untouched).
pub fn backprop_dot(
    w: &[f32],
    delta: &[f32],
    dinp: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(delta.len(), n * dout);
    debug_assert_eq!(dinp.len(), n * din);
    for r in 0..n {
        let drow = &delta[r * dout..(r + 1) * dout];
        let irow = &mut dinp[r * din..(r + 1) * din];
        let mut k = 0;
        while k + KB <= din {
            let w0 = &w[k * dout..(k + 1) * dout];
            let w1 = &w[(k + 1) * dout..(k + 2) * dout];
            let w2 = &w[(k + 2) * dout..(k + 3) * dout];
            let w3 = &w[(k + 3) * dout..(k + 4) * dout];
            let (mut a0, mut a1, mut a2, mut a3) =
                (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (j, &dv) in drow.iter().enumerate() {
                a0 += w0[j] * dv;
                a1 += w1[j] * dv;
                a2 += w2[j] * dv;
                a3 += w3[j] * dv;
            }
            irow[k] = a0;
            irow[k + 1] = a1;
            irow[k + 2] = a2;
            irow[k + 3] = a3;
            k += KB;
        }
        while k < din {
            let wrow = &w[k * dout..(k + 1) * dout];
            let mut acc = 0.0f32;
            for (wv, dv) in wrow.iter().zip(drow) {
                acc += wv * dv;
            }
            irow[k] = acc;
            k += 1;
        }
    }
}

/// ReLU backward mask: zero `dinp[i]` where the forward activation was
/// clamped (`acts[i] <= 0`, the model's historical comparison).
#[inline]
pub fn relu_mask(dinp: &mut [f32], acts: &[f32]) {
    debug_assert_eq!(dinp.len(), acts.len());
    for (iv, &av) in dinp.iter_mut().zip(acts) {
        if av <= 0.0 {
            *iv = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// Fused SGD update kernels
// ---------------------------------------------------------------------------

/// Prox-SGD step `p -= lr (g + ρ (p - (ẑ - u)))` — the `local_admm`
/// inner update, expression order identical to the historical scalar
/// loop.
pub fn sgd_prox_step(
    p: &mut [f32],
    g: &[f32],
    zhat: &[f32],
    u: &[f32],
    lr: f32,
    rho: f32,
) {
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(p.len(), zhat.len());
    debug_assert_eq!(p.len(), u.len());
    for i in 0..p.len() {
        let anchor = zhat[i] - u[i];
        p[i] -= lr * (g[i] + rho * (p[i] - anchor));
    }
}

/// [`sgd_prox_step`] with a pre-combined anchor (`anchor = ẑ - u`).
/// Bit-identical to passing `(zhat = anchor, u = 0)`: IEEE subtraction
/// of `+0.0` is the identity for every `f32` value including `-0.0`.
pub fn sgd_prox_step_anchor(
    p: &mut [f32],
    g: &[f32],
    anchor: &[f32],
    lr: f32,
    rho: f32,
) {
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(p.len(), anchor.len());
    for i in 0..p.len() {
        p[i] -= lr * (g[i] + rho * (p[i] - anchor[i]));
    }
}

/// Corrected-SGD step `p -= lr (g + corr)` — the `local_scaffold` inner
/// update.
pub fn sgd_corr_step(p: &mut [f32], g: &[f32], corr: &[f32], lr: f32) {
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(p.len(), corr.len());
    for i in 0..p.len() {
        p[i] -= lr * (g[i] + corr[i]);
    }
}

// ---------------------------------------------------------------------------
// f64 kernels (the linalg substrate routes through these)
// ---------------------------------------------------------------------------

/// `y[i] += a * x[i]` (f64).
#[inline]
pub fn axpy_f64(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    let head = y.len() - y.len() % LANES;
    let (yh, yt) = y.split_at_mut(head);
    let (xh, xt) = x.split_at(head);
    for (yc, xc) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            yc[i] += a * xc[i];
        }
    }
    for (yv, &xv) in yt.iter_mut().zip(xt) {
        *yv += a * xv;
    }
}

/// Accumulating row-major GEMM `c += a b` (`a: m x k`, `b: k x n`),
/// ikj order with an axpy inner loop — no zero-skip (see the module
/// docs on the `±0.0` semantics).
pub fn gemm_acc_f64(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            axpy_f64(crow, a[i * k + kk], &b[kk * n..(kk + 1) * n]);
        }
    }
}

/// Rank-1 symmetric update on the **upper triangle** of row-major
/// `g: n x n`: `g[a, b] += row[a] row[b]` for `b >= a`.  Each element
/// accumulates in the caller's data-row order (ascending, one call per
/// data row).
pub fn syrk_upper_acc_f64(row: &[f64], g: &mut [f64], n: usize) {
    debug_assert_eq!(row.len(), n);
    debug_assert_eq!(g.len(), n * n);
    for a in 0..n {
        let ra = row[a];
        axpy_f64(&mut g[a * n + a..a * n + n], ra, &row[a..n]);
    }
}

/// `y[i] = Σ_j a[i,j] x[j]` for row-major `a: rows x cols` — a
/// j-ascending dot per output row, register-blocked by [`KB`] across
/// independent rows.
pub fn mat_vec_f64(a: &[f64], x: &[f64], y: &mut [f64], rows: usize, cols: usize) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(y.len(), rows);
    let mut i = 0;
    while i + KB <= rows {
        let r0 = &a[i * cols..(i + 1) * cols];
        let r1 = &a[(i + 1) * cols..(i + 2) * cols];
        let r2 = &a[(i + 2) * cols..(i + 3) * cols];
        let r3 = &a[(i + 3) * cols..(i + 4) * cols];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for (j, &xv) in x.iter().enumerate() {
            a0 += r0[j] * xv;
            a1 += r1[j] * xv;
            a2 += r2[j] * xv;
            a3 += r3[j] * xv;
        }
        y[i] = a0;
        y[i + 1] = a1;
        y[i + 2] = a2;
        y[i + 3] = a3;
        i += KB;
    }
    while i < rows {
        let row = &a[i * cols..(i + 1) * cols];
        let mut acc = 0.0f64;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y[i] = acc;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Per-worker scratch arena for the solve phase.
///
/// Ownership contract (DESIGN.md §15): **one `Scratch` per worker**, or
/// one per endpoint for the sequential coordinator path — it is plain
/// `Send` data, never shared between concurrent solves.  Every buffer
/// is reused via `clear()` + `extend`/`resize`, so after one warmup
/// round of a fixed-shape workload no call through the arena allocates
/// (asserted by the counting allocator in `rust/tests/alloc.rs`).
/// Holders keep their arena across rounds; the model entry points
/// (`MlpSpec::*_into`) size whatever they need on the way in, so a
/// fresh `Scratch::new()` is always valid input — just not
/// allocation-free on first use.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    /// Post-activation output per layer (`acts[li]` = layer `li`'s
    /// output, `n x layers[li + 1]`; the input batch is *not* copied).
    pub acts: Vec<Vec<f32>>,
    /// Backprop delta ping-pong buffers.
    pub delta: Vec<f32>,
    pub delta2: Vec<f32>,
    /// Flat gradient accumulator (`MlpSpec::loss_grad_into` output).
    pub grad: Vec<f32>,
    /// Parameter work vector for the SGD loops.
    pub params: Vec<f32>,
    /// `(w_offset, b_offset, din, dout)` per layer — the arena-resident
    /// twin of `MlpSpec::layer_offsets`.
    pub offs: Vec<(usize, usize, usize, usize)>,
    /// Stacked minibatch arenas: the whole shard-chunk's `[agents*S*B, D]`
    /// features / `[agents*S*B, C]` one-hot labels for one round.
    pub bx: Vec<f32>,
    pub by: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch::default()
    }
}

// ---------------------------------------------------------------------------
// Naive references (the bit-exactness oracle for tests/benches)
// ---------------------------------------------------------------------------

/// Unblocked scalar twins of every kernel, written as the plainest
/// possible loops in the *same documented accumulation order*.  The
/// kernel proptests assert `kernel(x) == reference(x)` **bit-exactly**;
/// the microbench's `kernel=reference` cases run these to quantify what
/// the blocking buys.
pub mod reference {
    /// Scalar twin of [`super::layer_forward`].
    pub fn layer_forward(
        inp: &[f32],
        w: &[f32],
        bias: &[f32],
        out: &mut [f32],
        n: usize,
        din: usize,
        dout: usize,
        fuse_relu: bool,
    ) {
        for r in 0..n {
            for j in 0..dout {
                out[r * dout + j] = bias[j];
            }
            for k in 0..din {
                let xv = inp[r * din + k];
                for j in 0..dout {
                    out[r * dout + j] += xv * w[k * dout + j];
                }
            }
            if fuse_relu {
                for j in 0..dout {
                    if out[r * dout + j] < 0.0 {
                        out[r * dout + j] = 0.0;
                    }
                }
            }
        }
    }

    /// Scalar twin of [`super::accum_outer`].
    pub fn accum_outer(
        inp: &[f32],
        delta: &[f32],
        gw: &mut [f32],
        n: usize,
        din: usize,
        dout: usize,
    ) {
        for k in 0..din {
            for j in 0..dout {
                for r in 0..n {
                    gw[k * dout + j] += inp[r * din + k] * delta[r * dout + j];
                }
            }
        }
    }

    /// Scalar twin of [`super::backprop_dot`].
    pub fn backprop_dot(
        w: &[f32],
        delta: &[f32],
        dinp: &mut [f32],
        n: usize,
        din: usize,
        dout: usize,
    ) {
        for r in 0..n {
            for k in 0..din {
                let mut acc = 0.0f32;
                for j in 0..dout {
                    acc += w[k * dout + j] * delta[r * dout + j];
                }
                dinp[r * din + k] = acc;
            }
        }
    }

    /// Scalar twin of [`super::gemm_acc_f64`].
    pub fn gemm_acc_f64(
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
    }

    /// Scalar twin of [`super::mat_vec_f64`].
    pub fn mat_vec_f64(
        a: &[f64],
        x: &[f64],
        y: &mut [f64],
        rows: usize,
        cols: usize,
    ) {
        for i in 0..rows {
            let mut acc = 0.0f64;
            for j in 0..cols {
                acc += a[i * cols + j] * x[j];
            }
            y[i] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn randv(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| rng.f32n()).collect()
    }

    #[test]
    fn axpy_matches_scalar_bitwise() {
        let mut rng = Pcg64::seed(1);
        for n in [0, 1, 7, 8, 9, 31, 64] {
            let x = randv(n, &mut rng);
            let y0 = randv(n, &mut rng);
            let a = rng.f32n();
            let mut y = y0.clone();
            axpy(&mut y, a, &x);
            let want: Vec<f32> =
                y0.iter().zip(&x).map(|(&yv, &xv)| yv + a * xv).collect();
            assert_eq!(y, want, "n = {n}");
        }
    }

    #[test]
    fn layer_forward_matches_reference_bitwise() {
        let mut rng = Pcg64::seed(2);
        for (n, din, dout) in [(1, 3, 5), (8, 8, 16), (13, 17, 9)] {
            let inp = randv(n * din, &mut rng);
            let w = randv(din * dout, &mut rng);
            let b = randv(dout, &mut rng);
            for fuse_relu in [false, true] {
                let mut out = vec![0.0f32; n * dout];
                let mut want = vec![0.0f32; n * dout];
                layer_forward(&inp, &w, &b, &mut out, n, din, dout, fuse_relu);
                reference::layer_forward(
                    &inp, &w, &b, &mut want, n, din, dout, fuse_relu,
                );
                assert_eq!(out, want, "n={n} din={din} dout={dout}");
            }
        }
    }

    #[test]
    fn backprop_dot_matches_reference_bitwise() {
        let mut rng = Pcg64::seed(3);
        for (n, din, dout) in [(2, 4, 4), (5, 9, 7), (8, 16, 4)] {
            let w = randv(din * dout, &mut rng);
            let delta = randv(n * dout, &mut rng);
            let mut got = vec![0.0f32; n * din];
            let mut want = vec![0.0f32; n * din];
            backprop_dot(&w, &delta, &mut got, n, din, dout);
            reference::backprop_dot(&w, &delta, &mut want, n, din, dout);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn mat_vec_f64_matches_reference_bitwise() {
        let mut rng = Pcg64::seed(4);
        for (rows, cols) in [(1, 1), (4, 7), (9, 5), (16, 16)] {
            let a: Vec<f64> = (0..rows * cols).map(|_| rng.normal()).collect();
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let mut got = vec![0.0f64; rows];
            let mut want = vec![0.0f64; rows];
            mat_vec_f64(&a, &x, &mut got, rows, cols);
            reference::mat_vec_f64(&a, &x, &mut want, rows, cols);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn gemm_acc_f64_matches_reference_bitwise() {
        let mut rng = Pcg64::seed(5);
        let (m, k, n) = (5, 7, 6);
        let mut a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        a[3] = 0.0; // exercise the no-zero-skip path
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let mut got = vec![0.0f64; m * n];
        let mut want = vec![0.0f64; m * n];
        gemm_acc_f64(&a, &b, &mut got, m, k, n);
        reference::gemm_acc_f64(&a, &b, &mut want, m, k, n);
        assert_eq!(got, want);
    }

    #[test]
    fn prox_anchor_equals_zhat_minus_zero_u() {
        let mut rng = Pcg64::seed(6);
        let n = 33;
        let p0 = randv(n, &mut rng);
        let g = randv(n, &mut rng);
        let mut anchor = randv(n, &mut rng);
        anchor[0] = -0.0; // the -0.0 edge the doc comment claims is safe
        let u = vec![0.0f32; n];
        let mut a = p0.clone();
        let mut b = p0.clone();
        sgd_prox_step(&mut a, &g, &anchor, &u, 0.1, 0.7);
        sgd_prox_step_anchor(&mut b, &g, &anchor, 0.1, 0.7);
        assert_eq!(a, b);
    }

    #[test]
    fn relu_mask_zeroes_clamped_lanes() {
        let mut d = vec![1.0f32, 2.0, 3.0, 4.0];
        let acts = vec![0.5f32, 0.0, -1.0, 2.0];
        relu_mask(&mut d, &acts);
        assert_eq!(d, vec![1.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn scratch_buffers_keep_capacity_across_reuse() {
        let mut s = Scratch::new();
        s.grad.resize(128, 0.0);
        let cap = s.grad.capacity();
        s.grad.clear();
        s.grad.resize(128, 0.0);
        assert_eq!(s.grad.capacity(), cap);
    }
}
