//! The sharing problem (App. A.1):
//!
//! ```text
//! min Σ_i f_i(x_i) + g(Σ_i x_i)
//! ```
//!
//! arising from (4) with `A = I`, `B = −(I, …, I)`, `c = 0`.  Updates
//! (Eqs. 5–6): each agent proxes its own `x_i` against the shared signal
//! `ĥ`; the server averages the (event-communicated) local variables,
//! proxes `g`, updates the dual and broadcasts `h = x̄ − z + u/ρ`
//! event-wise.

use super::core::{self, EventLine, RoundCore};
use crate::comm::{Estimate, Trigger};
use crate::rng::Pcg64;
use crate::solver::LocalSolver;
use crate::wire::{CompressorCfg, WireStats};

/// The coupling function `g` applied to the *sum* `y = Σ_i x_i = N z`.
#[derive(Clone, Copy, Debug)]
pub enum SharingG {
    /// `g = 0` — uncoupled.
    Zero,
    /// `g(y) = (γ/2)|y|²` — quadratic price on aggregate usage.
    Quad { gamma: f64 },
    /// `g(y) = λ|y|₁` — sparse aggregate.
    L1 { lambda: f64 },
}

impl SharingG {
    /// `z = argmin_z g(Nz) + (Nρ/2)|z − v|²`.
    fn prox(&self, v: &[f64], n: usize, rho: f64) -> Vec<f64> {
        match *self {
            SharingG::Zero => v.to_vec(),
            SharingG::Quad { gamma } => {
                // γN²z + Nρ(z − v) = 0  →  z = ρ v / (γ N + ρ)
                let scale = rho / (gamma * n as f64 + rho);
                v.iter().map(|x| x * scale).collect()
            }
            SharingG::L1 { lambda } => {
                // λN|z|₁ + (Nρ/2)|z − v|² → z = S_{λ/ρ}(v)
                crate::linalg::soft_threshold(v, lambda / rho)
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct SharingConfig {
    pub rho: f64,
    pub rounds: usize,
    pub trigger_x: Trigger,
    pub trigger_h: Trigger,
    pub drop_rate: f64,
    pub reset_period: usize,
    pub g: SharingG,
    /// Delta compressor on both lines (unification bonus: the sharing
    /// engine now rides the same codec path as the other engines, so it
    /// gets byte accounting and compression for free).  `Identity`
    /// reproduces the uncompressed protocol bit-for-bit.
    pub compressor: CompressorCfg,
    /// Worker threads for the per-agent local-solve phase; 0 = auto
    /// (`DELUXE_WORKERS`, else one per core).  Trajectories are
    /// bit-identical for every value (see `admm::core`).
    pub workers: usize,
}

impl Default for SharingConfig {
    fn default() -> Self {
        SharingConfig {
            rho: 1.0,
            rounds: 100,
            trigger_x: Trigger::Always,
            trigger_h: Trigger::Always,
            drop_rate: 0.0,
            reset_period: 0,
            g: SharingG::Zero,
            compressor: CompressorCfg::Identity,
            workers: 0,
        }
    }
}

struct ShareAgent {
    x: Vec<f64>,
    hhat: Estimate<f64>,
    /// Agent → server x-line.
    up: EventLine<f64>,
    /// Server → agent h-line.
    down: EventLine<f64>,
    /// server-side estimate of this agent's x
    xhat: Estimate<f64>,
}

/// Event-based ADMM for the sharing problem, on the shared round core.
pub struct SharingAdmm {
    pub cfg: SharingConfig,
    pub n: usize,
    pub dim: usize,
    pub z: Vec<f64>,
    pub u: Vec<f64>,
    pub h: Vec<f64>,
    agents: Vec<ShareAgent>,
    core: RoundCore<f64>,
}

impl SharingAdmm {
    pub fn new(cfg: SharingConfig, n: usize, dim: usize) -> Self {
        let zeros = vec![0.0; dim];
        let agents = (0..n)
            .map(|_| ShareAgent {
                x: zeros.clone(),
                hhat: Estimate::new(zeros.clone()),
                up: EventLine::new(
                    cfg.trigger_x,
                    zeros.clone(),
                    cfg.drop_rate,
                ),
                down: EventLine::new(
                    cfg.trigger_h,
                    zeros.clone(),
                    cfg.drop_rate,
                ),
                xhat: Estimate::new(zeros.clone()),
            })
            .collect();
        let core = RoundCore::new(n, dim, &cfg.compressor, cfg.workers);
        SharingAdmm {
            cfg,
            n,
            dim,
            z: zeros.clone(),
            u: zeros.clone(),
            h: zeros,
            agents,
            core,
        }
    }

    /// Rounds completed so far.
    pub fn round_idx(&self) -> usize {
        self.core.round_idx
    }

    pub fn round(
        &mut self,
        solver: &mut dyn LocalSolver<f64>,
        rng: &mut Pcg64,
    ) {
        let rho = self.cfg.rho;
        let solve_base = rng.clone();

        // agents: x_i ← argmin f_i(x) + (ρ/2)|x − x_i + ĥ|² — anchors
        // sequentially, the solve phase on the worker pool (one forked
        // RNG stream per agent, bit-identical for any worker count)
        let anchors: Vec<Vec<f64>> = self
            .agents
            .iter()
            .map(|a| {
                a.x.iter()
                    .zip(a.hhat.get())
                    .map(|(&x, &h)| x - h)
                    .collect()
            })
            .collect();
        let mut rngs = self.core.round_solve_rngs(&solve_base);
        let xs = solver.solve_batch(
            self.core.agent_ids(),
            &anchors,
            rho,
            &mut rngs,
            &self.core.pool,
        );
        // ordered reduction: event send x_i to the server, agent order
        for (a, x) in self.agents.iter_mut().zip(xs) {
            a.x = x;
            let xi = a.x.clone();
            if let Some(msg) = a.up.offer_send(
                &xi,
                self.core.comp.as_ref(),
                rng,
                &mut self.core.scratch,
            ) {
                a.xhat.apply_msg(&msg);
            }
        }

        // server: x̄ = (1/N) Σ x̂_i ; z-prox ; dual ; h broadcast
        let mut xbar = vec![0.0; self.dim];
        for a in &self.agents {
            for (s, &v) in xbar.iter_mut().zip(a.xhat.get()) {
                *s += v;
            }
        }
        for v in &mut xbar {
            *v /= self.n as f64;
        }
        let v: Vec<f64> = xbar
            .iter()
            .zip(&self.u)
            .map(|(&xb, &u)| xb + u / rho)
            .collect();
        self.z = self.cfg.g.prox(&v, self.n, rho);
        for j in 0..self.dim {
            self.u[j] += rho * (xbar[j] - self.z[j]);
            self.h[j] = xbar[j] - self.z[j] + self.u[j] / rho;
        }
        // event broadcast of h on each downlink
        let h = self.h.clone();
        for a in &mut self.agents {
            if let Some(msg) = a.down.offer_send(
                &h,
                self.core.comp.as_ref(),
                rng,
                &mut self.core.scratch,
            ) {
                a.hhat.apply_msg(&msg);
            }
        }

        if self.core.finish_round(self.cfg.reset_period) {
            self.reset();
        }
    }

    /// Full resynchronization of both lines for every agent (one dense
    /// sync per line, triggers advanced, residuals dropped — see
    /// [`EventLine::resync`]).
    pub fn reset(&mut self) {
        let h = self.h.clone();
        for a in &mut self.agents {
            let xi = a.x.clone();
            a.up.resync(&xi);
            a.xhat.reset_to(&xi);
            a.down.resync(&h);
            a.hhat.reset_to(&h);
        }
    }

    pub fn agent_x(&self, i: usize) -> &[f64] {
        &self.agents[i].x
    }

    /// Aggregate `Σ_i x_i`.
    pub fn aggregate(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.dim];
        for a in &self.agents {
            for (acc, &v) in s.iter_mut().zip(&a.x) {
                *acc += v;
            }
        }
        s
    }

    pub fn total_events(&self) -> u64 {
        core::events_sum(self.agents.iter().map(|a| &a.up))
            + core::events_sum(self.agents.iter().map(|a| &a.down))
    }

    pub fn comm_load(&self) -> f64 {
        self.core.comm_load(self.total_events(), 2.0 * self.n as f64)
    }

    /// Total sent bytes `(uplink, downlink)` — new with the unified
    /// codec path: the sharing engine's traffic is now byte-accurate.
    pub fn bytes_split(&self) -> (u64, u64) {
        (
            core::bytes_sum(self.agents.iter().map(|a| &a.up)),
            core::bytes_sum(self.agents.iter().map(|a| &a.down)),
        )
    }

    /// Byte-accurate per-agent wire accounting (both directions).
    pub fn wire_stats(&self) -> WireStats {
        core::wire_stats(
            self.agents.iter().map(|a| &a.up),
            self.agents.iter().map(|a| &a.down),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// f_i(x) = 0.5 w_i |x − c_i|² over R^1.
    struct Quad {
        w: Vec<f64>,
        c: Vec<f64>,
    }

    impl LocalSolver<f64> for Quad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            vec![
                (self.w[agent] * self.c[agent] + rho * anchor[0])
                    / (self.w[agent] + rho),
            ]
        }
        fn dim(&self) -> usize {
            1
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    /// Closed-form optimum for g(y) = (γ/2) y²:
    /// x_i = c_i − (γ/w_i) S,  S = Σc / (1 + γ Σ 1/w_i).
    fn quad_opt(w: &[f64], c: &[f64], gamma: f64) -> (Vec<f64>, f64) {
        let csum: f64 = c.iter().sum();
        let winv: f64 = w.iter().map(|v| 1.0 / v).sum();
        let s = csum / (1.0 + gamma * winv);
        let xs: Vec<f64> =
            w.iter().zip(c).map(|(wi, ci)| ci - gamma / wi * s).collect();
        (xs, s)
    }

    #[test]
    fn quadratic_coupling_reaches_kkt_point() {
        let w = vec![1.0, 2.0, 0.5];
        let c = vec![3.0, -1.0, 2.0];
        let gamma = 0.8;
        let (x_opt, s_opt) = quad_opt(&w, &c, gamma);
        let mut solver = Quad { w, c };
        let cfg = SharingConfig {
            g: SharingG::Quad { gamma },
            rounds: 500,
            ..Default::default()
        };
        let mut eng = SharingAdmm::new(cfg, 3, 1);
        let mut rng = Pcg64::seed(1);
        for _ in 0..500 {
            eng.round(&mut solver, &mut rng);
        }
        let agg = eng.aggregate();
        assert!((agg[0] - s_opt).abs() < 1e-6, "agg {} vs {s_opt}", agg[0]);
        for i in 0..3 {
            assert!(
                (eng.agent_x(i)[0] - x_opt[i]).abs() < 1e-6,
                "x{i} {} vs {}",
                eng.agent_x(i)[0],
                x_opt[i]
            );
        }
    }

    #[test]
    fn zero_g_decouples_to_local_minima() {
        let w = vec![1.0, 4.0];
        let c = vec![2.0, -3.0];
        let mut solver = Quad { w: w.clone(), c: c.clone() };
        let mut eng = SharingAdmm::new(
            SharingConfig { rounds: 300, ..Default::default() },
            2,
            1,
        );
        let mut rng = Pcg64::seed(2);
        for _ in 0..300 {
            eng.round(&mut solver, &mut rng);
        }
        for i in 0..2 {
            assert!(
                (eng.agent_x(i)[0] - c[i]).abs() < 1e-6,
                "agent {i}: {} vs {}",
                eng.agent_x(i)[0],
                c[i]
            );
        }
    }

    #[test]
    fn event_based_saves_communication() {
        let w = vec![1.0, 2.0, 0.5, 1.5];
        let c = vec![3.0, -1.0, 2.0, 0.5];
        let gamma = 0.5;
        let (x_opt, _) = quad_opt(&w, &c, gamma);
        let mut solver = Quad { w, c };
        let cfg = SharingConfig {
            g: SharingG::Quad { gamma },
            trigger_x: Trigger::vanilla(1e-3),
            trigger_h: Trigger::vanilla(1e-4),
            rounds: 600,
            ..Default::default()
        };
        let mut eng = SharingAdmm::new(cfg, 4, 1);
        let mut rng = Pcg64::seed(3);
        for _ in 0..600 {
            eng.round(&mut solver, &mut rng);
        }
        for i in 0..4 {
            assert!((eng.agent_x(i)[0] - x_opt[i]).abs() < 0.05);
        }
        assert!(eng.comm_load() < 0.7, "load {}", eng.comm_load());
    }

    #[test]
    fn l1_coupling_sparsifies_aggregate() {
        // strong λ should pull the aggregate to exactly 0
        let w = vec![1.0, 1.0];
        let c = vec![0.3, -0.1];
        let mut solver = Quad { w, c };
        let cfg = SharingConfig {
            g: SharingG::L1 { lambda: 5.0 },
            rounds: 500,
            ..Default::default()
        };
        let mut eng = SharingAdmm::new(cfg, 2, 1);
        let mut rng = Pcg64::seed(4);
        for _ in 0..500 {
            eng.round(&mut solver, &mut rng);
        }
        assert!(eng.aggregate()[0].abs() < 1e-4,
                "aggregate {}", eng.aggregate()[0]);
    }
}
