//! The shared round core behind every ADMM engine (DESIGN.md §10).
//!
//! PRs 1–4 grew four independent engines (Alg. 1 consensus, Alg. 2
//! general, graph Eq. 7, sharing Eqs. 5–6) that each re-implemented the
//! same three concerns:
//!
//! * **per-line plumbing** — trigger state + lossy channel + error
//!   feedback + the `mark_round`/`charge_sync` reset accounting, now
//!   [`EventLine`] (point-to-point) and [`BroadcastLine`] (one trigger
//!   fanned out over per-neighbor links);
//! * **round/reset cadence and stats** — round counter, periodic-reset
//!   scheduling, and the event/drop/byte aggregation behind
//!   `total_events` / `comm_load` / `wire_stats`, now [`RoundCore`] plus
//!   the [`events_sum`]/[`drops_sum`]/[`bytes_sum`]/[`link_stats`]
//!   helpers;
//! * **the per-agent local-solve phase**, now executed on a
//!   [`WorkerPool`] with a fixed contiguous agent→shard assignment and a
//!   deterministic ordered reduction.
//!
//! # Determinism contract
//!
//! A round is split into three phases: (1) sequential communication on
//! the caller's RNG stream, (2) the embarrassingly parallel local-solve
//! phase, (3) a sequential reduction in agent order.  Phase 2 draws
//! *nothing* from the caller's stream: each agent's solver RNG is forked
//! from the round's base state via [`crate::rng::Pcg64::fork`] keyed by
//! `(round, agent)`, and results land in per-agent slots before the
//! ordered reduction reads them.  Trajectories are therefore
//! bit-identical for every `--workers` value, including `1` — pinned by
//! the `determinism` integration tests.

use crate::comm::{Scalar, Trigger, TriggerState};
use crate::obs::{clock::Stopwatch, Event, Line, Obs, SpanKind, TimedSpan};
use crate::transport::loss::{ChannelStats, LossyLink};
use crate::rng::Pcg64;
use crate::wire::{
    Compressor, CompressorCfg, ErrorFeedback, LinkStats, WireMessage,
    WireStats,
};

// ---------------------------------------------------------------------------
// Worker pool
// ---------------------------------------------------------------------------

/// Resolve a worker-count knob: `0` means "auto" — the `DELUXE_WORKERS`
/// environment variable if set (the CI matrix pins it to 1 and 4), else
/// one worker per available core.
pub fn resolve_workers(workers: usize) -> usize {
    if workers > 0 {
        return workers;
    }
    if let Ok(v) = std::env::var("DELUXE_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The engines' per-agent worker pool: scoped `std::thread` workers (the
/// `sim::sweep` pattern — no detached threads, no new dependencies) over
/// a **fixed contiguous agent→shard assignment**.
///
/// [`WorkerPool::run`] executes `f(i, &mut items[i])` for every item:
/// worker `w` owns items `[w·per, (w+1)·per)`, each item is touched by
/// exactly one worker, and results land in the item's own slot — so a
/// sequential pass over the slots afterwards observes the same values no
/// matter how many workers ran.  `f` must derive any randomness from the
/// item itself (see [`crate::rng::Pcg64::fork`]), never from shared
/// state.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// `workers = 0` resolves via [`resolve_workers`] (env, then cores).
    pub fn new(workers: usize) -> Self {
        WorkerPool { workers: resolve_workers(workers) }
    }

    /// Single-threaded pool (the deterministic reference path).
    pub fn sequential() -> Self {
        WorkerPool { workers: 1 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(i, &mut items[i])` for every item, sharded contiguously
    /// across the pool.  Falls back to a plain loop for one worker or
    /// one item — bit-identical either way by construction.
    pub fn run<S, F>(&self, items: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        let n = items.len();
        let w = self.workers.min(n);
        if w <= 1 {
            for (i, s) in items.iter_mut().enumerate() {
                f(i, s);
            }
            return;
        }
        let per = n.div_ceil(w);
        std::thread::scope(|scope| {
            for (ci, chunk) in items.chunks_mut(per).enumerate() {
                let f = &f;
                scope.spawn(move || {
                    for (j, s) in chunk.iter_mut().enumerate() {
                        f(ci * per + j, s);
                    }
                });
            }
        });
    }

    /// [`WorkerPool::run`] plus per-item wall-clock timing: returns the
    /// microseconds each `f(i, …)` call took, indexed like `items`.  The
    /// item updates are bit-identical to [`WorkerPool::run`]; the timings
    /// are wall-side observability data only and must never feed
    /// deterministic state (they serialize under `"wall_us"` — see
    /// [`crate::obs::strip_wall`]).
    pub fn run_timed<S, F>(&self, items: &mut [S], f: F) -> Vec<u64>
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        let n = items.len();
        let mut micros = vec![0u64; n];
        let w = self.workers.min(n);
        if w <= 1 {
            for (i, s) in items.iter_mut().enumerate() {
                let sw = Stopwatch::start();
                f(i, s);
                micros[i] = sw.micros();
            }
            return micros;
        }
        let per = n.div_ceil(w);
        std::thread::scope(|scope| {
            for ((ci, chunk), mchunk) in items
                .chunks_mut(per)
                .enumerate()
                .zip(micros.chunks_mut(per))
            {
                let f = &f;
                scope.spawn(move || {
                    for ((j, s), m) in
                        chunk.iter_mut().enumerate().zip(mchunk.iter_mut())
                    {
                        let sw = Stopwatch::start();
                        f(ci * per + j, s);
                        *m = sw.micros();
                    }
                });
            }
        });
        micros
    }
}

/// Per-agent solver streams for one round: `base.fork(round, agent)` for
/// each agent.  `base` is the caller's RNG state at the *start* of the
/// round (before any communication draws), so the streams are identical
/// no matter where in the round the solves execute or on how many
/// workers.
pub fn solve_rngs(base: &Pcg64, round: u64, n: usize) -> Vec<Pcg64> {
    (0..n).map(|i| base.fork(round, i as u64)).collect()
}

/// Agent `agent`'s share of a fused dispatch's `total` wall
/// microseconds: `total / n` each, with the remainder handed one
/// microsecond apiece to the earliest agents — so the `n` shares sum to
/// `total` exactly (the span-reconciliation invariant behind
/// [`RoundCore::solve_timed_chunked`]).
pub fn prorate(total: u64, n: usize, agent: usize) -> u64 {
    debug_assert!(agent < n);
    let n64 = n as u64;
    total / n64 + u64::from((agent as u64) < total % n64)
}

// ---------------------------------------------------------------------------
// Lines
// ---------------------------------------------------------------------------

/// One event-triggered, error-feedback-compressed, lossy transmit line —
/// the bundle every engine previously hand-rolled per link.
#[derive(Clone, Debug)]
pub struct EventLine<T: Scalar> {
    pub trig: TriggerState<T>,
    pub ch: LossyLink,
    pub ef: ErrorFeedback<T>,
}

impl<T: Scalar> EventLine<T> {
    pub fn new(trigger: Trigger, init: Vec<T>, drop_rate: f64) -> Self {
        EventLine {
            trig: TriggerState::new(trigger, init),
            ch: LossyLink::new(drop_rate),
            ef: ErrorFeedback::new(),
        }
    }

    /// One round's transmit opportunity: open the channel round
    /// (`mark_round`), offer `value` to the trigger, compress the fired
    /// delta with per-line error feedback, and push it through the lossy
    /// channel with byte-exact accounting.  Returns the delivered
    /// message, if any; the caller applies it to the receiver estimate.
    ///
    /// RNG consumption (trigger decision, compressor, channel) is
    /// identical to the pre-unification engines, so seeded trajectories
    /// are unchanged.
    pub fn offer_send(
        &mut self,
        value: &[T],
        comp: &dyn Compressor<T>,
        rng: &mut Pcg64,
        scratch: &mut Vec<T>,
    ) -> Option<WireMessage<T>> {
        self.ch.mark_round();
        if self.trig.offer_into(value, rng, scratch) {
            let msg = self.ef.compress(scratch, comp, rng);
            let bytes = msg.wire_bytes() as u64;
            self.ch.transmit_bytes(msg, bytes, rng)
        } else {
            None
        }
    }

    /// Reset-path resynchronization: advance the trigger reference to
    /// `value` (counting one event), drop the carried compression
    /// residual, and charge one full dense synchronization transfer — a
    /// same-round triggered-but-dropped packet is superseded by the sync
    /// (see [`LossyLink::charge_sync`]).
    pub fn resync(&mut self, value: &[T]) {
        self.trig.reset(value);
        self.ef.clear();
        self.ch
            .charge_sync(WireMessage::<T>::dense_bytes(value.len()) as u64);
    }

    /// [`EventLine::offer_send`] with journaling: emits `TriggerFired`,
    /// `MessageSent` and `PacketDropped` events whose byte fields are the
    /// exact [`ChannelStats`] deltas of the call, so a journal's sums
    /// reconcile against the line's books to the byte.  A dropped packet
    /// emits *both* `MessageSent` (it was charged to the wire) and
    /// `PacketDropped` (it never arrived), mirroring how
    /// [`LossyLink::transmit_bytes`] books it under `sent_bytes` *and*
    /// `dropped_bytes`.  RNG consumption is identical to the unjournaled
    /// path.
    #[allow(clippy::too_many_arguments)]
    pub fn offer_send_obs(
        &mut self,
        value: &[T],
        comp: &dyn Compressor<T>,
        rng: &mut Pcg64,
        scratch: &mut Vec<T>,
        obs: &mut Obs,
        round: u64,
        agent: usize,
        line: Line,
    ) -> Option<WireMessage<T>> {
        let before = self.ch.stats;
        let events_before = self.trig.events;
        let out = self.offer_send(value, comp, rng, scratch);
        if obs.on() {
            let after = self.ch.stats;
            if self.trig.events > events_before {
                obs.emit(Event::TriggerFired { round, agent, line });
            }
            if after.sent_bytes > before.sent_bytes {
                obs.emit(Event::MessageSent {
                    round,
                    agent,
                    line,
                    bytes: after.sent_bytes - before.sent_bytes,
                });
            }
            if after.dropped_bytes > before.dropped_bytes {
                obs.emit(Event::PacketDropped {
                    round,
                    agent,
                    line,
                    bytes: after.dropped_bytes - before.dropped_bytes,
                });
            }
        }
        out
    }

    /// [`EventLine::resync`] with journaling: emits one `ResetSync` whose
    /// `bytes` is the net `sent_bytes` delta of the call — the dense sync
    /// charge, minus a superseded same-round drop if there was one (see
    /// [`LossyLink::charge_sync`]); under supersession the earlier
    /// `MessageSent`/`PacketDropped` pair for the retracted packet is
    /// folded back here, keeping `Σ msg_sent + Σ reset_sync ==
    /// sent_bytes` exact.
    pub fn resync_obs(
        &mut self,
        value: &[T],
        obs: &mut Obs,
        round: u64,
        agent: usize,
    ) {
        let before = self.ch.stats;
        self.resync(value);
        if obs.on() {
            let after = self.ch.stats;
            obs.emit(Event::ResetSync {
                round,
                agent,
                bytes: after.sent_bytes.saturating_sub(before.sent_bytes),
            });
        }
    }

    pub fn events(&self) -> u64 {
        self.trig.events
    }

    pub fn stats(&self) -> &ChannelStats {
        &self.ch.stats
    }
}

/// One event trigger + error feedback fanned out over per-neighbor lossy
/// links — the decentralized (graph) engine's broadcast pattern: a fired
/// event compresses once and transmits per link with byte accounting.
#[derive(Clone, Debug)]
pub struct BroadcastLine<T: Scalar> {
    pub trig: TriggerState<T>,
    pub ef: ErrorFeedback<T>,
    pub channels: Vec<LossyLink>,
}

impl<T: Scalar> BroadcastLine<T> {
    pub fn new(
        trigger: Trigger,
        init: Vec<T>,
        fanout: usize,
        drop_rate: f64,
    ) -> Self {
        BroadcastLine {
            trig: TriggerState::new(trigger, init),
            ef: ErrorFeedback::new(),
            channels: (0..fanout)
                .map(|_| LossyLink::new(drop_rate))
                .collect(),
        }
    }

    /// Open every link's round, offer `value` to the broadcast trigger
    /// and compress the fired delta once.  The caller fans the returned
    /// payload out via [`Self::transmit`].
    pub fn offer_compress(
        &mut self,
        value: &[T],
        comp: &dyn Compressor<T>,
        rng: &mut Pcg64,
        scratch: &mut Vec<T>,
    ) -> Option<WireMessage<T>> {
        for ch in &mut self.channels {
            ch.mark_round();
        }
        if self.trig.offer_into(value, rng, scratch) {
            Some(self.ef.compress(scratch, comp, rng))
        } else {
            None
        }
    }

    /// Transmit one copy of the broadcast payload over link `li`.
    pub fn transmit(
        &mut self,
        li: usize,
        msg: WireMessage<T>,
        bytes: u64,
        rng: &mut Pcg64,
    ) -> Option<WireMessage<T>> {
        self.channels[li].transmit_bytes(msg, bytes, rng)
    }

    /// Reset-path resynchronization: one dense sync per link, trigger
    /// advanced, residual dropped (same supersession rule as
    /// [`EventLine::resync`]).
    pub fn resync(&mut self, value: &[T]) {
        self.trig.reset(value);
        self.ef.clear();
        let sync = WireMessage::<T>::dense_bytes(value.len()) as u64;
        for ch in &mut self.channels {
            ch.charge_sync(sync);
        }
    }

    pub fn events(&self) -> u64 {
        self.trig.events
    }
}

// ---------------------------------------------------------------------------
// Stats aggregation (shared by every engine's accessors)
// ---------------------------------------------------------------------------

/// Total triggered events over a set of lines.
pub fn events_sum<'a, T: Scalar>(
    lines: impl IntoIterator<Item = &'a EventLine<T>>,
) -> u64 {
    lines.into_iter().map(|l| l.trig.events).sum()
}

/// Total dropped packets over a set of lines.
pub fn drops_sum<'a, T: Scalar>(
    lines: impl IntoIterator<Item = &'a EventLine<T>>,
) -> u64 {
    lines.into_iter().map(|l| l.ch.stats.dropped).sum()
}

/// Total sent bytes over a set of lines.
pub fn bytes_sum<'a, T: Scalar>(
    lines: impl IntoIterator<Item = &'a EventLine<T>>,
) -> u64 {
    lines.into_iter().map(|l| l.ch.stats.sent_bytes).sum()
}

/// Per-line [`LinkStats`] snapshots over a set of lines.
pub fn link_stats<'a, T: Scalar>(
    lines: impl IntoIterator<Item = &'a EventLine<T>>,
) -> Vec<LinkStats> {
    lines.into_iter().map(|l| LinkStats::from(&l.ch.stats)).collect()
}

/// Assemble a [`WireStats`] snapshot from uplink/downlink line sets.
pub fn wire_stats<'a, 'b, T: Scalar>(
    uplink: impl IntoIterator<Item = &'a EventLine<T>>,
    downlink: impl IntoIterator<Item = &'b EventLine<T>>,
) -> WireStats {
    WireStats { uplink: link_stats(uplink), downlink: link_stats(downlink) }
}

// ---------------------------------------------------------------------------
// Round core
// ---------------------------------------------------------------------------

/// The engine-agnostic round state: agent count, problem dimension,
/// round counter, the shared compression operator, the delta scratch
/// buffer for the allocation-free trigger hot path, and the worker pool
/// for the local-solve phase.  The reset period stays in each engine's
/// config (engines allow mutating it between rounds) and is passed to
/// [`Self::finish_round`] per round.
pub struct RoundCore<T: Scalar> {
    pub n: usize,
    pub dim: usize,
    pub round_idx: usize,
    pub comp: Box<dyn Compressor<T>>,
    pub pool: WorkerPool,
    pub scratch: Vec<T>,
    agent_ids: Vec<usize>,
}

impl<T: Scalar> RoundCore<T> {
    pub fn new(
        n: usize,
        dim: usize,
        compressor: &CompressorCfg,
        workers: usize,
    ) -> Self {
        RoundCore {
            n,
            dim,
            round_idx: 0,
            comp: compressor.build::<T>(),
            pool: WorkerPool::new(workers),
            scratch: Vec::with_capacity(dim),
            agent_ids: (0..n).collect(),
        }
    }

    /// `[0, n)` — the batch passed to `LocalSolver::solve_batch` by the
    /// all-agents synchronous engines (cached to keep rounds
    /// allocation-free).
    pub fn agent_ids(&self) -> &[usize] {
        &self.agent_ids
    }

    /// Per-agent solver streams for this round (see [`solve_rngs`]).
    pub fn round_solve_rngs(&self, base: &Pcg64) -> Vec<Pcg64> {
        solve_rngs(base, self.round_idx as u64, self.n)
    }

    /// Run the local-solve phase on the pool, journaling one `SolveDone`
    /// per agent when `obs` is live.  Timings come from
    /// [`WorkerPool::run_timed`] but are emitted **post-barrier in agent
    /// order**, so the journal's event sequence is independent of worker
    /// count and scheduling (only the `wall_us` values differ, and those
    /// are stripped for determinism comparisons).  With spans on the
    /// phase is wrapped in a `local_solve` span containing one `solve`
    /// span per agent (DESIGN.md §14); each agent's `SolveDone` line
    /// lands positionally inside its own span, and the span's wall is
    /// the pool's per-agent measurement — no extra clock reads.  With
    /// `obs` off this is exactly [`WorkerPool::run`].
    pub fn solve_timed<S, F>(&self, items: &mut [S], f: F, obs: &mut Obs)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        if !obs.on() {
            self.pool.run(items, f);
            return;
        }
        let round = self.round_idx as u64;
        let phase = TimedSpan::open(obs, SpanKind::LocalSolve, round, None);
        let micros = self.pool.run_timed(items, f);
        for (agent, us) in micros.into_iter().enumerate() {
            let s = obs.open_span(SpanKind::Solve, round, Some(agent));
            obs.emit(Event::SolveDone { round, agent, micros: us });
            obs.close_span(s, None, None, Some(us));
        }
        phase.close(obs, None, None);
    }

    /// [`Self::solve_timed`] for **fused** batch solvers
    /// ([`crate::solver::LocalSolver::solve_batch_into`]): the whole
    /// phase is one dispatch — `f` runs the entire batch, chunked
    /// internally across the pool — so there is no per-item pool
    /// measurement to forward.  The dispatch wall is measured once and
    /// attributed pro rata ([`prorate`]) across the core's `n` agents;
    /// the journal keeps the exact shape of the unfused path — one
    /// `local_solve` phase span, then one `solve` span + `SolveDone`
    /// line per agent **in agent order** — and the per-agent walls sum
    /// to the measured dispatch wall exactly.  With `obs` off this is
    /// just `f()`.
    pub fn solve_timed_chunked<F: FnOnce()>(&self, f: F, obs: &mut Obs) {
        if !obs.on() {
            f();
            return;
        }
        let round = self.round_idx as u64;
        let phase = TimedSpan::open(obs, SpanKind::LocalSolve, round, None);
        let sw = Stopwatch::start();
        f();
        let total = sw.micros();
        for agent in 0..self.n {
            let us = prorate(total, self.n, agent);
            let s = obs.open_span(SpanKind::Solve, round, Some(agent));
            obs.emit(Event::SolveDone { round, agent, micros: us });
            obs.close_span(s, None, None, Some(us));
        }
        phase.close(obs, None, None);
    }

    /// Close the round: advance the counter and report whether the
    /// periodic reset (period `T`, 0 = disabled) is due.
    pub fn finish_round(&mut self, reset_period: usize) -> bool {
        self.round_idx += 1;
        reset_period > 0 && self.round_idx % reset_period == 0
    }

    /// Events normalized by full communication at `lines_per_round`
    /// transmit opportunities per round.
    pub fn comm_load(&self, total_events: u64, lines_per_round: f64) -> f64 {
        if self.round_idx == 0 {
            return 0.0;
        }
        total_events as f64 / (lines_per_round * self.round_idx as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Trigger;
    use crate::rng::Rng;

    #[test]
    fn pool_run_matches_sequential_for_any_worker_count() {
        let base: Vec<u64> = (0..97).collect();
        let mut want = base.clone();
        for (i, v) in want.iter_mut().enumerate() {
            *v = *v * 3 + i as u64;
        }
        for workers in [1, 2, 3, 8, 200] {
            let pool = WorkerPool { workers };
            let mut items = base.clone();
            pool.run(&mut items, |i, v| *v = *v * 3 + i as u64);
            assert_eq!(items, want, "workers = {workers}");
        }
    }

    #[test]
    fn pool_run_passes_global_indices() {
        let pool = WorkerPool { workers: 4 };
        let mut items = vec![0usize; 10];
        pool.run(&mut items, |i, v| *v = i);
        assert_eq!(items, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pool_run_empty_is_a_noop() {
        let pool = WorkerPool { workers: 4 };
        let mut items: Vec<u8> = Vec::new();
        pool.run(&mut items, |_, _| panic!("must not be called"));
    }

    #[test]
    fn resolve_workers_prefers_explicit_value() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn solve_rngs_are_stable_and_per_agent() {
        let base = Pcg64::seed(7);
        let mut a = solve_rngs(&base, 5, 3);
        let mut b = solve_rngs(&base, 5, 3);
        for (x, y) in a.iter_mut().zip(b.iter_mut()) {
            assert_eq!(x.next_u64(), y.next_u64());
        }
        // distinct agents and distinct rounds give distinct streams
        let mut r0 = solve_rngs(&base, 5, 2);
        let mut r1 = solve_rngs(&base, 6, 2);
        let (a0, a1) = (r0[0].next_u64(), r0[1].next_u64());
        assert_ne!(a0, a1);
        let mut again = solve_rngs(&base, 5, 1);
        assert_eq!(again[0].next_u64(), a0);
        assert_ne!(a0, r1[0].next_u64());
    }

    #[test]
    fn event_line_counts_and_resync_accounting() {
        let comp = CompressorCfg::Identity.build::<f64>();
        let mut line = EventLine::new(Trigger::Always, vec![0.0; 2], 0.0);
        let mut rng = Pcg64::seed(1);
        let mut scratch = Vec::new();
        let msg = line
            .offer_send(&[1.0, -1.0], comp.as_ref(), &mut rng, &mut scratch)
            .expect("Always trigger must fire and deliver");
        assert_eq!(msg.to_dense(), vec![1.0, -1.0]);
        assert_eq!(line.events(), 1);
        let dense = WireMessage::<f64>::dense_bytes(2) as u64;
        assert_eq!(line.stats().sent_bytes, dense);
        line.resync(&[2.0, 2.0]);
        assert_eq!(line.events(), 2, "resync counts one event");
        assert_eq!(line.stats().sent_bytes, 2 * dense);
        assert_eq!(line.stats().dropped, 0);
    }

    #[test]
    fn event_line_resync_supersedes_same_round_drop() {
        let comp = CompressorCfg::Identity.build::<f64>();
        let mut line = EventLine::new(Trigger::Always, vec![0.0], 1.0);
        let mut rng = Pcg64::seed(2);
        let mut scratch = Vec::new();
        assert!(line
            .offer_send(&[1.0], comp.as_ref(), &mut rng, &mut scratch)
            .is_none());
        line.resync(&[1.0]);
        let dense = WireMessage::<f64>::dense_bytes(1) as u64;
        assert_eq!(line.stats().sent, 1, "drop superseded by the sync");
        assert_eq!(line.stats().sent_bytes, dense);
        assert_eq!(line.stats().dropped, 0);
    }

    #[test]
    fn broadcast_line_compresses_once_and_charges_per_link() {
        let comp = CompressorCfg::Identity.build::<f64>();
        let mut line =
            BroadcastLine::new(Trigger::Always, vec![0.0; 2], 3, 0.0);
        let mut rng = Pcg64::seed(3);
        let mut scratch = Vec::new();
        let msg = line
            .offer_compress(&[1.0, 2.0], comp.as_ref(), &mut rng, &mut scratch)
            .expect("fires");
        let bytes = msg.wire_bytes() as u64;
        for li in 0..3 {
            assert!(line
                .transmit(li, msg.clone(), bytes, &mut rng)
                .is_some());
        }
        assert_eq!(line.events(), 1, "one event per broadcast");
        let total: u64 =
            line.channels.iter().map(|c| c.stats.sent_bytes).sum();
        assert_eq!(total, 3 * bytes);
        line.resync(&[1.0, 2.0]);
        assert_eq!(line.events(), 2);
        let dense = WireMessage::<f64>::dense_bytes(2) as u64;
        let total: u64 =
            line.channels.iter().map(|c| c.stats.sent_bytes).sum();
        assert_eq!(total, 3 * (bytes + dense));
    }

    #[test]
    fn run_timed_matches_run_and_times_every_item() {
        let base: Vec<u64> = (0..37).collect();
        let mut want = base.clone();
        for (i, v) in want.iter_mut().enumerate() {
            *v = *v * 7 + i as u64;
        }
        for workers in [1, 4] {
            let pool = WorkerPool { workers };
            let mut items = base.clone();
            let micros = pool.run_timed(&mut items, |i, v| *v = *v * 7 + i as u64);
            assert_eq!(items, want, "workers = {workers}");
            assert_eq!(micros.len(), items.len());
        }
    }

    #[test]
    fn offer_send_obs_journal_matches_channel_books() {
        use crate::obs::{Line, Obs};
        let comp = CompressorCfg::Identity.build::<f64>();
        // drop_rate 1.0: the packet is charged AND dropped — both events
        let mut line = EventLine::new(Trigger::Always, vec![0.0], 1.0);
        let mut rng = Pcg64::seed(11);
        let mut scratch = Vec::new();
        let mut obs = Obs::in_memory();
        assert!(line
            .offer_send_obs(
                &[1.0],
                comp.as_ref(),
                &mut rng,
                &mut scratch,
                &mut obs,
                0,
                2,
                Line::Up,
            )
            .is_none());
        assert_eq!(obs.metrics.counter("trigger_up"), 1);
        assert_eq!(obs.metrics.counter("bytes_up"), line.stats().sent_bytes);
        assert_eq!(
            obs.metrics.counter("dropped_bytes_up"),
            line.stats().dropped_bytes
        );
        // same-round resync supersedes the drop: net ResetSync delta keeps
        // the journal's sent-byte sum equal to the books
        line.resync_obs(&[1.0], &mut obs, 0, 2);
        assert_eq!(
            obs.metrics.counter("bytes_up") + obs.metrics.counter("reset_bytes"),
            line.stats().sent_bytes
        );
        assert_eq!(obs.metrics.counter("resyncs"), 1);
        // journal trigger count + resync count == the line's event book
        assert_eq!(
            obs.metrics.counter("trigger_up") + obs.metrics.counter("resyncs"),
            line.events()
        );
    }

    #[test]
    fn solve_timed_emits_solves_in_agent_order() {
        use crate::obs::{Event, Obs};
        let core = RoundCore::<f64>::new(6, 2, &CompressorCfg::Identity, 4);
        let mut items = vec![0u64; 6];
        let mut obs = Obs::in_memory();
        core.solve_timed(&mut items, |i, v| *v = i as u64 + 1, &mut obs);
        assert_eq!(items, vec![1, 2, 3, 4, 5, 6]);
        let agents: Vec<usize> = obs
            .flight
            .events()
            .filter_map(|e| match e {
                Event::SolveDone { agent, round, .. } => {
                    assert_eq!(*round, 0);
                    Some(*agent)
                }
                _ => None,
            })
            .collect();
        assert_eq!(agents, (0..6).collect::<Vec<_>>());
        assert_eq!(obs.metrics.hist("solve_us").map(|h| h.count()), Some(6));
        // obs off: no events, same values
        let mut off = Obs::off();
        let mut items2 = vec![0u64; 6];
        core.solve_timed(&mut items2, |i, v| *v = i as u64 + 1, &mut off);
        assert_eq!(items2, items);
        assert_eq!(off.flight.len(), 0);
    }

    #[test]
    fn prorate_distributes_remainder_to_earliest() {
        let shares: Vec<u64> = (0..4).map(|i| prorate(10, 4, i)).collect();
        assert_eq!(shares, vec![3, 3, 2, 2]);
        for (total, n) in [(0u64, 3usize), (7, 1), (13, 5), (100, 7)] {
            let sum: u64 = (0..n).map(|i| prorate(total, n, i)).sum();
            assert_eq!(sum, total, "shares must sum to the dispatch wall");
        }
    }

    #[test]
    fn solve_timed_chunked_reconciles_fused_dispatch_walls() {
        use crate::obs::{Event, Obs};
        let core = RoundCore::<f64>::new(5, 2, &CompressorCfg::Identity, 4);
        let mut obs = Obs::in_memory();
        let mut ran = false;
        core.solve_timed_chunked(|| ran = true, &mut obs);
        assert!(ran);
        // one SolveDone per agent, in agent order, walls matching the
        // per-agent solve spans
        let mut done: Vec<(usize, u64)> = Vec::new();
        let mut span_agent = std::collections::BTreeMap::new();
        let mut span_wall: Vec<(usize, u64)> = Vec::new();
        for e in obs.flight.events() {
            match e {
                Event::SolveDone { agent, micros, round } => {
                    assert_eq!(*round, 0);
                    done.push((*agent, *micros));
                }
                Event::SpanOpen {
                    span, kind: SpanKind::Solve, agent, ..
                } => {
                    span_agent.insert(*span, agent.unwrap());
                }
                Event::SpanClose { span, wall_us, .. } => {
                    if let Some(&a) = span_agent.get(span) {
                        span_wall.push((a, wall_us.unwrap()));
                    }
                }
                _ => {}
            }
        }
        let agents: Vec<usize> = done.iter().map(|d| d.0).collect();
        assert_eq!(agents, (0..5).collect::<Vec<_>>());
        assert_eq!(done, span_wall, "span walls must equal the SolveDone attribution");
        // the pro-rata shares sum to the dispatch wall and match prorate()
        let total: u64 = done.iter().map(|d| d.1).sum();
        for &(agent, us) in &done {
            assert_eq!(us, prorate(total, 5, agent));
        }
        assert_eq!(obs.metrics.hist("solve_us").map(|h| h.count()), Some(5));
        // obs off: plain dispatch, nothing journaled
        let mut off = Obs::off();
        let mut ran2 = false;
        core.solve_timed_chunked(|| ran2 = true, &mut off);
        assert!(ran2);
        assert_eq!(off.flight.len(), 0);
    }

    #[test]
    fn round_core_cadence_and_load() {
        let mut core =
            RoundCore::<f64>::new(4, 2, &CompressorCfg::Identity, 1);
        assert_eq!(core.agent_ids(), &[0, 1, 2, 3]);
        assert_eq!(core.comm_load(10, 8.0), 0.0, "no rounds yet");
        assert!(!core.finish_round(3));
        assert!(!core.finish_round(3));
        assert!(core.finish_round(3), "reset due every 3rd round");
        assert!(!core.finish_round(0), "period 0 disables resets");
        assert_eq!(core.round_idx, 4);
        assert!((core.comm_load(16, 8.0) - 0.5).abs() < 1e-15);
    }
}
