//! Decentralized consensus ADMM over a communication graph (Eq. 7,
//! App. A.2 / G.3) — no central server.
//!
//! Each agent i keeps `(x^i, p^i)` and estimates `x̂^j` of each neighbor's
//! local model; it broadcasts its own model to the neighborhood only when
//! the event trigger fires.  Updates (Eq. 7, with the standard
//! decentralized-consensus ADMM sign convention; the anchor is the average
//! of the agent's own model and its neighborhood mean):
//!
//! ```text
//! x^i_{k+1} = argmin f_i(x) + (|N_i| ρ / 2) |x − ½(x^i_k + x̄^i_k) + p^i_k/ρ|²
//! x̄^i_{k+1} = (1/|N_i|) Σ_{j ∈ N_i} x̂^j_{k+1}
//! p^i_{k+1} = p^i_k + (ρ/2) (x^i_{k+1} − x̄^i_{k+1})
//! ```
//!
//! The event protocol is the paper's: agent i transmits `x^i_{k+1} − x^i_{[k]}`
//! to all neighbors iff `|x^i_{k+1} − x^i_{[k]}| > Δˣ` (or per the
//! randomized/participation variants — App. G.3 compares against a purely
//! random selection).

use super::core::{BroadcastLine, RoundCore};
use crate::comm::{Estimate, Scalar, Trigger};
use crate::rng::Pcg64;
use crate::solver::LocalSolver;
use crate::topology::Graph;
use crate::wire::CompressorCfg;

#[derive(Clone, Debug)]
pub struct GraphConfig {
    pub rho: f64,
    pub rounds: usize,
    pub trigger_x: Trigger,
    pub drop_rate: f64,
    /// Reset period T; 0 disables.
    pub reset_period: usize,
    /// Broadcast compressor (one compressed message per event, fanned out
    /// to every neighbor); `Identity` reproduces the uncompressed engine.
    pub compressor: CompressorCfg,
    /// Worker threads for the per-agent local-solve phase; 0 = auto
    /// (`DELUXE_WORKERS`, else one per core).  Trajectories are
    /// bit-identical for every value (see `admm::core`).
    pub workers: usize,
}

impl Default for GraphConfig {
    fn default() -> Self {
        GraphConfig {
            rho: 1.0,
            rounds: 100,
            trigger_x: Trigger::Always,
            drop_rate: 0.0,
            reset_period: 0,
            compressor: CompressorCfg::Identity,
            workers: 0,
        }
    }
}

struct GraphAgent<T: Scalar> {
    x: Vec<T>,
    p: Vec<T>,
    xbar: Vec<T>,
    /// Estimates of each neighbor's model, keyed by position in `nbrs`.
    nbr_est: Vec<Estimate<T>>,
    /// One broadcast trigger + error feedback fanned out over per-link
    /// lossy channels (an event sends to ALL neighbors, as in the
    /// paper's Fig. 6 diagram).
    bcast: BroadcastLine<T>,
}

/// Group agents by degree — the static partition behind the
/// degree-dependent prox weights `ρ_i = |N_i|·ρ` (computed once at
/// engine construction; ascending ids within each class).
fn degree_classes(nbrs: &[Vec<usize>]) -> Vec<(usize, Vec<usize>)> {
    let mut by_deg: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for (i, nb) in nbrs.iter().enumerate() {
        by_deg.entry(nb.len().max(1)).or_default().push(i);
    }
    by_deg.into_iter().collect()
}

/// Run the per-agent prox solves class-by-class: each degree class runs
/// as one `solve_batch` on the worker pool with its own weight.  Every
/// agent still draws from its own forked stream, so the result is
/// bit-identical for any worker count and any class interleaving.
fn solve_degree_weighted<T: Scalar>(
    solver: &mut dyn LocalSolver<T>,
    classes: &[(usize, Vec<usize>)],
    anchors: Vec<Vec<T>>,
    rho: f64,
    rngs: &[Pcg64],
    core: &RoundCore<T>,
) -> Vec<Vec<T>> {
    let n = anchors.len();
    let mut anchors: Vec<Option<Vec<T>>> =
        anchors.into_iter().map(Some).collect();
    let mut out: Vec<Option<Vec<T>>> = (0..n).map(|_| None).collect();
    for (deg, agents) in classes {
        let sub_anchors: Vec<Vec<T>> = agents
            .iter()
            // lint:allow(panic-in-library): degree classes partition the agent set, so each anchor is taken exactly once
            .map(|&i| anchors[i].take().expect("one class per agent"))
            .collect();
        let mut sub_rngs: Vec<Pcg64> =
            agents.iter().map(|&i| rngs[i].clone()).collect();
        let xs = solver.solve_batch(
            agents,
            &sub_anchors,
            *deg as f64 * rho,
            &mut sub_rngs,
            &core.pool,
        );
        for (&i, x) in agents.iter().zip(xs) {
            out[i] = Some(x);
        }
    }
    // lint:allow(panic-in-library): every agent appears in exactly one degree class, so every slot is filled
    out.into_iter().map(|x| x.expect("every agent solved")).collect()
}

/// Decentralized event-based consensus ADMM, on the shared round core.
pub struct GraphAdmm<T: Scalar> {
    pub cfg: GraphConfig,
    pub graph: Graph,
    nbrs: Vec<Vec<usize>>,
    /// Agents grouped by degree (fixed topology ⇒ computed once).
    deg_classes: Vec<(usize, Vec<usize>)>,
    agents: Vec<GraphAgent<T>>,
    pub dim: usize,
    core: RoundCore<T>,
}

impl<T: Scalar> GraphAdmm<T> {
    pub fn new(cfg: GraphConfig, graph: Graph, x0: Vec<T>) -> Self {
        assert!(
            graph.is_connected(),
            "graph engine requires a connected topology ({} vertices, {} \
             edges given): consensus over a disconnected graph would \
             silently stall on the unreachable components — use \
             Graph::erdos_renyi_connected / random_connected or add \
             bridging edges",
            graph.n,
            graph.edges.len()
        );
        let dim = x0.len();
        let nbrs = graph.neighbors();
        let agents = (0..graph.n)
            .map(|i| GraphAgent {
                x: x0.clone(),
                p: vec![T::zero(); dim],
                xbar: x0.clone(),
                nbr_est: nbrs[i]
                    .iter()
                    .map(|_| Estimate::new(x0.clone()))
                    .collect(),
                bcast: BroadcastLine::new(
                    cfg.trigger_x,
                    x0.clone(),
                    nbrs[i].len(),
                    cfg.drop_rate,
                ),
            })
            .collect();
        let core =
            RoundCore::new(graph.n, dim, &cfg.compressor, cfg.workers);
        let deg_classes = degree_classes(&nbrs);
        GraphAdmm { cfg, graph, nbrs, deg_classes, agents, dim, core }
    }

    /// Rounds completed so far.
    pub fn round_idx(&self) -> usize {
        self.core.round_idx
    }

    /// One synchronous round over the whole network.
    pub fn round(&mut self, solver: &mut dyn LocalSolver<T>, rng: &mut Pcg64) {
        let rho = self.cfg.rho;
        let n = self.graph.n;
        let solve_base = rng.clone();

        // 1. local prox solves: anchors sequentially, then the solve
        //    phase on the worker pool (one forked RNG stream per agent,
        //    deterministic for every worker count — see admm::core)
        let mut anchors: Vec<Vec<T>> = Vec::with_capacity(n);
        for a in &self.agents {
            // anchor = ½(x_i + x̄_i) − p_i/ρ
            anchors.push(
                (0..self.dim)
                    .map(|j| {
                        T::from_f64(
                            0.5 * (a.x[j].to_f64() + a.xbar[j].to_f64())
                                - a.p[j].to_f64() / rho,
                        )
                    })
                    .collect(),
            );
        }
        let rngs = self.core.round_solve_rngs(&solve_base);
        let new_x = solve_degree_weighted(
            solver,
            &self.deg_classes,
            anchors,
            rho,
            &rngs,
            &self.core,
        );
        for (a, x) in self.agents.iter_mut().zip(new_x) {
            a.x = x;
        }

        // 2. event-based broadcast of x to neighbors: one compressed
        //    message per event, fanned out per lossy link with byte
        //    accounting
        for i in 0..n {
            let xi = self.agents[i].x.clone();
            let msg = self.agents[i].bcast.offer_compress(
                &xi,
                self.core.comp.as_ref(),
                rng,
                &mut self.core.scratch,
            );
            if let Some(msg) = msg {
                let bytes = msg.wire_bytes() as u64;
                // deliver to each neighbor j over the (i -> j) link
                for (li, &j) in self.nbrs[i].clone().iter().enumerate() {
                    let sent = self.agents[i]
                        .bcast
                        .transmit(li, msg.clone(), bytes, rng);
                    if let Some(m) = sent {
                        // neighbor j's estimate slot for i
                        let slot = self.nbrs[j]
                            .iter()
                            .position(|&v| v == i)
                            // lint:allow(panic-in-library): the adjacency is built symmetric in GraphAdmm::new; a missing back-edge is an internal invariant violation
                            .expect("symmetric adjacency");
                        self.agents[j].nbr_est[slot].apply_msg(&m);
                    }
                }
            }
        }

        // 3. neighborhood means + dual updates
        for i in 0..n {
            let deg = self.nbrs[i].len().max(1) as f64;
            let a = &mut self.agents[i];
            let mut xbar = vec![0.0f64; self.dim];
            for est in &a.nbr_est {
                for (s, &v) in xbar.iter_mut().zip(est.get()) {
                    *s += v.to_f64();
                }
            }
            for (j, s) in xbar.iter().enumerate() {
                a.xbar[j] = T::from_f64(s / deg);
            }
            for j in 0..self.dim {
                let p = a.p[j].to_f64()
                    + 0.5 * rho * (a.x[j].to_f64() - a.xbar[j].to_f64());
                a.p[j] = T::from_f64(p);
            }
        }

        if self.core.finish_round(self.cfg.reset_period) {
            self.reset();
        }
    }

    /// Full neighborhood resynchronization (counts as one broadcast per
    /// agent; charges one dense message per link and drops any carried
    /// compression residual).  A broadcast that triggered but dropped on
    /// a link in the same round is superseded by the sync on that link
    /// (see [`crate::transport::loss::LossyLink::charge_sync`] /
    /// [`BroadcastLine::resync`]).
    pub fn reset(&mut self) {
        for i in 0..self.graph.n {
            let xi = self.agents[i].x.clone();
            self.agents[i].bcast.resync(&xi);
            for &j in self.nbrs[i].clone().iter() {
                let slot = self.nbrs[j]
                    .iter()
                    .position(|&v| v == i)
                    // lint:allow(panic-in-library): the adjacency is built symmetric in GraphAdmm::new; a missing back-edge is an internal invariant violation
                    .unwrap();
                self.agents[j].nbr_est[slot].reset_to(&xi);
            }
        }
    }

    pub fn agent_x(&self, i: usize) -> &[T] {
        &self.agents[i].x
    }

    /// Network-average model (the quantity that converges to x*).
    pub fn mean_x(&self) -> Vec<f64> {
        let mut m = vec![0.0f64; self.dim];
        for a in &self.agents {
            for (s, &v) in m.iter_mut().zip(&a.x) {
                *s += v.to_f64();
            }
        }
        for v in &mut m {
            *v /= self.graph.n as f64;
        }
        m
    }

    /// Mean pairwise disagreement `(1/N) Σ_i |x_i − mean|`.
    pub fn disagreement(&self) -> f64 {
        let m = self.mean_x();
        self.agents
            .iter()
            .map(|a| {
                a.x.iter()
                    .zip(&m)
                    .map(|(&x, &mm)| {
                        let d = x.to_f64() - mm;
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / self.graph.n as f64
    }

    /// Total broadcast events (each event = one neighborhood broadcast;
    /// multiply by degree for link-level counting).
    pub fn total_events(&self) -> u64 {
        self.agents.iter().map(|a| a.bcast.events()).sum()
    }

    /// Link-level events: Σ_i events_i * deg_i.
    pub fn total_link_events(&self) -> u64 {
        self.agents
            .iter()
            .enumerate()
            .map(|(i, a)| a.bcast.events() * self.nbrs[i].len() as u64)
            .sum()
    }

    /// Load normalized by full communication (every agent broadcasting
    /// every round).
    pub fn comm_load(&self) -> f64 {
        self.core.comm_load(self.total_events(), self.graph.n as f64)
    }

    /// Total bytes put on the wire across every directed link.
    pub fn total_wire_bytes(&self) -> u64 {
        self.agents
            .iter()
            .map(|a| {
                a.bcast
                    .channels
                    .iter()
                    .map(|c| c.stats.sent_bytes)
                    .sum::<u64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::LocalSolver;

    /// Quadratic agents f_i(x) = 0.5 w_i |x - c_i|^2 (vector dim 2).
    struct Quad {
        w: Vec<f64>,
        c: Vec<Vec<f64>>,
    }

    impl LocalSolver<f64> for Quad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            let w = self.w[agent];
            anchor
                .iter()
                .zip(&self.c[agent])
                .map(|(&a, &c)| (w * c + rho * a) / (w + rho))
                .collect()
        }
        fn dim(&self) -> usize {
            2
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    fn setup(n: usize) -> (Quad, Vec<f64>) {
        let mut rng = Pcg64::seed(100);
        use crate::rng::Rng;
        let w: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64()).collect();
        let c: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.normal() * 3.0, rng.normal() * 3.0]).collect();
        let wsum: f64 = w.iter().sum();
        let opt: Vec<f64> = (0..2)
            .map(|j| {
                w.iter().zip(&c).map(|(wi, ci)| wi * ci[j]).sum::<f64>() / wsum
            })
            .collect();
        (Quad { w, c }, opt)
    }

    #[test]
    #[should_panic(expected = "connected topology")]
    fn rejects_disconnected_topology_with_clear_error() {
        // two components: {0,1} and {2,3} — the engine must refuse to
        // start rather than silently stall
        let g = Graph::new(4, vec![(0, 1), (2, 3)]);
        assert!(!g.is_connected());
        let _ = GraphAdmm::<f64>::new(GraphConfig::default(), g, vec![0.0; 2]);
    }

    #[test]
    fn full_comm_converges_on_ring() {
        let (mut solver, opt) = setup(6);
        let g = Graph::ring(6);
        let mut eng = GraphAdmm::new(
            GraphConfig { rounds: 400, ..Default::default() },
            g,
            vec![0.0; 2],
        );
        let mut rng = Pcg64::seed(1);
        for _ in 0..400 {
            eng.round(&mut solver, &mut rng);
        }
        let m = eng.mean_x();
        assert!(crate::linalg::dist2(&m, &opt) < 1e-4,
                "mean {m:?} vs opt {opt:?}");
        assert!(eng.disagreement() < 1e-4, "disagreement {}", eng.disagreement());
    }

    #[test]
    fn full_comm_converges_on_random_graph() {
        let (mut solver, opt) = setup(10);
        let mut rng = Pcg64::seed(2);
        let g = Graph::random_connected(10, 20, &mut rng);
        let mut eng = GraphAdmm::new(GraphConfig::default(), g, vec![0.0; 2]);
        for _ in 0..500 {
            eng.round(&mut solver, &mut rng);
        }
        assert!(crate::linalg::dist2(&eng.mean_x(), &opt) < 1e-3);
    }

    #[test]
    fn event_based_converges_near_optimum_with_less_comm() {
        let (mut solver, opt) = setup(8);
        let mut rng = Pcg64::seed(3);
        let g = Graph::random_connected(8, 16, &mut rng);
        let cfg = GraphConfig {
            trigger_x: Trigger::vanilla(5e-3),
            ..Default::default()
        };
        let mut eng = GraphAdmm::new(cfg, g, vec![0.0; 2]);
        for _ in 0..600 {
            eng.round(&mut solver, &mut rng);
        }
        assert!(crate::linalg::dist2(&eng.mean_x(), &opt) < 0.2,
                "err {}", crate::linalg::dist2(&eng.mean_x(), &opt));
        assert!(eng.comm_load() < 0.9, "load {}", eng.comm_load());
    }

    #[test]
    fn random_selection_needs_more_events_for_same_accuracy() {
        // App. G.3: purely random agent selection yields a worse trade-off
        // than event-based selection at matched event budgets.
        let mut rng = Pcg64::seed(4);
        let g = Graph::random_connected(8, 16, &mut rng);

        let run = |trigger: Trigger, rng: &mut Pcg64| {
            let (mut solver, opt) = setup(8);
            let mut eng = GraphAdmm::new(
                GraphConfig { trigger_x: trigger, ..Default::default() },
                g.clone(),
                vec![0.0; 2],
            );
            for _ in 0..400 {
                eng.round(&mut solver, rng);
            }
            (crate::linalg::dist2(&eng.mean_x(), &opt), eng.total_events())
        };
        let (err_event, ev_event) = run(Trigger::vanilla(2e-3), &mut rng);
        // match the event budget with a participation rate
        let rate = ev_event as f64 / (8.0 * 400.0);
        let (err_rand, ev_rand) = run(Trigger::participation(rate), &mut rng);
        assert!((ev_rand as f64) < 1.3 * ev_event as f64 + 200.0);
        assert!(
            err_event < err_rand,
            "event {err_event} !< random {err_rand}"
        );
    }

    #[test]
    fn drops_hurt_and_resets_help() {
        // averaged over seeds: drop-channel noise makes single runs flaky
        let mut rng = Pcg64::seed(5);
        let g = Graph::random_connected(6, 9, &mut rng);
        let run = |reset: usize, seed: u64| {
            let (mut solver, opt) = setup(6);
            let cfg = GraphConfig {
                trigger_x: Trigger::vanilla(1e-4),
                drop_rate: 0.4,
                reset_period: reset,
                ..Default::default()
            };
            let mut eng = GraphAdmm::new(cfg, g.clone(), vec![0.0; 2]);
            let mut rng = Pcg64::seed(seed);
            for _ in 0..500 {
                eng.round(&mut solver, &mut rng);
            }
            crate::linalg::dist2(&eng.mean_x(), &opt)
        };
        let mut err_noreset = 0.0;
        let mut err_reset = 0.0;
        for seed in 0..5u64 {
            err_noreset += run(0, seed) / 5.0;
            err_reset += run(5, seed) / 5.0;
        }
        assert!(err_reset < err_noreset,
                "reset {err_reset} !< noreset {err_noreset}");
    }

    #[test]
    #[should_panic(expected = "connected")]
    fn rejects_disconnected_graph() {
        let g = Graph::new(4, vec![(0, 1), (2, 3)]);
        let _ = GraphAdmm::<f64>::new(GraphConfig::default(), g, vec![0.0]);
    }

    #[test]
    fn link_events_scale_with_degree() {
        let (mut solver, _) = setup(4);
        let g = Graph::complete(4); // degree 3 everywhere
        let mut eng = GraphAdmm::new(GraphConfig::default(), g, vec![0.0; 2]);
        let mut rng = Pcg64::seed(6);
        for _ in 0..10 {
            eng.round(&mut solver, &mut rng);
        }
        assert_eq!(eng.total_events(), 40);
        assert_eq!(eng.total_link_events(), 120);
    }

    #[test]
    fn broadcast_bytes_match_link_events() {
        // identity compressor: every link event carries one dense dim-2
        // message, so total bytes = link events x dense size exactly.
        let (mut solver, _) = setup(4);
        let g = Graph::complete(4);
        let mut eng = GraphAdmm::new(GraphConfig::default(), g, vec![0.0; 2]);
        let mut rng = Pcg64::seed(7);
        for _ in 0..10 {
            eng.round(&mut solver, &mut rng);
        }
        let dense = crate::wire::WireMessage::<f64>::dense_bytes(2) as u64;
        assert_eq!(eng.total_wire_bytes(), eng.total_link_events() * dense);
    }

    #[test]
    fn compressed_broadcast_converges_on_ring() {
        let (mut solver, opt) = setup(6);
        let g = Graph::ring(6);
        let cfg = GraphConfig {
            rounds: 500,
            compressor: crate::wire::CompressorCfg::Quant { bits: 10 },
            ..Default::default()
        };
        let mut eng = GraphAdmm::new(cfg, g, vec![0.0; 2]);
        let mut rng = Pcg64::seed(8);
        for _ in 0..500 {
            eng.round(&mut solver, &mut rng);
        }
        assert!(
            crate::linalg::dist2(&eng.mean_x(), &opt) < 0.1,
            "compressed mean err {}",
            crate::linalg::dist2(&eng.mean_x(), &opt)
        );
    }
}
