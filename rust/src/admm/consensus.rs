//! Alg. 1 — Event-Based Distributed Learning with Over-Relaxed ADMM.
//!
//! N agents hold `(x^i, u^i)` and an estimate `ẑ^i` of the consensus
//! variable; the server (agent N+1) holds `z` and an estimate `ζ̂` of
//! `ζ_k = (1/N) Σ_i (α x^i_{k+1} + u^i_k)`.  All communications are
//! event-based deltas over lossy links; rare periodic resets bound the
//! drop-induced error (Prop. 2.1).
//!
//! One round k:
//!
//! 1. server offers `z_k` on each downlink (`|z_k − z_{[k-1]}| > Δᶻ`);
//!    surviving deltas update the agents' `ẑ^i`;
//! 2. each agent updates
//!    `u^i_k = u^i_{k-1} + α x^i_k − ẑ^i_k + (1−α) ẑ^i_{k-1}`, solves the
//!    local prox problem `x^i_{k+1} = argmin f^i + (ρ/2)|x − ẑ^i_k + u^i_k|²`
//!    (exactly, or by S SGD steps — the `LocalSolver`), and offers
//!    `d^i_{k+1} = α x^i_{k+1} + u^i_k` on its uplink; surviving deltas are
//!    accumulated into `ζ̂` with weight 1/N;
//! 3. server updates `z_{k+1} = prox_g(ζ̂_k + (1−α) z_k; Nρ)`;
//! 4. if `mod(k+1, T) = 0`: full resynchronization (counted as
//!    communication).

use super::core::{self, EventLine, RoundCore};
use crate::comm::{Estimate, Scalar, Trigger};
use crate::rng::Pcg64;
use crate::solver::{LocalSolver, ServerProx};
use crate::wire::{CompressorCfg, WireStats};

/// Hyperparameters of Alg. 1.
#[derive(Clone, Debug)]
pub struct ConsensusConfig {
    /// Augmented-Lagrangian parameter ρ.
    pub rho: f64,
    /// Over-relaxation α ∈ (0, 2); α = 1 is standard ADMM.
    pub alpha: f64,
    pub rounds: usize,
    /// Uplink (d-line) trigger.
    pub trigger_d: Trigger,
    /// Downlink (z-line) trigger, applied per agent link.
    pub trigger_z: Trigger,
    /// Uplink packet-drop probability.
    pub drop_up: f64,
    /// Downlink packet-drop probability.
    pub drop_down: f64,
    /// Reset period T; 0 disables resets.
    pub reset_period: usize,
    /// Delta compressor applied on every line (uplink and downlink), with
    /// per-line error feedback.  `Identity` reproduces the uncompressed
    /// protocol bit-for-bit.
    pub compressor: CompressorCfg,
    /// Worker threads for the per-agent local-solve phase; 0 = auto
    /// (`DELUXE_WORKERS`, else one per core).  Trajectories are
    /// bit-identical for every value (see `admm::core`).
    pub workers: usize,
}

impl Default for ConsensusConfig {
    fn default() -> Self {
        ConsensusConfig {
            rho: 1.0,
            alpha: 1.0,
            rounds: 100,
            trigger_d: Trigger::Always,
            trigger_z: Trigger::Always,
            drop_up: 0.0,
            drop_down: 0.0,
            reset_period: 0,
            compressor: CompressorCfg::Identity,
            workers: 0,
        }
    }
}

struct AgentState<T: Scalar> {
    x: Vec<T>,
    u: Vec<T>,
    zhat: Estimate<T>,
    zhat_prev: Vec<T>,
    d: Vec<T>,
    /// Agent → server d-line.
    up: EventLine<T>,
    /// Server → agent z-line (per-link trigger lives server-side).
    down: EventLine<T>,
}

/// The Alg. 1 engine. Generic over scalar type: `f64` for the convex
/// experiments, `f32` for the neural parameter vectors.  The per-line
/// plumbing, reset accounting, stats aggregation and the parallel
/// local-solve phase all live in [`crate::admm::core`].
pub struct ConsensusAdmm<T: Scalar> {
    pub cfg: ConsensusConfig,
    pub n: usize,
    pub dim: usize,
    pub z: Vec<T>,
    zeta_hat: Estimate<T>,
    agents: Vec<AgentState<T>>,
    core: RoundCore<T>,
}

impl<T: Scalar> ConsensusAdmm<T> {
    /// All state starts synchronized at `z0` (the paper's initialization
    /// `x̂_0 = x_0 = ẑ_0 = ζ_0`).
    pub fn new(cfg: ConsensusConfig, n: usize, z0: Vec<T>) -> Self {
        let dim = z0.len();
        let zeros = vec![T::zero(); dim];
        let agents = (0..n)
            .map(|_| AgentState {
                x: z0.clone(),
                u: zeros.clone(),
                zhat: Estimate::new(z0.clone()),
                zhat_prev: z0.clone(),
                d: z0.clone(),
                up: EventLine::new(cfg.trigger_d, z0.clone(), cfg.drop_up),
                down: EventLine::new(
                    cfg.trigger_z,
                    z0.clone(),
                    cfg.drop_down,
                ),
            })
            .collect();
        let core = RoundCore::new(n, dim, &cfg.compressor, cfg.workers);
        ConsensusAdmm {
            cfg,
            n,
            dim,
            zeta_hat: Estimate::new(z0.clone()),
            z: z0,
            agents,
            core,
        }
    }

    /// Rounds completed so far.
    pub fn round_idx(&self) -> usize {
        self.core.round_idx
    }

    /// Execute one synchronous round.
    pub fn round(
        &mut self,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
        rng: &mut Pcg64,
    ) {
        let alpha = self.cfg.alpha;
        let rho = self.cfg.rho;
        let invn = 1.0 / self.n as f64;
        // per-agent solver streams fork off the round-entry state, so
        // the solve phase is independent of the communication draws
        // below and of worker count (see admm::core)
        let solve_base = rng.clone();

        // 1. server -> agents (z line, per-link trigger + EF-compressed
        //    codec + channel with byte accounting)
        for a in &mut self.agents {
            a.zhat_prev.clear();
            a.zhat_prev.extend_from_slice(a.zhat.get());
            if let Some(msg) = a.down.offer_send(
                &self.z,
                self.core.comp.as_ref(),
                rng,
                &mut self.core.scratch,
            ) {
                a.zhat.apply_msg(&msg);
            }
        }

        // 2a. agents: dual update + prox anchor (sequential, cheap)
        let mut anchors: Vec<Vec<T>> = Vec::with_capacity(self.n);
        for a in &mut self.agents {
            // u^i_k = u^i_{k-1} + α x^i_k − ẑ^i_k + (1−α) ẑ^i_{k-1}
            for j in 0..self.dim {
                let u = a.u[j].to_f64()
                    + alpha * a.x[j].to_f64()
                    - a.zhat.get()[j].to_f64()
                    + (1.0 - alpha) * a.zhat_prev[j].to_f64();
                a.u[j] = T::from_f64(u);
            }
            // anchor = ẑ − u ; x ← argmin f + (ρ/2)|x − anchor|²
            anchors.push(
                a.zhat
                    .get()
                    .iter()
                    .zip(&a.u)
                    .map(|(&z, &u)| T::from_f64(z.to_f64() - u.to_f64()))
                    .collect(),
            );
        }

        // 2b. the local-solve phase — the round's dominant cost — on the
        //     worker pool, one forked RNG stream per agent
        let mut rngs = self.core.round_solve_rngs(&solve_base);
        let xs = solver.solve_batch(
            self.core.agent_ids(),
            &anchors,
            rho,
            &mut rngs,
            &self.core.pool,
        );

        // 2c. ordered reduction: event send of d in agent order
        for (a, x) in self.agents.iter_mut().zip(xs) {
            debug_assert_eq!(x.len(), self.dim);
            a.x = x;
            // d^i = α x^i_{k+1} + u^i_k
            a.d = a
                .x
                .iter()
                .zip(&a.u)
                .map(|(&x, &u)| T::from_f64(alpha * x.to_f64() + u.to_f64()))
                .collect();
            if let Some(msg) = a.up.offer_send(
                &a.d,
                self.core.comp.as_ref(),
                rng,
                &mut self.core.scratch,
            ) {
                self.zeta_hat.apply_scaled_msg(&msg, invn);
            }
        }

        // 3. server: z_{k+1} = prox_g(ζ̂_k + (1−α) z_k; Nρ)
        let v: Vec<T> = self
            .zeta_hat
            .get()
            .iter()
            .zip(&self.z)
            .map(|(&zh, &z)| {
                T::from_f64(zh.to_f64() + (1.0 - alpha) * z.to_f64())
            })
            .collect();
        self.z = prox.prox(&v, self.n as f64 * rho);
        debug_assert_eq!(self.z.len(), self.dim);

        // 4. periodic reset (full resynchronization, counted as comm)
        if self.core.finish_round(self.cfg.reset_period) {
            self.reset();
        }
    }

    /// Full resynchronization: `ζ̂ = ζ` (true average of the `d^i`), and
    /// every agent receives the exact `z`.  Advances all trigger reference
    /// points, counts one event per line, charges each line one full dense
    /// message (a reset is an uncompressed synchronization transfer), and
    /// drops any carried compression residual.  A packet that triggered
    /// but *dropped* in the same round is superseded by the sync — the
    /// round bills exactly one dense transfer on that line, never two
    /// (see [`crate::transport::loss::LossyLink::charge_sync`] /
    /// [`EventLine::resync`]).
    pub fn reset(&mut self) {
        let mut zeta = vec![0.0f64; self.dim];
        for a in &self.agents {
            for (s, &d) in zeta.iter_mut().zip(&a.d) {
                *s += d.to_f64();
            }
        }
        let invn = 1.0 / self.n as f64;
        let zeta: Vec<T> =
            zeta.into_iter().map(|v| T::from_f64(v * invn)).collect();
        self.zeta_hat.reset_to(&zeta);
        for a in &mut self.agents {
            a.zhat.reset_to(&self.z);
            a.up.resync(&a.d);
            a.down.resync(&self.z);
        }
    }

    /// True `ζ_k` (what `ζ̂` estimates) — for Prop. 2.1 diagnostics.
    pub fn true_zeta(&self) -> Vec<f64> {
        let mut zeta = vec![0.0f64; self.dim];
        for a in &self.agents {
            for (s, &d) in zeta.iter_mut().zip(&a.d) {
                *s += d.to_f64();
            }
        }
        for v in &mut zeta {
            *v /= self.n as f64;
        }
        zeta
    }

    /// `|ζ̂ − ζ|` — the quantity Prop. 2.1 bounds by `Δᵈ + T χ̄ᵈ`.
    pub fn zeta_error(&self) -> f64 {
        let t = self.true_zeta();
        self.zeta_hat
            .get()
            .iter()
            .zip(&t)
            .map(|(&a, &b)| (a.to_f64() - b) * (a.to_f64() - b))
            .sum::<f64>()
            .sqrt()
    }

    pub fn agent_x(&self, i: usize) -> &[T] {
        &self.agents[i].x
    }
    pub fn agent_u(&self, i: usize) -> &[T] {
        &self.agents[i].u
    }
    pub fn agent_zhat(&self, i: usize) -> &[T] {
        self.agents[i].zhat.get()
    }

    /// Mean residual `(1/N) Σ |x^i − z|`.
    pub fn mean_residual(&self) -> f64 {
        self.agents
            .iter()
            .map(|a| {
                a.x.iter()
                    .zip(&self.z)
                    .map(|(&x, &z)| {
                        let d = x.to_f64() - z.to_f64();
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / self.n as f64
    }

    /// Total triggered communication events (up + down lines; resets
    /// included via the trigger counters).
    pub fn total_events(&self) -> u64 {
        core::events_sum(self.agents.iter().map(|a| &a.up))
            + core::events_sum(self.agents.iter().map(|a| &a.down))
    }

    /// Events normalized by full communication (2N links per round).
    pub fn comm_load(&self) -> f64 {
        self.core.comm_load(self.total_events(), 2.0 * self.n as f64)
    }

    /// Per-direction event counts `(uplink, downlink)`.
    pub fn events_split(&self) -> (u64, u64) {
        (
            core::events_sum(self.agents.iter().map(|a| &a.up)),
            core::events_sum(self.agents.iter().map(|a| &a.down)),
        )
    }

    /// Dropped-packet counts `(uplink, downlink)`.
    pub fn drops_split(&self) -> (u64, u64) {
        (
            core::drops_sum(self.agents.iter().map(|a| &a.up)),
            core::drops_sum(self.agents.iter().map(|a| &a.down)),
        )
    }

    /// Byte-accurate per-agent wire accounting (both directions).
    pub fn wire_stats(&self) -> WireStats {
        core::wire_stats(
            self.agents.iter().map(|a| &a.up),
            self.agents.iter().map(|a| &a.down),
        )
    }

    /// Total sent bytes `(uplink, downlink)`.
    pub fn bytes_split(&self) -> (u64, u64) {
        (
            core::bytes_sum(self.agents.iter().map(|a| &a.up)),
            core::bytes_sum(self.agents.iter().map(|a| &a.down)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::IdentityProx;

    /// Scalar quadratic agents: f_i(x) = 0.5 w_i (x - c_i)^2 over R^1.
    /// Global optimum of sum: x* = Σ w_i c_i / Σ w_i.
    struct ScalarQuad {
        w: Vec<f64>,
        c: Vec<f64>,
    }

    impl LocalSolver<f64> for ScalarQuad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            // argmin 0.5 w (x-c)^2 + rho/2 (x-a)^2
            let (w, c) = (self.w[agent], self.c[agent]);
            vec![(w * c + rho * anchor[0]) / (w + rho)]
        }
        fn dim(&self) -> usize {
            1
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    fn quad() -> (ScalarQuad, f64) {
        let w = vec![1.0, 2.0, 0.5, 3.0];
        let c = vec![-1.0, 4.0, 10.0, 0.5];
        let opt = w.iter().zip(&c).map(|(a, b)| a * b).sum::<f64>()
            / w.iter().sum::<f64>();
        (ScalarQuad { w, c }, opt)
    }

    fn run(cfg: ConsensusConfig, seed: u64) -> (ConsensusAdmm<f64>, f64) {
        let (mut solver, opt) = quad();
        let mut engine = ConsensusAdmm::new(cfg.clone(), 4, vec![0.0]);
        let mut prox = IdentityProx;
        let mut rng = Pcg64::seed(seed);
        for _ in 0..cfg.rounds {
            engine.round(&mut solver, &mut prox, &mut rng);
        }
        (engine, opt)
    }

    #[test]
    fn full_communication_converges_to_global_optimum() {
        let (engine, opt) = run(
            ConsensusConfig { rounds: 300, ..Default::default() },
            1,
        );
        assert!(
            (engine.z[0] - opt).abs() < 1e-8,
            "z {} vs opt {opt}",
            engine.z[0]
        );
        assert!(engine.mean_residual() < 1e-6);
        // full communication => load 1
        assert!((engine.comm_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn over_relaxed_converges() {
        let cfg = ConsensusConfig {
            alpha: 1.5,
            rounds: 300,
            ..Default::default()
        };
        let (engine, opt) = run(cfg, 2);
        assert!((engine.z[0] - opt).abs() < 1e-8);
    }

    #[test]
    fn event_based_converges_within_delta_band_with_less_comm() {
        let cfg = ConsensusConfig {
            rounds: 400,
            trigger_d: Trigger::vanilla(1e-3),
            trigger_z: Trigger::vanilla(1e-4),
            ..Default::default()
        };
        let (engine, opt) = run(cfg, 3);
        // Cor 2.2: steady-state error proportional to Delta
        assert!(
            (engine.z[0] - opt).abs() < 0.2,
            "z {} vs {opt}",
            engine.z[0]
        );
        assert!(engine.comm_load() < 0.7, "load {}", engine.comm_load());
    }

    #[test]
    fn smaller_delta_gives_better_accuracy_more_comm() {
        let mk = |delta: f64| ConsensusConfig {
            rounds: 400,
            trigger_d: Trigger::vanilla(delta),
            trigger_z: Trigger::vanilla(delta * 0.1),
            ..Default::default()
        };
        let (e_small, opt) = run(mk(1e-4), 4);
        let (e_large, _) = run(mk(1e-1), 4);
        let err_small = (e_small.z[0] - opt).abs();
        let err_large = (e_large.z[0] - opt).abs();
        assert!(err_small <= err_large + 1e-12);
        assert!(e_small.total_events() > e_large.total_events());
    }

    #[test]
    fn randomized_trigger_converges() {
        let cfg = ConsensusConfig {
            rounds: 400,
            trigger_d: Trigger::randomized(1e-2, 0.1),
            trigger_z: Trigger::randomized(1e-3, 0.1),
            ..Default::default()
        };
        let (engine, opt) = run(cfg, 5);
        assert!((engine.z[0] - opt).abs() < 0.3);
    }

    #[test]
    fn drops_without_reset_leave_large_error() {
        let cfg = ConsensusConfig {
            rounds: 400,
            trigger_d: Trigger::vanilla(1e-4),
            trigger_z: Trigger::vanilla(1e-5),
            drop_up: 0.3,
            reset_period: 0,
            ..Default::default()
        };
        let (engine, opt) = run(cfg.clone(), 6);
        let err_noreset = (engine.z[0] - opt).abs();
        // with frequent resets the error collapses
        let cfg_reset = ConsensusConfig { reset_period: 5, ..cfg };
        let (engine_r, _) = run(cfg_reset, 6);
        let err_reset = (engine_r.z[0] - opt).abs();
        assert!(
            err_reset < err_noreset,
            "reset {err_reset} !< no-reset {err_noreset}"
        );
        assert!(err_reset < 0.05, "err with reset {err_reset}");
    }

    #[test]
    fn prop21_zeta_error_bounded_without_drops() {
        // |ζ̂ − ζ| <= Δ^d with reliable links (Prop 2.1, χ̄ = 0).
        let delta_d = 5e-2;
        let cfg = ConsensusConfig {
            rounds: 200,
            trigger_d: Trigger::vanilla(delta_d),
            trigger_z: Trigger::vanilla(1e-3),
            ..Default::default()
        };
        let (mut solver, _) = quad();
        let mut engine = ConsensusAdmm::new(cfg, 4, vec![0.0]);
        let mut prox = IdentityProx;
        let mut rng = Pcg64::seed(7);
        for _ in 0..200 {
            engine.round(&mut solver, &mut prox, &mut rng);
            assert!(
                engine.zeta_error() <= delta_d + 1e-12,
                "zeta error {} > Delta {delta_d}",
                engine.zeta_error()
            );
        }
    }

    #[test]
    fn participation_trigger_mimics_fedadmm_sampling() {
        let cfg = ConsensusConfig {
            rounds: 600,
            trigger_d: Trigger::participation(0.5),
            trigger_z: Trigger::Always,
            ..Default::default()
        };
        let (engine, opt) = run(cfg, 8);
        assert!(
            (engine.z[0] - opt).abs() < 0.3,
            "z {} vs {opt}",
            engine.z[0]
        );
        let (up, _) = engine.events_split();
        let rate = up as f64 / (4.0 * 600.0);
        assert!((rate - 0.5).abs() < 0.1, "uplink rate {rate}");
    }

    #[test]
    fn identity_compressor_bytes_equal_events_times_dense_size() {
        // Byte accounting sanity: with the identity compressor every
        // triggered message is one dense payload of the problem dimension.
        let cfg = ConsensusConfig {
            rounds: 200,
            trigger_d: Trigger::vanilla(1e-3),
            trigger_z: Trigger::vanilla(1e-4),
            ..Default::default()
        };
        let (engine, _) = run(cfg, 21);
        let (up_ev, down_ev) = engine.events_split();
        let (up_bytes, down_bytes) = engine.bytes_split();
        let dense = crate::wire::WireMessage::<f64>::dense_bytes(1) as u64;
        assert_eq!(up_bytes, up_ev * dense);
        assert_eq!(down_bytes, down_ev * dense);
        let ws = engine.wire_stats();
        assert_eq!(ws.uplink_bytes(), up_bytes);
        assert_eq!(ws.downlink_bytes(), down_bytes);
        assert_eq!(ws.uplink.len(), 4);
    }

    #[test]
    fn default_identity_compressor_matches_handrolled_protocol() {
        // ConsensusConfig::default() must reproduce the *uncompressed*
        // protocol bit-for-bit.  Pinned against an independent scalar
        // re-implementation of Alg. 1 (dim 1, α = 1, g = 0, vanilla
        // triggers, reliable links) rather than a second run of the same
        // engine, so a regression in the identity wire path cannot hide.
        let delta_d = 1e-3;
        let delta_z = 1e-4;
        let cfg = ConsensusConfig {
            rounds: 200,
            trigger_d: Trigger::vanilla(delta_d),
            trigger_z: Trigger::vanilla(delta_z),
            ..Default::default()
        };
        assert_eq!(cfg.compressor, crate::wire::CompressorCfg::Identity);
        let (mut solver, _) = quad();
        let mut engine = ConsensusAdmm::new(cfg, 4, vec![0.0]);
        let mut prox = IdentityProx;
        let mut rng = Pcg64::seed(55);

        // reference state (mirrors quad()'s weights/centers)
        let w = [1.0f64, 2.0, 0.5, 3.0];
        let c = [-1.0f64, 4.0, 10.0, 0.5];
        let rho = 1.0;
        let alpha = 1.0;
        let mut x = [0.0f64; 4];
        let mut u = [0.0f64; 4];
        let mut zhat = [0.0f64; 4]; // per-agent estimate of z
        let mut z_last = [0.0f64; 4]; // per-link last-sent z
        let mut d = [0.0f64; 4];
        let mut d_last = [0.0f64; 4];
        let mut zeta_hat = 0.0f64;
        let mut z = 0.0f64;

        for k in 0..200 {
            // 1. downlink (vanilla trigger per link, no drops)
            let mut zhat_prev = [0.0f64; 4];
            for i in 0..4 {
                zhat_prev[i] = zhat[i];
                if (z - z_last[i]).abs() > delta_z {
                    let delta = z - z_last[i];
                    z_last[i] = z;
                    zhat[i] += delta;
                }
            }
            // 2. agents: u update, exact prox solve, uplink
            for i in 0..4 {
                u[i] = u[i] + alpha * x[i] - zhat[i]
                    + (1.0 - alpha) * zhat_prev[i];
                let anchor = zhat[i] - u[i];
                x[i] = (w[i] * c[i] + rho * anchor) / (w[i] + rho);
                d[i] = alpha * x[i] + u[i];
                if (d[i] - d_last[i]).abs() > delta_d {
                    let delta = d[i] - d_last[i];
                    d_last[i] = d[i];
                    zeta_hat += delta * 0.25;
                }
            }
            // 3. server (g = 0, alpha = 1)
            z = zeta_hat + (1.0 - alpha) * z;

            engine.round(&mut solver, &mut prox, &mut rng);
            assert_eq!(
                engine.z[0], z,
                "identity wire path diverged from the uncompressed \
                 protocol at round {k}"
            );
        }
        for i in 0..4 {
            assert_eq!(engine.agent_x(i)[0], x[i]);
            assert_eq!(engine.agent_u(i)[0], u[i]);
        }
    }

    #[test]
    fn reset_supersedes_same_round_dropped_packet() {
        // Accounting edge case: with drop_up = 1.0, trigger Always and a
        // reset every round, each round's uplink carries one
        // triggered-but-dropped delta followed by the reset sync.  The
        // reset supersedes the lost packet, so the books must show
        // exactly one dense sync per round — not a dropped message PLUS
        // a sync.
        let cfg = ConsensusConfig {
            rounds: 3,
            drop_up: 1.0,
            reset_period: 1,
            ..Default::default()
        };
        let (engine, _) = run(cfg, 40);
        let dense = crate::wire::WireMessage::<f64>::dense_bytes(1) as u64;
        let ws = engine.wire_stats();
        for l in &ws.uplink {
            assert_eq!(l.msgs, 3, "one sync per round, drop superseded");
            assert_eq!(l.bytes, 3 * dense);
            assert_eq!(l.dropped_msgs, 0);
            assert_eq!(l.dropped_bytes, 0);
        }
        // downlink is reliable here: each round bills the delivered
        // triggered delta AND the reset sync
        for l in &ws.downlink {
            assert_eq!(l.msgs, 6);
            assert_eq!(l.bytes, 6 * dense);
            assert_eq!(l.dropped_msgs, 0);
        }
    }

    #[test]
    fn unified_core_reproduces_pre_refactor_counters() {
        // Pinned against the pre-unification engine's accounting rules:
        // with Always triggers, reliable links and T = 5 over 20 rounds,
        // every line fires once per round and each of the 4 resets adds
        // one event + one dense sync per line.  These closed-form
        // counters are exactly what the four hand-rolled engines
        // produced before the round core existed.
        let cfg = ConsensusConfig {
            rounds: 20,
            reset_period: 5,
            ..Default::default()
        };
        let (engine, _) = run(cfg, 17);
        let per_line: u64 = 20 + 4; // triggered + reset events
        assert_eq!(engine.events_split(), (4 * per_line, 4 * per_line));
        assert_eq!(engine.drops_split(), (0, 0));
        let dense = crate::wire::WireMessage::<f64>::dense_bytes(1) as u64;
        assert_eq!(
            engine.bytes_split(),
            (4 * per_line * dense, 4 * per_line * dense)
        );
        let ws = engine.wire_stats();
        for l in ws.uplink.iter().chain(&ws.downlink) {
            assert_eq!(l.msgs, per_line);
            assert_eq!(l.bytes, per_line * dense);
        }
        assert_eq!(engine.round_idx(), 20);
        assert!((engine.comm_load() - 1.2).abs() < 1e-12);
    }

    #[test]
    fn quantized_engine_with_error_feedback_still_converges() {
        // 8-bit stochastic quantization + per-line error feedback on the
        // scalar quadratic: the engine must still settle near the optimum
        // (per-message bytes are only interesting at real dimensions —
        // see experiments::pareto for the ratio claims).
        let cfg = ConsensusConfig {
            rounds: 500,
            trigger_d: Trigger::vanilla(1e-3),
            trigger_z: Trigger::vanilla(1e-4),
            compressor: crate::wire::CompressorCfg::Quant { bits: 8 },
            ..Default::default()
        };
        let (quant, opt) = run(cfg, 23);
        assert!(
            (quant.z[0] - opt).abs() < 0.3,
            "quantized z {} vs opt {opt}",
            quant.z[0]
        );
        let (up_bytes, down_bytes) = quant.bytes_split();
        assert!(up_bytes > 0 && down_bytes > 0, "bytes must be counted");
    }

    #[test]
    fn f32_engine_runs() {
        struct Pull;
        impl LocalSolver<f32> for Pull {
            fn solve(
                &mut self,
                _a: usize,
                anchor: &[f32],
                _rho: f64,
                _rng: &mut Pcg64,
            ) -> Vec<f32> {
                anchor.iter().map(|v| v + 1.0).collect()
            }
            fn dim(&self) -> usize {
                3
            }
            fn n_agents(&self) -> usize {
                2
            }
        }
        let mut engine = ConsensusAdmm::<f32>::new(
            ConsensusConfig::default(),
            2,
            vec![0.0f32; 3],
        );
        let mut rng = Pcg64::seed(9);
        let mut prox = IdentityProx;
        engine.round(&mut Pull, &mut prox, &mut rng);
        assert_eq!(engine.z.len(), 3);
        assert!(engine.z.iter().all(|v| v.is_finite()));
    }
}
