//! The paper's algorithms, all built on one shared round core.
//!
//! * [`core`] — the unified engine substrate: [`core::EventLine`] /
//!   [`core::BroadcastLine`] communication lines, [`core::RoundCore`]
//!   round/reset cadence + stats aggregation, and the deterministic
//!   [`core::WorkerPool`] executing the per-agent local-solve phase in
//!   parallel (bit-identical for every `--workers` value).
//! * [`consensus`] — Alg. 1: event-based consensus ADMM (server–client).
//! * [`general`] — Alg. 2: event-based over-relaxed ADMM for
//!   `min f(x) + g(z) s.t. Ax + Bz = c` with r/s/u agents (App. C).
//! * [`graph`] — decentralized consensus over a communication graph
//!   (Eq. 7, App. A.2).
//! * [`sharing`] — the sharing problem (Eqs. 5–6, App. A.1).

pub mod consensus;
pub mod core;
pub mod general;
pub mod graph;
pub mod sharing;

pub use consensus::{ConsensusAdmm, ConsensusConfig};
pub use general::{GeneralAdmm, GeneralConfig, QuadraticF, ZProx};
pub use self::core::{BroadcastLine, EventLine, RoundCore, WorkerPool};
pub use graph::{GraphAdmm, GraphConfig};
pub use sharing::{SharingAdmm, SharingConfig};
