//! The paper's algorithms.
//!
//! * [`consensus`] — Alg. 1: event-based consensus ADMM (server–client).
//! * [`general`] — Alg. 2: event-based over-relaxed ADMM for
//!   `min f(x) + g(z) s.t. Ax + Bz = c` with r/s/u agents (App. C).
//! * [`graph`] — decentralized consensus over a communication graph
//!   (Eq. 7, App. A.2).
//! * [`sharing`] — the sharing problem (Eqs. 5–6, App. A.1).

pub mod consensus;
pub mod general;
pub mod graph;
pub mod sharing;

pub use consensus::{ConsensusAdmm, ConsensusConfig};
pub use general::{GeneralAdmm, GeneralConfig, QuadraticF, ZProx};
pub use graph::{GraphAdmm, GraphConfig};
pub use sharing::{SharingAdmm, SharingConfig};
