//! Alg. 2 — Event-Based Distributed Optimization with Over-Relaxed ADMM
//! for the general constrained problem
//!
//! ```text
//! min f(x) + g(z)   s.t.  A x + B z = c
//! ```
//!
//! Three agents keep `r = Ax`, `s = Bz` and the dual `u`; the six
//! communication lines (r→s, r→u, s→r, s→u, u→r, u→s — Fig. 2/4) are each
//! an event-triggered lossy link with its own threshold.  This is the
//! dynamical system of App. C; the convergence envelope of Thm. 4.1 is
//! validated against this implementation in `experiments::rates` and the
//! integration tests.

use super::core::{self, EventLine, RoundCore};
use crate::comm::{Estimate, Trigger};
use crate::linalg::{soft_threshold, Cholesky, Matrix};
use crate::rng::Pcg64;
use crate::wire::CompressorCfg;

/// Smooth part: `f(x) = ½ xᵀHx + qᵀx` (covers least squares
/// `½|Dx−b|²` via `H = DᵀD`, `q = −Dᵀb`).  The x-update is the linear
/// solve `(H + ρAᵀA) x = −q + ρAᵀ(c − ŝ − û)` with a cached factorization.
pub struct QuadraticF {
    pub h: Matrix,
    pub q: Vec<f64>,
    cache: Option<(f64, Cholesky)>,
}

impl QuadraticF {
    pub fn new(h: Matrix, q: Vec<f64>) -> Self {
        assert_eq!(h.rows, h.cols);
        assert_eq!(h.rows, q.len());
        QuadraticF { h, q, cache: None }
    }

    /// From least squares `½|Dx − b|²`.
    pub fn least_squares(d: &Matrix, b: &[f64]) -> Self {
        let h = d.gram();
        let q: Vec<f64> = d.tmatvec(b).iter().map(|v| -v).collect();
        QuadraticF::new(h, q)
    }

    /// `f(x)` value.
    pub fn eval(&self, x: &[f64]) -> f64 {
        0.5 * crate::linalg::dot(x, &self.h.matvec(x))
            + crate::linalg::dot(&self.q, x)
    }

    fn solve_x(&mut self, a: &Matrix, rhs_dir: &[f64], rho: f64) -> Vec<f64> {
        // rhs_dir = c − ŝ − û (length r); solve (H + ρAᵀA)x = −q + ρAᵀ rhs_dir
        let stale = match &self.cache {
            Some((r, _)) => (*r - rho).abs() > 1e-12 * rho.max(1.0),
            None => true,
        };
        if stale {
            let mut m = a.gram();
            for v in &mut m.data {
                *v *= rho;
            }
            for i in 0..self.h.rows {
                for j in 0..self.h.cols {
                    m[(i, j)] += self.h[(i, j)];
                }
            }
            let chol =
                // lint:allow(panic-in-library): H ⪰ 0 plus ρAᵀA with ρ > 0 is PD for the full-rank problems this engine accepts; failure means malformed problem data
                Cholesky::factor(&m).expect("H + rho A'A must be PD");
            self.cache = Some((rho, chol));
        }
        let mut rhs: Vec<f64> = self.q.iter().map(|v| -v).collect();
        let at_rhs = a.tmatvec(rhs_dir);
        crate::linalg::axpy(&mut rhs, rho, &at_rhs);
        // rhs doubles as the solution buffer (§Perf: allocation-free
        // Cholesky::solve_in_place on the per-round x-update)
        // lint:allow(panic-in-library): the stale-branch above just filled the cache, so as_ref() cannot be None
        self.cache.as_ref().unwrap().1.solve_in_place(&mut rhs);
        rhs
    }
}

/// The z-update: `argmin_z g(z) + (ρ/2)|Bz + w|²`, returning `(z, s=Bz)`.
pub enum ZProx {
    /// `B = b_diag · I`, `g = λ|z|₁` (λ = 0 for smooth-free consensus).
    Diag { b_diag: f64, lambda: f64 },
    /// General full-column-rank `B`, `g = 0`.
    Dense { b: Matrix, chol: Cholesky },
}

impl ZProx {
    pub fn diag(b_diag: f64, lambda: f64) -> Self {
        assert!(b_diag != 0.0);
        ZProx::Diag { b_diag, lambda }
    }

    pub fn dense(b: Matrix) -> Self {
        // lint:allow(panic-in-library): full column rank of B is this constructor's documented precondition; failing fast at construction beats a wrong fixed point later
        let chol = Cholesky::factor(&b.gram()).expect("B must be full rank");
        ZProx::Dense { b, chol }
    }

    pub fn z_dim(&self, r_dim: usize) -> usize {
        match self {
            ZProx::Diag { .. } => r_dim,
            ZProx::Dense { b, .. } => b.cols,
        }
    }

    fn update(&self, w: &[f64], rho: f64) -> (Vec<f64>, Vec<f64>) {
        match self {
            ZProx::Diag { b_diag, lambda } => {
                let b = *b_diag;
                // minimize λ|z|₁ + (ρb²/2)|z + w/b|² → z = S_{λ/(ρb²)}(−w/b)
                let target: Vec<f64> = w.iter().map(|v| -v / b).collect();
                let z = if *lambda > 0.0 {
                    soft_threshold(&target, lambda / (rho * b * b))
                } else {
                    target
                };
                let s: Vec<f64> = z.iter().map(|v| v * b).collect();
                (z, s)
            }
            ZProx::Dense { b, chol } => {
                // BᵀB z = −Bᵀ w
                let rhs: Vec<f64> =
                    b.tmatvec(w).iter().map(|v| -v).collect();
                let z = chol.solve(&rhs);
                let s = b.matvec(&z);
                (z, s)
            }
        }
    }
}

/// Per-line thresholds/settings of Alg. 2.
#[derive(Clone, Debug)]
pub struct GeneralConfig {
    pub rho: f64,
    pub alpha: f64,
    pub rounds: usize,
    pub trig_rs: Trigger,
    pub trig_ru: Trigger,
    pub trig_sr: Trigger,
    pub trig_su: Trigger,
    pub trig_ur: Trigger,
    pub trig_us: Trigger,
    pub drop_rate: f64,
    pub reset_period: usize,
    /// Delta compressor applied on all six lines (per-line error
    /// feedback); `Identity` reproduces the uncompressed protocol.
    pub compressor: CompressorCfg,
    /// Worker-pool knob threaded for config uniformity; Alg. 2 has one
    /// monolithic x-update (a single linear solve), so its round has no
    /// per-agent solve phase to shard.
    pub workers: usize,
}

impl Default for GeneralConfig {
    fn default() -> Self {
        GeneralConfig {
            rho: 1.0,
            alpha: 1.0,
            rounds: 100,
            trig_rs: Trigger::Always,
            trig_ru: Trigger::Always,
            trig_sr: Trigger::Always,
            trig_su: Trigger::Always,
            trig_ur: Trigger::Always,
            trig_us: Trigger::Always,
            drop_rate: 0.0,
            reset_period: 0,
            compressor: CompressorCfg::Identity,
            workers: 1,
        }
    }
}

impl GeneralConfig {
    /// Set all six thresholds to the same vanilla Δ.
    pub fn with_uniform_delta(mut self, delta: f64) -> Self {
        let t = Trigger::vanilla(delta);
        self.trig_rs = t;
        self.trig_ru = t;
        self.trig_sr = t;
        self.trig_su = t;
        self.trig_ur = t;
        self.trig_us = t;
        self
    }
}

/// The Alg. 2 engine.  The six transmit lines are
/// [`EventLine`]s from the shared round core (Alg. 2 was the template
/// the core's line bundle was extracted from).
pub struct GeneralAdmm {
    pub cfg: GeneralConfig,
    pub a: Matrix,
    pub c: Vec<f64>,
    pub f: QuadraticF,
    pub zprox: ZProx,

    pub x: Vec<f64>,
    pub z: Vec<f64>,
    pub r: Vec<f64>,
    pub s: Vec<f64>,
    pub u: Vec<f64>,

    // receiver estimates
    s_at_r: Estimate<f64>,
    u_at_r: Estimate<f64>,
    r_at_s: Estimate<f64>,
    u_at_s: Estimate<f64>,
    r_at_u: Estimate<f64>,
    s_at_u: Estimate<f64>,
    s_at_u_prev: Vec<f64>,

    // transmit lines
    line_rs: EventLine<f64>,
    line_ru: EventLine<f64>,
    line_sr: EventLine<f64>,
    line_su: EventLine<f64>,
    line_ur: EventLine<f64>,
    line_us: EventLine<f64>,

    /// Round/reset cadence, shared compressor, scratch, stats plumbing.
    core: RoundCore<f64>,
}

impl GeneralAdmm {
    pub fn new(
        cfg: GeneralConfig,
        a: Matrix,
        c: Vec<f64>,
        f: QuadraticF,
        zprox: ZProx,
        x0: Vec<f64>,
        z0: Vec<f64>,
    ) -> Self {
        assert_eq!(a.rows, c.len());
        assert_eq!(a.cols, x0.len());
        let r0 = a.matvec(&x0);
        let s0 = match &zprox {
            ZProx::Diag { b_diag, .. } => {
                z0.iter().map(|v| v * b_diag).collect::<Vec<f64>>()
            }
            ZProx::Dense { b, .. } => b.matvec(&z0),
        };
        assert_eq!(s0.len(), r0.len(), "B rows must match A rows");
        let u0 = vec![0.0; r0.len()];
        let dr = cfg.drop_rate;
        // r-, s- and u-agents
        let core =
            RoundCore::new(3, r0.len(), &cfg.compressor, cfg.workers);
        GeneralAdmm {
            line_rs: EventLine::new(cfg.trig_rs, r0.clone(), dr),
            line_ru: EventLine::new(cfg.trig_ru, r0.clone(), dr),
            line_sr: EventLine::new(cfg.trig_sr, s0.clone(), dr),
            line_su: EventLine::new(cfg.trig_su, s0.clone(), dr),
            line_ur: EventLine::new(cfg.trig_ur, u0.clone(), dr),
            line_us: EventLine::new(cfg.trig_us, u0.clone(), dr),
            s_at_r: Estimate::new(s0.clone()),
            u_at_r: Estimate::new(u0.clone()),
            r_at_s: Estimate::new(r0.clone()),
            u_at_s: Estimate::new(u0.clone()),
            r_at_u: Estimate::new(r0.clone()),
            s_at_u: Estimate::new(s0.clone()),
            s_at_u_prev: s0.clone(),
            core,
            cfg,
            a,
            c,
            f,
            zprox,
            x: x0,
            z: z0,
            r: r0,
            s: s0,
            u: u0,
        }
    }

    /// Rounds completed so far.
    pub fn round_idx(&self) -> usize {
        self.core.round_idx
    }

    /// One synchronous round of Alg. 2.
    pub fn round(&mut self, rng: &mut Pcg64) {
        let rho = self.cfg.rho;
        let alpha = self.cfg.alpha;
        let rdim = self.r.len();

        // ---- r-agent: x-update from its estimates of s and u ----
        // (H + ρAᵀA) x = −q + ρAᵀ(c − ŝ − û)
        let dir: Vec<f64> = (0..rdim)
            .map(|j| {
                self.c[j] - self.s_at_r.get()[j] - self.u_at_r.get()[j]
            })
            .collect();
        self.x = self.f.solve_x(&self.a, &dir, rho);
        self.r = self.a.matvec(&self.x);
        if let Some(msg) = self.line_rs.offer_send(
            &self.r,
            self.core.comp.as_ref(),
            rng,
            &mut self.core.scratch,
        ) {
            self.r_at_s.apply_msg(&msg);
        }
        if let Some(msg) = self.line_ru.offer_send(
            &self.r,
            self.core.comp.as_ref(),
            rng,
            &mut self.core.scratch,
        ) {
            self.r_at_u.apply_msg(&msg);
        }

        // ---- s-agent: z-update ----
        // w = α r̂ˢ − (1−α) s_k + û ˢ − α c   (note: uses the s-agent's own
        // true s_k; the estimate errors enter through r̂ and û)
        let w: Vec<f64> = (0..rdim)
            .map(|j| {
                alpha * self.r_at_s.get()[j] - (1.0 - alpha) * self.s[j]
                    + self.u_at_s.get()[j]
                    - alpha * self.c[j]
            })
            .collect();
        let (z, s_new) = self.zprox.update(&w, rho);
        self.z = z;
        self.s = s_new;
        if let Some(msg) = self.line_sr.offer_send(
            &self.s,
            self.core.comp.as_ref(),
            rng,
            &mut self.core.scratch,
        ) {
            self.s_at_r.apply_msg(&msg);
        }
        // u-agent needs ŝᵘ_k and ŝᵘ_{k+1}: stash prev before delivery
        self.s_at_u_prev.clear();
        self.s_at_u_prev.extend_from_slice(self.s_at_u.get());
        if let Some(msg) = self.line_su.offer_send(
            &self.s,
            self.core.comp.as_ref(),
            rng,
            &mut self.core.scratch,
        ) {
            self.s_at_u.apply_msg(&msg);
        }

        // ---- u-agent ----
        // u_{k+1} = u_k + α r̂ᵘ_{k+1} − (1−α) ŝᵘ_k + ŝᵘ_{k+1} − α c
        for j in 0..rdim {
            self.u[j] += alpha * self.r_at_u.get()[j]
                - (1.0 - alpha) * self.s_at_u_prev[j]
                + self.s_at_u.get()[j]
                - alpha * self.c[j];
        }
        if let Some(msg) = self.line_ur.offer_send(
            &self.u,
            self.core.comp.as_ref(),
            rng,
            &mut self.core.scratch,
        ) {
            self.u_at_r.apply_msg(&msg);
        }
        if let Some(msg) = self.line_us.offer_send(
            &self.u,
            self.core.comp.as_ref(),
            rng,
            &mut self.core.scratch,
        ) {
            self.u_at_s.apply_msg(&msg);
        }

        if self.core.finish_round(self.cfg.reset_period) {
            self.reset();
        }
    }

    /// Full resynchronization of all six lines (each counted as an
    /// event; one dense sync charged per line with the same drop
    /// supersession rule as every engine — see [`EventLine::resync`]).
    pub fn reset(&mut self) {
        self.line_rs.resync(&self.r);
        self.r_at_s.reset_to(&self.r);
        self.line_ru.resync(&self.r);
        self.r_at_u.reset_to(&self.r);
        self.line_sr.resync(&self.s);
        self.s_at_r.reset_to(&self.s);
        self.line_su.resync(&self.s);
        self.s_at_u.reset_to(&self.s);
        self.line_ur.resync(&self.u);
        self.u_at_r.reset_to(&self.u);
        self.line_us.resync(&self.u);
        self.u_at_s.reset_to(&self.u);
    }

    /// Constraint residual `|Ax + Bz − c|`.
    pub fn primal_residual(&self) -> f64 {
        (0..self.r.len())
            .map(|j| {
                let v = self.r[j] + self.s[j] - self.c[j];
                v * v
            })
            .sum::<f64>()
            .sqrt()
    }

    fn lines(&self) -> [&EventLine<f64>; 6] {
        [
            &self.line_rs,
            &self.line_ru,
            &self.line_sr,
            &self.line_su,
            &self.line_ur,
            &self.line_us,
        ]
    }

    /// Total triggered events over all six lines.
    pub fn total_events(&self) -> u64 {
        core::events_sum(self.lines())
    }

    /// Load normalized by full communication (6 lines per round).
    pub fn comm_load(&self) -> f64 {
        self.core.comm_load(self.total_events(), 6.0)
    }

    /// Total bytes put on the wire across all six lines.
    pub fn total_wire_bytes(&self) -> u64 {
        core::bytes_sum(self.lines())
    }

    /// Per-line `(label, ChannelStats)` snapshot for byte accounting.
    pub fn line_stats(
        &self,
    ) -> Vec<(&'static str, crate::transport::loss::ChannelStats)> {
        vec![
            ("rs", self.line_rs.ch.stats),
            ("ru", self.line_ru.ch.stats),
            ("sr", self.line_sr.ch.stats),
            ("su", self.line_su.ch.stats),
            ("ur", self.line_ur.ch.stats),
            ("us", self.line_us.ch.stats),
        ]
    }

    /// State distance `|ξ_k − ξ*|` with `ξ = (s, u)` (Thm. 4.1's metric).
    pub fn xi_dist(&self, s_star: &[f64], u_star: &[f64]) -> f64 {
        let ds: f64 = self
            .s
            .iter()
            .zip(s_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let du: f64 = self
            .u
            .iter()
            .zip(u_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        (ds + du).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::wire::WireMessage;

    /// min ½|Dx−b|² s.t. x − z = 0, g = 0  →  x* = argmin ½|Dx−b|².
    fn ls_consensus(
        alpha: f64,
        delta: Option<f64>,
    ) -> (GeneralAdmm, Vec<f64>) {
        let mut rng = Pcg64::seed(11);
        let d = Matrix::randn(20, 5, &mut rng);
        let xtrue: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let b = d.matvec(&xtrue);
        let f = QuadraticF::least_squares(&d, &b);
        let mut cfg = GeneralConfig { alpha, rounds: 300, ..Default::default() };
        if let Some(dl) = delta {
            cfg = cfg.with_uniform_delta(dl);
        }
        let eng = GeneralAdmm::new(
            cfg,
            Matrix::eye(5),
            vec![0.0; 5],
            f,
            ZProx::diag(-1.0, 0.0),
            vec![0.0; 5],
            vec![0.0; 5],
        );
        (eng, xtrue)
    }

    #[test]
    fn consensus_instance_converges_to_least_squares() {
        let (mut eng, xtrue) = ls_consensus(1.0, None);
        let mut rng = Pcg64::seed(1);
        for _ in 0..300 {
            eng.round(&mut rng);
        }
        assert!(
            crate::linalg::dist2(&eng.x, &xtrue) < 1e-6,
            "x {:?} vs {:?}",
            eng.x,
            xtrue
        );
        assert!(eng.primal_residual() < 1e-6);
    }

    #[test]
    fn over_relaxation_converges_and_accelerates() {
        let run = |alpha: f64| {
            let (mut eng, xtrue) = ls_consensus(alpha, None);
            let mut rng = Pcg64::seed(2);
            let mut err_at_50 = f64::NAN;
            for k in 0..300 {
                eng.round(&mut rng);
                if k == 50 {
                    err_at_50 = crate::linalg::dist2(&eng.x, &xtrue);
                }
            }
            (crate::linalg::dist2(&eng.x, &xtrue), err_at_50)
        };
        let (final_15, _) = run(1.5);
        assert!(final_15 < 1e-6, "alpha=1.5 err {final_15}");
    }

    #[test]
    fn event_based_steady_state_error_scales_with_delta() {
        let run = |delta: f64| {
            let (mut eng, xtrue) = ls_consensus(1.0, Some(delta));
            let mut rng = Pcg64::seed(3);
            for _ in 0..300 {
                eng.round(&mut rng);
            }
            (crate::linalg::dist2(&eng.x, &xtrue), eng.total_events())
        };
        let (err_s, ev_s) = run(1e-5);
        let (err_l, ev_l) = run(1e-2);
        assert!(err_s < err_l + 1e-12, "err {err_s} !<= {err_l}");
        assert!(ev_s > ev_l, "events {ev_s} !> {ev_l}");
        assert!(err_s < 1e-3);
    }

    #[test]
    fn lasso_instance_matches_ista_reference() {
        let mut rng = Pcg64::seed(4);
        let d = Matrix::randn(30, 8, &mut rng);
        let xtrue: Vec<f64> = (0..8)
            .map(|i| if i % 3 == 0 { 2.0 } else { 0.0 })
            .collect();
        let mut b = d.matvec(&xtrue);
        for v in &mut b {
            *v += 0.01 * rng.normal();
        }
        let lambda = 0.5;

        // ADMM via Alg 2 (A=I, B=-I, c=0, g = λ|z|₁)
        let f = QuadraticF::least_squares(&d, &b);
        let mut eng = GeneralAdmm::new(
            GeneralConfig { rho: 2.0, rounds: 500, ..Default::default() },
            Matrix::eye(8),
            vec![0.0; 8],
            f,
            ZProx::diag(-1.0, lambda),
            vec![0.0; 8],
            vec![0.0; 8],
        );
        for _ in 0..500 {
            eng.round(&mut rng);
        }

        // ISTA reference
        let lip = d.sigma_max(100, &mut rng).powi(2) * 1.05;
        let mut xr = vec![0.0; 8];
        for _ in 0..20_000 {
            let grad = d.tmatvec(
                &d.matvec(&xr)
                    .iter()
                    .zip(&b)
                    .map(|(p, q)| p - q)
                    .collect::<Vec<f64>>(),
            );
            let step: Vec<f64> = xr
                .iter()
                .zip(&grad)
                .map(|(x, g)| x - g / lip)
                .collect();
            xr = soft_threshold(&step, lambda / lip);
        }
        assert!(
            crate::linalg::dist2(&eng.z, &xr) < 1e-4,
            "admm z {:?} vs ista {:?}",
            eng.z,
            xr
        );
    }

    #[test]
    fn dense_b_least_squares_constraint() {
        // min ½|x−x₀|² s.t. x = B z with random B (g = 0):
        // solution projects x₀'s target onto range(B).
        let mut rng = Pcg64::seed(5);
        let bmat = Matrix::randn(6, 3, &mut rng);
        let x0: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let f = QuadraticF::new(Matrix::eye(6), x0.iter().map(|v| -v).collect());
        // constraint: x − Bz = 0 → A = I₆, B matrix with negated sign
        let mut negb = bmat.clone();
        for v in &mut negb.data {
            *v = -*v;
        }
        let mut eng = GeneralAdmm::new(
            GeneralConfig { rounds: 400, ..Default::default() },
            Matrix::eye(6),
            vec![0.0; 6],
            f,
            ZProx::dense(negb),
            vec![0.0; 6],
            vec![0.0; 3],
        );
        for _ in 0..400 {
            eng.round(&mut rng);
        }
        assert!(eng.primal_residual() < 1e-6,
                "residual {}", eng.primal_residual());
        // optimality: x must be the projection of x0 onto range(B)
        // (minimizes |x − x₀| within the range) — check Bᵀ(x − x₀) ≈ 0
        let diff: Vec<f64> =
            eng.x.iter().zip(&x0).map(|(a, b)| a - b).collect();
        let bt = bmat.tmatvec(&diff);
        assert!(crate::linalg::norm2(&bt) < 1e-5,
                "B'(x-x0) = {bt:?}");
    }

    #[test]
    fn thm41_linear_convergence_envelope() {
        // strongly convex f: error should decay at least geometrically
        // until the Δ-floor; measure the empirical rate over the linear
        // phase and check it beats the Thm 4.1 bound (1 − 1/(4√κ)).
        let mut rng = Pcg64::seed(6);
        let d = Matrix::randn(40, 6, &mut rng);
        let xtrue: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let b = d.matvec(&xtrue);
        let f = QuadraticF::least_squares(&d, &b);
        // κ of \hat f per Def. C.1 with A = I: L/m of f itself
        let smax = d.sigma_max(200, &mut rng).powi(2);
        let smin = d.sigma_min(200, &mut rng).powi(2);
        let kappa = smax / smin;
        let rho = (smax * smin).sqrt(); // ρ = √(mL), ε = 0
        let mut eng = GeneralAdmm::new(
            GeneralConfig { rho, rounds: 200, ..Default::default() },
            Matrix::eye(6),
            vec![0.0; 6],
            f,
            ZProx::diag(-1.0, 0.0),
            vec![0.0; 6],
            vec![0.0; 6],
        );
        let s_star: Vec<f64> = xtrue.iter().map(|v| -v).collect();
        // u* for consensus g=0: gradient of \hat f at r*: u* = -∇f(x*)/ρ = 0
        let u_star = vec![0.0; 6];
        let e0 = eng.xi_dist(&s_star, &u_star);
        let mut errs = Vec::new();
        for _ in 0..200 {
            eng.round(&mut rng);
            errs.push(eng.xi_dist(&s_star, &u_star));
        }
        // empirical per-iteration factor over the first 30 rounds
        let measured = (errs[29] / e0).powf(1.0 / 30.0);
        let bound = 1.0 - 1.0 / (4.0 * kappa.sqrt());
        assert!(
            measured <= bound + 0.02,
            "measured rate {measured} vs bound {bound} (kappa {kappa})"
        );
        assert!(errs[199] < 1e-8);
    }

    #[test]
    fn wire_bytes_counted_on_all_six_lines() {
        let (mut eng, _) = ls_consensus(1.0, None);
        let mut rng = Pcg64::seed(40);
        for _ in 0..10 {
            eng.round(&mut rng);
        }
        // full communication: 6 lines x 10 rounds x dense(dim 5) bytes
        let dense = WireMessage::<f64>::dense_bytes(5) as u64;
        assert_eq!(eng.total_wire_bytes(), 60 * dense);
        assert_eq!(eng.line_stats().len(), 6);
        for (_, st) in eng.line_stats() {
            assert_eq!(st.sent_bytes, 10 * dense);
        }
    }

    #[test]
    fn compressed_general_engine_still_converges() {
        let mut rng = Pcg64::seed(41);
        let d = Matrix::randn(20, 5, &mut rng);
        let xtrue: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let b = d.matvec(&xtrue);
        let f = QuadraticF::least_squares(&d, &b);
        let cfg = GeneralConfig {
            rounds: 400,
            compressor: crate::wire::CompressorCfg::Quant { bits: 10 },
            ..Default::default()
        }
        .with_uniform_delta(1e-4);
        let mut eng = GeneralAdmm::new(
            cfg,
            Matrix::eye(5),
            vec![0.0; 5],
            f,
            ZProx::diag(-1.0, 0.0),
            vec![0.0; 5],
            vec![0.0; 5],
        );
        for _ in 0..400 {
            eng.round(&mut rng);
        }
        assert!(
            crate::linalg::dist2(&eng.x, &xtrue) < 0.1,
            "compressed err {}",
            crate::linalg::dist2(&eng.x, &xtrue)
        );
    }

    #[test]
    fn drops_break_convergence_resets_restore_it() {
        let run = |reset: usize| {
            let (mut eng, xtrue) = ls_consensus(1.0, Some(1e-4));
            eng.cfg.drop_rate = 0.3;
            eng.cfg.reset_period = reset;
            eng.line_rs.ch.drop_rate = 0.3;
            eng.line_ru.ch.drop_rate = 0.3;
            eng.line_sr.ch.drop_rate = 0.3;
            eng.line_su.ch.drop_rate = 0.3;
            eng.line_ur.ch.drop_rate = 0.3;
            eng.line_us.ch.drop_rate = 0.3;
            let mut rng = Pcg64::seed(7);
            for _ in 0..400 {
                eng.round(&mut rng);
            }
            crate::linalg::dist2(&eng.x, &xtrue)
        };
        let err_noreset = run(0);
        let err_reset = run(10);
        assert!(
            err_reset < err_noreset.max(1e-3),
            "reset {err_reset} !< no-reset {err_noreset}"
        );
    }
}
