//! Byte-accurate communication accounting.
//!
//! Event counters (how many messages fired) already existed in the
//! trigger/channel layer; this module adds the quantity the paper's
//! "production-scale, heavy traffic" framing actually cares about —
//! **bytes on the wire**, per agent and per direction, as charged by the
//! exact encoded size of each [`crate::wire::WireMessage`].

use crate::transport::loss::ChannelStats;
use crate::jsonio::Json;

/// Per-link transfer totals (messages and bytes, sent and lost).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    pub msgs: u64,
    pub bytes: u64,
    pub dropped_msgs: u64,
    pub dropped_bytes: u64,
}

impl LinkStats {
    /// Bytes that actually arrived.
    pub fn delivered_bytes(&self) -> u64 {
        self.bytes - self.dropped_bytes
    }
}

impl From<&ChannelStats> for LinkStats {
    fn from(s: &ChannelStats) -> LinkStats {
        LinkStats {
            msgs: s.sent,
            bytes: s.sent_bytes,
            dropped_msgs: s.dropped,
            dropped_bytes: s.dropped_bytes,
        }
    }
}

/// Snapshot of an engine's wire usage: one [`LinkStats`] per agent per
/// direction.  Engines expose this each round; sampling the monotone
/// counters per round yields the per-round byte series the experiments
/// record.
#[derive(Clone, Debug, Default)]
pub struct WireStats {
    pub uplink: Vec<LinkStats>,
    pub downlink: Vec<LinkStats>,
}

impl WireStats {
    pub fn uplink_bytes(&self) -> u64 {
        self.uplink.iter().map(|l| l.bytes).sum()
    }

    pub fn downlink_bytes(&self) -> u64 {
        self.downlink.iter().map(|l| l.bytes).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes() + self.downlink_bytes()
    }

    pub fn uplink_msgs(&self) -> u64 {
        self.uplink.iter().map(|l| l.msgs).sum()
    }

    pub fn downlink_msgs(&self) -> u64 {
        self.downlink.iter().map(|l| l.msgs).sum()
    }

    /// JSON export (the experiments' `*.json` bytes columns).
    pub fn to_json(&self) -> Json {
        let links = |ls: &[LinkStats]| {
            Json::Arr(
                ls.iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("msgs", Json::Num(l.msgs as f64)),
                            ("bytes", Json::Num(l.bytes as f64)),
                            ("dropped_msgs", Json::Num(l.dropped_msgs as f64)),
                            (
                                "dropped_bytes",
                                Json::Num(l.dropped_bytes as f64),
                            ),
                        ])
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("uplink_bytes", Json::Num(self.uplink_bytes() as f64)),
            ("downlink_bytes", Json::Num(self.downlink_bytes() as f64)),
            ("uplink", links(&self.uplink)),
            ("downlink", links(&self.downlink)),
        ])
    }
}

/// Minimal two-direction byte tally for the averaging-family baselines
/// (which have no per-link channel objects — the server touches every
/// selected agent directly).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ByteTally {
    pub uplink: u64,
    pub downlink: u64,
}

impl ByteTally {
    pub fn total(&self) -> u64 {
        self.uplink + self.downlink
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_stats_from_channel_stats() {
        let cs = ChannelStats {
            sent: 10,
            dropped: 3,
            sent_bytes: 1000,
            dropped_bytes: 300,
        };
        let ls = LinkStats::from(&cs);
        assert_eq!(ls.msgs, 10);
        assert_eq!(ls.bytes, 1000);
        assert_eq!(ls.delivered_bytes(), 700);
    }

    #[test]
    fn wire_stats_sums() {
        let ws = WireStats {
            uplink: vec![
                LinkStats { msgs: 2, bytes: 20, ..Default::default() },
                LinkStats { msgs: 3, bytes: 30, ..Default::default() },
            ],
            downlink: vec![LinkStats {
                msgs: 1,
                bytes: 5,
                ..Default::default()
            }],
        };
        assert_eq!(ws.uplink_bytes(), 50);
        assert_eq!(ws.downlink_bytes(), 5);
        assert_eq!(ws.total_bytes(), 55);
        assert_eq!(ws.uplink_msgs(), 5);
        assert_eq!(ws.downlink_msgs(), 1);
        let j = ws.to_json();
        assert_eq!(j.get("uplink_bytes").and_then(Json::as_f64), Some(50.0));
    }

    #[test]
    fn byte_tally_totals() {
        let t = ByteTally { uplink: 7, downlink: 11 };
        assert_eq!(t.total(), 18);
    }
}
