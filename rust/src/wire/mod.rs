//! The wire layer: compressed-message codec + byte-accurate accounting
//! (DESIGN.md §7).
//!
//! The paper's protocol reduces communication *events*; this subsystem
//! models what each event actually costs on a network.  Three pieces:
//!
//! * [`compress`] — the [`Compressor`] operators ([`Identity`], [`TopK`],
//!   [`RandK`], b-bit stochastic [`Quant`], and the combined
//!   [`TopKQuant`]) plus the per-line [`ErrorFeedback`] accumulator that
//!   re-injects compression residuals instead of losing them.
//! * [`codec`] — [`WireMessage`]: the dense / sparse / quantized payload
//!   layouts with exact (bit-preserving) encode/decode and exact byte
//!   sizing.
//! * [`stats`] — [`WireStats`] / [`LinkStats`] / [`ByteTally`]: uplink
//!   and downlink bytes per agent, fed by the byte counters that
//!   [`crate::transport::loss::LossyLink`] charges per transmitted message.
//!
//! Everything composes with the existing event triggers: a trigger
//! decides *whether* a delta is sent, the compressor decides *how many
//! bytes* it costs, and the `Δ`-threshold × compressor product space is
//! what [`crate::experiments::pareto`] sweeps.

mod codec;
mod compress;
mod stats;

pub use codec::{QuantBlock, WireMessage, HEADER_BYTES};
pub use compress::{
    Compressor, CompressorCfg, ErrorFeedback, Identity, Quant, RandK, TopK,
    TopKQuant,
};
pub use stats::{ByteTally, LinkStats, WireStats};
