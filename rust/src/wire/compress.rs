//! Compression operators and the per-line error-feedback accumulator.
//!
//! Event triggering decides *when* a delta is worth sending;
//! compression decides *how many bytes* the sent delta costs.  The two
//! compose multiplicatively (Ren et al., arXiv:2501.13516 /
//! arXiv:2508.15509): a TopK-sparsified, b-bit-quantized delta on an
//! event-triggered line cuts uplink bytes by orders of magnitude at a
//! bounded accuracy cost — provided the compression residual is not
//! *lost*.  [`ErrorFeedback`] keeps the residual `e ← (δ + e) − C(δ + e)`
//! per transmit line and folds it into the next payload, the standard
//! EF14 correction that restores convergence for contractive operators.
//!
//! All operators are deterministic given the caller's RNG stream;
//! [`Identity`] and [`TopK`] draw nothing, so enabling them leaves every
//! seeded trajectory's random sequence untouched.

use crate::comm::Scalar;
use crate::rng::{Pcg64, Rng};

use super::codec::{QuantBlock, WireMessage};

/// A (possibly lossy) delta compressor for one transmit line.
pub trait Compressor<T: Scalar> {
    /// Compress a dense delta into a wire payload.
    fn compress(&self, input: &[T], rng: &mut Pcg64) -> WireMessage<T>;

    /// `true` iff `compress(v).to_dense() == v` for every input; lossless
    /// operators skip the error-feedback bookkeeping entirely.
    fn is_lossless(&self) -> bool {
        false
    }

    /// Short human-readable label for tables/CSV.
    fn name(&self) -> String;
}

/// No compression: the dense codec path (bit-exact round-trip).
pub struct Identity;

impl<T: Scalar> Compressor<T> for Identity {
    fn compress(&self, input: &[T], _rng: &mut Pcg64) -> WireMessage<T> {
        WireMessage::dense(input)
    }
    fn is_lossless(&self) -> bool {
        true
    }
    fn name(&self) -> String {
        "identity".into()
    }
}

/// Number of kept coordinates for a sparsification fraction.
fn k_of(frac: f64, dim: usize) -> usize {
    ((frac * dim as f64).ceil() as usize).clamp(1, dim.max(1))
}

/// Indices of the `k` largest-magnitude coordinates, ascending.
/// Partial selection (O(dim) expected) rather than a full sort — this
/// runs once per fired event per line on full-model-sized deltas.
fn topk_indices<T: Scalar>(input: &[T], k: usize) -> Vec<u32> {
    let mut order: Vec<usize> = (0..input.len()).collect();
    if k < order.len() {
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            input[b]
                .to_f64()
                .abs()
                .partial_cmp(&input[a].to_f64().abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        order.truncate(k);
    }
    let mut idx: Vec<u32> = order.into_iter().map(|i| i as u32).collect();
    idx.sort_unstable();
    idx
}

/// Keep the `ceil(frac * dim)` largest-magnitude coordinates exactly.
pub struct TopK {
    pub frac: f64,
}

impl<T: Scalar> Compressor<T> for TopK {
    fn compress(&self, input: &[T], _rng: &mut Pcg64) -> WireMessage<T> {
        let k = k_of(self.frac, input.len());
        let idx = topk_indices(input, k);
        let val = idx.iter().map(|&i| input[i as usize]).collect();
        WireMessage::Sparse { dim: input.len() as u32, idx, val }
    }
    fn name(&self) -> String {
        format!("topk:{}", self.frac)
    }
}

/// Keep `ceil(frac * dim)` *uniformly random* coordinates exactly
/// (unscaled — the error-feedback accumulator re-injects what is
/// dropped, so the biased-but-contractive form is the right one here).
pub struct RandK {
    pub frac: f64,
}

impl<T: Scalar> Compressor<T> for RandK {
    fn compress(&self, input: &[T], rng: &mut Pcg64) -> WireMessage<T> {
        let k = k_of(self.frac, input.len());
        let mut idx: Vec<u32> = rng
            .sample_indices(input.len(), k)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| input[i as usize]).collect();
        WireMessage::Sparse { dim: input.len() as u32, idx, val }
    }
    fn name(&self) -> String {
        format!("randk:{}", self.frac)
    }
}

/// Stochastically round values onto the b-bit uniform grid over the
/// message's own `[min, max]` range.  Unbiased: `E[Q(v)] = v`.
fn quantize<T: Scalar>(vals: &[T], bits: u8, rng: &mut Pcg64) -> QuantBlock {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for v in vals {
        let x = v.to_f64();
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if vals.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    let maxl = QuantBlock::max_level(bits);
    let step = (hi - lo) / maxl as f64;
    let levels = vals
        .iter()
        .map(|v| {
            if step <= 0.0 || !step.is_finite() {
                return 0;
            }
            let t = (v.to_f64() - lo) / step;
            let base = t.floor();
            let frac = t - base;
            let mut level = base as u32;
            if rng.f64() < frac {
                level += 1;
            }
            level.min(maxl)
        })
        .collect();
    QuantBlock { bits, lo, hi, levels }
}

/// b-bit uniform stochastic quantization of the full delta.
pub struct Quant {
    pub bits: u8,
}

impl<T: Scalar> Compressor<T> for Quant {
    fn compress(&self, input: &[T], rng: &mut Pcg64) -> WireMessage<T> {
        WireMessage::Quant(quantize(input, self.bits, rng))
    }
    fn name(&self) -> String {
        format!("quant:{}", self.bits)
    }
}

/// TopK sparsification followed by b-bit quantization of the kept values
/// — the multiplicative-savings combination.
pub struct TopKQuant {
    pub frac: f64,
    pub bits: u8,
}

impl<T: Scalar> Compressor<T> for TopKQuant {
    fn compress(&self, input: &[T], rng: &mut Pcg64) -> WireMessage<T> {
        let k = k_of(self.frac, input.len());
        let idx = topk_indices(input, k);
        let kept: Vec<T> = idx.iter().map(|&i| input[i as usize]).collect();
        let q = quantize(&kept, self.bits, rng);
        WireMessage::SparseQuant { dim: input.len() as u32, idx, q }
    }
    fn name(&self) -> String {
        format!("topkq:{}:{}", self.frac, self.bits)
    }
}

/// Declarative compressor choice — what `--compressor` parses into and
/// what the engine configs carry (the trait objects are built per engine
/// via [`CompressorCfg::build`]).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum CompressorCfg {
    #[default]
    Identity,
    TopK { frac: f64 },
    RandK { frac: f64 },
    Quant { bits: u8 },
    TopKQuant { frac: f64, bits: u8 },
}

impl CompressorCfg {
    /// Parse the CLI syntax: `none` | `identity` | `topk:FRAC` |
    /// `randk:FRAC` | `quant:BITS` | `topkq:FRAC:BITS`.
    pub fn parse(s: &str) -> Result<CompressorCfg, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let frac_arg = |p: &[&str]| -> Result<f64, String> {
            let f: f64 = p
                .get(1)
                .ok_or_else(|| format!("{s:?}: missing fraction"))?
                .parse()
                .map_err(|_| format!("{s:?}: bad fraction"))?;
            if !(f > 0.0 && f <= 1.0) {
                return Err(format!("{s:?}: fraction must be in (0, 1]"));
            }
            Ok(f)
        };
        let bits_arg = |p: &str| -> Result<u8, String> {
            let b: u8 =
                p.parse().map_err(|_| format!("{s:?}: bad bit width"))?;
            if !(1..=16).contains(&b) {
                return Err(format!("{s:?}: bits must be in 1..=16"));
            }
            Ok(b)
        };
        match parts[0] {
            "none" | "identity" => Ok(CompressorCfg::Identity),
            "topk" => Ok(CompressorCfg::TopK { frac: frac_arg(&parts)? }),
            "randk" => Ok(CompressorCfg::RandK { frac: frac_arg(&parts)? }),
            "quant" => Ok(CompressorCfg::Quant {
                bits: bits_arg(
                    parts.get(1).ok_or_else(|| format!("{s:?}: missing bits"))?,
                )?,
            }),
            "topkq" => Ok(CompressorCfg::TopKQuant {
                frac: frac_arg(&parts)?,
                bits: bits_arg(
                    parts.get(2).ok_or_else(|| format!("{s:?}: missing bits"))?,
                )?,
            }),
            other => Err(format!(
                "unknown compressor {other:?} (expected none | topk:F | \
                 randk:F | quant:B | topkq:F:B)"
            )),
        }
    }

    /// Instantiate the operator for a scalar type.
    pub fn build<T: Scalar>(&self) -> Box<dyn Compressor<T>> {
        match *self {
            CompressorCfg::Identity => Box::new(Identity),
            CompressorCfg::TopK { frac } => Box::new(TopK { frac }),
            CompressorCfg::RandK { frac } => Box::new(RandK { frac }),
            CompressorCfg::Quant { bits } => Box::new(Quant { bits }),
            CompressorCfg::TopKQuant { frac, bits } => {
                Box::new(TopKQuant { frac, bits })
            }
        }
    }

    /// The operator's display label (matches `Compressor::name`).
    pub fn label(&self) -> String {
        match *self {
            CompressorCfg::Identity => "identity".into(),
            CompressorCfg::TopK { frac } => format!("topk:{frac}"),
            CompressorCfg::RandK { frac } => format!("randk:{frac}"),
            CompressorCfg::Quant { bits } => format!("quant:{bits}"),
            CompressorCfg::TopKQuant { frac, bits } => {
                format!("topkq:{frac}:{bits}")
            }
        }
    }
}

/// Per-line error-feedback accumulator: the compression residual is
/// carried forward and re-injected into the next transmitted delta
/// instead of being silently dropped.
#[derive(Clone, Debug)]
pub struct ErrorFeedback<T: Scalar> {
    residual: Vec<T>,
}

impl<T: Scalar> Default for ErrorFeedback<T> {
    fn default() -> Self {
        ErrorFeedback::new()
    }
}

impl<T: Scalar> ErrorFeedback<T> {
    pub fn new() -> Self {
        ErrorFeedback { residual: Vec::new() }
    }

    /// Drop the carried residual (used on the periodic hard resets, which
    /// resynchronize receivers with the *exact* state).
    pub fn clear(&mut self) {
        self.residual.clear();
    }

    /// Euclidean norm of the carried residual (diagnostics).
    pub fn residual_norm(&self) -> f64 {
        self.residual
            .iter()
            .map(|r| {
                let x = r.to_f64();
                x * x
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Compress `delta + residual`, store the new residual, and return
    /// the payload.  Lossless operators bypass the accumulator (zero
    /// residual forever), keeping the identity path allocation-light and
    /// bit-identical to uncompressed operation.
    pub fn compress(
        &mut self,
        delta: &[T],
        comp: &dyn Compressor<T>,
        rng: &mut Pcg64,
    ) -> WireMessage<T> {
        if comp.is_lossless() {
            return comp.compress(delta, rng);
        }
        if self.residual.len() != delta.len() {
            self.residual = vec![T::zero(); delta.len()];
        }
        let corrected: Vec<T> = delta
            .iter()
            .zip(&self.residual)
            .map(|(&d, &r)| T::from_f64(d.to_f64() + r.to_f64()))
            .collect();
        let msg = comp.compress(&corrected, rng);
        let approx = msg.to_dense();
        for ((r, &c), &a) in
            self.residual.iter_mut().zip(&corrected).zip(&approx)
        {
            *r = T::from_f64(c.to_f64() - a.to_f64());
        }
        msg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn norm(v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    #[test]
    fn identity_is_lossless_and_exact() {
        let comp = Identity;
        let v = vec![1.5f64, -2.25, 0.0, 1e-30];
        let mut rng = Pcg64::seed(1);
        let msg = Compressor::<f64>::compress(&comp, &v, &mut rng);
        assert!(Compressor::<f64>::is_lossless(&comp));
        assert_eq!(msg.to_dense(), v);
        assert_eq!(msg.wire_bytes(), WireMessage::<f64>::dense_bytes(4));
    }

    #[test]
    fn topk_keeps_largest_magnitudes_exactly() {
        let comp = TopK { frac: 0.4 }; // k = 2 of 5
        let v = vec![0.1f64, -5.0, 0.2, 3.0, -0.05];
        let mut rng = Pcg64::seed(2);
        let msg = comp.compress(&v, &mut rng);
        match &msg {
            WireMessage::Sparse { idx, val, .. } => {
                assert_eq!(idx, &vec![1, 3]);
                assert_eq!(val, &vec![-5.0, 3.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        // contraction: dropping coordinates can only shrink the vector
        let err: Vec<f64> = v
            .iter()
            .zip(msg.to_dense())
            .map(|(a, b)| a - b)
            .collect();
        assert!(norm(&err) <= norm(&v));
    }

    #[test]
    fn randk_is_seeded_and_keeps_exact_values() {
        let v: Vec<f64> = (0..20).map(|i| i as f64 - 10.0).collect();
        let comp = RandK { frac: 0.25 };
        let m1 = comp.compress(&v, &mut Pcg64::seed(7));
        let m2 = comp.compress(&v, &mut Pcg64::seed(7));
        assert_eq!(m1, m2, "same seed must select the same coordinates");
        if let WireMessage::Sparse { idx, val, .. } = &m1 {
            assert_eq!(idx.len(), 5);
            for (&i, &x) in idx.iter().zip(val) {
                assert_eq!(x, v[i as usize]);
            }
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn quant_hits_range_endpoints_exactly() {
        let comp = Quant { bits: 8 };
        let v = vec![-4.0f64, 4.0];
        let mut rng = Pcg64::seed(3);
        let out = comp.compress(&v, &mut rng).to_dense();
        assert_eq!(out, vec![-4.0, 4.0]);
    }

    #[test]
    fn quant_error_bounded_by_step() {
        let mut rng = Pcg64::seed(4);
        let v: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let comp = Quant { bits: 8 };
        let out = comp.compress(&v, &mut rng).to_dense();
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let step = (hi - lo) / 255.0;
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() <= step + 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn quant_constant_vector_is_exact() {
        let comp = Quant { bits: 4 };
        let v = vec![2.5f64; 9];
        let mut rng = Pcg64::seed(5);
        assert_eq!(comp.compress(&v, &mut rng).to_dense(), v);
    }

    #[test]
    fn topkq_message_is_small() {
        let v: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
        let comp = TopKQuant { frac: 0.05, bits: 8 };
        let mut rng = Pcg64::seed(6);
        let msg = comp.compress(&v, &mut rng);
        let dense = WireMessage::<f64>::dense_bytes(1000);
        assert!(
            msg.wire_bytes() * 4 < dense,
            "topkq {} !<< dense {dense}",
            msg.wire_bytes()
        );
        // and the codec round-trips it
        let back = WireMessage::<f64>::decode(&msg.encode()).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn error_feedback_recovers_dropped_mass() {
        // a constant stream through aggressive TopK: with EF the receiver's
        // integrated sum must track the true cumulative sum closely.
        let dim = 16;
        let delta = vec![1.0f64; dim];
        let comp = TopK { frac: 0.25 }; // keeps 4 of 16 per message
        let mut ef = ErrorFeedback::new();
        let mut rng = Pcg64::seed(8);
        let mut received = vec![0.0f64; dim];
        let rounds = 40;
        for _ in 0..rounds {
            let msg = ef.compress(&delta, &comp, &mut rng);
            msg.add_scaled_to(1.0, &mut received);
        }
        let true_sum = rounds as f64;
        for r in &received {
            // EF carries at most a bounded residual per coordinate
            assert!(
                (r - true_sum).abs() <= true_sum * 0.5,
                "received {r} vs true {true_sum}"
            );
        }
        // total received mass = total injected mass minus the bounded
        // carried residual
        let total: f64 = received.iter().sum();
        let injected = dim as f64 * true_sum;
        assert!((total - injected).abs() / injected < 0.3);
    }

    #[test]
    fn error_feedback_lossless_path_keeps_zero_residual() {
        let mut ef = ErrorFeedback::new();
        let mut rng = Pcg64::seed(9);
        let delta = vec![1.0f32, -2.0];
        let msg = ef.compress(&delta, &Identity, &mut rng);
        assert_eq!(msg.to_dense(), delta);
        assert_eq!(ef.residual_norm(), 0.0);
    }

    #[test]
    fn cfg_parse_accepts_the_documented_syntax() {
        assert_eq!(CompressorCfg::parse("none"), Ok(CompressorCfg::Identity));
        assert_eq!(
            CompressorCfg::parse("identity"),
            Ok(CompressorCfg::Identity)
        );
        assert_eq!(
            CompressorCfg::parse("topk:0.05"),
            Ok(CompressorCfg::TopK { frac: 0.05 })
        );
        assert_eq!(
            CompressorCfg::parse("randk:0.1"),
            Ok(CompressorCfg::RandK { frac: 0.1 })
        );
        assert_eq!(
            CompressorCfg::parse("quant:8"),
            Ok(CompressorCfg::Quant { bits: 8 })
        );
        assert_eq!(
            CompressorCfg::parse("topkq:0.05:8"),
            Ok(CompressorCfg::TopKQuant { frac: 0.05, bits: 8 })
        );
        for bad in [
            "nope", "topk", "topk:0", "topk:2", "quant:0", "quant:33",
            "topkq:0.1", "topkq:x:8",
        ] {
            assert!(CompressorCfg::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn cfg_label_matches_operator_name() {
        for cfg in [
            CompressorCfg::Identity,
            CompressorCfg::TopK { frac: 0.05 },
            CompressorCfg::RandK { frac: 0.5 },
            CompressorCfg::Quant { bits: 8 },
            CompressorCfg::TopKQuant { frac: 0.05, bits: 8 },
        ] {
            assert_eq!(cfg.label(), cfg.build::<f64>().name());
        }
    }
}
