//! `WireMessage` — the byte-exact encode/decode codec for every payload
//! that crosses a simulated link.
//!
//! Four payload layouts cover the compressor outputs:
//!
//! * `Dense` — raw little-endian IEEE-754 values; encode→decode is
//!   bit-exact for both f32 and f64 (the codec stores bit patterns, never
//!   re-rounded decimal text).
//! * `Sparse` — index+value pairs (TopK / RandK sparsification).
//! * `Quant` — b-bit uniform stochastic quantization of a dense vector:
//!   per-message `[lo, hi]` range plus bit-packed level indices.
//! * `SparseQuant` — TopK indices with quantized values (the
//!   multiplicative combination of Ren et al., arXiv:2501.13516).
//!
//! Every layout knows its exact encoded size ([`WireMessage::wire_bytes`],
//! equal to `encode().len()`), which is what the byte-accurate
//! communication accounting in [`crate::wire::WireStats`] charges.
//!
//! Wire format (all integers little-endian):
//!
//! ```text
//! [0] magic 0xD1   [1] scalar tag (= Scalar::WIRE_BYTES)   [2] kind
//! [3..7] u32 dim (Dense: value count; others: decompressed dimension)
//! kind 0 Dense:       dim * WIRE_BYTES raw values
//! kind 1 Sparse:      u32 k, k * u32 idx, k * WIRE_BYTES values
//! kind 2 Quant:       u8 bits, f64 lo, f64 hi, ceil(dim*bits/8) packed
//! kind 3 SparseQuant: u32 k, k * u32 idx,
//!                     u8 bits, f64 lo, f64 hi, ceil(k*bits/8) packed
//! ```

use crate::comm::Scalar;

const MAGIC: u8 = 0xD1;
const KIND_DENSE: u8 = 0;
const KIND_SPARSE: u8 = 1;
const KIND_QUANT: u8 = 2;
const KIND_SPARSE_QUANT: u8 = 3;

/// Fixed per-message overhead: magic + scalar tag + kind + u32 dim.
pub const HEADER_BYTES: usize = 7;

/// A b-bit uniformly quantized block: level indices over `[lo, hi]`.
///
/// Kept unpacked in memory (one `u32` level per value); bit-packing
/// happens at encode time and is what [`Self::wire_bytes`] charges.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantBlock {
    /// Bits per value, 1..=16.
    pub bits: u8,
    pub lo: f64,
    pub hi: f64,
    /// One level index per value, each < 2^bits.
    pub levels: Vec<u32>,
}

impl QuantBlock {
    /// Largest representable level for a bit width.
    pub fn max_level(bits: u8) -> u32 {
        debug_assert!((1..=16).contains(&bits));
        (1u32 << bits) - 1
    }

    /// Dequantize one level index back to the value grid.
    pub fn dequant(&self, level: u32) -> f64 {
        let maxl = Self::max_level(self.bits);
        if maxl == 0 || self.hi <= self.lo {
            return self.lo;
        }
        self.lo + (self.hi - self.lo) * level as f64 / maxl as f64
    }

    /// Encoded size of the block body: bits + lo + hi + packed levels.
    pub fn wire_bytes(&self) -> usize {
        1 + 8 + 8 + Self::packed_len(self.levels.len(), self.bits)
    }

    fn packed_len(count: usize, bits: u8) -> usize {
        (count * bits as usize + 7) / 8
    }

    fn encode_into(&self, out: &mut Vec<u8>) {
        out.push(self.bits);
        out.extend_from_slice(&self.lo.to_le_bytes());
        out.extend_from_slice(&self.hi.to_le_bytes());
        out.extend_from_slice(&pack_bits(&self.levels, self.bits));
    }

    fn decode_from(
        buf: &[u8],
        pos: &mut usize,
        count: usize,
    ) -> anyhow::Result<QuantBlock> {
        let bits = *buf
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("truncated quant block"))?;
        *pos += 1;
        if !(1..=16).contains(&bits) {
            anyhow::bail!("quant bits {bits} out of range 1..=16");
        }
        let lo = read_f64(buf, pos)?;
        let hi = read_f64(buf, pos)?;
        // u64 math: count is wire-controlled, the product must not wrap
        let plen64 = (count as u64 * bits as u64 + 7) / 8;
        if (buf.len() as u64) < *pos as u64 + plen64 {
            anyhow::bail!("truncated quant levels");
        }
        let plen = plen64 as usize;
        let levels = unpack_bits(&buf[*pos..*pos + plen], count, bits);
        *pos += plen;
        Ok(QuantBlock { bits, lo, hi, levels })
    }
}

/// LSB-first bit packing of level indices.
fn pack_bits(levels: &[u32], bits: u8) -> Vec<u8> {
    let bits = bits as usize;
    let mut out = vec![0u8; (levels.len() * bits + 7) / 8];
    let mut bitpos = 0usize;
    for &v in levels {
        for b in 0..bits {
            if (v >> b) & 1 == 1 {
                out[(bitpos + b) / 8] |= 1 << ((bitpos + b) % 8);
            }
        }
        bitpos += bits;
    }
    out
}

fn unpack_bits(buf: &[u8], count: usize, bits: u8) -> Vec<u32> {
    let bits = bits as usize;
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let mut v = 0u32;
        for b in 0..bits {
            if (buf[(bitpos + b) / 8] >> ((bitpos + b) % 8)) & 1 == 1 {
                v |= 1 << b;
            }
        }
        out.push(v);
        bitpos += bits;
    }
    out
}

fn read_u32(buf: &[u8], pos: &mut usize) -> anyhow::Result<u32> {
    if buf.len() < *pos + 4 {
        anyhow::bail!("truncated u32");
    }
    let v = u32::from_le_bytes([
        buf[*pos],
        buf[*pos + 1],
        buf[*pos + 2],
        buf[*pos + 3],
    ]);
    *pos += 4;
    Ok(v)
}

fn read_f64(buf: &[u8], pos: &mut usize) -> anyhow::Result<f64> {
    if buf.len() < *pos + 8 {
        anyhow::bail!("truncated f64");
    }
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[*pos..*pos + 8]);
    *pos += 8;
    Ok(f64::from_le_bytes(b))
}

/// One compressed (or dense) payload as it travels a link.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMessage<T: Scalar> {
    /// All `dim` values, bit-exact.
    Dense(Vec<T>),
    /// `val[j]` lives at coordinate `idx[j]`; all other coordinates are 0.
    Sparse { dim: u32, idx: Vec<u32>, val: Vec<T> },
    /// Every coordinate quantized to `bits` levels over `[lo, hi]`.
    Quant(QuantBlock),
    /// TopK indices with quantized values.
    SparseQuant { dim: u32, idx: Vec<u32>, q: QuantBlock },
}

impl<T: Scalar> WireMessage<T> {
    /// Dense message from a slice (clones; the codec owns its payload).
    pub fn dense(v: &[T]) -> Self {
        WireMessage::Dense(v.to_vec())
    }

    /// Encoded size of a dense message of `dim` values — the normalizer
    /// for compression-ratio reporting and the cost the baselines charge
    /// per full-model transfer.
    pub fn dense_bytes(dim: usize) -> usize {
        HEADER_BYTES + dim * T::WIRE_BYTES
    }

    /// Decompressed dimension.
    pub fn dim(&self) -> usize {
        match self {
            WireMessage::Dense(v) => v.len(),
            WireMessage::Sparse { dim, .. } => *dim as usize,
            WireMessage::Quant(q) => q.levels.len(),
            WireMessage::SparseQuant { dim, .. } => *dim as usize,
        }
    }

    /// Exact encoded length (`== self.encode().len()`) without encoding.
    pub fn wire_bytes(&self) -> usize {
        HEADER_BYTES
            + match self {
                WireMessage::Dense(v) => v.len() * T::WIRE_BYTES,
                WireMessage::Sparse { idx, val, .. } => {
                    4 + idx.len() * 4 + val.len() * T::WIRE_BYTES
                }
                WireMessage::Quant(q) => q.wire_bytes(),
                WireMessage::SparseQuant { idx, q, .. } => {
                    4 + idx.len() * 4 + q.wire_bytes()
                }
            }
    }

    /// Serialize to the wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.push(MAGIC);
        out.push(T::WIRE_BYTES as u8);
        match self {
            WireMessage::Dense(v) => {
                out.push(KIND_DENSE);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for &x in v {
                    x.write_le(&mut out);
                }
            }
            WireMessage::Sparse { dim, idx, val } => {
                out.push(KIND_SPARSE);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for &i in idx {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for &x in val {
                    x.write_le(&mut out);
                }
            }
            WireMessage::Quant(q) => {
                out.push(KIND_QUANT);
                out.extend_from_slice(
                    &(q.levels.len() as u32).to_le_bytes(),
                );
                q.encode_into(&mut out);
            }
            WireMessage::SparseQuant { dim, idx, q } => {
                out.push(KIND_SPARSE_QUANT);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for &i in idx {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                q.encode_into(&mut out);
            }
        }
        debug_assert_eq!(out.len(), self.wire_bytes());
        out
    }

    /// Parse the wire format back; errors on wrong magic, scalar-width
    /// mismatch, unknown kind, or truncation.
    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        if buf.len() < HEADER_BYTES {
            anyhow::bail!("message shorter than header");
        }
        if buf[0] != MAGIC {
            anyhow::bail!("bad magic 0x{:02x}", buf[0]);
        }
        if buf[1] as usize != T::WIRE_BYTES {
            anyhow::bail!(
                "scalar width mismatch: wire {} vs decoder {}",
                buf[1],
                T::WIRE_BYTES
            );
        }
        let kind = buf[2];
        let mut pos = 3;
        let dim = read_u32(buf, &mut pos)? as usize;
        match kind {
            KIND_DENSE => {
                if (buf.len() as u64)
                    < pos as u64 + dim as u64 * T::WIRE_BYTES as u64
                {
                    anyhow::bail!("truncated dense payload");
                }
                let mut v = Vec::with_capacity(dim);
                for j in 0..dim {
                    v.push(T::read_le(&buf[pos + j * T::WIRE_BYTES..]));
                }
                Ok(WireMessage::Dense(v))
            }
            KIND_SPARSE => {
                let k = read_u32(buf, &mut pos)? as usize;
                if k > dim {
                    anyhow::bail!("sparse k {k} > dim {dim}");
                }
                // validate the full remaining length BEFORE allocating:
                // k is wire-controlled and must never size an allocation
                // on its own (a garbage k near u32::MAX would abort);
                // u64 math so the product cannot wrap on 32-bit targets
                if (buf.len() as u64)
                    < pos as u64 + k as u64 * (4 + T::WIRE_BYTES) as u64
                {
                    anyhow::bail!("truncated sparse payload");
                }
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    let i = read_u32(buf, &mut pos)?;
                    if i as usize >= dim {
                        anyhow::bail!(
                            "sparse index {i} out of range (dim {dim})"
                        );
                    }
                    idx.push(i);
                }
                let mut val = Vec::with_capacity(k);
                for j in 0..k {
                    val.push(T::read_le(&buf[pos + j * T::WIRE_BYTES..]));
                }
                Ok(WireMessage::Sparse { dim: dim as u32, idx, val })
            }
            KIND_QUANT => {
                let q = QuantBlock::decode_from(buf, &mut pos, dim)?;
                Ok(WireMessage::Quant(q))
            }
            KIND_SPARSE_QUANT => {
                let k = read_u32(buf, &mut pos)? as usize;
                if k > dim {
                    anyhow::bail!("sparse-quant k {k} > dim {dim}");
                }
                // length check before any k-sized allocation (see Sparse)
                if (buf.len() as u64) < pos as u64 + k as u64 * 4 {
                    anyhow::bail!("truncated sparse-quant indices");
                }
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    let i = read_u32(buf, &mut pos)?;
                    if i as usize >= dim {
                        anyhow::bail!(
                            "sparse-quant index {i} out of range (dim {dim})"
                        );
                    }
                    idx.push(i);
                }
                let q = QuantBlock::decode_from(buf, &mut pos, k)?;
                Ok(WireMessage::SparseQuant { dim: dim as u32, idx, q })
            }
            other => Err(anyhow::anyhow!("unknown payload kind {other}")),
        }
    }

    /// Decompress to a full vector (zeros where a sparse message is
    /// silent).
    pub fn to_dense(&self) -> Vec<T> {
        match self {
            WireMessage::Dense(v) => v.clone(),
            WireMessage::Sparse { dim, idx, val } => {
                let mut out = vec![T::zero(); *dim as usize];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
                out
            }
            WireMessage::Quant(q) => q
                .levels
                .iter()
                .map(|&l| T::from_f64(q.dequant(l)))
                .collect(),
            WireMessage::SparseQuant { dim, idx, q } => {
                let mut out = vec![T::zero(); *dim as usize];
                for (&i, &l) in idx.iter().zip(&q.levels) {
                    out[i as usize] = T::from_f64(q.dequant(l));
                }
                out
            }
        }
    }

    /// `out += scale * decompress(self)`, touching only the coordinates
    /// the message carries.  The scaled addend is rounded to `T` *before*
    /// the accumulate so the identity-compressor path is bit-identical to
    /// the historical uncompressed code (`apply(scale * delta)`).
    pub fn add_scaled_to(&self, scale: f64, out: &mut [T]) {
        debug_assert_eq!(self.dim(), out.len());
        let acc = |o: &mut T, v: f64| {
            let addend = T::from_f64(v * scale);
            *o = T::from_f64(o.to_f64() + addend.to_f64());
        };
        match self {
            WireMessage::Dense(v) => {
                for (o, &x) in out.iter_mut().zip(v) {
                    acc(o, x.to_f64());
                }
            }
            WireMessage::Sparse { idx, val, .. } => {
                for (&i, &x) in idx.iter().zip(val) {
                    acc(&mut out[i as usize], x.to_f64());
                }
            }
            WireMessage::Quant(q) => {
                for (o, &l) in out.iter_mut().zip(&q.levels) {
                    acc(o, q.dequant(l));
                }
            }
            WireMessage::SparseQuant { idx, q, .. } => {
                for (&i, &l) in idx.iter().zip(&q.levels) {
                    acc(&mut out[i as usize], q.dequant(l));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn randvec_f64(n: usize, rng: &mut Pcg64) -> Vec<f64> {
        (0..n).map(|_| rng.normal() * 3.0).collect()
    }

    #[test]
    fn dense_f64_roundtrip_is_bit_exact() {
        let mut rng = Pcg64::seed(1);
        let v = randvec_f64(137, &mut rng);
        let msg = WireMessage::dense(&v);
        let buf = msg.encode();
        assert_eq!(buf.len(), msg.wire_bytes());
        let back = WireMessage::<f64>::decode(&buf).unwrap();
        assert_eq!(back, msg);
        assert_eq!(back.to_dense(), v);
    }

    #[test]
    fn dense_f32_roundtrip_is_bit_exact() {
        let mut rng = Pcg64::seed(2);
        let v: Vec<f32> = (0..211).map(|_| rng.f32n()).collect();
        let msg = WireMessage::dense(&v);
        let back = WireMessage::<f32>::decode(&msg.encode()).unwrap();
        // bit-exact, including any subnormals/signed zeros
        let got = back.to_dense();
        assert_eq!(got.len(), v.len());
        for (g, w) in got.iter().zip(&v) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn dense_roundtrip_preserves_special_values() {
        let v = vec![0.0f64, -0.0, f64::MIN_POSITIVE, 1e300, -1e-300];
        let back =
            WireMessage::<f64>::decode(&WireMessage::dense(&v).encode())
                .unwrap()
                .to_dense();
        for (g, w) in back.iter().zip(&v) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn sparse_roundtrip_and_to_dense() {
        let msg: WireMessage<f64> = WireMessage::Sparse {
            dim: 6,
            idx: vec![1, 4],
            val: vec![2.5, -7.0],
        };
        let back = WireMessage::<f64>::decode(&msg.encode()).unwrap();
        assert_eq!(back, msg);
        assert_eq!(back.to_dense(), vec![0.0, 2.5, 0.0, 0.0, -7.0, 0.0]);
        assert_eq!(msg.encode().len(), msg.wire_bytes());
    }

    #[test]
    fn quant_roundtrip_preserves_levels() {
        let q = QuantBlock {
            bits: 5,
            lo: -1.0,
            hi: 3.0,
            levels: vec![0, 31, 7, 15, 1],
        };
        let msg: WireMessage<f64> = WireMessage::Quant(q.clone());
        let back = WireMessage::<f64>::decode(&msg.encode()).unwrap();
        assert_eq!(back, msg);
        assert_eq!(msg.encode().len(), msg.wire_bytes());
        // grid endpoints decode exactly
        assert_eq!(q.dequant(0), -1.0);
        assert_eq!(q.dequant(31), 3.0);
    }

    #[test]
    fn sparse_quant_roundtrip() {
        let msg: WireMessage<f32> = WireMessage::SparseQuant {
            dim: 10,
            idx: vec![0, 3, 9],
            q: QuantBlock {
                bits: 8,
                lo: -2.0,
                hi: 2.0,
                levels: vec![0, 128, 255],
            },
        };
        let back = WireMessage::<f32>::decode(&msg.encode()).unwrap();
        assert_eq!(back, msg);
        let dense = back.to_dense();
        assert_eq!(dense.len(), 10);
        assert_eq!(dense[0], -2.0);
        assert_eq!(dense[9], 2.0);
        assert_eq!(dense[5], 0.0);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(WireMessage::<f64>::decode(&[]).is_err());
        assert!(WireMessage::<f64>::decode(&[0xFF; 16]).is_err());
        // scalar-width mismatch: encode as f32, decode as f64
        let msg = WireMessage::dense(&[1.0f32, 2.0]);
        assert!(WireMessage::<f64>::decode(&msg.encode()).is_err());
        // truncation
        let buf = WireMessage::dense(&[1.0f64, 2.0]).encode();
        assert!(WireMessage::<f64>::decode(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn decode_rejects_out_of_range_indices() {
        // a wire-controlled index >= dim must fail decode, not panic
        // later in to_dense()/add_scaled_to()
        let msg: WireMessage<f64> = WireMessage::Sparse {
            dim: 4,
            idx: vec![100],
            val: vec![1.0],
        };
        assert!(WireMessage::<f64>::decode(&msg.encode()).is_err());
        let msg: WireMessage<f64> = WireMessage::SparseQuant {
            dim: 4,
            idx: vec![7],
            q: QuantBlock { bits: 8, lo: 0.0, hi: 1.0, levels: vec![3] },
        };
        assert!(WireMessage::<f64>::decode(&msg.encode()).is_err());
    }

    #[test]
    fn decode_rejects_huge_counts_without_allocating() {
        // a wire-controlled k near u32::MAX must fail the length check,
        // not size an allocation (which would abort the process)
        for kind in [1u8, 3u8] {
            let mut buf = vec![0xD1, 8, kind];
            buf.extend_from_slice(&u32::MAX.to_le_bytes()); // dim
            buf.extend_from_slice(&u32::MAX.to_le_bytes()); // k
            assert!(WireMessage::<f64>::decode(&buf).is_err());
        }
        // same for a dense header claiming u32::MAX values
        let mut buf = vec![0xD1, 8, 0];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(WireMessage::<f64>::decode(&buf).is_err());
    }

    #[test]
    fn bit_packing_roundtrips_all_widths() {
        let mut rng = Pcg64::seed(3);
        for bits in 1..=16u8 {
            let maxl = QuantBlock::max_level(bits);
            let levels: Vec<u32> =
                (0..53).map(|_| rng.below(maxl as usize + 1) as u32).collect();
            let packed = pack_bits(&levels, bits);
            assert_eq!(
                packed.len(),
                (levels.len() * bits as usize + 7) / 8
            );
            assert_eq!(unpack_bits(&packed, levels.len(), bits), levels);
        }
    }

    #[test]
    fn add_scaled_to_matches_historical_apply() {
        // identity path: adding a dense message with scale s must equal
        // rounding s*delta to T first, then accumulating — per coordinate.
        let mut rng = Pcg64::seed(4);
        let delta: Vec<f32> = (0..64).map(|_| rng.f32n()).collect();
        let mut acc = vec![1.5f32; 64];
        let mut want = acc.clone();
        let scale = 0.1f64;
        WireMessage::dense(&delta).add_scaled_to(scale, &mut acc);
        for (w, &d) in want.iter_mut().zip(&delta) {
            let addend = (d as f64 * scale) as f32;
            *w += addend;
        }
        assert_eq!(acc, want);
    }

    #[test]
    fn dense_bytes_matches_encoded_len() {
        let v = vec![0.0f64; 33];
        assert_eq!(
            WireMessage::<f64>::dense_bytes(33),
            WireMessage::dense(&v).encode().len()
        );
        let v32 = vec![0.0f32; 33];
        assert_eq!(
            WireMessage::<f32>::dense_bytes(33),
            WireMessage::dense(&v32).encode().len()
        );
    }
}
