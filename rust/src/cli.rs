//! CLI argument parsing substrate (the offline environment has no `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! `--switch`, and positional arguments, with typed getters and a usage
//! formatter used by `main.rs`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse everything after the program name (and after the subcommand if
    /// the caller already consumed it).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(rest) = item.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.switches.push(rest.to_string());
                }
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    pub fn from_env() -> (Option<String>, Args) {
        let mut items: Vec<String> = std::env::args().skip(1).collect();
        if items.is_empty() || items[0].starts_with("--") {
            return (None, Args::parse(items));
        }
        let cmd = items.remove(0);
        (Some(cmd), Args::parse(items))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|_| {
                anyhow::anyhow!("--{key}: cannot parse {s:?}")
            }),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get_parse(key).ok().flatten().unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get_parse(key).ok().flatten().unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get_parse(key).ok().flatten().unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("exp tab1 --rounds 100 --delta=3.5 --verbose --seed 7");
        assert_eq!(a.positional, vec!["exp", "tab1"]);
        assert_eq!(a.get("rounds"), Some("100"));
        assert_eq!(a.get("delta"), Some("3.5"));
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 42 --rho 0.25");
        assert_eq!(a.usize_or("n", 0), 42);
        assert!((a.f64_or("rho", 0.0) - 0.25).abs() < 1e-15);
        assert_eq!(a.usize_or("missing", 9), 9);
        assert_eq!(a.str_or("name", "dflt"), "dflt");
    }

    #[test]
    fn parse_error_reported() {
        let a = parse("--n notanumber");
        assert!(a.get_parse::<usize>("n").is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse("--fast");
        assert!(a.has("fast"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--fast --n 3");
        assert!(a.has("fast"));
        assert_eq!(a.usize_or("n", 0), 3);
    }
}
