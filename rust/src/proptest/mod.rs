//! Mini property-testing harness (the offline environment has no
//! `proptest`).
//!
//! Seeded, deterministic, with failure-case reporting and a bounded
//! "shrink by scaling" pass for numeric generators: on failure the runner
//! retries the failing case with inputs scaled toward a simpler baseline
//! and reports the smallest still-failing case it found.

use crate::rng::Pcg64;
#[cfg(test)]
use crate::rng::Rng;

/// Number of cases per property (override with `DELA_PROPTEST_CASES`).
pub fn default_cases() -> usize {
    std::env::var("DELA_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` generated inputs; panics with the seed and a
/// debug dump of the first failing case.
pub fn forall<T: std::fmt::Debug + Clone>(
    name: &str,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall_seeded(name, 0xDE1A_2025, gen, prop)
}

/// Seeded variant for reproducing failures.
pub fn forall_seeded<T: std::fmt::Debug + Clone>(
    name: &str,
    seed: u64,
    gen: impl Fn(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Pcg64::seed_stream(seed.wrapping_add(case as u64), 77);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {case}/{cases}, seed {seed}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            "abs is nonneg",
            |rng| rng.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        forall(
            "always fails",
            |rng| rng.f64(),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut collected_a = Vec::new();
        forall_seeded("collect a", 9, |rng| rng.next_u64(), |x| {
            collected_a.push(*x);
            Ok(())
        });
        let mut collected_b = Vec::new();
        forall_seeded("collect b", 9, |rng| rng.next_u64(), |x| {
            collected_b.push(*x);
            Ok(())
        });
        assert_eq!(collected_a, collected_b);
    }
}
