//! Mini property-testing harness (the offline environment has no
//! `proptest`).
//!
//! Seeded, deterministic, with failure-case reporting and a bounded
//! "shrink by scaling" pass for numeric generators: on failure the runner
//! retries the failing case with inputs scaled toward a simpler baseline
//! and reports the smallest still-failing case it found.

use crate::rng::Pcg64;
#[cfg(test)]
use crate::rng::Rng;

/// Number of cases per property (override with `DELA_PROPTEST_CASES`).
pub fn default_cases() -> usize {
    std::env::var("DELA_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` generated inputs; panics with the seed and a
/// debug dump of the first failing case.
pub fn forall<T: std::fmt::Debug + Clone>(
    name: &str,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall_seeded(name, 0xDE1A_2025, gen, prop)
}

/// Seeded variant for reproducing failures.
pub fn forall_seeded<T: std::fmt::Debug + Clone>(
    name: &str,
    seed: u64,
    gen: impl Fn(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Pcg64::seed_stream(seed.wrapping_add(case as u64), 77);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // lint:allow(panic-in-library): panicking with the seed and the failing case IS this harness's reporting contract (mirrors upstream proptest); every caller is a test
            panic!(
                "property '{name}' failed (case {case}/{cases}, seed {seed}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Property tests for the wire layer (codec + compressors), driven by the
/// harness above.  Kept here rather than in `wire` so the properties read
/// as specifications: unbiasedness of the stochastic quantizer,
/// contraction of TopK, losslessness of the identity codec path.
#[cfg(test)]
mod wire_props {
    use super::forall;
    use crate::rng::{Pcg64, Rng};
    use crate::wire::{
        Compressor, CompressorCfg, Quant, TopK, WireMessage,
    };

    fn norm(v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    #[test]
    fn prop_identity_codec_roundtrip_is_lossless() {
        forall(
            "identity encode/decode is lossless",
            |rng| {
                let dim = 1 + rng.below(64);
                let v: Vec<f64> =
                    (0..dim).map(|_| rng.normal() * 10.0).collect();
                v
            },
            |v| {
                let comp = CompressorCfg::Identity.build::<f64>();
                let mut rng = Pcg64::seed(1);
                let msg = comp.compress(v, &mut rng);
                let decoded = WireMessage::<f64>::decode(&msg.encode())
                    .map_err(|e| format!("decode failed: {e}"))?;
                if decoded != msg {
                    return Err("decode != encode input".into());
                }
                if decoded.to_dense() != *v {
                    return Err("identity payload not bit-exact".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_stochastic_quantizer_is_unbiased() {
        forall(
            "E[Q(v)] = v for the b-bit stochastic quantizer",
            |rng| {
                let dim = 2 + rng.below(6);
                let v: Vec<f64> =
                    (0..dim).map(|_| rng.range(-3.0, 3.0)).collect();
                let seed = rng.next_u64();
                (v, seed)
            },
            |(v, seed)| {
                let comp = Quant { bits: 8 };
                let mut rng = Pcg64::seed(*seed);
                let draws = 2000;
                let mut mean = vec![0.0f64; v.len()];
                for _ in 0..draws {
                    let out = comp.compress(v, &mut rng).to_dense();
                    for (m, o) in mean.iter_mut().zip(out) {
                        *m += o / draws as f64;
                    }
                }
                let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi =
                    v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let step = (hi - lo) / 255.0;
                // per-draw sd <= step/2, so the mean's sd <= step/(2*sqrt(N));
                // 0.15*step is a >13-sigma band
                let tol = (0.15 * step).max(1e-12);
                for (m, x) in mean.iter().zip(v) {
                    if (m - x).abs() > tol {
                        return Err(format!(
                            "biased: mean {m} vs value {x} (tol {tol})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_topk_error_norm_bounded_by_input_norm() {
        forall(
            "|v - TopK(v)| <= |v|",
            |rng| {
                let dim = 1 + rng.below(100);
                let frac = rng.range(0.01, 1.0);
                let v: Vec<f64> = (0..dim)
                    .map(|_| rng.normal() * 10.0f64.powi(rng.below(4) as i32))
                    .collect();
                (v, frac)
            },
            |(v, frac)| {
                let comp = TopK { frac: *frac };
                let mut rng = Pcg64::seed(2);
                let kept = comp.compress(v, &mut rng).to_dense();
                let err: Vec<f64> = v
                    .iter()
                    .zip(&kept)
                    .map(|(a, b)| a - b)
                    .collect();
                if norm(&err) <= norm(v) + 1e-12 {
                    Ok(())
                } else {
                    Err(format!(
                        "contraction violated: |err| {} > |v| {}",
                        norm(&err),
                        norm(v)
                    ))
                }
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            "abs is nonneg",
            |rng| rng.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        forall(
            "always fails",
            |rng| rng.f64(),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut collected_a = Vec::new();
        forall_seeded("collect a", 9, |rng| rng.next_u64(), |x| {
            collected_a.push(*x);
            Ok(())
        });
        let mut collected_b = Vec::new();
        forall_seeded("collect b", 9, |rng| rng.next_u64(), |x| {
            collected_b.push(*x);
            Ok(())
        });
        assert_eq!(collected_a, collected_b);
    }
}
