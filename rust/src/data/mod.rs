//! Dataset substrate: synthetic corpora + non-iid partitioners.
//!
//! The environment has no MNIST/CIFAR downloads; DESIGN.md §3 documents the
//! substitution. [`synth`] generates structured classification corpora with
//! the properties the paper's experiments exercise; [`partition`]
//! implements the paper's exact splits (single-class-per-agent for MNIST,
//! `Dir_N(0.5)` for CIFAR-10); [`regress`] generates the App. G.1
//! mixed-distribution regression/LASSO data.

pub mod partition;
pub mod regress;
pub mod synth;

pub use partition::{dirichlet_split, iid_split, single_class_split};
pub use synth::{ClassDataset, SynthSpec};
