//! Non-iid partitioners — the paper's exact agent splits.
//!
//! * [`single_class_split`] — the MNIST setup: N = #classes agents, each
//!   storing *only one digit* ("the most extreme non-i.i.d. distribution").
//! * [`dirichlet_split`] — the CIFAR setup: for each class `a` sample
//!   `p_a ~ Dir_N(β)` and give agent `j` a `p_{a,j}` fraction of class `a`
//!   (β = 0.5 in the paper).
//! * [`iid_split`] — shuffled equal split (control).

use super::synth::ClassDataset;
use crate::rng::Rng;

/// One shard per class; requires `n_agents == data.classes` multiples —
/// more generally, agent `i` receives class `i % classes`.
pub fn single_class_split(data: &ClassDataset, n_agents: usize) -> Vec<ClassDataset> {
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.classes];
    for (i, &l) in data.labels.iter().enumerate() {
        by_class[l].push(i);
    }
    (0..n_agents)
        .map(|a| {
            let c = a % data.classes;
            // agents sharing a class split it contiguously
            let sharers = (0..n_agents).filter(|&b| b % data.classes == c).count();
            let my_rank = (0..a).filter(|&b| b % data.classes == c).count();
            let idx = &by_class[c];
            let chunk = idx.len() / sharers.max(1);
            let start = my_rank * chunk;
            let end = if my_rank + 1 == sharers { idx.len() } else { start + chunk };
            data.subset(&idx[start..end])
        })
        .collect()
}

/// Dirichlet split: `p_a ~ Dir_N(beta)` per class, rows assigned by
/// proportion (largest-remainder rounding so every sample lands somewhere).
pub fn dirichlet_split(
    data: &ClassDataset,
    n_agents: usize,
    beta: f64,
    rng: &mut impl Rng,
) -> Vec<ClassDataset> {
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_agents];
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.classes];
    for (i, &l) in data.labels.iter().enumerate() {
        by_class[l].push(i);
    }
    for idx in by_class {
        if idx.is_empty() {
            continue;
        }
        let p = rng.dirichlet(beta, n_agents);
        // largest-remainder apportionment of idx.len() rows
        let n = idx.len();
        let mut counts: Vec<usize> = p.iter().map(|&pi| (pi * n as f64) as usize).collect();
        let mut rem: Vec<(f64, usize)> = p
            .iter()
            .enumerate()
            .map(|(j, &pi)| (pi * n as f64 - counts[j] as f64, j))
            .collect();
        rem.sort_by(|a, b| b.0.total_cmp(&a.0));
        let assigned: usize = counts.iter().sum();
        for k in 0..(n - assigned) {
            counts[rem[k % n_agents].1] += 1;
        }
        let mut pos = 0;
        for (j, &cnt) in counts.iter().enumerate() {
            shards[j].extend_from_slice(&idx[pos..pos + cnt]);
            pos += cnt;
        }
        debug_assert_eq!(pos, n);
    }
    shards.iter().map(|idx| data.subset(idx)).collect()
}

/// Shuffled equal split (iid control).
pub fn iid_split(
    data: &ClassDataset,
    n_agents: usize,
    rng: &mut impl Rng,
) -> Vec<ClassDataset> {
    let mut idx: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut idx);
    let chunk = data.len() / n_agents;
    (0..n_agents)
        .map(|a| {
            let start = a * chunk;
            let end = if a + 1 == n_agents { data.len() } else { start + chunk };
            data.subset(&idx[start..end])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::rng::Pcg64;

    fn corpus() -> ClassDataset {
        generate(&SynthSpec::tiny(), &mut Pcg64::seed(1)).0
    }

    #[test]
    fn single_class_each_agent_one_class() {
        let data = corpus();
        let shards = single_class_split(&data, data.classes);
        assert_eq!(shards.len(), data.classes);
        for (a, shard) in shards.iter().enumerate() {
            assert!(!shard.is_empty());
            assert!(shard.labels.iter().all(|&l| l == a));
        }
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, data.len());
    }

    #[test]
    fn single_class_more_agents_than_classes() {
        let data = corpus();
        let shards = single_class_split(&data, 2 * data.classes);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, data.len());
        for (a, shard) in shards.iter().enumerate() {
            assert!(shard.labels.iter().all(|&l| l == a % data.classes));
        }
    }

    #[test]
    fn dirichlet_preserves_all_samples() {
        let data = corpus();
        let mut rng = Pcg64::seed(2);
        let shards = dirichlet_split(&data, 7, 0.5, &mut rng);
        assert_eq!(shards.len(), 7);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, data.len());
    }

    #[test]
    fn dirichlet_small_beta_skews_shards() {
        let data = corpus();
        let mut rng = Pcg64::seed(3);
        let shards = dirichlet_split(&data, 5, 0.1, &mut rng);
        // with beta=0.1 most shards should be class-dominated
        let mut dominated = 0;
        for shard in &shards {
            if shard.is_empty() {
                continue;
            }
            let counts = shard.class_counts();
            let max = *counts.iter().max().unwrap();
            if max as f64 > 0.6 * shard.len() as f64 {
                dominated += 1;
            }
        }
        assert!(dominated >= 3, "only {dominated} dominated shards");
    }

    #[test]
    fn iid_split_balances_sizes_and_classes() {
        let data = corpus();
        let mut rng = Pcg64::seed(4);
        let shards = iid_split(&data, 4, &mut rng);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, data.len());
        for shard in &shards {
            assert!(shard.len() >= data.len() / 4);
            // every class should appear in an iid shard of 40 samples
            assert!(shard.class_counts().iter().all(|&c| c > 0));
        }
    }
}
