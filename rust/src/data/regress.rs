//! Regression / LASSO data generation (App. G.1).
//!
//! "We generate samples from three different distributions: a standard
//! normal distribution, a Student's t distribution with one degree of
//! freedom, and a uniform distribution in the range [-5, 5]. These samples
//! are concatenated [...] then partitioned into subsets for each agent i to
//! obtain (A^i, b^i). Finally, we normalize the feature vectors and target
//! values for each agent."  In this non-iid setting the agents' local
//! optima are far apart — the regime where FedAvg/FedProx stall.

use crate::linalg::Matrix;
use crate::rng::Rng;

/// One agent's local least-squares block `(A^i, b^i)`.
#[derive(Clone, Debug)]
pub struct AgentBlock {
    pub a: Matrix,
    pub b: Vec<f64>,
}

/// Configuration of the App. G.1 generator.
#[derive(Clone, Debug)]
pub struct RegressSpec {
    pub n_agents: usize,
    /// Rows per agent.
    pub rows_per_agent: usize,
    /// Feature dimension n.
    pub dim: usize,
    /// Ground-truth sparsity (fraction of nonzero coefficients).
    pub sparsity: f64,
    /// Observation noise std.
    pub noise_std: f64,
}

impl Default for RegressSpec {
    fn default() -> Self {
        RegressSpec {
            n_agents: 50,
            rows_per_agent: 12,
            dim: 20,
            sparsity: 0.3,
            noise_std: 0.1,
        }
    }
}

/// Generate the mixed-distribution agent blocks.
pub fn generate(spec: &RegressSpec, rng: &mut impl Rng) -> (Vec<AgentBlock>, Vec<f64>) {
    let n = spec.dim;
    // sparse ground truth
    let x_true: Vec<f64> = (0..n)
        .map(|_| if rng.bernoulli(spec.sparsity) { 3.0 * rng.normal() } else { 0.0 })
        .collect();

    let total_rows = spec.n_agents * spec.rows_per_agent;
    // thirds from each distribution, concatenated (per the paper), so
    // contiguous agent shards are distribution-homogeneous -> non-iid.
    let mut rows: Vec<Vec<f64>> = Vec::with_capacity(total_rows);
    for r in 0..total_rows {
        let third = r * 3 / total_rows;
        let row: Vec<f64> = (0..n)
            .map(|_| match third {
                0 => rng.normal(),
                1 => rng.student_t(1.0).clamp(-50.0, 50.0),
                _ => rng.range(-5.0, 5.0),
            })
            .collect();
        rows.push(row);
    }

    let mut blocks = Vec::with_capacity(spec.n_agents);
    for a in 0..spec.n_agents {
        let start = a * spec.rows_per_agent;
        let mut am = Matrix::from_rows(
            rows[start..start + spec.rows_per_agent].to_vec(),
        );
        let mut b: Vec<f64> = am
            .matvec(&x_true)
            .iter()
            .map(|v| v + spec.noise_std * rng.normal())
            .collect();
        normalize_block(&mut am, &mut b);
        blocks.push(AgentBlock { a: am, b });
    }
    (blocks, x_true)
}

/// Per-agent normalization: unit-norm feature columns scale + RMS targets.
fn normalize_block(a: &mut Matrix, b: &mut [f64]) {
    let scale_a = (a.data.iter().map(|v| v * v).sum::<f64>()
        / a.data.len() as f64)
        .sqrt()
        .max(1e-12);
    for v in &mut a.data {
        *v /= scale_a;
    }
    let scale_b = (b.iter().map(|v| v * v).sum::<f64>() / b.len() as f64)
        .sqrt()
        .max(1e-12);
    for v in b.iter_mut() {
        *v /= scale_b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn shapes() {
        let spec = RegressSpec { n_agents: 10, rows_per_agent: 5, dim: 8, ..Default::default() };
        let (blocks, x_true) = generate(&spec, &mut Pcg64::seed(1));
        assert_eq!(blocks.len(), 10);
        assert_eq!(x_true.len(), 8);
        for blk in &blocks {
            assert_eq!(blk.a.rows, 5);
            assert_eq!(blk.a.cols, 8);
            assert_eq!(blk.b.len(), 5);
        }
    }

    #[test]
    fn normalization_bounds_scales() {
        let spec = RegressSpec::default();
        let (blocks, _) = generate(&spec, &mut Pcg64::seed(2));
        for blk in &blocks {
            let rms_a = (blk.a.data.iter().map(|v| v * v).sum::<f64>()
                / blk.a.data.len() as f64)
                .sqrt();
            let rms_b = (blk.b.iter().map(|v| v * v).sum::<f64>()
                / blk.b.len() as f64)
                .sqrt();
            assert!((rms_a - 1.0).abs() < 1e-9, "rms_a {rms_a}");
            assert!((rms_b - 1.0).abs() < 1e-9, "rms_b {rms_b}");
        }
    }

    #[test]
    fn blocks_are_heterogeneous() {
        // local least-squares solutions should be far apart (non-iid):
        // compare local solutions of first and last agents.
        let spec = RegressSpec {
            n_agents: 6,
            rows_per_agent: 30,
            dim: 10,
            sparsity: 0.5,
            noise_std: 0.05,
        };
        let (blocks, _) = generate(&spec, &mut Pcg64::seed(3));
        let solve = |blk: &AgentBlock| {
            let mut g = blk.a.gram();
            g.add_diag(1e-6);
            let chol = crate::linalg::Cholesky::factor(&g).unwrap();
            chol.solve(&blk.a.tmatvec(&blk.b))
        };
        let x0 = solve(&blocks[0]);
        let x5 = solve(&blocks[5]);
        let d = crate::linalg::dist2(&x0, &x5);
        assert!(d > 0.05, "local optima suspiciously close: {d}");
    }

    #[test]
    fn deterministic() {
        let spec = RegressSpec::default();
        let (a, xa) = generate(&spec, &mut Pcg64::seed(4));
        let (b, xb) = generate(&spec, &mut Pcg64::seed(4));
        assert_eq!(xa, xb);
        assert_eq!(a[0].b, b[0].b);
    }
}
