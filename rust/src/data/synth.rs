//! Synthetic structured classification corpora (MNIST/CIFAR surrogates).
//!
//! Each class `c` gets a smooth random prototype pattern; a sample is the
//! prototype under a random smooth deformation plus pixel noise:
//!
//! `x = proto_c + deform_strength * (M_c ξ) + noise_std * ε,  ξ, ε ~ N(0,I)`
//!
//! where `M_c` is a fixed low-rank "deformation basis" per class.  This
//! gives classes that (i) are learnable by an MLP but not trivially
//! linearly separable, (ii) produce *local optima far apart* under
//! single-class partitioning — the paper's extreme non-iid regime.

use crate::rng::Rng;

/// Corpus specification.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Feature dimension (e.g. 64 = 8x8 "digits", 192 = 3x8x8 "images").
    pub dim: usize,
    pub classes: usize,
    pub train_per_class: usize,
    pub test_per_class: usize,
    /// Rank of the per-class deformation basis.
    pub deform_rank: usize,
    pub deform_strength: f64,
    pub noise_std: f64,
    /// Multiply each sample by a random ±1: class means become zero, so
    /// classes are *not* linearly separable and a model trained on a single
    /// class degenerates — this induces the client-drift failure mode of
    /// FedAvg/FedProx under non-iid data that the paper's real-data
    /// experiments exhibit (see DESIGN.md §3).
    pub sign_flip: bool,
}

impl SynthSpec {
    /// MNIST-surrogate: 8x8, 10 classes. Difficulty calibrated so a
    /// centrally trained MLP [400,200,10] tops out around ~88% test
    /// accuracy (mirroring MNIST's headroom over the 90% Tab. 1 target).
    pub fn mnist() -> Self {
        SynthSpec {
            dim: 64,
            classes: 10,
            train_per_class: 600,
            test_per_class: 100,
            deform_rank: 16,
            deform_strength: 1.6,
            noise_std: 1.2,
            sign_flip: true,
        }
    }

    /// CIFAR-surrogate: 3x8x8, 10 classes, noisier — centralized ceiling
    /// around ~78% (the paper's CIFAR-10 top accuracy).
    pub fn cifar() -> Self {
        SynthSpec {
            dim: 192,
            classes: 10,
            train_per_class: 500,
            test_per_class: 100,
            deform_rank: 24,
            deform_strength: 2.4,
            noise_std: 2.0,
            sign_flip: true,
        }
    }

    /// Tiny corpus for unit tests (matches the `tiny` artifact config).
    pub fn tiny() -> Self {
        SynthSpec {
            dim: 8,
            classes: 4,
            train_per_class: 40,
            test_per_class: 10,
            deform_rank: 2,
            deform_strength: 0.5,
            noise_std: 0.3,
            sign_flip: false,
        }
    }
}

/// A labelled dataset, features flattened row-major.
#[derive(Clone, Debug)]
pub struct ClassDataset {
    pub dim: usize,
    pub classes: usize,
    pub xs: Vec<f32>,
    pub labels: Vec<usize>,
}

impl ClassDataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
    pub fn x(&self, i: usize) -> &[f32] {
        &self.xs[i * self.dim..(i + 1) * self.dim]
    }

    /// Select a subset by indices.
    pub fn subset(&self, idx: &[usize]) -> ClassDataset {
        let mut xs = Vec::with_capacity(idx.len() * self.dim);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.x(i));
            labels.push(self.labels[i]);
        }
        ClassDataset { dim: self.dim, classes: self.classes, xs, labels }
    }

    /// Sample a minibatch (with replacement) into flat (xs, one-hot ys).
    pub fn sample_batch(
        &self,
        batch: usize,
        rng: &mut impl Rng,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ys = Vec::with_capacity(batch * self.classes);
        self.sample_batch_into(batch, rng, &mut xs, &mut ys);
        (xs, ys)
    }

    /// [`Self::sample_batch`] appending into caller-owned arenas — the
    /// allocation-free solve-phase path (`rust/tests/alloc.rs`).  RNG
    /// consumption is identical (one draw per row), so trajectories are
    /// unchanged whichever entry point a caller uses.
    pub fn sample_batch_into(
        &self,
        batch: usize,
        rng: &mut impl Rng,
        xs: &mut Vec<f32>,
        ys: &mut Vec<f32>,
    ) {
        for _ in 0..batch {
            let i = rng.below(self.len());
            xs.extend_from_slice(self.x(i));
            let base = ys.len();
            ys.resize(base + self.classes, 0.0);
            ys[base + self.labels[i]] = 1.0;
        }
    }

    /// One-hot labels for the whole set.
    pub fn onehot(&self) -> Vec<f32> {
        let mut ys = vec![0.0f32; self.len() * self.classes];
        for (i, &l) in self.labels.iter().enumerate() {
            ys[i * self.classes + l] = 1.0;
        }
        ys
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &l in &self.labels {
            counts[l] += 1;
        }
        counts
    }
}

/// Smooth a flat pattern by repeated neighbor averaging (cheap low-pass).
fn smooth(v: &mut [f64], passes: usize) {
    let n = v.len();
    for _ in 0..passes {
        let prev = v.to_vec();
        for i in 0..n {
            let l = prev[(i + n - 1) % n];
            let r = prev[(i + 1) % n];
            v[i] = 0.5 * prev[i] + 0.25 * (l + r);
        }
    }
}

/// Generate `(train, test)` corpora from a spec.
pub fn generate(spec: &SynthSpec, rng: &mut impl Rng) -> (ClassDataset, ClassDataset) {
    let d = spec.dim;
    // class prototypes: smoothed gaussian patterns, normalized to unit RMS
    let mut protos: Vec<Vec<f64>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut p: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        smooth(&mut p, 4);
        let rms = (p.iter().map(|x| x * x).sum::<f64>() / d as f64).sqrt();
        for x in &mut p {
            *x /= rms.max(1e-9);
        }
        protos.push(p);
    }
    // per-class deformation bases (columns smoothed too)
    let mut bases: Vec<Vec<Vec<f64>>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut cols = Vec::with_capacity(spec.deform_rank);
        for _ in 0..spec.deform_rank {
            let mut col: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            smooth(&mut col, 2);
            let nrm = col.iter().map(|x| x * x).sum::<f64>().sqrt();
            for x in &mut col {
                *x /= nrm.max(1e-9);
            }
            cols.push(col);
        }
        bases.push(cols);
    }

    let mut gen_split = |per_class: usize| -> ClassDataset {
        let n = per_class * spec.classes;
        let mut xs = Vec::with_capacity(n * d);
        let mut labels = Vec::with_capacity(n);
        for c in 0..spec.classes {
            for _ in 0..per_class {
                let mut x = protos[c].clone();
                for col in &bases[c] {
                    let xi = rng.normal() * spec.deform_strength;
                    for (v, b) in x.iter_mut().zip(col) {
                        *v += xi * b;
                    }
                }
                for v in x.iter_mut() {
                    *v += spec.noise_std * rng.normal();
                }
                if spec.sign_flip && rng.bernoulli(0.5) {
                    for v in x.iter_mut() {
                        *v = -*v;
                    }
                }
                xs.extend(x.iter().map(|&v| v as f32));
                labels.push(c);
            }
        }
        ClassDataset { dim: d, classes: spec.classes, xs, labels }
    };

    let train = gen_split(spec.train_per_class);
    let test = gen_split(spec.test_per_class);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn shapes_and_labels() {
        let mut rng = Pcg64::seed(1);
        let spec = SynthSpec::tiny();
        let (train, test) = generate(&spec, &mut rng);
        assert_eq!(train.len(), spec.classes * spec.train_per_class);
        assert_eq!(test.len(), spec.classes * spec.test_per_class);
        assert_eq!(train.xs.len(), train.len() * spec.dim);
        assert!(train.labels.iter().all(|&l| l < spec.classes));
        assert_eq!(train.class_counts(), vec![spec.train_per_class; 4]);
    }

    #[test]
    fn deterministic_from_seed() {
        let spec = SynthSpec::tiny();
        let (a, _) = generate(&spec, &mut Pcg64::seed(9));
        let (b, _) = generate(&spec, &mut Pcg64::seed(9));
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn classes_are_separated() {
        // nearest-prototype classification on the train means should beat
        // chance by a wide margin — i.e. the corpus is learnable.
        let mut rng = Pcg64::seed(2);
        let spec = SynthSpec::tiny();
        let (train, test) = generate(&spec, &mut rng);
        let d = spec.dim;
        let mut means = vec![vec![0.0f64; d]; spec.classes];
        let counts = train.class_counts();
        for i in 0..train.len() {
            let c = train.labels[i];
            for (m, &x) in means[c].iter_mut().zip(train.x(i)) {
                *m += x as f64;
            }
        }
        for (m, &cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= cnt as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let x = test.x(i);
            let best = (0..spec.classes)
                .min_by(|&a, &b| {
                    let da: f64 = x.iter().zip(&means[a])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2)).sum();
                    let db: f64 = x.iter().zip(&means[b])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "nearest-prototype acc only {acc}");
    }

    #[test]
    fn sample_batch_shapes_and_onehot() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let (xs, ys) = train.sample_batch(5, &mut rng);
        assert_eq!(xs.len(), 5 * train.dim);
        assert_eq!(ys.len(), 5 * train.classes);
        for b in 0..5 {
            let row = &ys[b * train.classes..(b + 1) * train.classes];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn subset_picks_rows() {
        let mut rng = Pcg64::seed(4);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let sub = train.subset(&[0, 5, 10]);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.x(1), train.x(5));
        assert_eq!(sub.labels[2], train.labels[10]);
    }
}
