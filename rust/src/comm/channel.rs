//! Lossy channel simulation — the paper's packet-drop model.
//!
//! A sent delta is lost with probability `drop_rate`; the *sender does not
//! learn about the loss* (no acknowledgements), which is exactly why the
//! paper needs the periodic reset strategy (App. E, Fig. 10): receiver
//! estimates drift by the accumulated `χ` disturbances until a reset
//! re-synchronizes them.

use crate::rng::Rng;

/// Per-link transmission counters — messages *and* wire bytes (the byte
/// totals are charged with each message's exact encoded size, see
/// [`crate::wire::WireMessage::wire_bytes`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChannelStats {
    pub sent: u64,
    pub dropped: u64,
    /// Bytes put on the wire (delivered or not).
    pub sent_bytes: u64,
    /// Bytes lost in flight.
    pub dropped_bytes: u64,
}

impl ChannelStats {
    pub fn delivered(&self) -> u64 {
        self.sent - self.dropped
    }
    pub fn delivered_bytes(&self) -> u64 {
        self.sent_bytes - self.dropped_bytes
    }
    pub fn drop_fraction(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.dropped as f64 / self.sent as f64
        }
    }

    /// Charge a message that bypasses the lossy channel (the periodic
    /// resets are full, reliable synchronization messages — they count as
    /// traffic but can never drop).
    pub fn record_reliable(&mut self, bytes: u64) {
        self.sent += 1;
        self.sent_bytes += bytes;
    }
}

/// A lossy point-to-point link.
#[derive(Clone, Debug)]
pub struct DropChannel {
    pub drop_rate: f64,
    pub stats: ChannelStats,
}

impl DropChannel {
    pub fn new(drop_rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&drop_rate), "drop_rate in [0,1]");
        DropChannel { drop_rate, stats: ChannelStats::default() }
    }

    /// A perfect link.
    pub fn reliable() -> Self {
        DropChannel::new(0.0)
    }

    /// Transmit a payload; `None` means the packet was dropped in flight.
    pub fn transmit<T>(&mut self, payload: T, rng: &mut impl Rng) -> Option<T> {
        self.transmit_bytes(payload, 0, rng)
    }

    /// Transmit a payload of known wire size, charging the byte counters.
    pub fn transmit_bytes<T>(
        &mut self,
        payload: T,
        bytes: u64,
        rng: &mut impl Rng,
    ) -> Option<T> {
        self.stats.sent += 1;
        self.stats.sent_bytes += bytes;
        if self.drop_rate > 0.0 && rng.bernoulli(self.drop_rate) {
            self.stats.dropped += 1;
            self.stats.dropped_bytes += bytes;
            None
        } else {
            Some(payload)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn reliable_never_drops() {
        let mut ch = DropChannel::reliable();
        let mut rng = Pcg64::seed(0);
        for i in 0..1000 {
            assert_eq!(ch.transmit(i, &mut rng), Some(i));
        }
        assert_eq!(ch.stats.dropped, 0);
        assert_eq!(ch.stats.sent, 1000);
    }

    #[test]
    fn full_loss_drops_everything() {
        let mut ch = DropChannel::new(1.0);
        let mut rng = Pcg64::seed(1);
        for i in 0..100 {
            assert_eq!(ch.transmit(i, &mut rng), None);
        }
        assert_eq!(ch.stats.dropped, 100);
    }

    #[test]
    fn drop_rate_is_respected() {
        let mut ch = DropChannel::new(0.3);
        let mut rng = Pcg64::seed(2);
        for _ in 0..50_000 {
            ch.transmit((), &mut rng);
        }
        let frac = ch.stats.drop_fraction();
        assert!((frac - 0.3).abs() < 0.01, "drop fraction {frac}");
        assert_eq!(ch.stats.delivered() + ch.stats.dropped, ch.stats.sent);
    }

    #[test]
    fn rejects_bad_rate() {
        let res = std::panic::catch_unwind(|| DropChannel::new(1.5));
        assert!(res.is_err());
    }

    #[test]
    fn byte_counters_track_sent_and_dropped() {
        let mut ch = DropChannel::new(0.5);
        let mut rng = Pcg64::seed(4);
        for _ in 0..10_000 {
            ch.transmit_bytes((), 100, &mut rng);
        }
        assert_eq!(ch.stats.sent_bytes, 1_000_000);
        assert_eq!(ch.stats.dropped_bytes, ch.stats.dropped * 100);
        assert_eq!(
            ch.stats.delivered_bytes(),
            ch.stats.delivered() * 100
        );
    }

    #[test]
    fn reliable_messages_count_traffic_but_never_drop() {
        let mut ch = DropChannel::new(1.0);
        ch.stats.record_reliable(42);
        assert_eq!(ch.stats.sent, 1);
        assert_eq!(ch.stats.sent_bytes, 42);
        assert_eq!(ch.stats.dropped, 0);
    }
}
