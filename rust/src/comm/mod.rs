//! Event-based communication substrate (Sec. 2, App. C/E of the paper).
//!
//! Three pieces compose every communication line in Alg. 1 / Alg. 2:
//!
//! * [`Trigger`] / [`TriggerState`] — decides *whether* an update is sent:
//!   vanilla send-on-delta (`|v_{k+1} − v_{[k]}| > Δ`), the randomized
//!   variant (below-threshold sends with probability `p_trig`), the
//!   baselines' random participation, or always/never.
//! * [`crate::transport::loss::LossyLink`] — decides whether a sent delta
//!   *arrives* (Bernoulli packet drops, the paper's `χ` disturbances).
//!   It lives in [`crate::transport`] since the transport layer landed;
//!   this module re-exports its stats/model types for convenience.
//! * [`Estimate`] — the receiver-side accumulator `v̂` that integrates the
//!   received deltas and can be hard-reset (the rare periodic reset
//!   strategy of Alg. 1/2).
//!
//! All pieces count events, so the paper's *communication load* metric
//! (triggered events normalized by full communication) falls out of the
//! counters.

mod estimate;
mod trigger;

pub use crate::transport::loss::{ChannelStats, LossModel};
pub use estimate::Estimate;
pub use trigger::{Trigger, TriggerState};

/// Scalar abstraction so the protocol works over both the f32 PJRT
/// parameter ABI and the f64 convex experiments.
///
/// Beyond the arithmetic hooks, a scalar knows its exact wire format
/// ([`Scalar::WIRE_BYTES`] little-endian bytes, raw IEEE-754 bit pattern)
/// so [`crate::wire`]'s codec round-trips dense payloads losslessly.
pub trait Scalar: Copy + PartialEq + std::fmt::Debug + 'static {
    /// Bytes per value on the wire (4 for f32, 8 for f64); doubles as the
    /// codec's scalar tag so decoding with the wrong type fails loudly.
    const WIRE_BYTES: usize;
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
    fn zero() -> Self;
    /// Append the exact little-endian bit pattern to `out`.
    fn write_le(self, out: &mut Vec<u8>);
    /// Read the exact bit pattern back (`buf` holds >= `WIRE_BYTES`).
    fn read_le(buf: &[u8]) -> Self;
}

impl Scalar for f32 {
    const WIRE_BYTES: usize = 4;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn zero() -> Self {
        0.0
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(buf: &[u8]) -> Self {
        f32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]])
    }
}

impl Scalar for f64 {
    const WIRE_BYTES: usize = 8;
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f64(v: f64) -> Self {
        v
    }
    fn zero() -> Self {
        0.0
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(buf: &[u8]) -> Self {
        f64::from_le_bytes([
            buf[0], buf[1], buf[2], buf[3], buf[4], buf[5], buf[6], buf[7],
        ])
    }
}

/// Euclidean norm of a difference, in f64 regardless of storage type.
///
/// Hot path of every trigger evaluation (§Perf): four independent
/// accumulators break the horizontal-sum dependency.  On 108k-element
/// parameter vectors the loop is memory-bandwidth-bound (~230 µs,
/// ≈3.7 GB/s streaming on the test box), i.e. already at the practical
/// roofline — see EXPERIMENTS.md §Perf.
pub fn delta_norm<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let n4 = a.len() & !3;
    let mut i = 0;
    while i < n4 {
        // four independent chains
        let d0 = a[i].to_f64() - b[i].to_f64();
        let d1 = a[i + 1].to_f64() - b[i + 1].to_f64();
        let d2 = a[i + 2].to_f64() - b[i + 2].to_f64();
        let d3 = a[i + 3].to_f64() - b[i + 3].to_f64();
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
        i += 4;
    }
    let mut tail = 0.0;
    while i < a.len() {
        let d = a[i].to_f64() - b[i].to_f64();
        tail += d * d;
        i += 1;
    }
    (acc[0] + acc[1] + acc[2] + acc[3] + tail).sqrt()
}

/// `a - b` elementwise.
pub fn sub<T: Scalar>(a: &[T], b: &[T]) -> Vec<T> {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| T::from_f64(x.to_f64() - y.to_f64()))
        .collect()
}

/// `a - b` elementwise into a reusable buffer — the allocation-free twin
/// of [`sub`] for the per-round trigger hot path (§Perf: the ADMM loops
/// fire one delta per line per round; reusing one scratch buffer removes
/// that allocation entirely).
pub fn sub_into<T: Scalar>(a: &[T], b: &[T], out: &mut Vec<T>) {
    debug_assert_eq!(a.len(), b.len());
    out.clear();
    out.reserve(a.len());
    out.extend(
        a.iter()
            .zip(b)
            .map(|(&x, &y)| T::from_f64(x.to_f64() - y.to_f64())),
    );
}
