//! Event-based communication substrate (Sec. 2, App. C/E of the paper).
//!
//! Three pieces compose every communication line in Alg. 1 / Alg. 2:
//!
//! * [`Trigger`] / [`TriggerState`] — decides *whether* an update is sent:
//!   vanilla send-on-delta (`|v_{k+1} − v_{[k]}| > Δ`), the randomized
//!   variant (below-threshold sends with probability `p_trig`), the
//!   baselines' random participation, or always/never.
//! * [`DropChannel`] — decides whether a sent delta *arrives* (Bernoulli
//!   packet drops, the paper's `χ` disturbances).
//! * [`Estimate`] — the receiver-side accumulator `v̂` that integrates the
//!   received deltas and can be hard-reset (the rare periodic reset
//!   strategy of Alg. 1/2).
//!
//! All pieces count events, so the paper's *communication load* metric
//! (triggered events normalized by full communication) falls out of the
//! counters.

mod channel;
mod estimate;
mod trigger;

pub use channel::{ChannelStats, DropChannel};
pub use estimate::Estimate;
pub use trigger::{Trigger, TriggerState};

/// Scalar abstraction so the protocol works over both the f32 PJRT
/// parameter ABI and the f64 convex experiments.
pub trait Scalar: Copy + PartialEq + std::fmt::Debug + 'static {
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
    fn zero() -> Self;
}

impl Scalar for f32 {
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn zero() -> Self {
        0.0
    }
}

impl Scalar for f64 {
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f64(v: f64) -> Self {
        v
    }
    fn zero() -> Self {
        0.0
    }
}

/// Euclidean norm of a difference, in f64 regardless of storage type.
///
/// Hot path of every trigger evaluation (§Perf): four independent
/// accumulators break the horizontal-sum dependency.  On 108k-element
/// parameter vectors the loop is memory-bandwidth-bound (~230 µs,
/// ≈3.7 GB/s streaming on the test box), i.e. already at the practical
/// roofline — see EXPERIMENTS.md §Perf.
pub fn delta_norm<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let n4 = a.len() & !3;
    let mut i = 0;
    while i < n4 {
        // four independent chains
        let d0 = a[i].to_f64() - b[i].to_f64();
        let d1 = a[i + 1].to_f64() - b[i + 1].to_f64();
        let d2 = a[i + 2].to_f64() - b[i + 2].to_f64();
        let d3 = a[i + 3].to_f64() - b[i + 3].to_f64();
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
        i += 4;
    }
    let mut tail = 0.0;
    while i < a.len() {
        let d = a[i].to_f64() - b[i].to_f64();
        tail += d * d;
        i += 1;
    }
    (acc[0] + acc[1] + acc[2] + acc[3] + tail).sqrt()
}

/// `a - b` elementwise.
pub fn sub<T: Scalar>(a: &[T], b: &[T]) -> Vec<T> {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| T::from_f64(x.to_f64() - y.to_f64()))
        .collect()
}
