//! Event triggers: *when* does an agent communicate?

use super::{delta_norm, sub, sub_into, Scalar};
use crate::rng::Rng;

/// Communication policy for one transmit line.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// Full communication — one packet every round (the normalizer for the
    /// paper's communication-load percentage).
    Always,
    /// No communication (useful for ablations/tests).
    Never,
    /// Vanilla event-based (sent-on-delta, Eq. 2):
    /// send iff `|v_{k+1} − v_{[k]}| > Δ`.
    Vanilla { delta: f64 },
    /// Randomized event-based (Sec. 2): above threshold send with
    /// certainty; below threshold send with probability `p_trig`.
    Randomized { delta: f64, p_trig: f64 },
    /// Random participation with rate `p` — the mechanism of the FedAvg /
    /// FedProx / FedADMM / SCAFFOLD baselines and of the "purely random
    /// selection" comparison in App. G.3.
    Participation { p: f64 },
    /// Diminishing threshold `Δ_k = Δ₀ / (k+1)^t` (App. F): guarantees
    /// exact convergence with rate `O(1/k^t)` (Cor. F.2); `t = 2` is the
    /// schedule of the nonconvex result Thm. 2.3.
    Decaying { delta0: f64, power: f64 },
}

impl Trigger {
    pub fn vanilla(delta: f64) -> Trigger {
        Trigger::Vanilla { delta }
    }
    pub fn randomized(delta: f64, p_trig: f64) -> Trigger {
        Trigger::Randomized { delta, p_trig }
    }
    pub fn participation(p: f64) -> Trigger {
        Trigger::Participation { p }
    }
    pub fn decaying(delta0: f64, power: f64) -> Trigger {
        Trigger::Decaying { delta0, power }
    }

    /// Parse the CLI/scenario syntax: `always` | `never` | `vanilla:D` |
    /// `randomized:D:P` | `participation:P` | `decaying:D0:T`.
    /// Thresholds must be >= 0 and probabilities in [0,1] — a mistyped
    /// value must not silently degenerate into a different policy.
    pub fn parse(s: &str) -> Result<Trigger, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize, what: &str| -> Result<f64, String> {
            parts
                .get(i)
                .ok_or_else(|| format!("{s:?}: missing {what}"))?
                .parse::<f64>()
                .map_err(|_| format!("{s:?}: bad {what}"))
        };
        let nonneg = |i: usize, what: &str| -> Result<f64, String> {
            let v = num(i, what)?;
            if v.is_nan() || v < 0.0 {
                return Err(format!("{s:?}: {what} must be >= 0"));
            }
            Ok(v)
        };
        let prob = |i: usize, what: &str| -> Result<f64, String> {
            let v = num(i, what)?;
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{s:?}: {what} must be in [0,1]"));
            }
            Ok(v)
        };
        match parts[0] {
            "always" => Ok(Trigger::Always),
            "never" => Ok(Trigger::Never),
            "vanilla" => {
                Ok(Trigger::Vanilla { delta: nonneg(1, "delta")? })
            }
            "randomized" => Ok(Trigger::Randomized {
                delta: nonneg(1, "delta")?,
                p_trig: prob(2, "p_trig")?,
            }),
            "participation" => {
                Ok(Trigger::Participation { p: prob(1, "p")? })
            }
            "decaying" => Ok(Trigger::Decaying {
                delta0: nonneg(1, "delta0")?,
                power: nonneg(2, "power")?,
            }),
            other => Err(format!(
                "unknown trigger {other:?} (expected always | never | \
                 vanilla:D | randomized:D:P | participation:P | \
                 decaying:D0:T)"
            )),
        }
    }

    /// Display label matching the [`Self::parse`] syntax.
    pub fn label(&self) -> String {
        match *self {
            Trigger::Always => "always".into(),
            Trigger::Never => "never".into(),
            Trigger::Vanilla { delta } => format!("vanilla:{delta}"),
            Trigger::Randomized { delta, p_trig } => {
                format!("randomized:{delta}:{p_trig}")
            }
            Trigger::Participation { p } => format!("participation:{p}"),
            Trigger::Decaying { delta0, power } => {
                format!("decaying:{delta0}:{power}")
            }
        }
    }
}

/// Per-line trigger state: tracks the last *communicated* value `v_{[k]}`
/// and decides, for each new `v_{k+1}`, whether to emit the delta
/// `v_{k+1} − v_{[k]}`.
#[derive(Clone, Debug)]
pub struct TriggerState<T: Scalar> {
    pub trigger: Trigger,
    last_sent: Vec<T>,
    /// Number of rounds observed (communication opportunities).
    pub opportunities: u64,
    /// Number of triggered communications.
    pub events: u64,
}

impl<T: Scalar> TriggerState<T> {
    /// `init` is the commonly known initial value (the paper initializes
    /// `x̂_0 = x_0`, `ẑ_0 = z_0`, … so all estimates start in sync).
    pub fn new(trigger: Trigger, init: Vec<T>) -> Self {
        TriggerState { trigger, last_sent: init, opportunities: 0, events: 0 }
    }

    /// Current `v_{[k]}` — the value the receivers believe (absent drops).
    pub fn last_sent(&self) -> &[T] {
        &self.last_sent
    }

    /// Would `current` fire the deterministic part of the trigger?
    pub fn deviation(&self, current: &[T]) -> f64 {
        delta_norm(current, &self.last_sent)
    }

    /// The firing rule shared by [`Self::offer`] and [`Self::offer_into`];
    /// counts the opportunity and consumes the same RNG stream either way.
    fn decide(&mut self, current: &[T], rng: &mut impl Rng) -> bool {
        self.opportunities += 1;
        match self.trigger {
            Trigger::Always => true,
            Trigger::Never => false,
            Trigger::Vanilla { delta } => self.deviation(current) > delta,
            Trigger::Randomized { delta, p_trig } => {
                self.deviation(current) > delta || rng.bernoulli(p_trig)
            }
            Trigger::Participation { p } => rng.bernoulli(p),
            Trigger::Decaying { delta0, power } => {
                // opportunities was just incremented, so k+1 = opportunities
                let dk = delta0 / (self.opportunities as f64).powf(power);
                self.deviation(current) > dk
            }
        }
    }

    /// Observe the new value; return `Some(delta)` if a communication is
    /// triggered. On a trigger, `v_{[k]}` advances to `current` (the sender
    /// does NOT know whether the packet survives the channel — that is the
    /// paper's drop model, Eq. 32/33).
    pub fn offer(&mut self, current: &[T], rng: &mut impl Rng) -> Option<Vec<T>> {
        if self.decide(current, rng) {
            self.events += 1;
            let delta = sub(current, &self.last_sent);
            self.last_sent.clear();
            self.last_sent.extend_from_slice(current);
            Some(delta)
        } else {
            None
        }
    }

    /// Allocation-free twin of [`Self::offer`] for the per-round hot
    /// loops: on a trigger the delta is written into `delta_out` (reused
    /// across rounds) and `true` is returned; otherwise `delta_out` is
    /// cleared.  Identical firing decisions and RNG consumption.
    pub fn offer_into(
        &mut self,
        current: &[T],
        rng: &mut impl Rng,
        delta_out: &mut Vec<T>,
    ) -> bool {
        if self.decide(current, rng) {
            self.events += 1;
            sub_into(current, &self.last_sent, delta_out);
            self.last_sent.clear();
            self.last_sent.extend_from_slice(current);
            true
        } else {
            delta_out.clear();
            false
        }
    }

    /// Periodic reset: force `v_{[k]} = current` *and* count the implied
    /// communication (a reset is a full synchronization message).
    pub fn reset(&mut self, current: &[T]) {
        self.last_sent = current.to_vec();
        self.events += 1;
    }

    /// Triggered fraction (the paper's per-line communication load).
    pub fn load(&self) -> f64 {
        if self.opportunities == 0 {
            0.0
        } else {
            self.events as f64 / self.opportunities as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn st(trigger: Trigger) -> TriggerState<f64> {
        TriggerState::new(trigger, vec![0.0; 3])
    }

    #[test]
    fn always_fires_every_round() {
        let mut s = st(Trigger::Always);
        let mut rng = Pcg64::seed(0);
        for k in 0..10 {
            assert!(s.offer(&[k as f64, 0.0, 0.0], &mut rng).is_some());
        }
        assert_eq!(s.events, 10);
        assert!((s.load() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn never_never_fires() {
        let mut s = st(Trigger::Never);
        let mut rng = Pcg64::seed(0);
        for _ in 0..10 {
            assert!(s.offer(&[100.0, 0.0, 0.0], &mut rng).is_none());
        }
        assert_eq!(s.events, 0);
    }

    #[test]
    fn vanilla_fires_iff_deviation_exceeds_delta() {
        let mut s = st(Trigger::vanilla(1.0));
        let mut rng = Pcg64::seed(1);
        // |(0.5,0,0)| = 0.5 <= 1: no event
        assert!(s.offer(&[0.5, 0.0, 0.0], &mut rng).is_none());
        // still measured against last SENT value (0): |(1.2,..)| > 1 fires
        let d = s.offer(&[1.2, 0.0, 0.0], &mut rng).unwrap();
        assert_eq!(d, vec![1.2, 0.0, 0.0]);
        // now reference is 1.2; small move doesn't fire
        assert!(s.offer(&[1.5, 0.0, 0.0], &mut rng).is_none());
        assert_eq!(s.events, 1);
        assert_eq!(s.opportunities, 3);
    }

    #[test]
    fn vanilla_delta_is_cumulative_since_last_send() {
        let mut s = st(Trigger::vanilla(0.4));
        let mut rng = Pcg64::seed(2);
        assert!(s.offer(&[0.3, 0.0, 0.0], &mut rng).is_none());
        // deviation from last SENT (zero), not from previous offer
        let d = s.offer(&[0.45, 0.0, 0.0], &mut rng).unwrap();
        assert!((d[0] - 0.45).abs() < 1e-15);
    }

    #[test]
    fn randomized_fires_with_certainty_above_threshold() {
        let mut rng = Pcg64::seed(3);
        let mut s = st(Trigger::randomized(1.0, 0.0));
        assert!(s.offer(&[2.0, 0.0, 0.0], &mut rng).is_some());
    }

    #[test]
    fn randomized_fires_at_rate_p_below_threshold() {
        let mut rng = Pcg64::seed(4);
        let mut s = st(Trigger::randomized(1e9, 0.25));
        let n = 20_000;
        let mut hits = 0;
        for _ in 0..n {
            // keep the value at 0 so the deterministic branch never fires
            if s.offer(&[0.0, 0.0, 0.0], &mut rng).is_some() {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn participation_rate() {
        let mut rng = Pcg64::seed(5);
        let mut s = st(Trigger::participation(0.4));
        let n = 20_000;
        let mut hits = 0;
        for _ in 0..n {
            if s.offer(&[1e6, 0.0, 0.0], &mut rng).is_some() {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.4).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn reset_syncs_and_counts() {
        let mut s = st(Trigger::vanilla(10.0));
        let mut rng = Pcg64::seed(6);
        assert!(s.offer(&[5.0, 0.0, 0.0], &mut rng).is_none());
        s.reset(&[5.0, 0.0, 0.0]);
        assert_eq!(s.last_sent(), &[5.0, 0.0, 0.0]);
        assert_eq!(s.events, 1);
        // after reset, deviation measured from the reset point
        assert!(s.deviation(&[5.0, 0.0, 0.0]) < 1e-15);
    }

    #[test]
    fn f32_payloads_work() {
        let mut s: TriggerState<f32> =
            TriggerState::new(Trigger::vanilla(0.5), vec![0.0f32; 2]);
        let mut rng = Pcg64::seed(7);
        assert!(s.offer(&[0.3, 0.0], &mut rng).is_none());
        assert!(s.offer(&[0.6, 0.0], &mut rng).is_some());
    }

    #[test]
    fn decaying_threshold_tightens_over_rounds() {
        // Δ_k = 1/(k+1): a deviation of 0.5 does not fire early but fires
        // once the schedule has decayed past it.
        let mut s = st(Trigger::decaying(1.0, 1.0));
        let mut rng = Pcg64::seed(20);
        // k = 0: Δ_0 = 1.0 > 0.5 -> no fire
        assert!(s.offer(&[0.5, 0.0, 0.0], &mut rng).is_none());
        // k = 1: Δ_1 = 0.5, strict > -> still no fire at exactly 0.5
        assert!(s.offer(&[0.5, 0.0, 0.0], &mut rng).is_none());
        // k = 2: Δ_2 = 1/3 < 0.5 -> fires
        assert!(s.offer(&[0.5, 0.0, 0.0], &mut rng).is_some());
    }

    #[test]
    fn decaying_drives_estimate_error_to_zero() {
        // App. F: with Δ_k -> 0 the receiver error must vanish even for a
        // drifting signal (here: converging geometrically).
        let mut s = st(Trigger::decaying(2.0, 2.0));
        let mut rng = Pcg64::seed(21);
        let mut v = [4.0, 0.0, 0.0];
        let mut last_err = f64::INFINITY;
        for k in 0..200 {
            v[0] = 4.0 * 0.97f64.powi(k); // converging signal
            s.offer(&v, &mut rng);
            if k > 150 {
                let err = s.deviation(&v);
                last_err = err;
            }
        }
        assert!(last_err < 1e-3, "residual estimate error {last_err}");
    }

    #[test]
    fn boundary_is_strict_inequality() {
        // Eq. 2 uses strict '>' — deviation exactly Delta must NOT fire.
        let mut s = st(Trigger::vanilla(1.0));
        let mut rng = Pcg64::seed(8);
        assert!(s.offer(&[1.0, 0.0, 0.0], &mut rng).is_none());
    }

    #[test]
    fn offer_into_matches_offer_exactly() {
        // Same trigger, same seed: the buffer variant must fire on the
        // same rounds with identical deltas and counters.
        let trig = Trigger::randomized(0.5, 0.2);
        let mut a = st(trig);
        let mut b = st(trig);
        let mut rng_a = Pcg64::seed(30);
        let mut rng_b = Pcg64::seed(30);
        let mut buf = Vec::new();
        for k in 0..200 {
            let v = [
                (k as f64 * 0.37).sin(),
                (k as f64 * 0.11).cos(),
                0.01 * k as f64,
            ];
            let got_a = a.offer(&v, &mut rng_a);
            let fired_b = b.offer_into(&v, &mut rng_b, &mut buf);
            assert_eq!(got_a.is_some(), fired_b, "round {k}");
            if let Some(da) = got_a {
                assert_eq!(da, buf, "round {k}");
            } else {
                assert!(buf.is_empty());
            }
        }
        assert_eq!(a.events, b.events);
        assert_eq!(a.opportunities, b.opportunities);
        assert_eq!(a.last_sent(), b.last_sent());
    }

    #[test]
    fn trigger_parse_roundtrip() {
        for s in [
            "always",
            "never",
            "vanilla:0.001",
            "randomized:0.5:0.1",
            "participation:0.4",
            "decaying:2:1.5",
        ] {
            let t = Trigger::parse(s).unwrap();
            assert_eq!(Trigger::parse(&t.label()).unwrap(), t);
        }
        assert!(Trigger::parse("vanilla").is_err());
        assert!(Trigger::parse("randomized:0.5").is_err());
        assert!(Trigger::parse("warp:9").is_err());
        // out-of-range values must not degenerate into another policy
        assert!(Trigger::parse("vanilla:-1").is_err());
        assert!(Trigger::parse("randomized:0.001:5").is_err());
        assert!(Trigger::parse("participation:1.5").is_err());
        assert!(Trigger::parse("decaying:2:-1").is_err());
    }

    #[test]
    fn offer_into_reuses_capacity() {
        let mut s = st(Trigger::Always);
        let mut rng = Pcg64::seed(31);
        let mut buf = Vec::with_capacity(3);
        let cap = buf.capacity();
        for k in 0..50 {
            assert!(s.offer_into(&[k as f64, 0.0, 0.0], &mut rng, &mut buf));
        }
        assert_eq!(buf.capacity(), cap, "hot path must not reallocate");
    }
}
