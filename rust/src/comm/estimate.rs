//! Receiver-side estimates `v̂` (the hatted variables of Alg. 1/2).
//!
//! An [`Estimate`] integrates the event-based deltas it receives:
//! `v̂_{k+1} = v̂_k + (v_{k+1} − v_{[k]})` — and can be hard-reset to the
//! true value during the rare periodic resets. With drops, the estimate
//! equals `v_{[k]} + Σ χ` (Eq. 33); Prop. 2.1 / C.3 bound the resulting
//! error, which our property tests verify numerically.

use super::Scalar;
use crate::wire::WireMessage;

#[derive(Clone, Debug)]
pub struct Estimate<T: Scalar> {
    value: Vec<T>,
    /// Deltas integrated since construction or last reset.
    pub updates: u64,
    /// Hard resets performed.
    pub resets: u64,
}

impl<T: Scalar> Estimate<T> {
    pub fn new(init: Vec<T>) -> Self {
        Estimate { value: init, updates: 0, resets: 0 }
    }

    pub fn get(&self) -> &[T] {
        &self.value
    }

    /// Integrate a received delta.
    pub fn apply(&mut self, delta: &[T]) {
        debug_assert_eq!(delta.len(), self.value.len());
        for (v, d) in self.value.iter_mut().zip(delta) {
            *v = T::from_f64(v.to_f64() + d.to_f64());
        }
        self.updates += 1;
    }

    /// Integrate a received wire message (decompressing in place; sparse
    /// payloads touch only the coordinates they carry).
    pub fn apply_msg(&mut self, msg: &WireMessage<T>) {
        self.apply_scaled_msg(msg, 1.0);
    }

    /// Integrate `scale * decompress(msg)` — the weighted-accumulator
    /// form the server's `ζ̂` uses (weight `1/N` per agent).
    pub fn apply_scaled_msg(&mut self, msg: &WireMessage<T>, scale: f64) {
        debug_assert_eq!(msg.dim(), self.value.len());
        msg.add_scaled_to(scale, &mut self.value);
        self.updates += 1;
    }

    /// Hard reset to the true value (periodic reset strategy).
    pub fn reset_to(&mut self, truth: &[T]) {
        self.value.clear();
        self.value.extend_from_slice(truth);
        self.resets += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{sub, Trigger, TriggerState};
    use crate::rng::Pcg64;

    #[test]
    fn integrates_deltas() {
        let mut e = Estimate::new(vec![1.0f64, 2.0]);
        e.apply(&[0.5, -1.0]);
        e.apply(&[0.5, -1.0]);
        assert_eq!(e.get(), &[2.0, 0.0]);
        assert_eq!(e.updates, 2);
    }

    #[test]
    fn reset_overwrites() {
        let mut e = Estimate::new(vec![0.0f64; 2]);
        e.apply(&[5.0, 5.0]);
        e.reset_to(&[1.0, 1.0]);
        assert_eq!(e.get(), &[1.0, 1.0]);
        assert_eq!(e.resets, 1);
    }

    #[test]
    fn tracks_sender_exactly_without_drops() {
        // The fundamental protocol invariant: with a reliable channel the
        // receiver's estimate always equals the sender's last-sent value.
        let mut rng = Pcg64::seed(3);
        let mut tx: TriggerState<f64> =
            TriggerState::new(Trigger::vanilla(0.7), vec![0.0; 4]);
        let mut rx = Estimate::new(vec![0.0f64; 4]);
        let mut v = vec![0.0f64; 4];
        for k in 0..200 {
            for (i, vi) in v.iter_mut().enumerate() {
                *vi += 0.1 * ((k + i) as f64).sin();
            }
            if let Some(delta) = tx.offer(&v, &mut rng) {
                rx.apply(&delta);
            }
            let err = sub(rx.get(), tx.last_sent());
            let norm: f64 = err.iter().map(|e| e * e).sum::<f64>().sqrt();
            assert!(norm < 1e-12, "estimate diverged from last_sent: {norm}");
        }
    }

    #[test]
    fn apply_msg_dense_equals_apply() {
        let mut a = Estimate::new(vec![1.0f64, -2.0, 0.5]);
        let mut b = a.clone();
        let delta = vec![0.25f64, 4.0, -1.5];
        a.apply(&delta);
        b.apply_msg(&WireMessage::dense(&delta));
        assert_eq!(a.get(), b.get());
        assert_eq!(a.updates, b.updates);
    }

    #[test]
    fn apply_scaled_msg_sparse_touches_only_carried_coords() {
        let mut e = Estimate::new(vec![1.0f64; 4]);
        let msg = WireMessage::Sparse {
            dim: 4,
            idx: vec![2],
            val: vec![8.0f64],
        };
        e.apply_scaled_msg(&msg, 0.5);
        assert_eq!(e.get(), &[1.0, 1.0, 5.0, 1.0]);
        assert_eq!(e.updates, 1);
    }
}
