//! Local-solve abstraction: how an agent performs
//! `argmin_x f_i(x) + (rho/2)|x - v|^2`.
//!
//! Three interchangeable backends drive the same ADMM cores:
//!
//! * [`ExactQuadratic`] — closed-form prox for least-squares `f_i`
//!   (cached Cholesky of `A_iᵀA_i + ρI`): the LASSO/regression experiments.
//! * [`NativeSgd`] — S minibatch prox-SGD steps on the Rust MLP (the
//!   paper replaces the exact minimization by a few SGD steps).
//! * `PjrtSgd` (in [`crate::runtime`]) — the production path: the same S
//!   steps executed by the AOT-compiled JAX/Pallas artifact.
//!
//! # Determinism contract (parallel solves)
//!
//! The engines execute the per-agent solve phase through
//! [`LocalSolver::solve_batch`] on the shared
//! [`crate::admm::core::WorkerPool`].  The contract every implementation
//! must uphold for trajectories to be **bit-identical across worker
//! counts**:
//!
//! * `solve(agent, …, rng)` may mutate only *per-agent* state (the
//!   cached factorization of `agent`, the warm-started iterate of
//!   `agent`) plus read-only shared state — never state another agent's
//!   concurrent solve touches;
//! * all randomness comes from the passed `rng` — one independent
//!   stream per agent per round, forked by the engine via
//!   [`crate::rng::Pcg64::fork`] keyed by `(round, agent)`, so the draw
//!   sequence each agent sees is a pure function of `(seed, round,
//!   agent)` and **independent of worker count and execution order**
//!   ([`NativeSgd`]'s minibatch sampling is the audited case);
//! * results are returned in batch order (the engines then reduce them
//!   sequentially in agent order).
//!
//! [`ExactQuadratic`] and [`NativeSgd`] are plain-data (`Send`) and
//! override `solve_batch` with a sharded parallel implementation.
//! `PjrtSgd` holds non-`Send` PJRT handles and keeps the sequential
//! default — the trait deliberately does *not* require `Send` so the
//! PJRT backend keeps compiling; a non-`Send` solver simply runs its
//! batch on the caller's thread.

use crate::admm::core::WorkerPool;
use crate::data::synth::ClassDataset;
use crate::kernels::Scratch;
use crate::linalg::{Cholesky, Matrix};
use crate::model::MlpSpec;
use crate::rng::Pcg64;
#[cfg(test)]
use crate::rng::Rng;
use std::collections::BTreeMap;

/// An agent-side local solver over scalar type `T`.
pub trait LocalSolver<T> {
    /// Return `x_{k+1} ≈ argmin_x f_agent(x) + (rho/2) |x - anchor|²`.
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[T],
        rho: f64,
        rng: &mut Pcg64,
    ) -> Vec<T>;

    /// Problem dimension.
    fn dim(&self) -> usize;

    /// Number of agents this solver serves.
    fn n_agents(&self) -> usize;

    /// Solve a whole round's batch: `agents[j]` (distinct ids) against
    /// `anchors[j]`, drawing from `rngs[j]`; results in batch order.
    ///
    /// The default runs sequentially on the caller's thread — correct
    /// for every implementation.  `Send` solvers with per-agent state
    /// override it to fan the batch across `pool` (see the module docs
    /// for the determinism contract; the override must be observably
    /// identical to this default).
    fn solve_batch(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<T>],
        rho: f64,
        rngs: &mut [Pcg64],
        _pool: &WorkerPool,
    ) -> Vec<Vec<T>> {
        debug_assert_eq!(agents.len(), anchors.len());
        debug_assert_eq!(agents.len(), rngs.len());
        agents
            .iter()
            .zip(anchors)
            .zip(rngs.iter_mut())
            .map(|((&a, anchor), rng)| self.solve(a, anchor, rho, rng))
            .collect()
    }

    /// [`Self::solve_batch`] into caller-owned output buffers, reused
    /// across rounds.  The default delegates to `solve_batch` (and so
    /// allocates); [`NativeSgd`] overrides it with the fused,
    /// allocation-free-after-warmup hot path that the zero-alloc test
    /// pins.  Must be observably identical to `solve_batch` — same
    /// values, same per-agent RNG consumption.
    fn solve_batch_into(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<T>],
        rho: f64,
        rngs: &mut [Pcg64],
        pool: &WorkerPool,
        outs: &mut Vec<Vec<T>>,
    ) {
        outs.clear();
        outs.append(&mut self.solve_batch(agents, anchors, rho, rngs, pool));
    }
}

/// Server-side prox for the (possibly nonsmooth) `g`:
/// `z = argmin_z g(z) + (w/2) |z - v|²`.
pub trait ServerProx<T> {
    fn prox(&mut self, v: &[T], weight: f64) -> Vec<T>;
}

/// `g = 0` — plain consensus (the neural-network experiments).
pub struct IdentityProx;

impl<T: Clone> ServerProx<T> for IdentityProx {
    fn prox(&mut self, v: &[T], _weight: f64) -> Vec<T> {
        v.to_vec()
    }
}

/// `g(z) = lambda |z|_1` — LASSO: prox is the soft threshold with
/// `tau = lambda / weight`.
pub struct L1Prox {
    pub lambda: f64,
}

impl ServerProx<f64> for L1Prox {
    fn prox(&mut self, v: &[f64], weight: f64) -> Vec<f64> {
        crate::linalg::soft_threshold(v, self.lambda / weight)
    }
}

// ---------------------------------------------------------------------------
// Exact quadratic prox (least-squares agents)
// ---------------------------------------------------------------------------

/// Agents with `f_i(x) = 0.5 |A_i x - b_i|²`; the prox step is the linear
/// solve `(A_iᵀA_i + ρI) x = A_iᵀ b_i + ρ v`, with the factorization held
/// in a **shared** [`CholCache`] keyed by `(gram digest, ρ bits)` — agents
/// with bit-identical Gram matrices (IID shards of a common design, the
/// replicated-block experiments) factor once and `solve_in_place` many.
pub struct ExactQuadratic {
    grams: Vec<Matrix>,
    atbs: Vec<Vec<f64>>,
    /// `grams[i].digest()`, precomputed — the cache key half.
    digests: Vec<u64>,
    dim: usize,
    cache: CholCache,
}

/// Shared Cholesky cache: `(Matrix::digest(), rho.to_bits())` →
/// factorization.  Keying on exact ρ bits replaces the historical
/// per-agent `|ρ - ρ'| <= 1e-12·max(|ρ|,1)` staleness test: any ρ the
/// engines actually revisit is bit-stable (it comes from config or a
/// deterministic schedule), and exact keys make hit/miss accounting
/// well-defined.  A `BTreeMap` keeps iteration deterministic (the
/// `nondet-iteration` lint applies to this module's callers).
#[derive(Debug, Default)]
pub struct CholCache {
    map: BTreeMap<(u64, u64), Cholesky>,
    hits: u64,
    misses: u64,
}

impl CholCache {
    fn factor(gram: &Matrix, rho: f64) -> Cholesky {
        let mut m = gram.clone();
        m.add_diag(rho);
        // lint:allow(panic-in-library): AᵀA + ρI with ρ > 0 is positive definite by construction; a failure means corrupted input data
        Cholesky::factor(&m).expect("gram + rho I must be PD")
    }

    /// Look up (counting a hit) or factor-and-insert (counting a miss).
    fn get_or_factor(&mut self, gram: &Matrix, digest: u64, rho: f64) -> &Cholesky {
        let key = (digest, rho.to_bits());
        if self.map.contains_key(&key) {
            self.hits += 1;
        } else {
            self.misses += 1;
            self.map.insert(key, Self::factor(gram, rho));
        }
        // lint:allow(panic-in-library): the branch above inserted the key if it was absent, so the lookup cannot fail
        self.map.get(&key).expect("key just ensured")
    }
}

impl ExactQuadratic {
    pub fn new(blocks: &[crate::data::regress::AgentBlock]) -> Self {
        assert!(!blocks.is_empty());
        let dim = blocks[0].a.cols;
        let grams: Vec<Matrix> = blocks.iter().map(|b| b.a.gram()).collect();
        let digests = grams.iter().map(Matrix::digest).collect();
        ExactQuadratic {
            atbs: blocks.iter().map(|b| b.a.tmatvec(&b.b)).collect(),
            grams,
            digests,
            dim,
            cache: CholCache::default(),
        }
    }

    /// `(hits, misses, entries)` of the shared factorization cache —
    /// the observable the cache-semantics tests pin.
    pub fn cache_stats(&self) -> (u64, u64, usize) {
        (self.cache.hits, self.cache.misses, self.cache.map.len())
    }
}

impl LocalSolver<f64> for ExactQuadratic {
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[f64],
        rho: f64,
        _rng: &mut Pcg64,
    ) -> Vec<f64> {
        // one allocation total: rhs doubles as the in-place solution
        // buffer (§Perf — Cholesky::solve_in_place)
        let mut x = self.atbs[agent].clone();
        crate::linalg::axpy(&mut x, rho, anchor);
        self.cache
            .get_or_factor(&self.grams[agent], self.digests[agent], rho)
            .solve_in_place(&mut x);
        x
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn n_agents(&self) -> usize {
        self.grams.len()
    }

    /// Pool-sharded batch in three deterministic passes: (1) a
    /// sequential scan accounts hits/misses and collects the distinct
    /// missing keys in batch order (later same-key entries count as
    /// hits — they reuse the factor the first entry produces); (2) the
    /// missing factorizations run on the pool (each key's representative
    /// agent factors it; the work set depends only on the batch, never
    /// on scheduling) and insert sequentially; (3) the solves run on the
    /// pool reading the now-complete cache immutably.  Draws nothing
    /// from the RNGs, so results are trivially order-independent.
    fn solve_batch(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<f64>],
        rho: f64,
        _rngs: &mut [Pcg64],
        pool: &WorkerPool,
    ) -> Vec<Vec<f64>> {
        debug_assert_eq!(agents.len(), anchors.len());
        let rho_bits = rho.to_bits();
        // pass 1: hit/miss accounting + distinct missing keys
        let mut missing_keys: Vec<(u64, u64)> = Vec::new();
        let mut reps: Vec<usize> = Vec::new();
        for &agent in agents {
            let key = (self.digests[agent], rho_bits);
            if self.cache.map.contains_key(&key)
                || missing_keys.contains(&key)
            {
                self.cache.hits += 1;
            } else {
                self.cache.misses += 1;
                missing_keys.push(key);
                reps.push(agent);
            }
        }
        // pass 2: parallel factorization of the missing keys
        struct FactorJob {
            agent: usize,
            out: Option<Cholesky>,
        }
        let mut fjobs: Vec<FactorJob> = reps
            .iter()
            .map(|&agent| FactorJob { agent, out: None })
            .collect();
        let grams = &self.grams;
        pool.run(&mut fjobs, |_, job| {
            job.out = Some(CholCache::factor(&grams[job.agent], rho));
        });
        for (key, job) in missing_keys.into_iter().zip(fjobs) {
            // lint:allow(panic-in-library): the pool ran every factor job, so out was filled
            self.cache.map.insert(key, job.out.expect("factored"));
        }
        // pass 3: parallel solves against the read-only cache
        struct SolveJob<'a> {
            agent: usize,
            anchor: &'a [f64],
            out: Vec<f64>,
        }
        let mut jobs: Vec<SolveJob> = agents
            .iter()
            .zip(anchors)
            .map(|(&agent, anchor)| SolveJob {
                agent,
                anchor,
                out: Vec::new(),
            })
            .collect();
        let atbs = &self.atbs;
        let digests = &self.digests;
        let cache = &self.cache;
        pool.run(&mut jobs, |_, job| {
            let mut x = atbs[job.agent].clone();
            crate::linalg::axpy(&mut x, rho, job.anchor);
            let key = (digests[job.agent], rho_bits);
            // lint:allow(panic-in-library): pass 2 inserted every key this batch needs, so the lookup cannot fail
            cache.map.get(&key).expect("factor present").solve_in_place(&mut x);
            job.out = x;
        });
        jobs.into_iter().map(|j| j.out).collect()
    }
}

/// Pair each batch entry `j` with a `&mut` borrow of that agent's slot
/// in `state` (distinct agent ids, any order).  The walk visits `state`
/// once in ascending-agent order, so the borrows are provably disjoint
/// without unsafe code.
fn pick_jobs<'a, S, J>(
    agents: &[usize],
    state: &'a mut [S],
    mut make: impl FnMut(usize, usize, &'a mut S) -> J,
) -> Vec<J> {
    let mut order: Vec<usize> = (0..agents.len()).collect();
    order.sort_unstable_by_key(|&j| agents[j]);
    let mut slots: Vec<Option<J>> =
        (0..agents.len()).map(|_| None).collect();
    let mut iter = state.iter_mut().enumerate();
    for &j in &order {
        let target = agents[j];
        let slot = loop {
            let (i, s) = iter
                .next()
                // lint:allow(panic-in-library): exhausting state means the caller passed duplicate or out-of-range agent ids — a round-core contract violation
                .expect("batch agent ids must be distinct and < n_agents");
            if i == target {
                break s;
            }
        };
        slots[j] = Some(make(j, target, slot));
    }
    // lint:allow(panic-in-library): the loop above fills every slot exactly once; an empty slot is unreachable
    slots.into_iter().map(|s| s.expect("every entry filled")).collect()
}

// ---------------------------------------------------------------------------
// Native SGD solver (Rust MLP twin of the PJRT artifact)
// ---------------------------------------------------------------------------

/// Inexact local solve: S minibatch prox-SGD steps on the native MLP.
pub struct NativeSgd {
    pub spec: MlpSpec,
    pub shards: Vec<ClassDataset>,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    /// Current local iterate per agent (warm start across rounds —
    /// x_{k+1} starts from x_k like the paper's implementation).
    pub xs: Vec<Vec<f32>>,
    /// Per-worker-chunk scratch arenas for the fused batch path, lazily
    /// sized to the pool shape and retained across rounds so the hot
    /// path stops allocating after warmup (`rust/tests/alloc.rs`).
    scratches: Vec<Scratch>,
}

impl NativeSgd {
    pub fn new(
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        lr: f32,
        steps: usize,
        batch: usize,
        init: &[f32],
    ) -> Self {
        let xs = vec![init.to_vec(); shards.len()];
        NativeSgd { spec, shards, lr, steps, batch, xs, scratches: Vec::new() }
    }

    /// Draw the S minibatches for one round as flat buffers.
    pub fn draw_batches(
        &self,
        agent: usize,
        rng: &mut Pcg64,
    ) -> (Vec<f32>, Vec<f32>) {
        draw_round_batches(
            &self.spec,
            &self.shards[agent],
            self.steps,
            self.batch,
            rng,
        )
    }
}

/// Draw S flat minibatches from one agent's shard — the shared sampling
/// routine behind [`NativeSgd`] and the federated baselines.  All
/// randomness comes from `rng`, so per-agent streams stay independent of
/// worker count (the determinism contract's audited path).
pub fn draw_round_batches(
    spec: &MlpSpec,
    shard: &ClassDataset,
    steps: usize,
    batch: usize,
    rng: &mut Pcg64,
) -> (Vec<f32>, Vec<f32>) {
    let d = spec.input_dim();
    let c = spec.classes();
    let mut xs = Vec::with_capacity(steps * batch * d);
    let mut ys = Vec::with_capacity(steps * batch * c);
    draw_round_batches_into(spec, shard, steps, batch, rng, &mut xs, &mut ys);
    (xs, ys)
}

/// [`draw_round_batches`] appending into caller-owned arenas — the fused
/// solve path stacks a whole worker chunk's minibatches (`agents·S·B`
/// rows) into one buffer pair before any solve runs.  RNG consumption is
/// identical to the allocating wrapper: one draw per sampled row, all
/// from `rng`.
pub fn draw_round_batches_into(
    spec: &MlpSpec,
    shard: &ClassDataset,
    steps: usize,
    batch: usize,
    rng: &mut Pcg64,
    xs: &mut Vec<f32>,
    ys: &mut Vec<f32>,
) {
    xs.reserve(steps * batch * spec.input_dim());
    ys.reserve(steps * batch * spec.classes());
    for _ in 0..steps {
        shard.sample_batch_into(batch, rng, xs, ys);
    }
}

impl LocalSolver<f32> for NativeSgd {
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[f32],
        rho: f64,
        rng: &mut Pcg64,
    ) -> Vec<f32> {
        let (bx, by) = self.draw_batches(agent, rng);
        // local_admm expects (zhat, u); anchor = zhat - u, and the
        // anchor variant folds u = 0 in bit-identically (x - 0.0 ≡ x).
        let x = self.spec.local_admm_anchor(
            &self.xs[agent],
            anchor,
            &bx,
            &by,
            self.lr,
            rho as f32,
            self.steps,
            self.batch,
        );
        self.xs[agent] = x.clone();
        x
    }

    fn dim(&self) -> usize {
        self.spec.param_len()
    }

    fn n_agents(&self) -> usize {
        self.shards.len()
    }

    /// Allocating wrapper over the fused [`Self::solve_batch_into`].
    fn solve_batch(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<f32>],
        rho: f64,
        rngs: &mut [Pcg64],
        pool: &WorkerPool,
    ) -> Vec<Vec<f32>> {
        let mut outs = Vec::new();
        self.solve_batch_into(agents, anchors, rho, rngs, pool, &mut outs);
        outs
    }

    /// The fused batch path.  Per-agent state is the warm-started
    /// iterate `xs[agent]`; the spec and shards are shared read-only;
    /// every minibatch draw comes from that entry's own `rngs[j]`
    /// stream, so values are bit-identical to the sequential default
    /// for every worker count.
    ///
    /// Shape: the batch is cut into the same contiguous chunks
    /// [`WorkerPool::run`] would form (`per = n.div_ceil(w)`), each
    /// chunk owning one retained [`Scratch`].  A chunk first stacks
    /// *all* its entries' minibatches into one `[entries·S·B, D]` arena
    /// pair (`scratch.bx`/`by`), then runs the solves over slices of
    /// that arena through [`MlpSpec::local_admm_anchor_into`].  With one
    /// worker the chunk machinery collapses to a plain loop that reuses
    /// buffers across rounds — zero allocations per round after warmup
    /// (pinned by `rust/tests/alloc.rs`).
    fn solve_batch_into(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<f32>],
        rho: f64,
        rngs: &mut [Pcg64],
        pool: &WorkerPool,
        outs: &mut Vec<Vec<f32>>,
    ) {
        debug_assert_eq!(agents.len(), anchors.len());
        debug_assert_eq!(agents.len(), rngs.len());
        let n = agents.len();
        if outs.len() != n {
            outs.clear();
            outs.resize_with(n, Vec::new);
        }
        let rho32 = rho as f32;
        let w = pool.workers().min(n);
        if w <= 1 {
            // Sequential fused path: one scratch, buffers reused across
            // both entries and rounds.  Warm iterates are mem::take'n
            // around the solve call to keep the borrows disjoint.
            if self.scratches.is_empty() {
                self.scratches.push(Scratch::new());
            }
            let NativeSgd { spec, shards, lr, steps, batch, xs, scratches } =
                self;
            let scratch = &mut scratches[0];
            let mut bx = std::mem::take(&mut scratch.bx);
            let mut by = std::mem::take(&mut scratch.by);
            for (j, (&agent, anchor)) in
                agents.iter().zip(anchors).enumerate()
            {
                bx.clear();
                by.clear();
                draw_round_batches_into(
                    spec, &shards[agent], *steps, *batch, &mut rngs[j],
                    &mut bx, &mut by,
                );
                let mut x = std::mem::take(&mut xs[agent]);
                spec.local_admm_anchor_into(
                    &x, anchor, &bx, &by, *lr, rho32, *steps, *batch,
                    scratch, &mut outs[j],
                );
                x.clear();
                x.extend_from_slice(&outs[j]);
                xs[agent] = x;
            }
            scratch.bx = bx;
            scratch.by = by;
            return;
        }
        // Chunked pool path.  Chunk boundaries replicate WorkerPool::run
        // exactly, so each chunk lands on one worker and its scratch is
        // touched by one thread.
        let per = n.div_ceil(w);
        let nchunks = n.div_ceil(per);
        if self.scratches.len() < nchunks {
            self.scratches.resize_with(nchunks, Scratch::new);
        }
        let NativeSgd { spec, shards, lr, steps, batch, xs, scratches } =
            self;
        // Disjoint &mut borrows of each entry's warm iterate, in batch
        // order (batch agent ids are distinct by the round-core contract).
        let mut xrefs: Vec<Option<&mut Vec<f32>>> =
            pick_jobs(agents, xs.as_mut_slice(), |_, _, x| Some(x));
        struct ChunkJob<'a, 'x> {
            agents: &'a [usize],
            anchors: &'a [Vec<f32>],
            rngs: &'a mut [Pcg64],
            xrefs: &'a mut [Option<&'x mut Vec<f32>>],
            outs: &'a mut [Vec<f32>],
            scratch: &'a mut Scratch,
        }
        let mut jobs: Vec<ChunkJob> = agents
            .chunks(per)
            .zip(anchors.chunks(per))
            .zip(rngs.chunks_mut(per))
            .zip(xrefs.chunks_mut(per))
            .zip(outs.chunks_mut(per))
            .zip(scratches[..nchunks].iter_mut())
            .map(|(((((ca, cn), cr), cx), co), scratch)| ChunkJob {
                agents: ca,
                anchors: cn,
                rngs: cr,
                xrefs: cx,
                outs: co,
                scratch,
            })
            .collect();
        let (lr, steps, batch) = (*lr, *steps, *batch);
        let spec = &*spec;
        let shards = &*shards;
        pool.run(&mut jobs, |_, job| {
            let scratch = &mut *job.scratch;
            let mut bx = std::mem::take(&mut scratch.bx);
            let mut by = std::mem::take(&mut scratch.by);
            bx.clear();
            by.clear();
            // pass 1: stack the whole chunk's minibatches
            for (i, &agent) in job.agents.iter().enumerate() {
                draw_round_batches_into(
                    spec, &shards[agent], steps, batch, &mut job.rngs[i],
                    &mut bx, &mut by,
                );
            }
            // pass 2: per-entry solves over slices of the arena
            let rows = steps * batch;
            let d = spec.input_dim();
            let c = spec.classes();
            for i in 0..job.agents.len() {
                let xsl = &bx[i * rows * d..(i + 1) * rows * d];
                let ysl = &by[i * rows * c..(i + 1) * rows * c];
                let x = job.xrefs[i]
                    .take()
                    // lint:allow(panic-in-library): pick_jobs filled every slot and each entry is visited once, so the slot cannot be empty
                    .expect("one warm iterate per entry");
                spec.local_admm_anchor_into(
                    x, &job.anchors[i], xsl, ysl, lr, rho32, steps, batch,
                    scratch, &mut job.outs[i],
                );
                x.clear();
                x.extend_from_slice(&job.outs[i]);
            }
            scratch.bx = bx;
            scratch.by = by;
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::regress::{generate, RegressSpec};
    use crate::data::synth::{self, SynthSpec};

    #[test]
    fn exact_quadratic_satisfies_stationarity() {
        let spec = RegressSpec {
            n_agents: 3,
            rows_per_agent: 10,
            dim: 6,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(1);
        let (blocks, _) = generate(&spec, &mut rng);
        let mut solver = ExactQuadratic::new(&blocks);
        let anchor: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let rho = 0.7;
        let x = solver.solve(1, &anchor, rho, &mut rng);
        // check gradient: A'(Ax - b) + rho (x - anchor) = 0
        let ax = blocks[1].a.matvec(&x);
        let resid: Vec<f64> =
            ax.iter().zip(&blocks[1].b).map(|(p, q)| p - q).collect();
        let mut grad = blocks[1].a.tmatvec(&resid);
        for i in 0..6 {
            grad[i] += rho * (x[i] - anchor[i]);
        }
        assert!(crate::linalg::norm2(&grad) < 1e-9);
    }

    #[test]
    fn exact_quadratic_cache_recomputes_on_rho_change() {
        let spec = RegressSpec {
            n_agents: 1,
            rows_per_agent: 8,
            dim: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(2);
        let (blocks, _) = generate(&spec, &mut rng);
        let mut solver = ExactQuadratic::new(&blocks);
        let anchor = vec![0.0; 4];
        let x1 = solver.solve(0, &anchor, 0.1, &mut rng);
        let x2 = solver.solve(0, &anchor, 10.0, &mut rng);
        // large rho pins to anchor = 0 harder
        assert!(crate::linalg::norm2(&x2) < crate::linalg::norm2(&x1));
    }

    #[test]
    fn identity_prox_is_identity() {
        let mut p = IdentityProx;
        let v = vec![1.0f64, -2.0];
        assert_eq!(ServerProx::<f64>::prox(&mut p, &v, 3.0), v);
    }

    #[test]
    fn l1_prox_shrinks() {
        let mut p = L1Prox { lambda: 1.0 };
        let out = p.prox(&[2.0, -0.1, 0.0], 2.0); // tau = 0.5
        assert!((out[0] - 1.5).abs() < 1e-12);
        assert_eq!(out[1], 0.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn native_sgd_improves_local_fit() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards =
            crate::data::partition::iid_split(&train, 2, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver =
            NativeSgd::new(spec.clone(), shards.clone(), 0.1, 4, 8, &init);
        let anchor = init.clone();
        let before = {
            let (bx, by) = shards[0].sample_batch(32, &mut rng);
            spec.loss_grad(&init, &bx, &by, 32).0
        };
        let mut x = init.clone();
        for _ in 0..5 {
            x = solver.solve(0, &anchor, 0.0, &mut rng);
        }
        let after = {
            let (bx, by) = shards[0].sample_batch(32, &mut rng);
            spec.loss_grad(&x, &bx, &by, 32).0
        };
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn native_sgd_warm_starts() {
        let mut rng = Pcg64::seed(4);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards = crate::data::partition::iid_split(&train, 1, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver = NativeSgd::new(spec, shards, 0.05, 2, 4, &init);
        let anchor = vec![0.0f32; solver.dim()];
        let x1 = solver.solve(0, &anchor, 0.1, &mut rng);
        assert_eq!(solver.xs[0], x1, "iterate must be persisted");
    }
}
