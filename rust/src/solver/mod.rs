//! Local-solve abstraction: how an agent performs
//! `argmin_x f_i(x) + (rho/2)|x - v|^2`.
//!
//! Three interchangeable backends drive the same ADMM cores:
//!
//! * [`ExactQuadratic`] — closed-form prox for least-squares `f_i`
//!   (cached Cholesky of `A_iᵀA_i + ρI`): the LASSO/regression experiments.
//! * [`NativeSgd`] — S minibatch prox-SGD steps on the Rust MLP (the
//!   paper replaces the exact minimization by a few SGD steps).
//! * `PjrtSgd` (in [`crate::runtime`]) — the production path: the same S
//!   steps executed by the AOT-compiled JAX/Pallas artifact.

use crate::data::synth::ClassDataset;
use crate::linalg::{Cholesky, Matrix};
use crate::model::MlpSpec;
use crate::rng::Pcg64;
#[cfg(test)]
use crate::rng::Rng;

/// An agent-side local solver over scalar type `T`.
pub trait LocalSolver<T> {
    /// Return `x_{k+1} ≈ argmin_x f_agent(x) + (rho/2) |x - anchor|²`.
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[T],
        rho: f64,
        rng: &mut Pcg64,
    ) -> Vec<T>;

    /// Problem dimension.
    fn dim(&self) -> usize;

    /// Number of agents this solver serves.
    fn n_agents(&self) -> usize;
}

/// Server-side prox for the (possibly nonsmooth) `g`:
/// `z = argmin_z g(z) + (w/2) |z - v|²`.
pub trait ServerProx<T> {
    fn prox(&mut self, v: &[T], weight: f64) -> Vec<T>;
}

/// `g = 0` — plain consensus (the neural-network experiments).
pub struct IdentityProx;

impl<T: Clone> ServerProx<T> for IdentityProx {
    fn prox(&mut self, v: &[T], _weight: f64) -> Vec<T> {
        v.to_vec()
    }
}

/// `g(z) = lambda |z|_1` — LASSO: prox is the soft threshold with
/// `tau = lambda / weight`.
pub struct L1Prox {
    pub lambda: f64,
}

impl ServerProx<f64> for L1Prox {
    fn prox(&mut self, v: &[f64], weight: f64) -> Vec<f64> {
        crate::linalg::soft_threshold(v, self.lambda / weight)
    }
}

// ---------------------------------------------------------------------------
// Exact quadratic prox (least-squares agents)
// ---------------------------------------------------------------------------

/// Agents with `f_i(x) = 0.5 |A_i x - b_i|²`; the prox step is the linear
/// solve `(A_iᵀA_i + ρI) x = A_iᵀ b_i + ρ v`, with the factorization cached
/// per (agent, ρ).
pub struct ExactQuadratic {
    grams: Vec<Matrix>,
    atbs: Vec<Vec<f64>>,
    dim: usize,
    cache: Vec<Option<(f64, Cholesky)>>,
}

impl ExactQuadratic {
    pub fn new(blocks: &[crate::data::regress::AgentBlock]) -> Self {
        assert!(!blocks.is_empty());
        let dim = blocks[0].a.cols;
        ExactQuadratic {
            grams: blocks.iter().map(|b| b.a.gram()).collect(),
            atbs: blocks.iter().map(|b| b.a.tmatvec(&b.b)).collect(),
            dim,
            cache: vec![None; blocks.len()],
        }
    }

    fn chol(&mut self, agent: usize, rho: f64) -> &Cholesky {
        let stale = match &self.cache[agent] {
            Some((r, _)) => (*r - rho).abs() > 1e-12 * rho.abs().max(1.0),
            None => true,
        };
        if stale {
            let mut m = self.grams[agent].clone();
            m.add_diag(rho);
            let c = Cholesky::factor(&m).expect("gram + rho I must be PD");
            self.cache[agent] = Some((rho, c));
        }
        &self.cache[agent].as_ref().unwrap().1
    }
}

impl LocalSolver<f64> for ExactQuadratic {
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[f64],
        rho: f64,
        _rng: &mut Pcg64,
    ) -> Vec<f64> {
        let mut rhs = self.atbs[agent].clone();
        crate::linalg::axpy(&mut rhs, rho, anchor);
        self.chol(agent, rho).solve(&rhs)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn n_agents(&self) -> usize {
        self.grams.len()
    }
}

// ---------------------------------------------------------------------------
// Native SGD solver (Rust MLP twin of the PJRT artifact)
// ---------------------------------------------------------------------------

/// Inexact local solve: S minibatch prox-SGD steps on the native MLP.
pub struct NativeSgd {
    pub spec: MlpSpec,
    pub shards: Vec<ClassDataset>,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    /// Current local iterate per agent (warm start across rounds —
    /// x_{k+1} starts from x_k like the paper's implementation).
    pub xs: Vec<Vec<f32>>,
}

impl NativeSgd {
    pub fn new(
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        lr: f32,
        steps: usize,
        batch: usize,
        init: &[f32],
    ) -> Self {
        let xs = vec![init.to_vec(); shards.len()];
        NativeSgd { spec, shards, lr, steps, batch, xs }
    }

    /// Draw the S minibatches for one round as flat buffers.
    pub fn draw_batches(
        &self,
        agent: usize,
        rng: &mut Pcg64,
    ) -> (Vec<f32>, Vec<f32>) {
        let d = self.spec.input_dim();
        let c = self.spec.classes();
        let mut xs = Vec::with_capacity(self.steps * self.batch * d);
        let mut ys = Vec::with_capacity(self.steps * self.batch * c);
        for _ in 0..self.steps {
            let (bx, by) = self.shards[agent].sample_batch(self.batch, rng);
            xs.extend_from_slice(&bx);
            ys.extend_from_slice(&by);
        }
        (xs, ys)
    }
}

impl LocalSolver<f32> for NativeSgd {
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[f32],
        rho: f64,
        rng: &mut Pcg64,
    ) -> Vec<f32> {
        let (bx, by) = self.draw_batches(agent, rng);
        let zeros = vec![0.0f32; anchor.len()];
        // local_admm expects (zhat, u); anchor = zhat - u, so pass
        // (anchor, 0).
        let x = self.spec.local_admm(
            &self.xs[agent],
            anchor,
            &zeros,
            &bx,
            &by,
            self.lr,
            rho as f32,
            self.steps,
            self.batch,
        );
        self.xs[agent] = x.clone();
        x
    }

    fn dim(&self) -> usize {
        self.spec.param_len()
    }

    fn n_agents(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::regress::{generate, RegressSpec};
    use crate::data::synth::{self, SynthSpec};

    #[test]
    fn exact_quadratic_satisfies_stationarity() {
        let spec = RegressSpec {
            n_agents: 3,
            rows_per_agent: 10,
            dim: 6,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(1);
        let (blocks, _) = generate(&spec, &mut rng);
        let mut solver = ExactQuadratic::new(&blocks);
        let anchor: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let rho = 0.7;
        let x = solver.solve(1, &anchor, rho, &mut rng);
        // check gradient: A'(Ax - b) + rho (x - anchor) = 0
        let ax = blocks[1].a.matvec(&x);
        let resid: Vec<f64> =
            ax.iter().zip(&blocks[1].b).map(|(p, q)| p - q).collect();
        let mut grad = blocks[1].a.tmatvec(&resid);
        for i in 0..6 {
            grad[i] += rho * (x[i] - anchor[i]);
        }
        assert!(crate::linalg::norm2(&grad) < 1e-9);
    }

    #[test]
    fn exact_quadratic_cache_recomputes_on_rho_change() {
        let spec = RegressSpec {
            n_agents: 1,
            rows_per_agent: 8,
            dim: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(2);
        let (blocks, _) = generate(&spec, &mut rng);
        let mut solver = ExactQuadratic::new(&blocks);
        let anchor = vec![0.0; 4];
        let x1 = solver.solve(0, &anchor, 0.1, &mut rng);
        let x2 = solver.solve(0, &anchor, 10.0, &mut rng);
        // large rho pins to anchor = 0 harder
        assert!(crate::linalg::norm2(&x2) < crate::linalg::norm2(&x1));
    }

    #[test]
    fn identity_prox_is_identity() {
        let mut p = IdentityProx;
        let v = vec![1.0f64, -2.0];
        assert_eq!(ServerProx::<f64>::prox(&mut p, &v, 3.0), v);
    }

    #[test]
    fn l1_prox_shrinks() {
        let mut p = L1Prox { lambda: 1.0 };
        let out = p.prox(&[2.0, -0.1, 0.0], 2.0); // tau = 0.5
        assert!((out[0] - 1.5).abs() < 1e-12);
        assert_eq!(out[1], 0.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn native_sgd_improves_local_fit() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards =
            crate::data::partition::iid_split(&train, 2, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver =
            NativeSgd::new(spec.clone(), shards.clone(), 0.1, 4, 8, &init);
        let anchor = init.clone();
        let before = {
            let (bx, by) = shards[0].sample_batch(32, &mut rng);
            spec.loss_grad(&init, &bx, &by, 32).0
        };
        let mut x = init.clone();
        for _ in 0..5 {
            x = solver.solve(0, &anchor, 0.0, &mut rng);
        }
        let after = {
            let (bx, by) = shards[0].sample_batch(32, &mut rng);
            spec.loss_grad(&x, &bx, &by, 32).0
        };
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn native_sgd_warm_starts() {
        let mut rng = Pcg64::seed(4);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards = crate::data::partition::iid_split(&train, 1, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver = NativeSgd::new(spec, shards, 0.05, 2, 4, &init);
        let anchor = vec![0.0f32; solver.dim()];
        let x1 = solver.solve(0, &anchor, 0.1, &mut rng);
        assert_eq!(solver.xs[0], x1, "iterate must be persisted");
    }
}
