//! Local-solve abstraction: how an agent performs
//! `argmin_x f_i(x) + (rho/2)|x - v|^2`.
//!
//! Three interchangeable backends drive the same ADMM cores:
//!
//! * [`ExactQuadratic`] — closed-form prox for least-squares `f_i`
//!   (cached Cholesky of `A_iᵀA_i + ρI`): the LASSO/regression experiments.
//! * [`NativeSgd`] — S minibatch prox-SGD steps on the Rust MLP (the
//!   paper replaces the exact minimization by a few SGD steps).
//! * `PjrtSgd` (in [`crate::runtime`]) — the production path: the same S
//!   steps executed by the AOT-compiled JAX/Pallas artifact.
//!
//! # Determinism contract (parallel solves)
//!
//! The engines execute the per-agent solve phase through
//! [`LocalSolver::solve_batch`] on the shared
//! [`crate::admm::core::WorkerPool`].  The contract every implementation
//! must uphold for trajectories to be **bit-identical across worker
//! counts**:
//!
//! * `solve(agent, …, rng)` may mutate only *per-agent* state (the
//!   cached factorization of `agent`, the warm-started iterate of
//!   `agent`) plus read-only shared state — never state another agent's
//!   concurrent solve touches;
//! * all randomness comes from the passed `rng` — one independent
//!   stream per agent per round, forked by the engine via
//!   [`crate::rng::Pcg64::fork`] keyed by `(round, agent)`, so the draw
//!   sequence each agent sees is a pure function of `(seed, round,
//!   agent)` and **independent of worker count and execution order**
//!   ([`NativeSgd`]'s minibatch sampling is the audited case);
//! * results are returned in batch order (the engines then reduce them
//!   sequentially in agent order).
//!
//! [`ExactQuadratic`] and [`NativeSgd`] are plain-data (`Send`) and
//! override `solve_batch` with a sharded parallel implementation.
//! `PjrtSgd` holds non-`Send` PJRT handles and keeps the sequential
//! default — the trait deliberately does *not* require `Send` so the
//! PJRT backend keeps compiling; a non-`Send` solver simply runs its
//! batch on the caller's thread.

use crate::admm::core::WorkerPool;
use crate::data::synth::ClassDataset;
use crate::linalg::{Cholesky, Matrix};
use crate::model::MlpSpec;
use crate::rng::Pcg64;
#[cfg(test)]
use crate::rng::Rng;

/// An agent-side local solver over scalar type `T`.
pub trait LocalSolver<T> {
    /// Return `x_{k+1} ≈ argmin_x f_agent(x) + (rho/2) |x - anchor|²`.
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[T],
        rho: f64,
        rng: &mut Pcg64,
    ) -> Vec<T>;

    /// Problem dimension.
    fn dim(&self) -> usize;

    /// Number of agents this solver serves.
    fn n_agents(&self) -> usize;

    /// Solve a whole round's batch: `agents[j]` (distinct ids) against
    /// `anchors[j]`, drawing from `rngs[j]`; results in batch order.
    ///
    /// The default runs sequentially on the caller's thread — correct
    /// for every implementation.  `Send` solvers with per-agent state
    /// override it to fan the batch across `pool` (see the module docs
    /// for the determinism contract; the override must be observably
    /// identical to this default).
    fn solve_batch(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<T>],
        rho: f64,
        rngs: &mut [Pcg64],
        _pool: &WorkerPool,
    ) -> Vec<Vec<T>> {
        debug_assert_eq!(agents.len(), anchors.len());
        debug_assert_eq!(agents.len(), rngs.len());
        agents
            .iter()
            .zip(anchors)
            .zip(rngs.iter_mut())
            .map(|((&a, anchor), rng)| self.solve(a, anchor, rho, rng))
            .collect()
    }
}

/// Server-side prox for the (possibly nonsmooth) `g`:
/// `z = argmin_z g(z) + (w/2) |z - v|²`.
pub trait ServerProx<T> {
    fn prox(&mut self, v: &[T], weight: f64) -> Vec<T>;
}

/// `g = 0` — plain consensus (the neural-network experiments).
pub struct IdentityProx;

impl<T: Clone> ServerProx<T> for IdentityProx {
    fn prox(&mut self, v: &[T], _weight: f64) -> Vec<T> {
        v.to_vec()
    }
}

/// `g(z) = lambda |z|_1` — LASSO: prox is the soft threshold with
/// `tau = lambda / weight`.
pub struct L1Prox {
    pub lambda: f64,
}

impl ServerProx<f64> for L1Prox {
    fn prox(&mut self, v: &[f64], weight: f64) -> Vec<f64> {
        crate::linalg::soft_threshold(v, self.lambda / weight)
    }
}

// ---------------------------------------------------------------------------
// Exact quadratic prox (least-squares agents)
// ---------------------------------------------------------------------------

/// Agents with `f_i(x) = 0.5 |A_i x - b_i|²`; the prox step is the linear
/// solve `(A_iᵀA_i + ρI) x = A_iᵀ b_i + ρ v`, with the factorization cached
/// per (agent, ρ).
pub struct ExactQuadratic {
    grams: Vec<Matrix>,
    atbs: Vec<Vec<f64>>,
    dim: usize,
    cache: Vec<Option<(f64, Cholesky)>>,
}

impl ExactQuadratic {
    pub fn new(blocks: &[crate::data::regress::AgentBlock]) -> Self {
        assert!(!blocks.is_empty());
        let dim = blocks[0].a.cols;
        ExactQuadratic {
            grams: blocks.iter().map(|b| b.a.gram()).collect(),
            atbs: blocks.iter().map(|b| b.a.tmatvec(&b.b)).collect(),
            dim,
            cache: vec![None; blocks.len()],
        }
    }
}

/// Cached `(AᵀA + ρI)` factorization for one agent — free function over
/// the agent's own cache slot so the sequential and pool-sharded paths
/// share it.
fn chol_for<'c>(
    gram: &Matrix,
    cache: &'c mut Option<(f64, Cholesky)>,
    rho: f64,
) -> &'c Cholesky {
    let stale = match cache {
        Some((r, _)) => (*r - rho).abs() > 1e-12 * rho.abs().max(1.0),
        None => true,
    };
    if stale {
        let mut m = gram.clone();
        m.add_diag(rho);
        // lint:allow(panic-in-library): AᵀA + ρI with ρ > 0 is positive definite by construction; a failure means corrupted input data
        let c = Cholesky::factor(&m).expect("gram + rho I must be PD");
        *cache = Some((rho, c));
    }
    // lint:allow(panic-in-library): the branch above just filled the cache slot, so as_ref() cannot be None
    &cache.as_ref().unwrap().1
}

impl LocalSolver<f64> for ExactQuadratic {
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[f64],
        rho: f64,
        _rng: &mut Pcg64,
    ) -> Vec<f64> {
        // one allocation total: rhs doubles as the in-place solution
        // buffer (§Perf — Cholesky::solve_in_place)
        let mut x = self.atbs[agent].clone();
        crate::linalg::axpy(&mut x, rho, anchor);
        chol_for(&self.grams[agent], &mut self.cache[agent], rho)
            .solve_in_place(&mut x);
        x
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn n_agents(&self) -> usize {
        self.grams.len()
    }

    /// Pool-sharded batch: per-agent state is each agent's cache slot;
    /// `grams`/`atbs` are shared read-only.  Draws nothing from the
    /// RNGs, so results are trivially order-independent.
    fn solve_batch(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<f64>],
        rho: f64,
        _rngs: &mut [Pcg64],
        pool: &WorkerPool,
    ) -> Vec<Vec<f64>> {
        debug_assert_eq!(agents.len(), anchors.len());
        struct Job<'a> {
            agent: usize,
            anchor: &'a [f64],
            cache: &'a mut Option<(f64, Cholesky)>,
            out: Vec<f64>,
        }
        let mut jobs =
            pick_jobs(agents, &mut self.cache, |j, agent, cache| Job {
                agent,
                anchor: &anchors[j],
                cache,
                out: Vec::new(),
            });
        let grams = &self.grams;
        let atbs = &self.atbs;
        pool.run(&mut jobs, |_, job| {
            let mut x = atbs[job.agent].clone();
            crate::linalg::axpy(&mut x, rho, job.anchor);
            chol_for(&grams[job.agent], job.cache, rho)
                .solve_in_place(&mut x);
            job.out = x;
        });
        jobs.into_iter().map(|j| j.out).collect()
    }
}

/// Pair each batch entry `j` with a `&mut` borrow of that agent's slot
/// in `state` (distinct agent ids, any order).  The walk visits `state`
/// once in ascending-agent order, so the borrows are provably disjoint
/// without unsafe code.
fn pick_jobs<'a, S, J>(
    agents: &[usize],
    state: &'a mut [S],
    mut make: impl FnMut(usize, usize, &'a mut S) -> J,
) -> Vec<J> {
    let mut order: Vec<usize> = (0..agents.len()).collect();
    order.sort_unstable_by_key(|&j| agents[j]);
    let mut slots: Vec<Option<J>> =
        (0..agents.len()).map(|_| None).collect();
    let mut iter = state.iter_mut().enumerate();
    for &j in &order {
        let target = agents[j];
        let slot = loop {
            let (i, s) = iter
                .next()
                // lint:allow(panic-in-library): exhausting state means the caller passed duplicate or out-of-range agent ids — a round-core contract violation
                .expect("batch agent ids must be distinct and < n_agents");
            if i == target {
                break s;
            }
        };
        slots[j] = Some(make(j, target, slot));
    }
    // lint:allow(panic-in-library): the loop above fills every slot exactly once; an empty slot is unreachable
    slots.into_iter().map(|s| s.expect("every entry filled")).collect()
}

// ---------------------------------------------------------------------------
// Native SGD solver (Rust MLP twin of the PJRT artifact)
// ---------------------------------------------------------------------------

/// Inexact local solve: S minibatch prox-SGD steps on the native MLP.
pub struct NativeSgd {
    pub spec: MlpSpec,
    pub shards: Vec<ClassDataset>,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    /// Current local iterate per agent (warm start across rounds —
    /// x_{k+1} starts from x_k like the paper's implementation).
    pub xs: Vec<Vec<f32>>,
}

impl NativeSgd {
    pub fn new(
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        lr: f32,
        steps: usize,
        batch: usize,
        init: &[f32],
    ) -> Self {
        let xs = vec![init.to_vec(); shards.len()];
        NativeSgd { spec, shards, lr, steps, batch, xs }
    }

    /// Draw the S minibatches for one round as flat buffers.
    pub fn draw_batches(
        &self,
        agent: usize,
        rng: &mut Pcg64,
    ) -> (Vec<f32>, Vec<f32>) {
        draw_round_batches(
            &self.spec,
            &self.shards[agent],
            self.steps,
            self.batch,
            rng,
        )
    }
}

/// Draw S flat minibatches from one agent's shard — the shared sampling
/// routine behind [`NativeSgd`] and the federated baselines.  All
/// randomness comes from `rng`, so per-agent streams stay independent of
/// worker count (the determinism contract's audited path).
pub fn draw_round_batches(
    spec: &MlpSpec,
    shard: &ClassDataset,
    steps: usize,
    batch: usize,
    rng: &mut Pcg64,
) -> (Vec<f32>, Vec<f32>) {
    let d = spec.input_dim();
    let c = spec.classes();
    let mut xs = Vec::with_capacity(steps * batch * d);
    let mut ys = Vec::with_capacity(steps * batch * c);
    for _ in 0..steps {
        let (bx, by) = shard.sample_batch(batch, rng);
        xs.extend_from_slice(&bx);
        ys.extend_from_slice(&by);
    }
    (xs, ys)
}

impl LocalSolver<f32> for NativeSgd {
    fn solve(
        &mut self,
        agent: usize,
        anchor: &[f32],
        rho: f64,
        rng: &mut Pcg64,
    ) -> Vec<f32> {
        let (bx, by) = self.draw_batches(agent, rng);
        let zeros = vec![0.0f32; anchor.len()];
        // local_admm expects (zhat, u); anchor = zhat - u, so pass
        // (anchor, 0).
        let x = self.spec.local_admm(
            &self.xs[agent],
            anchor,
            &zeros,
            &bx,
            &by,
            self.lr,
            rho as f32,
            self.steps,
            self.batch,
        );
        self.xs[agent] = x.clone();
        x
    }

    fn dim(&self) -> usize {
        self.spec.param_len()
    }

    fn n_agents(&self) -> usize {
        self.shards.len()
    }

    /// Pool-sharded batch: per-agent state is the warm-started iterate
    /// `xs[agent]`; the spec and shards are shared read-only; every
    /// minibatch draw comes from that agent's own `rngs[j]` stream.
    fn solve_batch(
        &mut self,
        agents: &[usize],
        anchors: &[Vec<f32>],
        rho: f64,
        rngs: &mut [Pcg64],
        pool: &WorkerPool,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(agents.len(), anchors.len());
        debug_assert_eq!(agents.len(), rngs.len());
        struct Job<'a> {
            agent: usize,
            anchor: &'a [f32],
            x: &'a mut Vec<f32>,
            rng: &'a mut Pcg64,
            out: Vec<f32>,
        }
        let mut rng_refs: Vec<Option<&mut Pcg64>> =
            rngs.iter_mut().map(Some).collect();
        let mut jobs =
            pick_jobs(agents, &mut self.xs, |j, agent, x| Job {
                agent,
                anchor: &anchors[j],
                x,
                // lint:allow(panic-in-library): pick_jobs visits each batch entry once, so each rng slot is taken exactly once
                rng: rng_refs[j].take().expect("one rng per entry"),
                out: Vec::new(),
            });
        let spec = &self.spec;
        let shards = &self.shards;
        let (lr, steps, batch) = (self.lr, self.steps, self.batch);
        pool.run(&mut jobs, |_, job| {
            let (bx, by) = draw_round_batches(
                spec,
                &shards[job.agent],
                steps,
                batch,
                job.rng,
            );
            let zeros = vec![0.0f32; job.anchor.len()];
            let x = spec.local_admm(
                &*job.x, job.anchor, &zeros, &bx, &by, lr, rho as f32,
                steps, batch,
            );
            *job.x = x.clone();
            job.out = x;
        });
        jobs.into_iter().map(|j| j.out).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::regress::{generate, RegressSpec};
    use crate::data::synth::{self, SynthSpec};

    #[test]
    fn exact_quadratic_satisfies_stationarity() {
        let spec = RegressSpec {
            n_agents: 3,
            rows_per_agent: 10,
            dim: 6,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(1);
        let (blocks, _) = generate(&spec, &mut rng);
        let mut solver = ExactQuadratic::new(&blocks);
        let anchor: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let rho = 0.7;
        let x = solver.solve(1, &anchor, rho, &mut rng);
        // check gradient: A'(Ax - b) + rho (x - anchor) = 0
        let ax = blocks[1].a.matvec(&x);
        let resid: Vec<f64> =
            ax.iter().zip(&blocks[1].b).map(|(p, q)| p - q).collect();
        let mut grad = blocks[1].a.tmatvec(&resid);
        for i in 0..6 {
            grad[i] += rho * (x[i] - anchor[i]);
        }
        assert!(crate::linalg::norm2(&grad) < 1e-9);
    }

    #[test]
    fn exact_quadratic_cache_recomputes_on_rho_change() {
        let spec = RegressSpec {
            n_agents: 1,
            rows_per_agent: 8,
            dim: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(2);
        let (blocks, _) = generate(&spec, &mut rng);
        let mut solver = ExactQuadratic::new(&blocks);
        let anchor = vec![0.0; 4];
        let x1 = solver.solve(0, &anchor, 0.1, &mut rng);
        let x2 = solver.solve(0, &anchor, 10.0, &mut rng);
        // large rho pins to anchor = 0 harder
        assert!(crate::linalg::norm2(&x2) < crate::linalg::norm2(&x1));
    }

    #[test]
    fn identity_prox_is_identity() {
        let mut p = IdentityProx;
        let v = vec![1.0f64, -2.0];
        assert_eq!(ServerProx::<f64>::prox(&mut p, &v, 3.0), v);
    }

    #[test]
    fn l1_prox_shrinks() {
        let mut p = L1Prox { lambda: 1.0 };
        let out = p.prox(&[2.0, -0.1, 0.0], 2.0); // tau = 0.5
        assert!((out[0] - 1.5).abs() < 1e-12);
        assert_eq!(out[1], 0.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn native_sgd_improves_local_fit() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards =
            crate::data::partition::iid_split(&train, 2, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver =
            NativeSgd::new(spec.clone(), shards.clone(), 0.1, 4, 8, &init);
        let anchor = init.clone();
        let before = {
            let (bx, by) = shards[0].sample_batch(32, &mut rng);
            spec.loss_grad(&init, &bx, &by, 32).0
        };
        let mut x = init.clone();
        for _ in 0..5 {
            x = solver.solve(0, &anchor, 0.0, &mut rng);
        }
        let after = {
            let (bx, by) = shards[0].sample_batch(32, &mut rng);
            spec.loss_grad(&x, &bx, &by, 32).0
        };
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn native_sgd_warm_starts() {
        let mut rng = Pcg64::seed(4);
        let (train, _) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards = crate::data::partition::iid_split(&train, 1, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let mut solver = NativeSgd::new(spec, shards, 0.05, 2, 4, &init);
        let anchor = vec![0.0f32; solver.dim()];
        let x1 = solver.solve(0, &anchor, 0.1, &mut rng);
        assert_eq!(solver.xs[0], x1, "iterate must be persisted");
    }
}
