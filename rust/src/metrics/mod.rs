//! Metrics recording substrate.
//!
//! Experiments record named series of `(x, y)` points (round vs accuracy,
//! cumulative communication load, suboptimality, ...) into a [`Recorder`],
//! which can smooth (the paper's window-3 smoothing of Fig. 3), summarize
//! and persist to CSV/JSON under `results/`.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::jsonio::Json;

/// Named series of (x, y) points.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Vec<(f64, f64)>>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn add(&mut self, name: &str, x: f64, y: f64) {
        self.series.entry(name.to_string()).or_default().push((x, y));
    }

    pub fn get(&self, name: &str) -> &[(f64, f64)] {
        self.series.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.get(name).last().map(|&(_, y)| y)
    }

    /// Moving-average smoothing of a series (the paper smooths the
    /// communication-load curves with window length 3 in Fig. 3).
    pub fn smoothed(&self, name: &str, window: usize) -> Vec<(f64, f64)> {
        let pts = self.get(name);
        let w = window.max(1);
        pts.iter()
            .enumerate()
            .map(|(i, &(x, _))| {
                let lo = i.saturating_sub(w - 1);
                let slice = &pts[lo..=i];
                let mean = slice.iter().map(|&(_, y)| y).sum::<f64>()
                    / slice.len() as f64;
                (x, mean)
            })
            .collect()
    }

    /// First x where the series reaches `target` (e.g. rounds-to-accuracy);
    /// `None` if never reached (the paper's "N/A" entries in Tab. 1).
    ///
    /// This is a *rising-threshold* scan: it returns the first sample
    /// with `y >= target` in insertion order, which is the intended
    /// crossing only for (approximately) non-decreasing series such as
    /// accuracy curves.  On an oscillating series it reports the first
    /// touch, not a sustained crossing; for falling series (suboptimality,
    /// loss) use [`Recorder::first_below`].
    pub fn first_reaching(&self, name: &str, target: f64) -> Option<f64> {
        self.get(name).iter().find(|&&(_, y)| y >= target).map(|&(x, _)| x)
    }

    /// Falling-threshold dual of [`Recorder::first_reaching`]: the first
    /// x with `y <= target`, for decreasing series like suboptimality or
    /// comm-load.  Same first-touch semantics on non-monotone data.
    pub fn first_below(&self, name: &str, target: f64) -> Option<f64> {
        self.get(name).iter().find(|&&(_, y)| y <= target).map(|&(x, _)| x)
    }

    /// Write all series as long-format CSV: `series,x,y`.
    pub fn to_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "series,x,y")?;
        for (name, pts) in &self.series {
            for &(x, y) in pts {
                writeln!(f, "{name},{x},{y}")?;
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (name, pts) in &self.series {
            let arr = Json::Arr(
                pts.iter()
                    .map(|&(x, y)| Json::Arr(vec![Json::Num(x), Json::Num(y)]))
                    .collect(),
            );
            obj.insert(name.clone(), arr);
        }
        Json::Obj(obj)
    }
}

/// Fixed-width table printer for regenerating the paper's tables on stdout.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:width$} |", cells[i], width = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format an optional count like the paper's Tab. 1 ("N/A" when a target
/// was never reached).
pub fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{:.0}", x),
        None => "N/A".to_string(),
    }
}

/// Human-readable byte count for the wire-accounting columns.
pub fn fmt_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b < KIB {
        format!("{bytes} B")
    } else if b < KIB * KIB {
        format!("{:.1} KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    }
}

/// Human-readable virtual-time duration for the simulator's summaries
/// (the sim's clock is integer microseconds, so µs is the floor unit).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.0} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs < 60.0 {
        format!("{secs:.2} s")
    } else {
        // round once, then split — "119.7" must print "2 min 0 s",
        // never "1 min 60 s"
        let total = secs.round() as u64;
        format!("{} min {} s", total / 60, total % 60)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_get() {
        let mut r = Recorder::new();
        r.add("acc", 0.0, 0.1);
        r.add("acc", 1.0, 0.5);
        assert_eq!(r.get("acc").len(), 2);
        assert_eq!(r.last("acc"), Some(0.5));
        assert_eq!(r.get("missing"), &[]);
        assert_eq!(r.last("missing"), None);
    }

    #[test]
    fn smoothing_window3() {
        let mut r = Recorder::new();
        for (i, y) in [0.0, 3.0, 6.0, 9.0].iter().enumerate() {
            r.add("s", i as f64, *y);
        }
        let sm = r.smoothed("s", 3);
        assert_eq!(sm[0].1, 0.0);
        assert_eq!(sm[1].1, 1.5);
        assert_eq!(sm[2].1, 3.0);
        assert_eq!(sm[3].1, 6.0);
    }

    #[test]
    fn first_reaching_and_na() {
        let mut r = Recorder::new();
        for (i, y) in [0.2, 0.5, 0.8, 0.9].iter().enumerate() {
            r.add("acc", (i * 10) as f64, *y);
        }
        assert_eq!(r.first_reaching("acc", 0.8), Some(20.0));
        assert_eq!(r.first_reaching("acc", 0.95), None);
        // rising-threshold semantics: the documented first-touch
        // behavior on a non-monotone series
        let mut osc = Recorder::new();
        for (i, y) in [0.1, 0.9, 0.3, 0.95].iter().enumerate() {
            osc.add("acc", i as f64, *y);
        }
        assert_eq!(osc.first_reaching("acc", 0.9), Some(1.0));
        assert_eq!(fmt_opt(None), "N/A");
        assert_eq!(fmt_duration(2.5e-5), "25 µs");
        assert_eq!(fmt_duration(0.0305), "30.5 ms");
        assert_eq!(fmt_duration(2.25), "2.25 s");
        assert_eq!(fmt_duration(95.0), "1 min 35 s");
        assert_eq!(fmt_duration(119.7), "2 min 0 s");
        assert_eq!(fmt_opt(Some(123.4)), "123");
    }

    #[test]
    fn first_below_for_falling_series() {
        let mut r = Recorder::new();
        for (i, y) in [1.0e-1, 3.0e-2, 8.0e-3, 9.0e-4].iter().enumerate() {
            r.add("subopt", (i * 5) as f64, *y);
        }
        assert_eq!(r.first_below("subopt", 1e-2), Some(10.0));
        assert_eq!(r.first_below("subopt", 1e-2 + 1e-9), Some(10.0));
        assert_eq!(r.first_below("subopt", 1e-5), None);
        assert_eq!(r.first_below("missing", 1.0), None);
        // exact-equality samples count as crossed on both scans
        let mut eq = Recorder::new();
        eq.add("s", 0.0, 0.5);
        assert_eq!(eq.first_below("s", 0.5), Some(0.0));
        assert_eq!(eq.first_reaching("s", 0.5), Some(0.0));
    }

    #[test]
    fn csv_roundtrip_lines() {
        let mut r = Recorder::new();
        r.add("a", 1.0, 2.0);
        r.add("b", 3.0, 4.0);
        let path = std::env::temp_dir().join("dela_metrics_test/m.csv");
        r.to_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("series,x,y"));
        assert!(text.contains("a,1,2"));
        assert!(text.contains("b,3,4"));
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn json_export() {
        let mut r = Recorder::new();
        r.add("a", 1.0, 2.0);
        let j = r.to_json();
        assert!(j.get("a").is_some());
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert!(fmt_bytes(5 * 1024 * 1024 * 1024).ends_with("GiB"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Algorithm", "80%"]);
        t.row(vec!["Alg. 1".into(), "816".into()]);
        t.row(vec!["FedAvg".into(), "N/A".into()]);
        let s = t.render();
        assert!(s.contains("| Algorithm | 80% |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only one".into()]);
    }
}
