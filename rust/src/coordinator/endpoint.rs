//! The agent side of the service protocol, factored out of the old
//! in-process worker thread so every transport shares one state
//! machine.
//!
//! [`AgentEndpoint`] is a pure frame-in / frame-out reducer: the mpsc
//! runtime ([`crate::transport::InProc`]), the socket client loop
//! ([`crate::coordinator::client`]) and tests all drive the same
//! `handle` method, so local-solve order, RNG draws and uplink byte
//! accounting are identical in every deployment shape — the property
//! the TCP-vs-in-proc bitwise test pins.

use crate::comm::{Estimate, TriggerState};
use crate::config::RunConfig;
use crate::data::synth::ClassDataset;
use crate::kernels::Scratch;
use crate::model::MlpSpec;
use crate::rng::Pcg64;
use crate::transport::frame::Frame;
use crate::transport::LossyLink;
use crate::wire::{Compressor, ErrorFeedback};

/// What the endpoint wants the driving loop to do after a frame.
pub enum EndpointStep {
    /// Send this reply to the leader and keep serving.
    Reply(Frame),
    /// Nothing to send (e.g. after a reset sync).
    Idle,
    /// Send this final reply, then close the session.
    Done(Frame),
}

/// One agent's complete protocol state: local iterate `x`, dual `u`,
/// downlink estimate `ẑ`, uplink trigger + error feedback + lossy link.
///
/// The uplink line survives a [`Frame::Reset`] on purpose: the
/// coordinator's reset resynchronizes only the z (downlink) line, while
/// the d-line keeps its trigger reference AND its error-feedback
/// residual, which is re-injected on the next event — clearing it would
/// silently discard compressed update mass (unlike
/// `ConsensusAdmm::reset`, which resyncs ζ̂ exactly and may therefore
/// drop the residual).
pub struct AgentEndpoint {
    id: usize,
    spec: MlpSpec,
    shard: ClassDataset,
    cfg: RunConfig,
    x: Vec<f32>,
    u: Vec<f32>,
    zhat: Estimate<f32>,
    zhat_prev: Vec<f32>,
    d_trig: TriggerState<f32>,
    up_ch: LossyLink,
    ef_up: ErrorFeedback<f32>,
    rng: Pcg64,
    comp: Box<dyn Compressor<f32>>,
    /// Retained solve-phase arenas (DESIGN.md §15): the kernel scratch,
    /// the stacked S·B minibatch pair, the next-iterate buffer and the
    /// uplink d-vector — reused across rounds so the steady-state round
    /// loop stops allocating on the model path.
    scratch: Scratch,
    bx: Vec<f32>,
    by: Vec<f32>,
    x_next: Vec<f32>,
    dvec: Vec<f32>,
}

impl AgentEndpoint {
    /// Build agent `id`'s endpoint.  `rng` must be the agent's
    /// deterministic stream from [`super::derive_rngs`] so that a
    /// process-per-agent run draws exactly what the in-proc run draws.
    pub fn new(
        id: usize,
        spec: MlpSpec,
        shard: ClassDataset,
        cfg: &RunConfig,
        init: Vec<f32>,
        rng: Pcg64,
    ) -> AgentEndpoint {
        let dim = init.len();
        assert_eq!(dim, spec.param_len());
        AgentEndpoint {
            id,
            spec,
            shard,
            x: init.clone(),
            u: vec![0.0; dim],
            zhat: Estimate::new(init.clone()),
            zhat_prev: init.clone(),
            d_trig: TriggerState::new(cfg.trigger_d, init),
            up_ch: LossyLink::new(cfg.drop_up),
            ef_up: ErrorFeedback::new(),
            rng,
            comp: cfg.compressor.build::<f32>(),
            cfg: cfg.clone(),
            scratch: Scratch::new(),
            bx: Vec::new(),
            by: Vec::new(),
            x_next: Vec::new(),
            dvec: Vec::new(),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    /// Uplink d-events triggered so far.
    pub fn events(&self) -> u64 {
        self.d_trig.events
    }

    /// Cumulative uplink bytes put on the wire by this agent.
    pub fn sent_bytes(&self) -> u64 {
        self.up_ch.stats.sent_bytes
    }

    fn reply(&self, delta: Option<crate::wire::WireMessage<f32>>) -> Frame {
        Frame::Reply {
            agent: self.id as u32,
            events: self.d_trig.events,
            sent_bytes: self.up_ch.stats.sent_bytes,
            delta,
        }
    }

    /// Advance the state machine by one leader frame.
    pub fn handle(&mut self, frame: Frame) -> EndpointStep {
        match frame {
            Frame::Round { zdelta } => {
                EndpointStep::Reply(self.run_round(zdelta))
            }
            Frame::Reset { z } => {
                self.zhat.reset_to(&z);
                EndpointStep::Idle
            }
            Frame::Stop => EndpointStep::Done(self.reply(None)),
            // Welcome is consumed by the session handshake; Hello/Reply
            // never travel leader -> agent; StatusReq/Status live on
            // one-shot probe connections the acceptor answers itself.
            // Ignoring them keeps the endpoint total over the alphabet.
            Frame::Welcome { .. } | Frame::Hello { .. }
            | Frame::Reply { .. } | Frame::StatusReq
            | Frame::Status { .. } => EndpointStep::Idle,
        }
    }

    /// One local ADMM round: apply the downlink payload, dual ascent,
    /// S prox-SGD steps, offer the uplink trigger.
    fn run_round(
        &mut self,
        zdelta: Option<crate::wire::WireMessage<f32>>,
    ) -> Frame {
        let dim = self.x.len();
        self.zhat_prev.clear();
        self.zhat_prev.extend_from_slice(self.zhat.get());
        if let Some(wire_msg) = zdelta {
            self.zhat.apply_msg(&wire_msg);
        }
        let alpha = self.cfg.alpha;
        for j in 0..dim {
            self.u[j] += alpha * self.x[j] - self.zhat.get()[j]
                + (1.0 - alpha) * self.zhat_prev[j];
        }
        // S prox-SGD steps from the warm-started x, through the retained
        // scratch arenas — no per-round model-path allocation after the
        // first round (DESIGN.md §15).  RNG consumption is identical to
        // the historical per-step sample_batch calls.
        self.bx.clear();
        self.by.clear();
        for _ in 0..self.cfg.steps {
            self.shard.sample_batch_into(
                self.cfg.batch,
                &mut self.rng,
                &mut self.bx,
                &mut self.by,
            );
        }
        let mut x_next = std::mem::take(&mut self.x_next);
        self.spec.local_admm_into(
            &self.x,
            self.zhat.get(),
            &self.u,
            &self.bx,
            &self.by,
            self.cfg.lr,
            self.cfg.rho,
            self.cfg.steps,
            self.cfg.batch,
            &mut self.scratch,
            &mut x_next,
        );
        std::mem::swap(&mut self.x, &mut x_next);
        self.x_next = x_next;
        self.dvec.clear();
        self.dvec.extend(
            self.x.iter().zip(&self.u).map(|(&x, &u)| alpha * x + u),
        );
        let mut payload = None;
        if let Some(dl) = self.d_trig.offer(&self.dvec, &mut self.rng) {
            let msg =
                self.ef_up.compress(&dl, self.comp.as_ref(), &mut self.rng);
            let bytes = msg.wire_bytes() as u64;
            payload = self.up_ch.transmit_bytes(msg, bytes, &mut self.rng);
        }
        self.reply(payload)
    }
}
