//! Threaded leader/worker runtime for Alg. 1.
//!
//! The algorithm cores in [`crate::admm`] are deterministic single-threaded
//! state machines (every experiment is reproducible from a seed); this
//! module is the *deployment shape*: one OS thread per agent, a leader
//! thread owning `z`, message passing over `std::sync::mpsc` channels with
//! the same event-trigger + drop-channel semantics on every link.  A round
//! barrier preserves Alg. 1's synchronous semantics; the event protocol
//! decides whether a message carries a payload.
//!
//! Used by the e2e example and the integration tests; single-threaded
//! experiment sweeps use [`crate::admm::ConsensusAdmm`] directly.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::comm::{DropChannel, Estimate, Trigger, TriggerState};
use crate::data::synth::ClassDataset;
use crate::model::MlpSpec;
use crate::rng::Pcg64;
use crate::wire::{CompressorCfg, ErrorFeedback, WireMessage};

/// Leader -> agent messages.  Payloads cross the thread boundary as
/// [`WireMessage`]s — the same codec the single-threaded engines use —
/// so byte accounting and compression behave identically in the
/// deployment-shaped runtime.
enum ToAgent {
    /// Start round k; `zdelta` is the event-based downlink payload
    /// (None = no event or packet dropped).
    Round { zdelta: Option<WireMessage<f32>> },
    /// Hard reset: synchronize `ẑ` to the true `z`.
    Reset { z: Vec<f32> },
    /// Terminate and report stats.
    Stop,
}

/// Agent -> leader messages.
struct FromAgent {
    /// Sender id.
    agent: usize,
    /// Uplink payload: `Some(msg)` if the d-trigger fired AND the packet
    /// survived; `None` otherwise.
    delta: Option<WireMessage<f32>>,
    /// d-events triggered so far (for load accounting).
    events: u64,
    /// Cumulative uplink bytes put on the wire by this agent.
    sent_bytes: u64,
}

/// Configuration of the threaded runtime.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub rho: f32,
    pub alpha: f32,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    pub trigger_d: Trigger,
    pub trigger_z: Trigger,
    pub drop_up: f64,
    pub drop_down: f64,
    pub reset_period: usize,
    pub seed: u64,
    /// Delta compressor on both directions (`--compressor` on the CLI).
    pub compressor: CompressorCfg,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            rho: 1.0,
            alpha: 1.0,
            lr: 0.1,
            steps: 5,
            batch: 32,
            trigger_d: Trigger::Always,
            trigger_z: Trigger::Always,
            drop_up: 0.0,
            drop_down: 0.0,
            reset_period: 0,
            seed: 0,
            compressor: CompressorCfg::Identity,
        }
    }
}

struct AgentHandle {
    tx: Sender<ToAgent>,
    join: JoinHandle<()>,
    z_trig: TriggerState<f32>,
    down_ch: DropChannel,
    ef_down: ErrorFeedback<f32>,
}

/// The leader: owns `z`, spawns one worker thread per shard.
pub struct Coordinator {
    pub cfg: CoordinatorConfig,
    pub spec: MlpSpec,
    pub z: Vec<f32>,
    zeta_hat: Estimate<f32>,
    agents: Vec<AgentHandle>,
    from_rx: Receiver<FromAgent>,
    rng: Pcg64,
    pub round_idx: usize,
    pub uplink_events: u64,
    comp: Box<dyn crate::wire::Compressor<f32>>,
    /// Latest cumulative uplink bytes reported by each agent thread.
    uplink_bytes_per_agent: Vec<u64>,
}

impl Coordinator {
    /// Spawn N agent threads, one per data shard.
    pub fn spawn(
        cfg: CoordinatorConfig,
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        init: Vec<f32>,
    ) -> Coordinator {
        let _n = shards.len();
        let dim = init.len();
        assert_eq!(dim, spec.param_len());
        let (from_tx, from_rx) = channel::<FromAgent>();
        let mut master_rng = Pcg64::seed(cfg.seed);
        let n_agents = shards.len();
        let agents = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let (tx, rx) = channel::<ToAgent>();
                let mut worker = AgentWorker {
                    id: i,
                    spec: spec.clone(),
                    shard,
                    cfg: cfg.clone(),
                    x: init.clone(),
                    u: vec![0.0; dim],
                    zhat: Estimate::new(init.clone()),
                    zhat_prev: init.clone(),
                    d_trig: TriggerState::new(cfg.trigger_d, init.clone()),
                    up_ch: DropChannel::new(cfg.drop_up),
                    ef_up: ErrorFeedback::new(),
                    rng: master_rng.split(i as u64 + 1),
                    to_leader: from_tx.clone(),
                };
                let join = std::thread::Builder::new()
                    .name(format!("dela-agent-{i}"))
                    .spawn(move || worker.run(rx))
                    // lint:allow(panic-in-library): thread spawn fails only on OS resource exhaustion; no meaningful recovery exists here
                    .expect("spawn agent thread");
                AgentHandle {
                    tx,
                    join,
                    z_trig: TriggerState::new(cfg.trigger_z, init.clone()),
                    down_ch: DropChannel::new(cfg.drop_down),
                    ef_down: ErrorFeedback::new(),
                }
            })
            .collect();
        let comp = cfg.compressor.build::<f32>();
        Coordinator {
            rng: master_rng.split(0),
            cfg,
            spec,
            zeta_hat: Estimate::new(init.clone()),
            z: init,
            agents,
            from_rx,
            round_idx: 0,
            uplink_events: 0,
            comp,
            uplink_bytes_per_agent: vec![0; n_agents],
        }
    }

    /// Execute one synchronous round across all agent threads.
    pub fn round(&mut self) {
        let n = self.agents.len();
        // downlink: per-link event trigger + EF-compressed codec + lossy
        // channel with byte accounting
        for a in &mut self.agents {
            let mut payload = None;
            if let Some(delta) = a.z_trig.offer(&self.z, &mut self.rng) {
                let msg = a.ef_down.compress(
                    &delta,
                    self.comp.as_ref(),
                    &mut self.rng,
                );
                let bytes = msg.wire_bytes() as u64;
                payload = a.down_ch.transmit_bytes(msg, bytes, &mut self.rng);
            }
            // lint:allow(unaccounted-send): downlink bytes were charged via transmit_bytes above; this mpsc send is the thread-boundary transfer, not a wire hop
            a.tx.send(ToAgent::Round { zdelta: payload })
                // lint:allow(panic-in-library): a closed channel means the agent thread already panicked; propagating that panic is intended
                .expect("agent thread alive");
        }
        // gather uplink
        let mut got = 0;
        let mut uplink_events = 0;
        while got < n {
            // lint:allow(panic-in-library): a closed channel means an agent thread already panicked; propagating that panic is intended
            let msg = self.from_rx.recv().expect("agent reply");
            if let Some(wire_msg) = msg.delta {
                self.zeta_hat.apply_scaled_msg(&wire_msg, 1.0 / n as f64);
            }
            self.uplink_bytes_per_agent[msg.agent] = msg.sent_bytes;
            uplink_events += msg.events;
            got += 1;
        }
        self.uplink_events = uplink_events;
        // z-update (g = 0): z = ζ̂ + (1−α) z
        let alpha = self.cfg.alpha;
        for (z, &zh) in self.z.iter_mut().zip(self.zeta_hat.get()) {
            *z = zh + (1.0 - alpha) * *z;
        }
        self.round_idx += 1;
        if self.cfg.reset_period > 0
            && self.round_idx % self.cfg.reset_period == 0
        {
            let z = self.z.clone();
            let sync_bytes =
                WireMessage::<f32>::dense_bytes(z.len()) as u64;
            for a in &mut self.agents {
                a.z_trig.reset(&z);
                a.ef_down.clear();
                a.down_ch.stats.record_reliable(sync_bytes);
                // lint:allow(unaccounted-send): reset bytes were charged via record_reliable on the line above; the mpsc send is the thread-boundary transfer
                a.tx.send(ToAgent::Reset { z: z.clone() })
                    // lint:allow(panic-in-library): a closed channel means the agent thread already panicked; propagating that panic is intended
                    .expect("agent thread alive");
            }
        }
    }

    /// Downlink events so far.
    pub fn downlink_events(&self) -> u64 {
        self.agents.iter().map(|a| a.z_trig.events).sum()
    }

    /// Downlink bytes put on the wire so far.
    pub fn downlink_bytes(&self) -> u64 {
        self.agents.iter().map(|a| a.down_ch.stats.sent_bytes).sum()
    }

    /// Uplink bytes put on the wire so far (as last reported by each
    /// agent thread).
    pub fn uplink_bytes(&self) -> u64 {
        self.uplink_bytes_per_agent.iter().sum()
    }

    /// Stop all agent threads; returns total uplink d-events.
    pub fn shutdown(mut self) -> u64 {
        for a in &self.agents {
            // lint:allow(unaccounted-send): Stop is a control message with no payload; nothing crosses the modelled wire
            let _ = a.tx.send(ToAgent::Stop);
        }
        // agents reply with a final stats message
        let mut uplink = 0;
        for _ in 0..self.agents.len() {
            if let Ok(msg) = self.from_rx.recv() {
                uplink += msg.events;
            }
        }
        for a in self.agents.drain(..) {
            let _ = a.join.join();
        }
        uplink
    }
}

struct AgentWorker {
    id: usize,
    spec: MlpSpec,
    shard: ClassDataset,
    cfg: CoordinatorConfig,
    x: Vec<f32>,
    u: Vec<f32>,
    zhat: Estimate<f32>,
    zhat_prev: Vec<f32>,
    d_trig: TriggerState<f32>,
    up_ch: DropChannel,
    ef_up: ErrorFeedback<f32>,
    rng: Pcg64,
    to_leader: Sender<FromAgent>,
}

impl AgentWorker {
    fn run(&mut self, rx: Receiver<ToAgent>) {
        let dim = self.x.len();
        let comp = self.cfg.compressor.build::<f32>();
        while let Ok(msg) = rx.recv() {
            match msg {
                ToAgent::Round { zdelta } => {
                    self.zhat_prev.clear();
                    let snapshot: Vec<f32> = self.zhat.get().to_vec();
                    self.zhat_prev.extend_from_slice(&snapshot);
                    if let Some(wire_msg) = zdelta {
                        self.zhat.apply_msg(&wire_msg);
                    }
                    let alpha = self.cfg.alpha;
                    for j in 0..dim {
                        self.u[j] += alpha * self.x[j] - self.zhat.get()[j]
                            + (1.0 - alpha) * self.zhat_prev[j];
                    }
                    // S prox-SGD steps from the warm-started x
                    let d = self.spec.input_dim();
                    let c = self.spec.classes();
                    let mut xs = Vec::with_capacity(
                        self.cfg.steps * self.cfg.batch * d,
                    );
                    let mut ys = Vec::with_capacity(
                        self.cfg.steps * self.cfg.batch * c,
                    );
                    for _ in 0..self.cfg.steps {
                        let (bx, by) =
                            self.shard.sample_batch(self.cfg.batch, &mut self.rng);
                        xs.extend_from_slice(&bx);
                        ys.extend_from_slice(&by);
                    }
                    self.x = self.spec.local_admm(
                        &self.x,
                        self.zhat.get(),
                        &self.u,
                        &xs,
                        &ys,
                        self.cfg.lr,
                        self.cfg.rho,
                        self.cfg.steps,
                        self.cfg.batch,
                    );
                    let dvec: Vec<f32> = self
                        .x
                        .iter()
                        .zip(&self.u)
                        .map(|(&x, &u)| alpha * x + u)
                        .collect();
                    let mut payload = None;
                    if let Some(dl) = self.d_trig.offer(&dvec, &mut self.rng)
                    {
                        let msg = self.ef_up.compress(
                            &dl,
                            comp.as_ref(),
                            &mut self.rng,
                        );
                        let bytes = msg.wire_bytes() as u64;
                        payload = self.up_ch.transmit_bytes(
                            msg,
                            bytes,
                            &mut self.rng,
                        );
                    }
                    // lint:allow(unaccounted-send): uplink bytes were charged via transmit_bytes when the payload was produced; this send reports them to the leader
                    let _ = self.to_leader.send(FromAgent {
                        agent: self.id,
                        delta: payload,
                        events: self.d_trig.events,
                        sent_bytes: self.up_ch.stats.sent_bytes,
                    });
                }
                ToAgent::Reset { z } => {
                    // the coordinator's reset resynchronizes only the z
                    // (downlink) line; the uplink d-line keeps its trigger
                    // reference AND its error-feedback residual, which is
                    // re-injected on the next event — clearing it here
                    // would silently discard compressed update mass
                    // (unlike ConsensusAdmm::reset, which resyncs ζ̂
                    // exactly and may therefore drop the residual).
                    self.zhat.reset_to(&z);
                }
                ToAgent::Stop => {
                    // lint:allow(unaccounted-send): final stats report carries no payload; all wire bytes were charged when transmitted
                    let _ = self.to_leader.send(FromAgent {
                        agent: self.id,
                        delta: None,
                        events: self.d_trig.events,
                        sent_bytes: self.up_ch.stats.sent_bytes,
                    });
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::single_class_split;
    use crate::data::synth::{generate, SynthSpec};

    #[test]
    fn threaded_training_improves_accuracy() {
        let mut rng = Pcg64::seed(1);
        let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let acc0 = spec.accuracy(&init, &test.xs, &test.labels);
        let cfg = CoordinatorConfig {
            rho: 1.0,
            lr: 0.1,
            steps: 3,
            batch: 8,
            trigger_d: Trigger::vanilla(0.05),
            trigger_z: Trigger::vanilla(0.05),
            seed: 7,
            ..Default::default()
        };
        let mut coord = Coordinator::spawn(cfg, spec.clone(), shards, init);
        for _ in 0..40 {
            coord.round();
        }
        let acc = spec.accuracy(&coord.z, &test.xs, &test.labels);
        let up = coord.shutdown();
        assert!(acc > acc0 + 0.2, "acc {acc0} -> {acc}");
        assert!(up > 0);
    }

    #[test]
    fn shutdown_is_clean_without_rounds() {
        let mut rng = Pcg64::seed(2);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let coord = Coordinator::spawn(
            CoordinatorConfig::default(),
            spec,
            shards,
            init,
        );
        assert_eq!(coord.shutdown(), 0);
    }

    #[test]
    fn event_triggers_reduce_uplink_traffic() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);

        let run = |trig: Trigger| {
            let shards = single_class_split(&train, 4);
            let cfg = CoordinatorConfig {
                trigger_d: trig,
                steps: 2,
                batch: 4,
                seed: 11,
                ..Default::default()
            };
            let mut coord =
                Coordinator::spawn(cfg, MlpSpec::new(vec![8, 16, 4]), shards, init.clone());
            for _ in 0..20 {
                coord.round();
            }
            coord.shutdown()
        };
        let up_always = run(Trigger::Always);
        let up_event = run(Trigger::vanilla(1.0));
        assert_eq!(up_always, 80);
        assert!(up_event < up_always, "event {up_event} !< {up_always}");
    }

    #[test]
    fn wire_bytes_counted_on_both_directions() {
        let mut rng = Pcg64::seed(4);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let dim = init.len();
        let cfg = CoordinatorConfig {
            steps: 1,
            batch: 4,
            seed: 13,
            ..Default::default()
        };
        let mut coord = Coordinator::spawn(cfg, spec, shards, init);
        let rounds = 15;
        for _ in 0..rounds {
            coord.round();
        }
        // Trigger::Always + identity compressor: every round, every agent,
        // both directions carry one dense message.
        let dense = crate::wire::WireMessage::<f32>::dense_bytes(dim) as u64;
        let expect = rounds as u64 * 4 * dense;
        assert_eq!(coord.downlink_bytes(), expect);
        assert_eq!(coord.uplink_bytes(), expect);
        coord.shutdown();
    }

    #[test]
    fn compressed_coordinator_still_learns() {
        let mut rng = Pcg64::seed(5);
        let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let acc0 = spec.accuracy(&init, &test.xs, &test.labels);
        let cfg = CoordinatorConfig {
            rho: 1.0,
            lr: 0.1,
            steps: 3,
            batch: 8,
            trigger_d: Trigger::vanilla(0.05),
            trigger_z: Trigger::vanilla(0.05),
            seed: 7,
            compressor: crate::wire::CompressorCfg::TopKQuant {
                frac: 0.25,
                bits: 10,
            },
            ..Default::default()
        };
        let mut coord = Coordinator::spawn(cfg, spec.clone(), shards, init);
        for _ in 0..40 {
            coord.round();
        }
        let acc = spec.accuracy(&coord.z, &test.xs, &test.labels);
        let uplink_bytes = coord.uplink_bytes();
        coord.shutdown();
        assert!(acc > acc0 + 0.15, "compressed acc {acc0} -> {acc}");
        assert!(uplink_bytes > 0);
    }
}
