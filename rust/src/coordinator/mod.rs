//! The long-running leader service for Alg. 1, generic over
//! [`Transport`].
//!
//! The algorithm cores in [`crate::admm`] are deterministic
//! single-threaded state machines; this module is the *deployment
//! shape*: a leader owning `z` and the per-agent downlink lines
//! (trigger + error feedback), talking to [`AgentEndpoint`] state
//! machines through whatever medium the transport provides — worker
//! threads ([`crate::transport::InProc`]), the simulator's cost model
//! ([`crate::transport::SimLink`]), or real sockets
//! ([`crate::transport::Tcp`] / `Uds`, driven by `deluxe serve` +
//! `deluxe agent`).
//!
//! A round barrier preserves Alg. 1's synchronous semantics; the event
//! protocol decides whether a message carries a payload.  Fault
//! semantics on lossy transports: an agent that dies mid-round
//! ([`TransportEvent::Left`]) is simply absent — the paper's
//! drop-tolerance already covers a missing delta — and a rejoining
//! agent is resynchronized through the same reliable `Reset` path the
//! periodic reset strategy uses ([`Coordinator::rejoin_resyncs`]
//! counts these).  Replies are buffered per agent and applied in agent
//! order, so a trajectory is bit-reproducible no matter which link
//! delivers first.

mod client;
mod endpoint;

pub use client::{run_agent_session, AgentOpts, SessionEnd};
#[cfg(unix)]
pub use client::{run_uds_agent, run_uds_agent_obs};
pub use client::{run_tcp_agent, run_tcp_agent_obs};
pub use endpoint::{AgentEndpoint, EndpointStep};

use crate::comm::{Estimate, TriggerState};
use crate::config::RunConfig;
use crate::data::synth::ClassDataset;
use crate::jsonio::Json;
use crate::model::MlpSpec;
use crate::obs::{clock::Stopwatch, Event, Line, Obs, SpanKind, TimedSpan};
use crate::rng::Pcg64;
use crate::sim::link::LinkModel;
use crate::transport::frame::Frame;
use crate::transport::{InProc, SimLink, Transport, TransportEvent};
use crate::wire::{Compressor, ErrorFeedback, WireMessage, WireStats};

/// Derive the leader's and every agent's RNG stream from the run seed.
///
/// This replicates the historical spawn order exactly (agents are split
/// off first, in id order, then the leader), so trajectories match the
/// pre-trait runtime bit-for-bit — and a `deluxe agent` process can
/// derive its own stream without ever talking to the leader.
pub fn derive_rngs(seed: u64, n: usize) -> (Pcg64, Vec<Pcg64>) {
    let mut master = Pcg64::seed(seed);
    let agents: Vec<Pcg64> =
        (0..n).map(|i| master.split(i as u64 + 1)).collect();
    (master.split(0), agents)
}

/// Build the per-shard [`AgentEndpoint`]s with their deterministic RNG
/// streams — shared by every in-process deployment shape, and by the
/// `deluxe agent` CLI (which builds all endpoints identically and keeps
/// only its own shard's).
pub fn make_endpoints(
    cfg: &RunConfig,
    spec: &MlpSpec,
    shards: Vec<ClassDataset>,
    init: &[f32],
) -> Vec<AgentEndpoint> {
    let (_, agent_rngs) = derive_rngs(cfg.seed, shards.len());
    shards
        .into_iter()
        .zip(agent_rngs)
        .enumerate()
        .map(|(i, (shard, rng))| {
            AgentEndpoint::new(i, spec.clone(), shard, cfg, init.to_vec(), rng)
        })
        .collect()
}

/// Per-agent downlink protocol line owned by the leader.
struct LeaderLine {
    z_trig: TriggerState<f32>,
    ef_down: ErrorFeedback<f32>,
}

/// The leader: owns `z` and drives one synchronous round at a time
/// over any [`Transport`].
pub struct Coordinator<TP: Transport = InProc> {
    pub cfg: RunConfig,
    pub spec: MlpSpec,
    pub z: Vec<f32>,
    zeta_hat: Estimate<f32>,
    lines: Vec<LeaderLine>,
    /// Membership view: `false` once a link died, back to `true` after
    /// a rejoin-resync.
    live: Vec<bool>,
    tp: TP,
    rng: Pcg64,
    pub round_idx: usize,
    pub uplink_events: u64,
    comp: Box<dyn Compressor<f32>>,
    /// Latest cumulative uplink bytes reported by each agent.
    uplink_bytes_per_agent: Vec<u64>,
    /// Latest cumulative uplink d-events reported by each agent.
    uplink_events_per_agent: Vec<u64>,
    /// Rejoin-resyncs performed (one reliable dense `Reset` each).
    pub rejoin_resyncs: u64,
    /// Replies that arrived after their round's gather closed.
    pub stale_replies: u64,
    /// Observability handle: journal + flight recorder + metrics.
    /// Defaults to [`Obs::off`] (zero overhead); `deluxe serve`/`train`
    /// install a live one before driving rounds.  Deterministic journal
    /// fields are emitted in agent order at *apply* time, never at
    /// receive time, so journals stay bit-identical across worker counts
    /// and transports (DESIGN.md §13).
    pub obs: Obs,
    meta_emitted: bool,
}

impl Coordinator<InProc> {
    /// Spawn N agent threads, one per data shard — the classic
    /// in-process runtime.
    pub fn spawn(
        cfg: RunConfig,
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        init: Vec<f32>,
    ) -> Coordinator<InProc> {
        let endpoints = make_endpoints(&cfg, &spec, shards, &init);
        let tp = InProc::spawn(endpoints, cfg.drop_down);
        Coordinator::over(tp, cfg, spec, init)
    }
}

impl Coordinator<SimLink> {
    /// Spawn agent threads behind the simulator's link cost model.
    pub fn spawn_sim(
        cfg: RunConfig,
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        init: Vec<f32>,
        model: LinkModel,
    ) -> Coordinator<SimLink> {
        let endpoints = make_endpoints(&cfg, &spec, shards, &init);
        let tp = SimLink::spawn(endpoints, model);
        Coordinator::over(tp, cfg, spec, init)
    }
}

impl<TP: Transport> Coordinator<TP> {
    /// Run the leader over an already-constructed transport (sockets,
    /// sims, or anything else implementing [`Transport`]).
    pub fn over(
        tp: TP,
        cfg: RunConfig,
        spec: MlpSpec,
        init: Vec<f32>,
    ) -> Coordinator<TP> {
        let n = tp.n_agents();
        let dim = init.len();
        assert_eq!(dim, spec.param_len());
        let (leader_rng, _) = derive_rngs(cfg.seed, n);
        let comp = cfg.compressor.build::<f32>();
        let lines = (0..n)
            .map(|_| LeaderLine {
                z_trig: TriggerState::new(cfg.trigger_z, init.clone()),
                ef_down: ErrorFeedback::new(),
            })
            .collect();
        Coordinator {
            rng: leader_rng,
            zeta_hat: Estimate::new(init.clone()),
            z: init,
            lines,
            live: vec![true; n],
            tp,
            round_idx: 0,
            uplink_events: 0,
            comp,
            uplink_bytes_per_agent: vec![0; n],
            uplink_events_per_agent: vec![0; n],
            rejoin_resyncs: 0,
            stale_replies: 0,
            obs: Obs::off(),
            meta_emitted: false,
            cfg,
            spec,
        }
    }

    /// Per-agent downlink `(sent_bytes, dropped_bytes)` snapshot, used
    /// to journal exact byte deltas around the send phase.
    fn downlink_book(&self) -> Vec<(u64, u64)> {
        self.tp
            .stats()
            .downlink
            .iter()
            .map(|l| (l.bytes, l.dropped_bytes))
            .collect()
    }

    /// Execute one synchronous round across all live agents.
    ///
    /// Journaling (when [`Coordinator::obs`] is live) follows the
    /// determinism split of DESIGN.md §13: downlink events come from
    /// exact per-agent book deltas around the send phase; uplink events
    /// are emitted **in agent order at apply time** from the cumulative
    /// `Reply` counters, never at receive time, so the deterministic
    /// journal fields are identical for every transport and worker
    /// count.  Churn events (`AgentLeft`/`Rejoin`/`FrameTimeout`) are
    /// journaled in arrival order — they only occur on faulty runs,
    /// which make no bit-identity promise.
    pub fn round(&mut self) {
        let n = self.tp.n_agents();
        let round = self.round_idx as u64;
        let sw = if self.obs.on() { Some(Stopwatch::start()) } else { None };
        if self.obs.on() && !self.meta_emitted {
            self.meta_emitted = true;
            self.obs.emit(Event::Meta {
                agents: n,
                dim: self.z.len(),
                dense_bytes: WireMessage::<f32>::dense_bytes(self.z.len())
                    as u64,
            });
            for i in 0..n {
                if self.live[i] {
                    self.obs.emit(Event::AgentJoined { agent: i });
                }
            }
        }
        if self.obs.on() {
            self.obs.emit(Event::RoundStart { round });
        }
        // the round span (DESIGN.md §14) wraps everything from transport
        // round-begin to the pre-RoundEnd close; idle-churn resyncs land
        // inside it but outside the phase spans
        let round_span =
            TimedSpan::open(&mut self.obs, SpanKind::Round, round, None);
        self.tp.begin_round();
        // absorb membership churn that happened between rounds, so a
        // crashed agent's rejoin is resynced before we address the round
        while let Some(ev) = self.tp.poll() {
            self.absorb_idle_event(ev);
        }
        // downlink: per-link event trigger + EF-compressed codec, then
        // the transport's lossy link with byte accounting
        let down_before = if self.obs.on() {
            self.downlink_book()
        } else {
            Vec::new()
        };
        // broadcast phase span: wraps the sends and the downlink journal
        // block, so trigger/msg/drop lines attribute to it positionally;
        // each live link's send gets its own transmit child span whose
        // deterministic fields come from the per-link book delta and the
        // sim transport's per-send virtual time
        let bcast_span =
            TimedSpan::open(&mut self.obs, SpanKind::Broadcast, round, None);
        let mut fired = vec![false; n];
        let mut pending = vec![false; n];
        for i in 0..n {
            if !self.live[i] {
                continue;
            }
            let mut payload = None;
            if let Some(delta) =
                self.lines[i].z_trig.offer(&self.z, &mut self.rng)
            {
                fired[i] = true;
                payload = Some(self.lines[i].ef_down.compress(
                    &delta,
                    self.comp.as_ref(),
                    &mut self.rng,
                ));
            }
            let t_span = TimedSpan::open(
                &mut self.obs,
                SpanKind::Transmit,
                round,
                Some(i),
            );
            let t_before = if self.obs.spans_on() {
                self.tp.stats().downlink.get(i).map_or(0, |l| l.bytes)
            } else {
                0
            };
            // lint:allow(unaccounted-send): Transport::send charges the wire books internally (loss draw + byte accounting per frame kind)
            match self.tp.send(i, Frame::Round { zdelta: payload }, &mut self.rng)
            {
                Ok(()) => pending[i] = true,
                // lint:allow(panic-in-library): a transport send error means the runtime fabric itself is gone (an agent thread panicked); propagating that panic is intended
                Err(e) => panic!("transport send to agent {i}: {e}"),
            }
            let t_bytes = if self.obs.spans_on() {
                self.tp
                    .stats()
                    .downlink
                    .get(i)
                    .map_or(0, |l| l.bytes)
                    .saturating_sub(t_before)
            } else {
                0
            };
            t_span.close(
                &mut self.obs,
                Some(t_bytes),
                self.tp.last_send_vtime_us(),
            );
        }
        let mut down_delta = 0u64;
        if self.obs.on() {
            let down_after = self.downlink_book();
            for i in 0..n {
                if fired[i] {
                    self.obs.emit(Event::TriggerFired {
                        round,
                        agent: i,
                        line: Line::Down,
                    });
                }
                let (b0, d0) = down_before[i];
                let (b1, d1) = down_after[i];
                if b1 > b0 {
                    down_delta += b1 - b0;
                    self.obs.emit(Event::MessageSent {
                        round,
                        agent: i,
                        line: Line::Down,
                        bytes: b1 - b0,
                    });
                }
                if d1 > d0 {
                    self.obs.emit(Event::PacketDropped {
                        round,
                        agent: i,
                        line: Line::Down,
                        bytes: d1 - d0,
                    });
                }
            }
        }
        bcast_span.close(&mut self.obs, Some(down_delta), None);
        // gather uplink: buffer replies per agent, apply in agent order
        // (bit-reproducible regardless of delivery order); the gather
        // phase span wraps the reply wait and the uplink journal block
        let gather_span =
            TimedSpan::open(&mut self.obs, SpanKind::Gather, round, None);
        let up_before = if self.obs.on() {
            Some((
                self.uplink_bytes_per_agent.clone(),
                self.uplink_events_per_agent.clone(),
            ))
        } else {
            None
        };
        let mut replies: Vec<Option<WireMessage<f32>>> = Vec::new();
        replies.resize_with(n, || None);
        let mut outstanding = pending.iter().filter(|&&p| p).count();
        while outstanding > 0 {
            let ev = match self.tp.recv() {
                Ok(ev) => ev,
                // lint:allow(panic-in-library): a failed transport recv means the runtime fabric is gone (agent thread panicked or event queue closed); propagating that panic is intended
                Err(e) => panic!("transport recv: {e}"),
            };
            match ev {
                TransportEvent::Frame { frame, .. } => {
                    if let Frame::Reply { agent, events, sent_bytes, delta } =
                        frame
                    {
                        let a = agent as usize;
                        if a < n && pending[a] {
                            pending[a] = false;
                            outstanding -= 1;
                            replies[a] = delta;
                            self.uplink_bytes_per_agent[a] = sent_bytes;
                            self.uplink_events_per_agent[a] = events;
                        } else {
                            self.stale_replies += 1;
                        }
                    }
                }
                TransportEvent::Left { from } => {
                    if from < n {
                        self.live[from] = false;
                        if pending[from] {
                            pending[from] = false;
                            outstanding -= 1;
                        }
                        if self.obs.on() {
                            self.obs.emit(Event::AgentLeft { agent: from });
                        }
                    }
                }
                TransportEvent::Joined { from } => {
                    self.resync_rejoined(from);
                }
                TransportEvent::Timeout => {
                    // slow agents stay live; their late replies will be
                    // discarded as stale when they finally land
                    for p in pending.iter_mut() {
                        if *p {
                            *p = false;
                            outstanding -= 1;
                        }
                    }
                    if self.obs.on() {
                        self.obs.emit(Event::FrameTimeout { round });
                    }
                }
            }
        }
        // uplink journal: agent-order apply-time emission from the
        // cumulative Reply counter deltas (receive order is not
        // deterministic; these deltas are)
        let mut up_delta = 0u64;
        if let Some((pb, pe)) = up_before {
            for i in 0..n {
                let ev_delta =
                    self.uplink_events_per_agent[i].saturating_sub(pe[i]);
                for _ in 0..ev_delta {
                    self.obs.emit(Event::TriggerFired {
                        round,
                        agent: i,
                        line: Line::Up,
                    });
                }
                let b_delta =
                    self.uplink_bytes_per_agent[i].saturating_sub(pb[i]);
                if b_delta > 0 {
                    up_delta += b_delta;
                    self.obs.emit(Event::MessageSent {
                        round,
                        agent: i,
                        line: Line::Up,
                        bytes: b_delta,
                    });
                }
            }
        }
        gather_span.close(&mut self.obs, Some(up_delta), None);
        // apply phase span: reply application, the z-update and the
        // periodic reset resync (its ResetSync lines land inside)
        let apply_span =
            TimedSpan::open(&mut self.obs, SpanKind::Apply, round, None);
        for msg in replies.iter().flatten() {
            self.zeta_hat.apply_scaled_msg(msg, 1.0 / n as f64);
        }
        self.uplink_events = self.uplink_events_per_agent.iter().sum();
        // z-update (g = 0): z = ζ̂ + (1−α) z
        let alpha = self.cfg.alpha;
        for (z, &zh) in self.z.iter_mut().zip(self.zeta_hat.get()) {
            *z = zh + (1.0 - alpha) * *z;
        }
        self.round_idx += 1;
        let mut reset_bytes = 0u64;
        if self.cfg.reset_period > 0
            && self.round_idx % self.cfg.reset_period == 0
        {
            let z = self.z.clone();
            let sync = WireMessage::<f32>::dense_bytes(z.len()) as u64;
            for i in 0..n {
                if !self.live[i] {
                    continue;
                }
                self.lines[i].z_trig.reset(&z);
                self.lines[i].ef_down.clear();
                // lint:allow(unaccounted-send): Transport::send charges the reset as one reliable dense sync transfer
                match self.tp.send(
                    i,
                    Frame::Reset { z: z.clone() },
                    &mut self.rng,
                ) {
                    Ok(()) => {}
                    // lint:allow(panic-in-library): a transport send error means the runtime fabric itself is gone; propagating that panic is intended
                    Err(e) => panic!("transport reset to agent {i}: {e}"),
                }
                if self.obs.on() {
                    reset_bytes += sync;
                    self.obs.emit(Event::ResetSync {
                        round,
                        agent: i,
                        bytes: sync,
                    });
                }
            }
        }
        apply_span.close(&mut self.obs, Some(reset_bytes), None);
        round_span.close(&mut self.obs, None, self.tp.vtime_us());
        if self.obs.on() {
            self.obs.emit(Event::RoundEnd {
                round,
                events: self.uplink_events + self.downlink_events(),
                up_bytes: self.uplink_bytes(),
                down_bytes: self.downlink_bytes(),
                vtime_us: self.tp.vtime_us(),
                wall_us: sw.map(|s| s.micros()),
            });
        }
        if self.tp.wants_status() {
            let status = self.status_json().to_string();
            self.tp.set_status(&status);
        }
    }

    /// Handle an event that arrived outside a gather.
    fn absorb_idle_event(&mut self, ev: TransportEvent) {
        match ev {
            TransportEvent::Frame {
                frame: Frame::Reply { .. }, ..
            } => self.stale_replies += 1,
            TransportEvent::Frame { .. } | TransportEvent::Timeout => {}
            TransportEvent::Left { from } => {
                if from < self.live.len() {
                    self.live[from] = false;
                    if self.obs.on() {
                        self.obs.emit(Event::AgentLeft { agent: from });
                    }
                }
            }
            TransportEvent::Joined { from } => self.resync_rejoined(from),
        }
    }

    /// A crashed agent reconnected: bring its slot back and resync its
    /// `ẑ` through the reliable reset path (charged as one dense sync).
    fn resync_rejoined(&mut self, from: usize) {
        if from >= self.lines.len() {
            return;
        }
        self.live[from] = true;
        let z = self.z.clone();
        self.lines[from].z_trig.reset(&z);
        self.lines[from].ef_down.clear();
        // lint:allow(unaccounted-send): Transport::send charges the resync as one reliable dense sync transfer
        match self.tp.send(from, Frame::Reset { z }, &mut self.rng) {
            Ok(()) => {}
            // lint:allow(panic-in-library): a transport send error means the runtime fabric itself is gone; propagating that panic is intended
            Err(e) => panic!("transport resync to agent {from}: {e}"),
        }
        self.rejoin_resyncs += 1;
        if self.obs.on() {
            let round = self.round_idx as u64;
            self.obs.emit(Event::Rejoin { round, agent: from });
            self.obs.emit(Event::ResetSync {
                round,
                agent: from,
                bytes: WireMessage::<f32>::dense_bytes(self.z.len()) as u64,
            });
        }
    }

    /// Live status snapshot served to `deluxe status` probes.
    ///
    /// Published to the transport after every round (when the transport
    /// wants one, i.e. socket runtimes).  The shape is stable JSON:
    /// scalar progress fields plus per-agent parallel arrays, and the
    /// metrics registry snapshot when journaling is live.
    pub fn status_json(&self) -> Json {
        let n = self.lines.len();
        let wire = self.tp.stats();
        let num = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("round", num(self.round_idx as u64)),
            ("agents", num(n as u64)),
            (
                "live",
                Json::Arr(self.live.iter().map(|&l| Json::Bool(l)).collect()),
            ),
            ("rejoin_resyncs", num(self.rejoin_resyncs)),
            ("stale_replies", num(self.stale_replies)),
            (
                "uplink_events",
                Json::Arr(
                    self.uplink_events_per_agent
                        .iter()
                        .map(|&e| num(e))
                        .collect(),
                ),
            ),
            (
                "uplink_bytes",
                Json::Arr(
                    self.uplink_bytes_per_agent
                        .iter()
                        .map(|&b| num(b))
                        .collect(),
                ),
            ),
            (
                "downlink_events",
                Json::Arr(
                    self.lines.iter().map(|l| num(l.z_trig.events)).collect(),
                ),
            ),
            (
                "downlink_bytes",
                Json::Arr(
                    wire.downlink.iter().map(|l| num(l.bytes)).collect(),
                ),
            ),
            ("metrics", self.obs.metrics.snapshot()),
        ])
    }

    /// Downlink events so far.
    pub fn downlink_events(&self) -> u64 {
        self.lines.iter().map(|l| l.z_trig.events).sum()
    }

    /// Downlink bytes put on the wire so far.
    pub fn downlink_bytes(&self) -> u64 {
        self.tp.stats().downlink_bytes()
    }

    /// Uplink bytes put on the wire so far (as last reported by each
    /// agent).
    pub fn uplink_bytes(&self) -> u64 {
        self.uplink_bytes_per_agent.iter().sum()
    }

    /// Per-link byte books from the transport.
    pub fn wire_stats(&self) -> WireStats {
        self.tp.stats()
    }

    /// Current membership view.
    pub fn live(&self) -> &[bool] {
        &self.live
    }

    /// Number of currently live agents.
    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Borrow the underlying transport (e.g. to read a sim clock or a
    /// socket address).
    pub fn transport(&self) -> &TP {
        &self.tp
    }

    /// Stop all agents; returns total uplink d-events.
    pub fn shutdown(mut self) -> u64 {
        let n = self.tp.n_agents();
        let mut awaited = vec![false; n];
        for (i, slot) in awaited.iter_mut().enumerate() {
            if !self.live[i] {
                continue;
            }
            // lint:allow(unaccounted-send): Stop is a control frame; Transport::send charges nothing for it by design
            if self.tp.send(i, Frame::Stop, &mut self.rng).is_ok() {
                *slot = true;
            }
        }
        let mut outstanding = awaited.iter().filter(|&&a| a).count();
        while outstanding > 0 {
            let ev = match self.tp.recv() {
                Ok(ev) => ev,
                Err(_) => break,
            };
            match ev {
                TransportEvent::Frame {
                    frame: Frame::Reply { agent, events, sent_bytes, .. },
                    ..
                } => {
                    let a = agent as usize;
                    if a < n {
                        self.uplink_events_per_agent[a] = events;
                        self.uplink_bytes_per_agent[a] = sent_bytes;
                        if awaited[a] {
                            awaited[a] = false;
                            outstanding -= 1;
                        }
                    }
                }
                TransportEvent::Left { from } => {
                    if from < n && awaited[from] {
                        awaited[from] = false;
                        outstanding -= 1;
                    }
                }
                TransportEvent::Timeout => break,
                _ => {}
            }
        }
        let _ = self.tp.shutdown();
        self.uplink_events_per_agent.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Trigger;
    use crate::data::partition::single_class_split;
    use crate::data::synth::{generate, SynthSpec};

    #[test]
    fn threaded_training_improves_accuracy() {
        let mut rng = Pcg64::seed(1);
        let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let acc0 = spec.accuracy(&init, &test.xs, &test.labels);
        let cfg = RunConfig::default()
            .with_rho(1.0)
            .with_lr(0.1)
            .with_steps(3)
            .with_batch(8)
            .with_trigger_d(Trigger::vanilla(0.05))
            .with_trigger_z(Trigger::vanilla(0.05))
            .with_seed(7);
        let mut coord = Coordinator::spawn(cfg, spec.clone(), shards, init);
        for _ in 0..40 {
            coord.round();
        }
        let acc = spec.accuracy(&coord.z, &test.xs, &test.labels);
        let up = coord.shutdown();
        assert!(acc > acc0 + 0.2, "acc {acc0} -> {acc}");
        assert!(up > 0);
    }

    #[test]
    fn shutdown_is_clean_without_rounds() {
        let mut rng = Pcg64::seed(2);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let coord =
            Coordinator::spawn(RunConfig::default(), spec, shards, init);
        assert_eq!(coord.shutdown(), 0);
    }

    #[test]
    fn event_triggers_reduce_uplink_traffic() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);

        let run = |trig: Trigger| {
            let shards = single_class_split(&train, 4);
            let cfg = RunConfig::default()
                .with_trigger_d(trig)
                .with_steps(2)
                .with_batch(4)
                .with_seed(11);
            let mut coord = Coordinator::spawn(
                cfg,
                MlpSpec::new(vec![8, 16, 4]),
                shards,
                init.clone(),
            );
            for _ in 0..20 {
                coord.round();
            }
            coord.shutdown()
        };
        let up_always = run(Trigger::Always);
        let up_event = run(Trigger::vanilla(1.0));
        assert_eq!(up_always, 80);
        assert!(up_event < up_always, "event {up_event} !< {up_always}");
    }

    #[test]
    fn wire_bytes_counted_on_both_directions() {
        let mut rng = Pcg64::seed(4);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let dim = init.len();
        let cfg = RunConfig::default()
            .with_steps(1)
            .with_batch(4)
            .with_seed(13);
        let mut coord = Coordinator::spawn(cfg, spec, shards, init);
        let rounds = 15;
        for _ in 0..rounds {
            coord.round();
        }
        // Trigger::Always + identity compressor: every round, every agent,
        // both directions carry one dense message.
        let dense = crate::wire::WireMessage::<f32>::dense_bytes(dim) as u64;
        let expect = rounds as u64 * 4 * dense;
        assert_eq!(coord.downlink_bytes(), expect);
        assert_eq!(coord.uplink_bytes(), expect);
        // the transport's WireStats books agree with the counters
        let ws = coord.wire_stats();
        assert_eq!(ws.downlink_bytes(), expect);
        assert_eq!(ws.uplink_bytes(), expect);
        coord.shutdown();
    }

    #[test]
    fn compressed_coordinator_still_learns() {
        let mut rng = Pcg64::seed(5);
        let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let acc0 = spec.accuracy(&init, &test.xs, &test.labels);
        let cfg = RunConfig::default()
            .with_rho(1.0)
            .with_lr(0.1)
            .with_steps(3)
            .with_batch(8)
            .with_trigger_d(Trigger::vanilla(0.05))
            .with_trigger_z(Trigger::vanilla(0.05))
            .with_seed(7)
            .with_compressor(crate::wire::CompressorCfg::TopKQuant {
                frac: 0.25,
                bits: 10,
            });
        let mut coord = Coordinator::spawn(cfg, spec.clone(), shards, init);
        for _ in 0..40 {
            coord.round();
        }
        let acc = spec.accuracy(&coord.z, &test.xs, &test.labels);
        let uplink_bytes = coord.uplink_bytes();
        coord.shutdown();
        assert!(acc > acc0 + 0.15, "compressed acc {acc0} -> {acc}");
        assert!(uplink_bytes > 0);
    }

    #[test]
    fn sim_transport_with_ideal_links_matches_inproc_bitwise() {
        // the keystone interchangeability property at the in-process
        // level: an ideal SimLink draws nothing extra from the leader
        // RNG, so the trajectory is bit-identical to InProc.
        let mut rng = Pcg64::seed(21);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let init = spec.init(&mut rng);
        let cfg = RunConfig::default()
            .with_steps(2)
            .with_batch(4)
            .with_trigger_d(Trigger::vanilla(0.05))
            .with_trigger_z(Trigger::vanilla(0.05))
            .with_seed(17);

        let mut a = Coordinator::spawn(
            cfg.clone(),
            spec.clone(),
            single_class_split(&train, 4),
            init.clone(),
        );
        let mut b = Coordinator::spawn_sim(
            cfg,
            spec,
            single_class_split(&train, 4),
            init,
            LinkModel::ideal(),
        );
        for r in 0..10 {
            a.round();
            b.round();
            assert_eq!(a.z, b.z, "z diverged at round {r}");
        }
        assert_eq!(a.downlink_bytes(), b.downlink_bytes());
        assert_eq!(a.uplink_bytes(), b.uplink_bytes());
        assert_eq!(b.transport().vtime_ticks(), 0, "ideal links take no time");
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn derive_rngs_streams_are_stable_and_distinct() {
        let (mut leader, mut agents) = derive_rngs(42, 4);
        let (mut leader2, mut agents2) = derive_rngs(42, 4);
        assert_eq!(leader.next_u64(), leader2.next_u64());
        for (a, b) in agents.iter_mut().zip(agents2.iter_mut()) {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // distinct streams across agents and leader
        let mut seen: Vec<u64> =
            agents.iter_mut().map(|r| r.next_u64()).collect();
        seen.push(leader.next_u64());
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 5);
    }
}
