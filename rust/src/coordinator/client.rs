//! The agent side of the socket runtime: connect, handshake, serve
//! rounds, reconnect with bounded backoff.
//!
//! A session is `Hello → Welcome → (Round/Reset … ) → Stop`.  On any
//! I/O error the driver reconnects (bounded attempts, exponential
//! backoff); the endpoint's state survives the reconnect, and the
//! leader answers the rejoin with a reliable `Reset` resync — crash
//! recovery rides the same path as the paper's periodic reset
//! strategy.  A *process* crash loses the endpoint state entirely; a
//! replacement process starts from `init` and is resynced the same
//! way.

use std::io::{self, Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::time::Duration;

use crate::obs::{Event, Obs};
use crate::transport::frame::{read_frame, write_frame, Frame};

use super::endpoint::{AgentEndpoint, EndpointStep};

/// Client-side knobs.
#[derive(Clone, Debug)]
pub struct AgentOpts {
    /// Reconnect budget after the first established session.
    pub reconnect_attempts: u32,
    /// Initial reconnect backoff; doubles per failure.
    pub backoff_ms: u64,
    /// Backoff ceiling.
    pub max_backoff_ms: u64,
    /// Write timeout on the connection.
    pub write_timeout_ms: u64,
    /// Test hook: silently drop the connection after serving this many
    /// rounds (simulates an agent crash without a goodbye).
    pub crash_after_rounds: Option<u64>,
}

impl Default for AgentOpts {
    fn default() -> Self {
        AgentOpts {
            reconnect_attempts: 5,
            backoff_ms: 200,
            max_backoff_ms: 5_000,
            write_timeout_ms: 5_000,
            crash_after_rounds: None,
        }
    }
}

/// How a session over one connection ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionEnd {
    /// The leader sent `Stop`; the final reply went out.
    Stopped,
    /// The `crash_after_rounds` test hook fired — the caller should
    /// drop the connection without a goodbye.
    Crashed,
}

/// Serve one connection: handshake, then frames until `Stop`, an I/O
/// error, or the crash hook.  Generic over the stream so tests can
/// drive it over TCP, UDS, or an in-memory pipe.
pub fn run_agent_session<S: Read + Write>(
    stream: &mut S,
    ep: &mut AgentEndpoint,
    digest: u64,
    opts: &AgentOpts,
) -> io::Result<SessionEnd> {
    write_frame(
        stream,
        &Frame::Hello {
            agent: ep.id() as u32,
            digest,
            dim: ep.dim() as u32,
        },
    )?;
    match read_frame(stream)? {
        Frame::Welcome { .. } => {}
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected Welcome, got {}", other.kind()),
            ));
        }
    }
    let mut rounds_served = 0u64;
    loop {
        let frame = read_frame(stream)?;
        let was_round = matches!(frame, Frame::Round { .. });
        match ep.handle(frame) {
            EndpointStep::Reply(r) => write_frame(stream, &r)?,
            EndpointStep::Idle => {}
            EndpointStep::Done(r) => {
                write_frame(stream, &r)?;
                return Ok(SessionEnd::Stopped);
            }
        }
        if was_round {
            rounds_served += 1;
            if opts.crash_after_rounds == Some(rounds_served) {
                return Ok(SessionEnd::Crashed);
            }
        }
    }
}

/// Connect-and-serve with bounded reconnect-and-backoff.
///
/// Every failed attempt journals a [`Event::ReconnectAttempt`] before
/// the backoff sleep — reconnects only happen on faulty runs, so these
/// are churn events outside the deterministic-journal promise.
fn drive<S, F>(
    mut connect: F,
    ep: &mut AgentEndpoint,
    digest: u64,
    opts: &AgentOpts,
    obs: &mut Obs,
) -> anyhow::Result<SessionEnd>
where
    S: Read + Write,
    F: FnMut() -> io::Result<S>,
{
    let mut attempts_left = opts.reconnect_attempts;
    let mut backoff = opts.backoff_ms.max(1);
    loop {
        let attempt = connect()
            .and_then(|mut s| run_agent_session(&mut s, ep, digest, opts));
        match attempt {
            Ok(end) => return Ok(end),
            Err(e) => {
                if attempts_left == 0 {
                    anyhow::bail!(
                        "agent {}: giving up after {} reconnect attempts: {e}",
                        ep.id(),
                        opts.reconnect_attempts
                    );
                }
                attempts_left -= 1;
                if obs.on() {
                    obs.emit(Event::ReconnectAttempt {
                        agent: ep.id(),
                        attempt: opts.reconnect_attempts - attempts_left,
                    });
                }
                std::thread::sleep(Duration::from_millis(backoff));
                backoff = (backoff * 2).min(opts.max_backoff_ms.max(1));
            }
        }
    }
}

/// Run one agent against a TCP leader (`deluxe agent --connect`).
pub fn run_tcp_agent(
    addr: &str,
    ep: &mut AgentEndpoint,
    digest: u64,
    opts: &AgentOpts,
) -> anyhow::Result<SessionEnd> {
    run_tcp_agent_obs(addr, ep, digest, opts, &mut Obs::off())
}

/// [`run_tcp_agent`] with a journal attached (`--journal` on the agent
/// CLI): reconnect attempts are recorded as they happen.
pub fn run_tcp_agent_obs(
    addr: &str,
    ep: &mut AgentEndpoint,
    digest: u64,
    opts: &AgentOpts,
    obs: &mut Obs,
) -> anyhow::Result<SessionEnd> {
    let addr = addr.to_string();
    let write_timeout = Duration::from_millis(opts.write_timeout_ms);
    drive(
        move || {
            let s = TcpStream::connect(&addr)?;
            s.set_nodelay(true)?;
            s.set_write_timeout(Some(write_timeout))?;
            // reads block indefinitely: silence between rounds is normal
            s.set_read_timeout(None)?;
            Ok(s)
        },
        ep,
        digest,
        opts,
        obs,
    )
}

/// Run one agent against a Unix-domain-socket leader.
#[cfg(unix)]
pub fn run_uds_agent(
    path: &str,
    ep: &mut AgentEndpoint,
    digest: u64,
    opts: &AgentOpts,
) -> anyhow::Result<SessionEnd> {
    run_uds_agent_obs(path, ep, digest, opts, &mut Obs::off())
}

/// [`run_uds_agent`] with a journal attached.
#[cfg(unix)]
pub fn run_uds_agent_obs(
    path: &str,
    ep: &mut AgentEndpoint,
    digest: u64,
    opts: &AgentOpts,
    obs: &mut Obs,
) -> anyhow::Result<SessionEnd> {
    let path = path.to_string();
    let write_timeout = Duration::from_millis(opts.write_timeout_ms);
    drive(
        move || {
            let s = UnixStream::connect(&path)?;
            s.set_write_timeout(Some(write_timeout))?;
            s.set_read_timeout(None)?;
            Ok(s)
        },
        ep,
        digest,
        opts,
        obs,
    )
}
