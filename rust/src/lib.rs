//! # DELA — Distributed Event-based Learning via ADMM
//!
//! A production-shaped reproduction of *“Distributed Event-Based Learning
//! via ADMM”* (Er, Trimpe, Muehlebach — ICML 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack
//! (see `DESIGN.md`):
//!
//! * [`comm`] — the paper's event-based communication protocol (vanilla and
//!   randomized triggers) and periodic resets (Sec. 2, App. E).
//! * [`transport`] — the deployment substrate: the object-safe
//!   [`transport::Transport`] trait, the in-process thread fabric
//!   ([`transport::InProc`]), the discrete-event cost model adapter
//!   ([`transport::SimLink`]), real sockets ([`transport::Tcp`] /
//!   `Uds`) with length-prefixed framing and handshake, and the lossy
//!   link model ([`transport::loss`]).
//! * [`wire`] — the compressed-message codec (TopK / RandK / b-bit
//!   stochastic quantization with error feedback) and byte-accurate
//!   uplink/downlink accounting layered under every link.
//! * [`admm`] — Alg. 1 (consensus), Alg. 2 (general `Ax + Bz = c`),
//!   consensus-over-graph (Eq. 7) and the sharing problem (App. A).
//! * [`baselines`] — FedAvg, FedProx, SCAFFOLD and FedADMM under an
//!   identical local-computation budget (Sec. 5).
//! * [`sim`] — deterministic discrete-event network simulator: latency /
//!   bandwidth / burst-loss links, stragglers, agent churn, and the
//!   asynchronous quorum-based variant of Alg. 1, with a threaded
//!   scenario-sweep runner.
//! * [`runtime`] — PJRT client executing the AOT-compiled JAX/Pallas
//!   artifacts from `artifacts/` (Python never runs on the request path).
//! * [`coordinator`] — the threaded leader/agent runtime.
//! * [`analysis`] — the `deluxe lint` static-analysis pass that
//!   machine-checks the determinism / panic-freedom / byte-accounting
//!   house invariants (DESIGN.md §11).
//! * [`obs`] — structured observability: typed event journal with a
//!   wall-clock/deterministic field split, bounded flight recorder,
//!   the metrics registry behind `deluxe status` / `deluxe trace`
//!   (DESIGN.md §13), and the hierarchical span layer + `deluxe
//!   profile` critical-path analyzer on top of it (DESIGN.md §14).
//! * [`kernels`] — SIMD-friendly f32/f64 microkernels with a documented
//!   accumulation-order contract plus the per-worker [`kernels::Scratch`]
//!   arena behind the allocation-free solve phase (DESIGN.md §15).
//! * Substrates built from scratch for the offline environment: [`rng`],
//!   [`jsonio`], [`linalg`], [`data`], [`topology`], [`metrics`],
//!   [`benchlib`], [`proptest`], [`cli`].

pub mod analysis;
pub mod benchlib;
pub mod cli;
pub mod comm;
pub mod config;
pub mod data;
pub mod jsonio;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod proptest;
pub mod rng;
pub mod sim;
pub mod topology;
pub mod transport;
pub mod wire;

pub mod admm;
pub mod baselines;
pub mod coordinator;
pub mod experiments;
pub mod lasso;
pub mod runtime;
pub mod solver;

/// The stable import surface: everything a downstream binary, example,
/// or integration test should need.  Internal plumbing (the lint
/// lexer, the in-proc thread fabric, frame codecs beyond [`Frame`])
/// stays out on purpose.
pub mod prelude {
    pub use crate::comm::{Estimate, Scalar, Trigger, TriggerState};
    pub use crate::config::RunConfig;
    pub use crate::coordinator::{
        derive_rngs, make_endpoints, run_agent_session, run_tcp_agent,
        AgentEndpoint, AgentOpts, Coordinator, SessionEnd,
    };
    #[cfg(unix)]
    pub use crate::coordinator::run_uds_agent;
    pub use crate::linalg::Matrix;
    pub use crate::metrics::Recorder;
    pub use crate::obs::{
        Event, FlightRecorder, Metrics, Obs, SpanKind, TimedSpan,
    };
    pub use crate::rng::{Pcg64, Rng};
    pub use crate::transport::{
        Frame, InProc, LossModel, LossyLink, SimLink, SocketOpts, Tcp,
        Transport, TransportEvent,
    };
    #[cfg(unix)]
    pub use crate::transport::Uds;
    pub use crate::wire::{
        Compressor, CompressorCfg, WireMessage, WireStats,
    };
}
