//! Communication-graph substrate (App. A.2, G.3).
//!
//! Decentralized consensus runs over an undirected connected graph
//! `G = (V, E)`; the constraint matrices `A = [Â_t; Â_r] ⊗ I_p`,
//! `B = [I; I]` of problem (4) encode the topology, and the condition
//! number `κ = L σ̄²(A) / (m σ̲²(A))` ties the graph to the convergence
//! rate (Thm. 4.1).

use crate::linalg::Matrix;
use crate::rng::Rng;

/// Undirected graph on `n` vertices.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// Edges with `a < b`, deduplicated, sorted.
    pub edges: Vec<(usize, usize)>,
}

impl Graph {
    pub fn new(n: usize, mut edges: Vec<(usize, usize)>) -> Self {
        for e in &mut edges {
            if e.0 > e.1 {
                *e = (e.1, e.0);
            }
            assert!(e.0 != e.1, "self loop");
            assert!(e.1 < n, "edge out of range");
        }
        edges.sort_unstable();
        edges.dedup();
        Graph { n, edges }
    }

    /// Complete graph.
    pub fn complete(n: usize) -> Self {
        let mut edges = Vec::new();
        for a in 0..n {
            for b in a + 1..n {
                edges.push((a, b));
            }
        }
        Graph { n, edges }
    }

    /// Ring.
    pub fn ring(n: usize) -> Self {
        Graph::new(n, (0..n).map(|i| (i, (i + 1) % n)).collect())
    }

    /// Star: vertex 0 is the hub, every other vertex is a leaf — the
    /// worst-case bottleneck topology (server-like, diameter 2).
    pub fn star(n: usize) -> Self {
        assert!(n >= 1, "star needs at least one vertex");
        Graph::new(n, (1..n).map(|i| (0, i)).collect())
    }

    /// `rows x cols` 4-neighbor grid (vertex `r*cols + c`) — the standard
    /// mesh topology for spatially local scenarios.
    pub fn grid2d(rows: usize, cols: usize) -> Self {
        assert!(rows >= 1 && cols >= 1, "grid needs positive extents");
        let mut edges = Vec::with_capacity(2 * rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let v = r * cols + c;
                if c + 1 < cols {
                    edges.push((v, v + 1));
                }
                if r + 1 < rows {
                    edges.push((v, v + cols));
                }
            }
        }
        Graph::new(rows * cols, edges)
    }

    /// Seeded Erdős–Rényi `G(n, p)`: each of the `n(n-1)/2` possible edges
    /// is present independently with probability `p`.  Deterministic given
    /// the RNG state; NOT guaranteed connected — callers that need
    /// connectivity should check [`Self::is_connected`] (or use
    /// [`Self::random_connected`], which plants a spanning tree).
    pub fn erdos_renyi(n: usize, p: f64, rng: &mut impl Rng) -> Self {
        assert!((0.0..=1.0).contains(&p), "p in [0,1]");
        let mut edges = Vec::new();
        for a in 0..n {
            for b in a + 1..n {
                if rng.bernoulli(p) {
                    edges.push((a, b));
                }
            }
        }
        // built in sorted order, no duplicates, all indices < n
        Graph { n, edges }
    }

    /// Seeded Erdős–Rényi `G(n, p)` resampled until connected (rejection
    /// loop).  Above the `ln n / n` connectivity threshold a handful of
    /// tries suffice; far below it the loop is bounded and the final
    /// attempt falls back to [`Self::random_connected`] at the same
    /// expected edge count, so the call always returns a connected graph.
    pub fn erdos_renyi_connected(
        n: usize,
        p: f64,
        rng: &mut impl Rng,
    ) -> Self {
        for _ in 0..64 {
            let g = Graph::erdos_renyi(n, p, rng);
            if g.is_connected() {
                return g;
            }
        }
        let max_edges = n * (n - 1) / 2;
        let m = ((max_edges as f64 * p).ceil() as usize)
            .clamp(n.saturating_sub(1), max_edges.max(1));
        Graph::random_connected(n, m, rng)
    }

    /// Random connected graph with exactly `m >= n-1` edges: random
    /// spanning tree (guarantees connectivity) + random extra edges.
    /// The paper's Fig. 11 uses (10, 70); Fig. 12 uses (50, 1762).
    pub fn random_connected(n: usize, m: usize, rng: &mut impl Rng) -> Self {
        assert!(m >= n.saturating_sub(1), "need >= n-1 edges");
        let max_edges = n * (n - 1) / 2;
        assert!(m <= max_edges, "too many edges for simple graph");
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(m);
        // random spanning tree: connect each new vertex to a random earlier
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for i in 1..n {
            let j = order[rng.below(i)];
            let (a, b) = (order[i].min(j), order[i].max(j));
            edges.push((a, b));
        }
        edges.sort_unstable();
        edges.dedup();
        // add random extra edges until we reach m
        let mut guard = 0usize;
        while edges.len() < m {
            let a = rng.below(n);
            let b = rng.below(n);
            if a == b {
                continue;
            }
            let e = (a.min(b), a.max(b));
            if let Err(pos) = edges.binary_search(&e) {
                edges.insert(pos, e);
            }
            guard += 1;
            if guard > 100 * max_edges {
                // dense fallback: deterministic fill
                for a in 0..n {
                    for b in a + 1..n {
                        if edges.len() >= m {
                            break;
                        }
                        let e = (a, b);
                        if let Err(pos) = edges.binary_search(&e) {
                            edges.insert(pos, e);
                        }
                    }
                }
            }
        }
        Graph { n, edges }
    }

    /// Adjacency lists.
    pub fn neighbors(&self) -> Vec<Vec<usize>> {
        let mut nbrs = vec![Vec::new(); self.n];
        for &(a, b) in &self.edges {
            nbrs[a].push(b);
            nbrs[b].push(a);
        }
        for v in &mut nbrs {
            v.sort_unstable();
        }
        nbrs
    }

    pub fn degree(&self, v: usize) -> usize {
        self.edges.iter().filter(|&&(a, b)| a == v || b == v).count()
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let nbrs = self.neighbors();
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &w in &nbrs[v] {
                if !seen[w] {
                    seen[w] = true;
                    count += 1;
                    stack.push(w);
                }
            }
        }
        count == self.n
    }

    /// Transmitter/receiver matrices `Â_t, Â_r ∈ R^{|E| x N}` (App. A.2):
    /// row `e = (i,j)` has a single 1 in column `i` (transmitter) resp.
    /// `j` (receiver).
    pub fn incidence(&self) -> (Matrix, Matrix) {
        let m = self.edges.len();
        let mut at = Matrix::zeros(m, self.n);
        let mut ar = Matrix::zeros(m, self.n);
        for (e, &(i, j)) in self.edges.iter().enumerate() {
            at[(e, i)] = 1.0;
            ar[(e, j)] = 1.0;
        }
        (at, ar)
    }

    /// Stacked constraint matrix `A = [Â_t; Â_r]` (p = 1 slice; the
    /// Kronecker lift to R^p is implicit in the vectorized updates).
    pub fn constraint_matrix(&self) -> Matrix {
        let (at, ar) = self.incidence();
        let m = self.edges.len();
        let mut a = Matrix::zeros(2 * m, self.n);
        for e in 0..m {
            for v in 0..self.n {
                a[(e, v)] = at[(e, v)];
                a[(m + e, v)] = ar[(e, v)];
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn ring_structure() {
        let g = Graph::ring(5);
        assert_eq!(g.edges.len(), 5);
        assert!(g.is_connected());
        assert!(g.neighbors().iter().all(|n| n.len() == 2));
    }

    #[test]
    fn complete_structure() {
        let g = Graph::complete(6);
        assert_eq!(g.edges.len(), 15);
        assert!(g.is_connected());
        assert_eq!(g.degree(3), 5);
    }

    #[test]
    fn random_connected_paper_sizes() {
        let mut rng = Pcg64::seed(1);
        // Fig. 11: 10 agents, 70 edges (out of max 45? no — 70 > 45, so the
        // paper's graph must be a multigraph or directed; we cap at the
        // simple-graph max and verify the cap panics past it).
        let g = Graph::random_connected(10, 45, &mut rng);
        assert_eq!(g.edges.len(), 45);
        assert!(g.is_connected());
        // Fig. 12: 50 agents, 1762 edges > 1225 max simple; use 1100.
        let g2 = Graph::random_connected(50, 1100, &mut rng);
        assert_eq!(g2.edges.len(), 1100);
        assert!(g2.is_connected());
    }

    #[test]
    fn random_connected_sparse() {
        let mut rng = Pcg64::seed(2);
        for _ in 0..20 {
            let g = Graph::random_connected(12, 11, &mut rng); // tree
            assert_eq!(g.edges.len(), 11);
            assert!(g.is_connected());
        }
    }

    #[test]
    fn star_structure() {
        let g = Graph::star(6);
        assert_eq!(g.edges.len(), 5);
        assert!(g.is_connected());
        assert_eq!(g.degree(0), 5);
        for v in 1..6 {
            assert_eq!(g.degree(v), 1);
        }
        // degenerate cases
        assert!(Graph::star(1).is_connected());
        assert_eq!(Graph::star(2).edges, vec![(0, 1)]);
    }

    #[test]
    fn grid2d_structure() {
        let g = Graph::grid2d(3, 4);
        assert_eq!(g.n, 12);
        // horizontal: 3 rows x 3; vertical: 2 gaps x 4 cols
        assert_eq!(g.edges.len(), 3 * 3 + 2 * 4);
        assert!(g.is_connected());
        assert_eq!(g.degree(0), 2); // corner
        assert_eq!(g.degree(1), 3); // edge
        assert_eq!(g.degree(5), 4); // interior (row 1, col 1)
        // 1 x n degenerates to a path
        let path = Graph::grid2d(1, 5);
        assert_eq!(path.edges.len(), 4);
        assert!(path.is_connected());
    }

    #[test]
    fn erdos_renyi_is_seed_deterministic() {
        let g1 = Graph::erdos_renyi(15, 0.4, &mut Pcg64::seed(9));
        let g2 = Graph::erdos_renyi(15, 0.4, &mut Pcg64::seed(9));
        assert_eq!(g1.edges, g2.edges);
        let g3 = Graph::erdos_renyi(15, 0.4, &mut Pcg64::seed(10));
        assert_ne!(g1.edges, g3.edges);
    }

    #[test]
    fn erdos_renyi_connectivity_regimes() {
        // p = 1 is the complete graph; p = 0 is edgeless.
        let mut rng = Pcg64::seed(11);
        let full = Graph::erdos_renyi(8, 1.0, &mut rng);
        assert_eq!(full.edges.len(), 28);
        assert!(full.is_connected());
        let empty = Graph::erdos_renyi(8, 0.0, &mut rng);
        assert!(empty.edges.is_empty());
        assert!(!empty.is_connected());
        // dense regime: p well above the ln(n)/n connectivity threshold
        // is connected for every seed we sample
        for seed in 0..20u64 {
            let g = Graph::erdos_renyi(20, 0.5, &mut Pcg64::seed(seed));
            assert!(g.is_connected(), "seed {seed} disconnected");
        }
    }

    #[test]
    fn prop_erdos_renyi_connected_is_always_connected() {
        // resample-loop contract: for any (n, p, seed) the helper returns
        // a connected graph on exactly n vertices — including p far below
        // the ln(n)/n connectivity threshold, where the fallback plants a
        // spanning tree
        crate::proptest::forall(
            "erdos_renyi_connected",
            |rng| {
                let n = 2 + rng.below(30);
                let p = rng.range(0.01, 0.95);
                (n, p, rng.next_u64())
            },
            |&(n, p, seed)| {
                let g = Graph::erdos_renyi_connected(
                    n,
                    p,
                    &mut Pcg64::seed(seed),
                );
                if g.n != n {
                    return Err(format!("wrong vertex count {}", g.n));
                }
                if !g.is_connected() {
                    return Err(format!(
                        "disconnected output (n={n}, p={p}, {} edges)",
                        g.edges.len()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_star_degrees_match_closed_form() {
        crate::proptest::forall(
            "star_degrees",
            |rng| 2 + rng.below(60),
            |&n| {
                let g = Graph::star(n);
                if g.edges.len() != n - 1 {
                    return Err(format!("edge count {}", g.edges.len()));
                }
                if g.degree(0) != n - 1 {
                    return Err(format!("hub degree {}", g.degree(0)));
                }
                for v in 1..n {
                    if g.degree(v) != 1 {
                        return Err(format!("leaf {v} degree {}", g.degree(v)));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_grid2d_degrees_match_closed_form() {
        // |E| = rows*(cols-1) + cols*(rows-1); deg(v) counts the in-grid
        // 4-neighborhood
        crate::proptest::forall(
            "grid2d_degrees",
            |rng| (1 + rng.below(7), 1 + rng.below(7)),
            |&(rows, cols)| {
                let g = Graph::grid2d(rows, cols);
                let expect_edges = rows * (cols - 1) + cols * (rows - 1);
                if g.edges.len() != expect_edges {
                    return Err(format!(
                        "edges {} != {expect_edges}",
                        g.edges.len()
                    ));
                }
                for r in 0..rows {
                    for c in 0..cols {
                        let v = r * cols + c;
                        let expect = usize::from(r > 0)
                            + usize::from(r + 1 < rows)
                            + usize::from(c > 0)
                            + usize::from(c + 1 < cols);
                        if g.degree(v) != expect {
                            return Err(format!(
                                "({r},{c}) degree {} != {expect}",
                                g.degree(v)
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn new_topologies_drive_graph_admm_shapes() {
        // the constructors must produce graphs the incidence machinery
        // accepts (canonical edges, valid indices)
        for g in [
            Graph::star(7),
            Graph::grid2d(3, 3),
            Graph::erdos_renyi(9, 0.6, &mut Pcg64::seed(12)),
        ] {
            let (at, ar) = g.incidence();
            assert_eq!(at.rows, g.edges.len());
            assert_eq!(ar.cols, g.n);
            for w in g.edges.windows(2) {
                assert!(w[0] < w[1], "edges must be sorted/deduped");
            }
        }
    }

    #[test]
    fn edges_are_canonical() {
        let g = Graph::new(4, vec![(2, 0), (3, 1), (1, 3)]);
        assert_eq!(g.edges, vec![(0, 2), (1, 3)]);
        assert!(!g.is_connected());
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        Graph::new(3, vec![(1, 1)]);
    }

    #[test]
    fn incidence_rows_sum_to_one() {
        let mut rng = Pcg64::seed(3);
        let g = Graph::random_connected(8, 14, &mut rng);
        let (at, ar) = g.incidence();
        assert_eq!(at.rows, 14);
        assert_eq!(ar.cols, 8);
        for e in 0..14 {
            assert_eq!(at.row(e).iter().sum::<f64>(), 1.0);
            assert_eq!(ar.row(e).iter().sum::<f64>(), 1.0);
            // transmitter and receiver differ
            let ti = at.row(e).iter().position(|&v| v == 1.0).unwrap();
            let ri = ar.row(e).iter().position(|&v| v == 1.0).unwrap();
            assert_ne!(ti, ri);
            assert_eq!(g.edges[e], (ti.min(ri), ti.max(ri)));
        }
    }

    #[test]
    fn constraint_matrix_shape_and_sigma() {
        let mut rng = Pcg64::seed(4);
        let g = Graph::complete(5);
        let a = g.constraint_matrix();
        assert_eq!(a.rows, 2 * g.edges.len());
        assert_eq!(a.cols, 5);
        // For a connected graph the stacked incidence has full column rank
        let smin = a.sigma_min(200, &mut rng);
        assert!(smin > 0.1, "sigma_min {smin}");
    }
}
