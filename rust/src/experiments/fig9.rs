//! Fig. 9 — communication load vs suboptimality `|f − f*|` for distributed
//! linear regression (λ = 0, α = 1.5) and LASSO (λ = 0.1) on the
//! App. G.1 mixed-distribution data (N = 50, 50 iterations).
//!
//! Series per method: trajectory of (cumulative events, |f − f*|).

use crate::admm::{ConsensusAdmm, ConsensusConfig};
use crate::comm::Trigger;
use crate::data::regress::RegressSpec;
use crate::lasso::{LassoConfig, LassoProblem};
use crate::metrics::Recorder;
use crate::rng::Pcg64;
use crate::solver::{ExactQuadratic, IdentityProx, L1Prox, ServerProx};

#[derive(Clone, Debug)]
pub struct Fig9Config {
    pub n_agents: usize,
    pub rows_per_agent: usize,
    pub dim: usize,
    pub rounds: usize,
    pub rho: f64,
    pub alpha: f64,
    pub seed: u64,
    /// Local-solve worker threads (0 = auto; bit-identical results).
    pub workers: usize,
}

impl Default for Fig9Config {
    fn default() -> Self {
        // Tab. 5: N = 50, rho = 1, 50 iterations.
        Fig9Config {
            n_agents: 50,
            rows_per_agent: 12,
            dim: 20,
            rounds: 50,
            rho: 1.0,
            alpha: 1.0,
            seed: 0,
            workers: 0,
        }
    }
}

/// Methods compared in Fig. 9.
#[derive(Clone, Copy, Debug)]
pub enum ConvexAlgo {
    Alg1Vanilla { delta: f64 },
    Alg1Rand { delta: f64, p_trig: f64 },
    /// Random participation at rate p (FedADMM-style sampling).
    RandomSelection { p: f64 },
    Full,
}

impl ConvexAlgo {
    pub fn label(&self) -> String {
        match self {
            ConvexAlgo::Alg1Vanilla { delta } => format!("Alg.1-Vanilla(Δ={delta:.0e})"),
            ConvexAlgo::Alg1Rand { delta, p_trig } => {
                format!("Alg.1-Rand(Δ={delta:.0e},p={p_trig})")
            }
            ConvexAlgo::RandomSelection { p } => format!("Random(p={p})"),
            ConvexAlgo::Full => "Full".into(),
        }
    }

    fn triggers(&self) -> (Trigger, Trigger) {
        match *self {
            ConvexAlgo::Alg1Vanilla { delta } => {
                (Trigger::vanilla(delta), Trigger::vanilla(delta))
            }
            ConvexAlgo::Alg1Rand { delta, p_trig } => (
                Trigger::randomized(delta, p_trig),
                Trigger::randomized(delta, p_trig),
            ),
            ConvexAlgo::RandomSelection { p } => {
                (Trigger::participation(p), Trigger::participation(p))
            }
            ConvexAlgo::Full => (Trigger::Always, Trigger::Always),
        }
    }
}

/// Run one method on one problem; series: `events(round)` and
/// `subopt(round)` = f(z) − f*.
pub fn run_convex(
    prob: &LassoProblem,
    fstar: f64,
    algo: ConvexAlgo,
    cfg: &Fig9Config,
) -> Recorder {
    let mut rec = Recorder::new();
    let (td, tz) = algo.triggers();
    let engine_cfg = ConsensusConfig {
        rho: cfg.rho,
        alpha: cfg.alpha,
        rounds: cfg.rounds,
        trigger_d: td,
        trigger_z: tz,
        workers: cfg.workers,
        ..Default::default()
    };
    let mut engine: ConsensusAdmm<f64> =
        ConsensusAdmm::new(engine_cfg, prob.n_agents(), vec![0.0; prob.dim]);
    let mut solver = ExactQuadratic::new(&prob.blocks);
    let mut rng = Pcg64::seed_stream(cfg.seed, 909);
    let mut prox_l1 = L1Prox { lambda: prob.lambda };
    let mut prox_id = IdentityProx;
    for k in 0..cfg.rounds {
        let prox: &mut dyn ServerProx<f64> = if prob.lambda > 0.0 {
            &mut prox_l1
        } else {
            &mut prox_id
        };
        engine.round(&mut solver, prox, &mut rng);
        let sub = (prob.objective(&engine.z) - fstar).max(1e-16);
        let (up_bytes, down_bytes) = engine.bytes_split();
        rec.add("events", (k + 1) as f64, engine.total_events() as f64);
        rec.add("subopt", (k + 1) as f64, sub);
        rec.add("load", (k + 1) as f64, engine.comm_load());
        rec.add("up_bytes", (k + 1) as f64, up_bytes as f64);
        rec.add("down_bytes", (k + 1) as f64, down_bytes as f64);
    }
    rec
}

/// Full Fig. 9: both panels (linear regression and LASSO), all methods.
/// Returns (panel label, method label, Recorder) triples.
pub fn run(cfg: &Fig9Config) -> Vec<(String, String, Recorder)> {
    let mut out = Vec::new();
    for (panel, lambda, alpha) in
        [("linreg", 0.0, 1.5), ("lasso", 0.1, 1.0)]
    {
        let mut rng = Pcg64::seed_stream(cfg.seed, 808);
        let prob = LassoProblem::generate(
            &LassoConfig {
                spec: RegressSpec {
                    n_agents: cfg.n_agents,
                    rows_per_agent: cfg.rows_per_agent,
                    dim: cfg.dim,
                    ..Default::default()
                },
                lambda,
            },
            &mut rng,
        );
        let (_, fstar) = prob.reference_solution(&mut rng);
        let algos = [
            ConvexAlgo::Full,
            ConvexAlgo::Alg1Vanilla { delta: 1e-3 },
            ConvexAlgo::Alg1Vanilla { delta: 1e-2 },
            ConvexAlgo::Alg1Rand { delta: 1e-2, p_trig: 0.1 },
            ConvexAlgo::RandomSelection { p: 0.5 },
            ConvexAlgo::RandomSelection { p: 0.8 },
        ];
        let mut panel_cfg = cfg.clone();
        panel_cfg.alpha = alpha;
        for algo in algos {
            let rec = run_convex(&prob, fstar, algo, &panel_cfg);
            out.push((panel.to_string(), algo.label(), rec));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Fig9Config {
        Fig9Config {
            n_agents: 8,
            rows_per_agent: 8,
            dim: 6,
            rounds: 400,
            ..Default::default()
        }
    }

    fn problem(lambda: f64, cfg: &Fig9Config) -> (LassoProblem, f64) {
        let mut rng = Pcg64::seed(3);
        let prob = LassoProblem::generate(
            &LassoConfig {
                spec: RegressSpec {
                    n_agents: cfg.n_agents,
                    rows_per_agent: cfg.rows_per_agent,
                    dim: cfg.dim,
                    ..Default::default()
                },
                lambda,
            },
            &mut rng,
        );
        let (_, fstar) = prob.reference_solution(&mut rng);
        (prob, fstar)
    }

    #[test]
    fn full_comm_drives_subopt_to_zero_linreg() {
        let cfg = small_cfg();
        let (prob, fstar) = problem(0.0, &cfg);
        let rec = run_convex(&prob, fstar, ConvexAlgo::Full, &cfg);
        let first = rec.get("subopt")[0].1;
        let last = rec.last("subopt").unwrap();
        assert!(last < 1e-5 || last < 1e-4 * first, "suboptimality {last}");
    }

    #[test]
    fn full_comm_drives_subopt_to_zero_lasso() {
        let cfg = small_cfg();
        let (prob, fstar) = problem(0.1, &cfg);
        let rec = run_convex(&prob, fstar, ConvexAlgo::Full, &cfg);
        let first = rec.get("subopt")[0].1;
        let last = rec.last("subopt").unwrap();
        assert!(last < 1e-5 || last < 1e-4 * first, "suboptimality {last}");
    }

    #[test]
    fn event_based_beats_random_selection_tradeoff() {
        // The Fig. 9 headline: at comparable (or lower) communication,
        // event-based reaches lower suboptimality than random selection.
        let cfg = Fig9Config { rounds: 80, ..small_cfg() };
        let (prob, fstar) = problem(0.1, &cfg);
        let ev =
            run_convex(&prob, fstar, ConvexAlgo::Alg1Vanilla { delta: 1e-2 }, &cfg);
        let ev_events = ev.last("events").unwrap();
        let ev_sub = ev.last("subopt").unwrap();
        // match random participation to the event budget (averaged over
        // seeds to de-noise the Bernoulli sampling)
        let p = (ev_events / (2.0 * cfg.n_agents as f64 * cfg.rounds as f64))
            .clamp(0.05, 1.0);
        let mut rs_sub = 0.0;
        for seed in 0..3u64 {
            let mut c = cfg.clone();
            c.seed = seed;
            let rs = run_convex(
                &prob,
                fstar,
                ConvexAlgo::RandomSelection { p },
                &c,
            );
            rs_sub += rs.last("subopt").unwrap() / 3.0;
        }
        assert!(
            ev_sub < rs_sub,
            "event {ev_sub:.3e} !< random {rs_sub:.3e} (p={p:.2})"
        );
    }

    #[test]
    fn over_relaxation_accelerates_linreg() {
        let cfg = small_cfg();
        let (prob, fstar) = problem(0.0, &cfg);
        let mut cfg15 = cfg.clone();
        cfg15.alpha = 1.5;
        let rec1 = run_convex(&prob, fstar, ConvexAlgo::Full, &cfg);
        let rec15 = run_convex(&prob, fstar, ConvexAlgo::Full, &cfg15);
        // compare suboptimality at mid-run
        let s1 = rec1.get("subopt")[25].1;
        let s15 = rec15.get("subopt")[25].1;
        assert!(
            s15 < s1 * 2.0,
            "alpha=1.5 should not be much slower: {s15:.2e} vs {s1:.2e}"
        );
    }
}
