//! Fig. 10 — effect of communication drops and the reset period T.
//!
//! LASSO (λ = 0.1), N = 50, uplink drop rate 0.3, Δ = 10⁻³: without resets
//! (T = ∞) the error plateaus; resets restore convergence, with smaller T
//! converging faster at extra (reset) communication cost.

use crate::admm::{ConsensusAdmm, ConsensusConfig};
use crate::comm::Trigger;
use crate::data::regress::RegressSpec;
use crate::lasso::{LassoConfig, LassoProblem};
use crate::metrics::Recorder;
use crate::rng::Pcg64;
use crate::solver::{ExactQuadratic, L1Prox};

#[derive(Clone, Debug)]
pub struct Fig10Config {
    pub n_agents: usize,
    pub rows_per_agent: usize,
    pub dim: usize,
    pub rounds: usize,
    pub rho: f64,
    pub delta: f64,
    pub drop_rate: f64,
    pub lambda: f64,
    pub seed: u64,
    /// Local-solve worker threads (0 = auto; bit-identical results).
    pub workers: usize,
}

impl Default for Fig10Config {
    fn default() -> Self {
        // Tab. 6: N = 50, λ = 0.1, ρ = 1, 50 iterations, Δ = 1e-3,
        // drop rate 0.3.
        Fig10Config {
            n_agents: 50,
            rows_per_agent: 12,
            dim: 20,
            rounds: 50,
            rho: 1.0,
            delta: 1e-3,
            drop_rate: 0.3,
            lambda: 0.1,
            seed: 0,
            workers: 0,
        }
    }
}

/// Run one reset period; `reset_period = 0` is the paper's `T = ∞`.
pub fn run_reset_period(
    prob: &LassoProblem,
    fstar: f64,
    reset_period: usize,
    cfg: &Fig10Config,
) -> Recorder {
    let engine_cfg = ConsensusConfig {
        rho: cfg.rho,
        alpha: 1.0,
        rounds: cfg.rounds,
        trigger_d: Trigger::vanilla(cfg.delta),
        trigger_z: Trigger::vanilla(cfg.delta),
        drop_up: cfg.drop_rate,
        reset_period,
        workers: cfg.workers,
        ..Default::default()
    };
    let mut engine: ConsensusAdmm<f64> =
        ConsensusAdmm::new(engine_cfg, prob.n_agents(), vec![0.0; prob.dim]);
    let mut solver = ExactQuadratic::new(&prob.blocks);
    let mut prox = L1Prox { lambda: prob.lambda };
    let mut rng = Pcg64::seed_stream(cfg.seed, 1010);
    let mut rec = Recorder::new();
    for k in 0..cfg.rounds {
        engine.round(&mut solver, &mut prox, &mut rng);
        rec.add(
            "subopt",
            (k + 1) as f64,
            (prob.objective(&engine.z) - fstar).max(1e-16),
        );
        rec.add("events", (k + 1) as f64, engine.total_events() as f64);
        rec.add("zeta_err", (k + 1) as f64, engine.zeta_error());
    }
    rec
}

/// The full Fig. 10 sweep over T ∈ {1, 5, 10, ∞}.
pub fn run(cfg: &Fig10Config) -> Vec<(String, Recorder)> {
    let mut rng = Pcg64::seed_stream(cfg.seed, 1111);
    let prob = LassoProblem::generate(
        &LassoConfig {
            spec: RegressSpec {
                n_agents: cfg.n_agents,
                rows_per_agent: cfg.rows_per_agent,
                dim: cfg.dim,
                ..Default::default()
            },
            lambda: cfg.lambda,
        },
        &mut rng,
    );
    let (_, fstar) = prob.reference_solution(&mut rng);
    [(1usize, "T=1"), (5, "T=5"), (10, "T=10"), (0, "T=inf")]
        .into_iter()
        .map(|(t, label)| {
            (label.to_string(), run_reset_period(&prob, fstar, t, cfg))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Fig10Config {
        Fig10Config {
            n_agents: 10,
            rows_per_agent: 8,
            dim: 6,
            rounds: 80,
            ..Default::default()
        }
    }

    #[test]
    fn resets_beat_no_reset_under_drops() {
        let cfg = small();
        let curves = run(&cfg);
        let get = |label: &str| {
            curves
                .iter()
                .find(|(l, _)| l == label)
                .map(|(_, r)| r.last("subopt").unwrap())
                .unwrap()
        };
        let t5 = get("T=5");
        let tinf = get("T=inf");
        assert!(t5 < tinf, "T=5 {t5:.3e} !< T=inf {tinf:.3e}");
    }

    #[test]
    fn more_frequent_resets_cost_more_events() {
        let cfg = small();
        let curves = run(&cfg);
        let events = |label: &str| {
            curves
                .iter()
                .find(|(l, _)| l == label)
                .map(|(_, r)| r.last("events").unwrap())
                .unwrap()
        };
        assert!(events("T=1") > events("T=10"));
        assert!(events("T=10") >= events("T=inf"));
    }

    #[test]
    fn zeta_error_stays_bounded_with_resets() {
        // Prop. 2.1 with drops: error bounded by Δ + T·χ̄; with T small the
        // recorded ζ-error must stay well below the no-reset accumulation.
        let cfg = small();
        let mut rng = Pcg64::seed(5);
        let prob = LassoProblem::generate(
            &LassoConfig {
                spec: RegressSpec {
                    n_agents: cfg.n_agents,
                    rows_per_agent: cfg.rows_per_agent,
                    dim: cfg.dim,
                    ..Default::default()
                },
                lambda: cfg.lambda,
            },
            &mut rng,
        );
        let (_, fstar) = prob.reference_solution(&mut rng);
        let r_reset = run_reset_period(&prob, fstar, 5, &cfg);
        let r_noreset = run_reset_period(&prob, fstar, 0, &cfg);
        let max_err = |r: &Recorder| {
            r.get("zeta_err")
                .iter()
                .map(|&(_, y)| y)
                .fold(0.0f64, f64::max)
        };
        assert!(max_err(&r_reset) <= max_err(&r_noreset) + 1e-12);
    }
}
