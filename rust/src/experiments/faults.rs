//! `faults` — the accuracy vs latency / participation frontier on the
//! discrete-event sim backend (DESIGN.md §9).
//!
//! The paper's robustness claims (Fig. 9–12) cover i.i.d. packet drops
//! inside a synchronous round barrier; this sweep exercises the failure
//! modes only the simulator can reach — link latency, partial
//! participation quorums, stragglers and drops at once — and checks the
//! qualitative claim: **event-triggered ADMM degrades gracefully**,
//! converging to a matched objective while the network misbehaves.
//!
//! Two panels: the convex LASSO workload (64+ agents, exact prox
//! solves, suboptimality vs the FISTA reference) and the NN surrogate
//! (inexact SGD local solves, test accuracy).  Cells fan out across
//! `std::thread` workers via [`crate::sim::run_parallel`]; each cell is
//! an independent seeded simulation, so the sweep is deterministic on
//! any worker count.

use crate::comm::Trigger;
use crate::transport::loss::LossModel;
use crate::data::regress::RegressSpec;
use crate::lasso::{LassoConfig, LassoProblem};
use crate::metrics::Recorder;
use crate::rng::Pcg64;
use crate::sim::{
    AsyncConsensus, ComputeModel, LatencyModel, LinkModel, Scenario,
    TopologySpec,
};
use crate::sim::{default_workers, run_parallel};
use crate::solver::{ExactQuadratic, IdentityProx, L1Prox, NativeSgd};
use crate::wire::CompressorCfg;

#[derive(Clone, Debug)]
pub struct FaultsConfig {
    pub n_agents: usize,
    pub rows_per_agent: usize,
    pub dim: usize,
    /// Leader rounds per cell.
    pub rounds: usize,
    pub rho: f64,
    pub lambda: f64,
    /// Vanilla trigger threshold on the d-line (z-line uses delta/10).
    pub delta: f64,
    pub seed: u64,
    /// Mean link latency levels (seconds) — the sweep's first axis.
    pub latencies: Vec<f64>,
    /// Participation quorum levels — the sweep's second axis.
    pub participations: Vec<f64>,
    /// Bernoulli drop rate applied to every cell's links.
    pub drop_rate: f64,
    /// Mean local-solve time in seconds — an axis independent of the
    /// link latency, so latency-free cells still model compute
    /// heterogeneity (stragglers multiply this).
    pub compute_secs: f64,
    pub straggler_frac: f64,
    pub straggler_mult: f64,
    pub reset_period: usize,
    pub staleness: u64,
    /// Sweep worker threads; 0 = one per core.
    pub workers: usize,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            n_agents: 64,
            rows_per_agent: 4,
            dim: 12,
            rounds: 240,
            rho: 1.0,
            lambda: 0.1,
            delta: 1e-3,
            seed: 0,
            latencies: vec![0.0, 0.010, 0.100],
            participations: vec![1.0, 0.6, 0.3],
            drop_rate: 0.05,
            compute_secs: 0.010,
            straggler_frac: 0.25,
            straggler_mult: 10.0,
            reset_period: 20,
            staleness: 3,
            workers: 0,
        }
    }
}

/// One cell of the frontier.
#[derive(Clone, Debug)]
pub struct FaultPoint {
    pub latency: f64,
    pub participation: f64,
    pub objective: f64,
    pub subopt: f64,
    /// `(objective − f*) / |f*|`.
    pub rel_gap: f64,
    /// Virtual time the horizon took.
    pub vtime_secs: f64,
    pub leader_rounds: u64,
    pub events: u64,
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub stale_discarded: u64,
    /// Series vs leader round AND vs virtual time (`subopt_vs_vtime`).
    pub recorder: Recorder,
}

/// Build the scenario for one `(latency, participation)` cell.
fn cell_scenario(
    cfg: &FaultsConfig,
    n_agents: usize,
    rho: f64,
    latency: f64,
    participation: f64,
) -> Scenario {
    let latency_model = if latency > 0.0 {
        LatencyModel::Uniform { lo: 0.5 * latency, hi: 1.5 * latency }
    } else {
        LatencyModel::zero()
    };
    let compute_model = if cfg.compute_secs > 0.0 {
        LatencyModel::Uniform {
            lo: 0.5 * cfg.compute_secs,
            hi: 1.5 * cfg.compute_secs,
        }
    } else {
        LatencyModel::zero()
    };
    let loss = if cfg.drop_rate > 0.0 {
        LossModel::Bernoulli { p: cfg.drop_rate }
    } else {
        LossModel::None
    };
    let link = LinkModel { latency: latency_model, bandwidth: 0.0, loss };
    Scenario {
        name: format!("faults-l{latency}-q{participation}"),
        n_agents,
        rounds: cfg.rounds,
        seed: cfg.seed,
        rho,
        alpha: 1.0,
        topology: TopologySpec::Star,
        trigger_d: Trigger::vanilla(cfg.delta),
        trigger_z: Trigger::vanilla(cfg.delta * 0.1),
        compressor: CompressorCfg::Identity,
        link_up: link,
        link_down: link,
        compute: ComputeModel {
            time: compute_model,
            straggler_frac: cfg.straggler_frac,
            straggler_mult: cfg.straggler_mult,
        },
        participation,
        staleness: cfg.staleness,
        reset_period: cfg.reset_period,
        faults: Vec::new(),
    }
}

/// LASSO panel: every latency × participation cell on the same problem
/// instance, suboptimality against the centralized FISTA reference.
pub fn run(cfg: &FaultsConfig) -> Vec<FaultPoint> {
    let mut rng = Pcg64::seed_stream(cfg.seed, 4242);
    let prob = LassoProblem::generate(
        &LassoConfig {
            spec: RegressSpec {
                n_agents: cfg.n_agents,
                rows_per_agent: cfg.rows_per_agent,
                dim: cfg.dim,
                ..Default::default()
            },
            lambda: cfg.lambda,
        },
        &mut rng,
    );
    let (_, fstar) = prob.reference_solution(&mut rng);
    let cells: Vec<(f64, f64)> = cfg
        .latencies
        .iter()
        .flat_map(|&l| cfg.participations.iter().map(move |&p| (l, p)))
        .collect();
    let workers =
        if cfg.workers == 0 { default_workers() } else { cfg.workers };
    run_parallel(&cells, workers, |_, &(latency, participation)| {
        let scn =
            cell_scenario(cfg, prob.n_agents(), cfg.rho, latency, participation);
        let rounds = scn.rounds as u64;
        let mut engine =
            AsyncConsensus::<f64>::new(scn, vec![0.0; prob.dim]);
        let mut solver = ExactQuadratic::new(&prob.blocks);
        let mut prox = L1Prox { lambda: prob.lambda };
        let mut rec = Recorder::new();
        for r in 1..=rounds {
            engine.run_until(r, &mut solver, &mut prox);
            let x = r as f64;
            let subopt = (prob.objective(&engine.z) - fstar).max(1e-16);
            let (up, down) = engine.bytes_split();
            rec.add("subopt", x, subopt);
            rec.add("vtime", x, engine.now_secs());
            rec.add("subopt_vs_vtime", engine.now_secs(), subopt);
            rec.add("up_bytes", x, up as f64);
            rec.add("down_bytes", x, down as f64);
        }
        let objective = prob.objective(&engine.z);
        let subopt = (objective - fstar).max(1e-16);
        let (up_bytes, down_bytes) = engine.bytes_split();
        FaultPoint {
            latency,
            participation,
            objective,
            subopt,
            rel_gap: subopt / fstar.abs().max(1e-12),
            vtime_secs: engine.now_secs(),
            leader_rounds: engine.leader_round,
            events: engine.total_events(),
            up_bytes,
            down_bytes,
            stale_discarded: engine.stale_discarded,
            recorder: rec,
        }
    })
}

/// One point of the NN-surrogate panel.
#[derive(Clone, Debug)]
pub struct NnFaultPoint {
    pub latency: f64,
    pub participation: f64,
    pub accuracy: f64,
    pub vtime_secs: f64,
    pub leader_rounds: u64,
    pub events: u64,
    pub up_bytes: u64,
}

/// NN-surrogate panel: the same frontier with inexact SGD local solves
/// on a federated classification workload (test accuracy per cell).
pub fn run_nn(
    w: &super::nn::NnWorkload,
    cfg: &FaultsConfig,
) -> Vec<NnFaultPoint> {
    let init = w.spec.init(&mut Pcg64::seed_stream(cfg.seed, 404));
    let cells: Vec<(f64, f64)> = cfg
        .latencies
        .iter()
        .flat_map(|&l| cfg.participations.iter().map(move |&p| (l, p)))
        .collect();
    let workers =
        if cfg.workers == 0 { default_workers() } else { cfg.workers };
    run_parallel(&cells, workers, |_, &(latency, participation)| {
        let scn =
            cell_scenario(cfg, w.n_agents(), w.rho, latency, participation);
        let rounds = scn.rounds as u64;
        let mut engine = AsyncConsensus::<f32>::new(scn, init.clone());
        let mut solver = NativeSgd::new(
            w.spec.clone(),
            w.shards.clone(),
            w.lr,
            w.steps,
            w.batch,
            &init,
        );
        let mut prox = IdentityProx;
        engine.run(&mut solver, &mut prox);
        let accuracy =
            w.spec.accuracy(&engine.z, &w.test.xs, &w.test.labels);
        let (up_bytes, _) = engine.bytes_split();
        NnFaultPoint {
            latency,
            participation,
            accuracy,
            vtime_secs: engine.now_secs(),
            leader_rounds: engine.leader_round,
            events: engine.total_events(),
            up_bytes,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> FaultsConfig {
        FaultsConfig {
            // acceptance shape: >= 3 latency x >= 3 participation levels
            // at 64+ simulated agents, in test mode, under the threaded
            // sweep runner
            workers: 4,
            ..Default::default()
        }
    }

    #[test]
    fn frontier_completes_and_degrades_gracefully() {
        let cfg = test_cfg();
        let points = run(&cfg);
        assert_eq!(
            points.len(),
            cfg.latencies.len() * cfg.participations.len()
        );
        assert!(cfg.latencies.len() >= 3 && cfg.participations.len() >= 3);
        assert!(cfg.n_agents >= 64);
        // the ideal corner (zero latency, full participation) converges
        // to the matched objective
        let ideal = points
            .iter()
            .find(|p| p.latency == 0.0 && p.participation == 1.0)
            .expect("ideal cell");
        assert!(
            ideal.rel_gap < 0.05,
            "ideal cell gap {:.4} too large",
            ideal.rel_gap
        );
        // graceful degradation: every cell completes its horizon with a
        // finite, bounded objective gap — latency, quorums, stragglers
        // and drops bend the frontier, they do not break convergence
        for p in &points {
            assert_eq!(
                p.leader_rounds, cfg.rounds as u64,
                "cell (l={}, q={}) stalled",
                p.latency, p.participation
            );
            assert!(p.objective.is_finite());
            assert!(
                p.rel_gap < 0.5,
                "cell (l={}, q={}) gap {:.4} not graceful",
                p.latency,
                p.participation,
                p.rel_gap
            );
        }
        // event triggering still pays: total uplink bytes under the
        // faulted network stay below the always-send dense equivalent
        let dense =
            crate::wire::WireMessage::<f64>::dense_bytes(cfg.dim) as u64;
        let full = cfg.rounds as u64 * cfg.n_agents as u64 * dense;
        for p in &points {
            assert!(
                p.up_bytes < full,
                "cell (l={}, q={}) sent {} >= dense {}",
                p.latency,
                p.participation,
                p.up_bytes,
                full
            );
        }
        // latency + tight quorums leave stragglers behind: the staleness
        // bound must actually engage somewhere on the frontier
        let discarded: u64 = points.iter().map(|p| p.stale_discarded).sum();
        assert!(discarded > 0, "staleness bound never engaged");
        // virtual time advances in every cell (compute time alone sees
        // to that), and adding link latency can only slow a cell down
        for p in &points {
            assert!(p.vtime_secs > 0.0);
        }
    }

    #[test]
    fn recorder_carries_virtual_time_series() {
        let cfg = FaultsConfig {
            n_agents: 64,
            rounds: 30,
            latencies: vec![0.01],
            participations: vec![0.5],
            workers: 2,
            ..Default::default()
        };
        let points = run(&cfg);
        assert_eq!(points.len(), 1);
        let rec = &points[0].recorder;
        assert_eq!(rec.get("subopt").len(), 30);
        assert_eq!(rec.get("vtime").len(), 30);
        // the virtual clock is monotone
        let vt = rec.get("vtime");
        for w in vt.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert!(vt.last().unwrap().1 > 0.0);
        // subopt_vs_vtime re-keys the same series on the virtual clock
        assert_eq!(rec.get("subopt_vs_vtime").len(), 30);
    }

    #[test]
    fn nn_surrogate_panel_runs_on_the_sim_backend() {
        // tiny workload: the NN panel exercises AsyncConsensus<f32> +
        // NativeSgd end to end under latency and partial participation
        let w = super::super::nn::NnWorkload::tiny(0);
        let cfg = FaultsConfig {
            n_agents: w.n_agents(),
            rounds: 20,
            delta: 0.05,
            latencies: vec![0.0, 0.01],
            participations: vec![1.0, 0.5],
            drop_rate: 0.05,
            straggler_frac: 0.25,
            straggler_mult: 5.0,
            reset_period: 10,
            workers: 2,
            ..Default::default()
        };
        let points = run_nn(&w, &cfg);
        assert_eq!(points.len(), 4);
        for p in &points {
            assert_eq!(p.leader_rounds, 20);
            assert!(p.accuracy.is_finite());
            assert!((0.0..=1.0).contains(&p.accuracy));
            assert!(p.events > 0);
        }
    }
}
