//! Fig. 11 — distributed MNIST training over a communication graph
//! (10 agents, dense random graph), comparing vanilla event-based,
//! randomized event-based and purely random agent selection (App. G.3).
//!
//! Each agent holds a single class; only neighbor communication is allowed
//! (no server — FedAvg/SCAFFOLD etc. are not applicable here).

use crate::admm::{GraphAdmm, GraphConfig};
use crate::comm::Trigger;
use crate::data::partition::single_class_split;
use crate::data::synth::{self, SynthSpec};
use crate::metrics::Recorder;
use crate::model::MlpSpec;
use crate::rng::Pcg64;
use crate::solver::NativeSgd;
use crate::topology::Graph;

#[derive(Clone, Debug)]
pub struct Fig11Config {
    pub n_agents: usize,
    pub n_edges: usize,
    pub rounds: usize,
    pub rho: f64,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// Local-solve worker threads (0 = auto; bit-identical results).
    pub workers: usize,
}

impl Default for Fig11Config {
    fn default() -> Self {
        // Tab. 7: 10 agents, lr = 5e-3, rho = 5e-3, 5 grad steps/iter.
        // The paper's 70-edge/10-node graph exceeds the simple-graph max
        // (45); we use the densest simple graph (see DESIGN.md).
        Fig11Config {
            n_agents: 10,
            n_edges: 45,
            rounds: 300,
            rho: 5e-3,
            lr: 5e-3,
            steps: 5,
            batch: 32,
            eval_every: 10,
            seed: 0,
            workers: 0,
        }
    }
}

/// Strategies compared in Fig. 11.
#[derive(Clone, Copy, Debug)]
pub enum GraphStrategy {
    Vanilla { delta: f64 },
    Randomized { delta: f64, p_trig: f64 },
    RandomSelection { p: f64 },
    Full,
}

impl GraphStrategy {
    pub fn label(&self) -> String {
        match self {
            GraphStrategy::Vanilla { delta } => format!("Vanilla(Δ={delta})"),
            GraphStrategy::Randomized { delta, p_trig } => {
                format!("Randomized(Δ={delta},p={p_trig})")
            }
            GraphStrategy::RandomSelection { p } => format!("Random(p={p})"),
            GraphStrategy::Full => "Full".into(),
        }
    }

    fn trigger(&self) -> Trigger {
        match *self {
            GraphStrategy::Vanilla { delta } => Trigger::vanilla(delta),
            GraphStrategy::Randomized { delta, p_trig } => {
                Trigger::randomized(delta, p_trig)
            }
            GraphStrategy::RandomSelection { p } => Trigger::participation(p),
            GraphStrategy::Full => Trigger::Always,
        }
    }
}

/// Run one strategy; records mean/min/max per-agent accuracy and events.
pub fn run_strategy(
    strategy: GraphStrategy,
    cfg: &Fig11Config,
) -> Recorder {
    let mut rng = Pcg64::seed_stream(cfg.seed, 1212);
    let (train, test) = synth::generate(&SynthSpec::mnist(), &mut rng);
    let shards = single_class_split(&train, cfg.n_agents);
    let spec = MlpSpec::new(vec![64, 400, 200, 10]);
    let init = spec.init(&mut rng);
    let graph = Graph::random_connected(cfg.n_agents, cfg.n_edges, &mut rng);

    let gcfg = GraphConfig {
        rho: cfg.rho,
        rounds: cfg.rounds,
        trigger_x: strategy.trigger(),
        workers: cfg.workers,
        ..Default::default()
    };
    let mut engine: GraphAdmm<f32> = GraphAdmm::new(gcfg, graph, init.clone());
    let mut solver = NativeSgd::new(
        spec.clone(),
        shards,
        cfg.lr,
        cfg.steps,
        cfg.batch,
        &init,
    );
    let mut rec = Recorder::new();
    for k in 0..cfg.rounds {
        engine.round(&mut solver, &mut rng);
        if (k + 1) % cfg.eval_every == 0 || k + 1 == cfg.rounds {
            let accs: Vec<f64> = (0..cfg.n_agents)
                .map(|i| {
                    spec.accuracy(engine.agent_x(i), &test.xs, &test.labels)
                })
                .collect();
            let mean = accs.iter().sum::<f64>() / accs.len() as f64;
            let min = accs.iter().cloned().fold(1.0, f64::min);
            let max = accs.iter().cloned().fold(0.0, f64::max);
            rec.add("acc_mean", (k + 1) as f64, mean);
            rec.add("acc_min", (k + 1) as f64, min);
            rec.add("acc_max", (k + 1) as f64, max);
            rec.add("events", (k + 1) as f64, engine.total_events() as f64);
            rec.add("load", (k + 1) as f64, engine.comm_load());
        }
    }
    rec
}

/// Full Fig. 11: all strategies.
pub fn run(cfg: &Fig11Config) -> Vec<(String, Recorder)> {
    [
        GraphStrategy::Full,
        GraphStrategy::Vanilla { delta: 0.05 },
        GraphStrategy::Vanilla { delta: 0.1 },
        GraphStrategy::Randomized { delta: 0.1, p_trig: 0.1 },
        GraphStrategy::RandomSelection { p: 0.5 },
    ]
    .into_iter()
    .map(|s| (s.label(), run_strategy(s, cfg)))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Fig11Config {
        Fig11Config {
            n_agents: 4,
            n_edges: 5,
            rounds: 30,
            rho: 0.05,
            lr: 0.05,
            steps: 2,
            batch: 8,
            eval_every: 10,
            seed: 1,
            ..Default::default()
        }
    }

    #[test]
    fn graph_training_improves_mean_accuracy() {
        // use the tiny corpus config via a reduced spec: patch the
        // strategy runner with a small custom workload
        let cfg = tiny_cfg();
        let rec = run_strategy(GraphStrategy::Full, &cfg);
        let first = rec.get("acc_mean")[0].1;
        let last = rec.last("acc_mean").unwrap();
        assert!(last >= first - 0.05, "accuracy decayed {first} -> {last}");
        assert!(rec.last("events").unwrap() > 0.0);
    }

    #[test]
    fn event_strategy_uses_fewer_events_than_full() {
        let cfg = tiny_cfg();
        let full = run_strategy(GraphStrategy::Full, &cfg);
        let ev = run_strategy(GraphStrategy::Vanilla { delta: 0.5 }, &cfg);
        assert!(
            ev.last("events").unwrap() < full.last("events").unwrap(),
            "event {} !< full {}",
            ev.last("events").unwrap(),
            full.last("events").unwrap()
        );
    }
}
