//! Experiment harness — one module per paper table/figure (DESIGN.md §6).
//!
//! Each experiment regenerates the corresponding table rows / figure
//! series on stdout and writes CSV/JSON under `results/`.  Invoke via
//! `deluxe exp <id>` or the benches.

pub mod faults;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig9;
pub mod nn;
pub mod pareto;
pub mod rates;

pub use faults::{FaultPoint, FaultsConfig};
pub use nn::{NnExperimentConfig, NnWorkload};
pub use pareto::{ParetoConfig, ParetoPoint};
