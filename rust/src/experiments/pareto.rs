//! `pareto` — the trigger-Δ × compression Pareto frontier with
//! byte-accurate accounting (DESIGN.md §6/§7).
//!
//! The paper shows event triggering cuts communication *events* by 35%+;
//! related work (Ren et al., arXiv:2501.13516, arXiv:2508.15509) shows
//! triggering composes with compressed updates for multiplicative
//! savings.  This experiment maps the product space on two convex
//! workloads — distributed **consensus least squares** (λ = 0) and
//! **LASSO** (λ = 0.1) over the App. G.1 non-iid blocks — reporting, per
//! (Δ, compressor) cell: events, uplink/downlink bytes (from
//! [`crate::wire::WireStats`]) and final objective/suboptimality.
//!
//! Headline check (wired into the test suite): TopK 5% + 8-bit
//! quantization reaches the dense final objective within 1% while
//! sending ≥4× fewer uplink bytes on the LASSO workload.

use crate::admm::{ConsensusAdmm, ConsensusConfig};
use crate::comm::Trigger;
use crate::data::regress::RegressSpec;
use crate::lasso::{LassoConfig, LassoProblem};
use crate::metrics::Recorder;
use crate::rng::Pcg64;
use crate::solver::{ExactQuadratic, IdentityProx, L1Prox, ServerProx};
use crate::wire::CompressorCfg;

#[derive(Clone, Debug)]
pub struct ParetoConfig {
    pub n_agents: usize,
    pub rows_per_agent: usize,
    pub dim: usize,
    pub rounds: usize,
    pub rho: f64,
    pub seed: u64,
    /// Vanilla trigger thresholds swept on both lines.
    pub deltas: Vec<f64>,
    /// Compressors swept against each threshold.
    pub compressors: Vec<CompressorCfg>,
    /// Local-solve worker threads (0 = auto; bit-identical results).
    pub workers: usize,
}

impl Default for ParetoConfig {
    fn default() -> Self {
        ParetoConfig {
            n_agents: 20,
            rows_per_agent: 30,
            dim: 50,
            rounds: 400,
            rho: 1.0,
            seed: 0,
            deltas: vec![1e-4, 1e-3, 1e-2],
            compressors: vec![
                CompressorCfg::Identity,
                CompressorCfg::TopK { frac: 0.05 },
                CompressorCfg::Quant { bits: 8 },
                CompressorCfg::TopKQuant { frac: 0.05, bits: 8 },
            ],
            workers: 0,
        }
    }
}

/// One cell of the frontier.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub panel: String,
    pub delta: f64,
    pub compressor: String,
    pub events: u64,
    pub up_bytes: u64,
    pub down_bytes: u64,
    /// Final global objective `f(z)`.
    pub objective: f64,
    /// `f(z) − f*` (clamped at 1e-16).
    pub subopt: f64,
    /// Per-round series (events, up_bytes, down_bytes, subopt).
    pub recorder: Recorder,
}

/// Run one (problem, Δ, compressor) cell.
pub fn run_point(
    prob: &LassoProblem,
    fstar: f64,
    panel: &str,
    delta: f64,
    compressor: CompressorCfg,
    cfg: &ParetoConfig,
) -> ParetoPoint {
    let engine_cfg = ConsensusConfig {
        rho: cfg.rho,
        alpha: 1.0,
        rounds: cfg.rounds,
        trigger_d: Trigger::vanilla(delta),
        trigger_z: Trigger::vanilla(delta * 0.1),
        compressor,
        workers: cfg.workers,
        ..Default::default()
    };
    let mut engine: ConsensusAdmm<f64> =
        ConsensusAdmm::new(engine_cfg, prob.n_agents(), vec![0.0; prob.dim]);
    let mut solver = ExactQuadratic::new(&prob.blocks);
    let mut prox_l1 = L1Prox { lambda: prob.lambda };
    let mut prox_id = IdentityProx;
    let mut rng = Pcg64::seed_stream(cfg.seed, 2424);
    let mut rec = Recorder::new();
    for k in 0..cfg.rounds {
        let prox: &mut dyn ServerProx<f64> = if prob.lambda > 0.0 {
            &mut prox_l1
        } else {
            &mut prox_id
        };
        engine.round(&mut solver, prox, &mut rng);
        let (up, down) = engine.bytes_split();
        let x = (k + 1) as f64;
        rec.add("events", x, engine.total_events() as f64);
        rec.add("up_bytes", x, up as f64);
        rec.add("down_bytes", x, down as f64);
        rec.add(
            "subopt",
            x,
            (prob.objective(&engine.z) - fstar).max(1e-16),
        );
    }
    let (up_bytes, down_bytes) = engine.bytes_split();
    let objective = prob.objective(&engine.z);
    ParetoPoint {
        panel: panel.to_string(),
        delta,
        compressor: compressor.label(),
        events: engine.total_events(),
        up_bytes,
        down_bytes,
        objective,
        subopt: (objective - fstar).max(1e-16),
        recorder: rec,
    }
}

/// Full sweep: both panels × all (Δ, compressor) cells.
pub fn run(cfg: &ParetoConfig) -> Vec<ParetoPoint> {
    let mut out = Vec::new();
    for (panel, lambda) in [("consensus", 0.0), ("lasso", 0.1)] {
        let mut rng = Pcg64::seed_stream(cfg.seed, 2323);
        let prob = LassoProblem::generate(
            &LassoConfig {
                spec: RegressSpec {
                    n_agents: cfg.n_agents,
                    rows_per_agent: cfg.rows_per_agent,
                    dim: cfg.dim,
                    ..Default::default()
                },
                lambda,
            },
            &mut rng,
        );
        let (_, fstar) = prob.reference_solution(&mut rng);
        for &delta in &cfg.deltas {
            for &comp in &cfg.compressors {
                out.push(run_point(&prob, fstar, panel, delta, comp, cfg));
            }
        }
    }
    out
}

/// Compare a compressed cell against the dense (identity) cell at the
/// same `(panel, Δ)`: returns `(uplink_byte_reduction_factor,
/// relative_objective_gap)` — the two numbers of the acceptance claim.
pub fn uplink_reduction(
    points: &[ParetoPoint],
    panel: &str,
    delta: f64,
    compressor_label: &str,
) -> Option<(f64, f64)> {
    let find = |label: &str| {
        points.iter().find(|p| {
            p.panel == panel
                && (p.delta - delta).abs() < 1e-15
                && p.compressor == label
        })
    };
    let dense = find(&CompressorCfg::Identity.label())?;
    let comp = find(compressor_label)?;
    let ratio = dense.up_bytes as f64 / comp.up_bytes.max(1) as f64;
    let rel_gap = (comp.objective - dense.objective).abs()
        / dense.objective.abs().max(1e-12);
    Some((ratio, rel_gap))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> ParetoConfig {
        ParetoConfig {
            n_agents: 12,
            rows_per_agent: 20,
            dim: 40,
            rounds: 400,
            deltas: vec![1e-4],
            compressors: vec![
                CompressorCfg::Identity,
                CompressorCfg::TopKQuant { frac: 0.05, bits: 8 },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn topkq_cuts_uplink_bytes_4x_at_matched_objective_on_lasso() {
        // The acceptance claim: TopK 5% + 8-bit quantization vs dense on
        // the lasso workload — >= 4x uplink-byte reduction with the final
        // objective within 1%, bytes counted by WireStats.
        let cfg = fast_cfg();
        let pts = run(&cfg);
        let label = CompressorCfg::TopKQuant { frac: 0.05, bits: 8 }.label();
        let (ratio, rel_gap) =
            uplink_reduction(&pts, "lasso", 1e-4, &label).expect("cells");
        assert!(
            ratio >= 4.0,
            "uplink byte reduction {ratio:.2}x < 4x (lasso, topkq 5%/8b)"
        );
        assert!(
            rel_gap <= 0.01,
            "objective gap {:.4}% > 1%",
            rel_gap * 100.0
        );
    }

    #[test]
    fn compression_also_pays_off_on_the_consensus_panel() {
        let cfg = fast_cfg();
        let pts = run(&cfg);
        let label = CompressorCfg::TopKQuant { frac: 0.05, bits: 8 }.label();
        let (ratio, rel_gap) =
            uplink_reduction(&pts, "consensus", 1e-4, &label).expect("cells");
        assert!(ratio >= 2.0, "consensus reduction {ratio:.2}x < 2x");
        assert!(rel_gap <= 0.05, "consensus gap {:.4}", rel_gap);
    }

    #[test]
    fn recorder_carries_bytes_series() {
        let cfg = ParetoConfig {
            n_agents: 6,
            rows_per_agent: 10,
            dim: 10,
            rounds: 30,
            deltas: vec![1e-3],
            compressors: vec![CompressorCfg::Identity],
            ..Default::default()
        };
        let pts = run(&cfg);
        assert_eq!(pts.len(), 2); // two panels x 1 x 1
        for p in &pts {
            assert_eq!(p.recorder.get("up_bytes").len(), 30);
            assert_eq!(p.recorder.last("up_bytes"), Some(p.up_bytes as f64));
            assert!(p.recorder.last("subopt").is_some());
            // monotone byte counters
            let ub = p.recorder.get("up_bytes");
            for w in ub.windows(2) {
                assert!(w[1].1 >= w[0].1);
            }
        }
    }
}
