//! Convergence-rate validation (Cor. 2.2 / Thm. 4.1).
//!
//! On strongly convex quadratic consensus instances with known `(m, L, κ)`
//! we measure the empirical linear rate and the Δ-induced error floor and
//! compare against the paper's symbolic bounds:
//!
//! * rate ≤ `1 − α/(4 κ^{ε+1/2})` (accelerated: scales with `1/√κ`),
//! * floor `|ξ_k − ξ*| = O(κ Δ)` for `ε = 0, α = 1`.

use crate::admm::{GeneralAdmm, GeneralConfig, QuadraticF, ZProx};
use crate::linalg::Matrix;
use crate::metrics::Recorder;
use crate::rng::{Pcg64, Rng};

#[derive(Clone, Debug)]
pub struct RatesConfig {
    pub dim: usize,
    pub rows: usize,
    pub rounds: usize,
    pub seed: u64,
    /// Worker-pool knob threaded for CLI uniformity (Alg. 2 itself has
    /// no per-agent solve phase).
    pub workers: usize,
}

impl Default for RatesConfig {
    fn default() -> Self {
        RatesConfig { dim: 8, rows: 60, rounds: 400, seed: 0, workers: 0 }
    }
}

pub struct RateResult {
    pub kappa: f64,
    pub measured_rate: f64,
    pub bound_rate: f64,
    pub delta: f64,
    pub floor: f64,
    pub floor_bound: f64,
    pub recorder: Recorder,
}

/// Build a strongly-convex least-squares consensus instance and run Alg. 2
/// with step-size ρ = √(mL) (ε = 0), measuring rate and floor.
pub fn measure(delta: f64, alpha: f64, cfg: &RatesConfig) -> RateResult {
    let mut rng = Pcg64::seed_stream(cfg.seed, 1515);
    let d = Matrix::randn(cfg.rows, cfg.dim, &mut rng);
    let xtrue: Vec<f64> = (0..cfg.dim).map(|_| rng.normal()).collect();
    let b = d.matvec(&xtrue);
    let f = QuadraticF::least_squares(&d, &b);

    let l = d.sigma_max(300, &mut rng).powi(2);
    let m = d.sigma_min(300, &mut rng).powi(2);
    let kappa = l / m;
    let rho = (m * l).sqrt();

    let mut gcfg = GeneralConfig {
        rho,
        alpha,
        rounds: cfg.rounds,
        workers: cfg.workers.max(1),
        ..Default::default()
    };
    if delta > 0.0 {
        gcfg = gcfg.with_uniform_delta(delta);
    }
    let mut eng = GeneralAdmm::new(
        gcfg,
        Matrix::eye(cfg.dim),
        vec![0.0; cfg.dim],
        f,
        ZProx::diag(-1.0, 0.0),
        vec![0.0; cfg.dim],
        vec![0.0; cfg.dim],
    );
    // ξ* = (s*, u*) = (−x*, 0) for the consensus instance with g = 0.
    let s_star: Vec<f64> = xtrue.iter().map(|v| -v).collect();
    let u_star = vec![0.0; cfg.dim];
    let e0 = eng.xi_dist(&s_star, &u_star);
    let mut rec = Recorder::new();
    let mut errs = Vec::with_capacity(cfg.rounds);
    for k in 0..cfg.rounds {
        eng.round(&mut rng);
        let e = eng.xi_dist(&s_star, &u_star);
        errs.push(e);
        rec.add("xi_err", (k + 1) as f64, e.max(1e-18));
    }
    // empirical linear-phase rate: fit over rounds where err > 10x floor
    let floor = errs[cfg.rounds / 2..]
        .iter()
        .cloned()
        .fold(f64::INFINITY, f64::min)
        .max(1e-16);
    let lin_end = errs
        .iter()
        .position(|&e| e < 100.0 * floor)
        .unwrap_or(errs.len() - 1)
        .max(5);
    let measured_rate = (errs[lin_end - 1] / e0).powf(1.0 / lin_end as f64);
    let bound_rate = 1.0 - alpha / (4.0 * kappa.sqrt());
    // Cor 2.2 floor bound (ε = 0): |ξ| ≤ 8 κ Δ_total; our six lines give
    // Δ_total = 6 Δ.
    let floor_bound = 8.0 * kappa * 6.0 * delta;
    RateResult {
        kappa,
        measured_rate,
        bound_rate,
        delta,
        floor,
        floor_bound,
        recorder: rec,
    }
}

/// Sweep Δ to expose the floor ∝ κΔ trend (returns one result per Δ).
pub fn sweep_deltas(cfg: &RatesConfig) -> Vec<RateResult> {
    [0.0, 1e-6, 1e-5, 1e-4, 1e-3]
        .into_iter()
        .map(|d| measure(d, 1.0, cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_rate_beats_thm41_bound() {
        let cfg = RatesConfig::default();
        let res = measure(0.0, 1.0, &cfg);
        assert!(
            res.measured_rate <= res.bound_rate + 0.02,
            "measured {} vs bound {} (kappa {})",
            res.measured_rate,
            res.bound_rate,
            res.kappa
        );
        assert!(res.measured_rate < 1.0);
    }

    #[test]
    fn floor_scales_with_delta_and_respects_bound() {
        let cfg = RatesConfig { rounds: 600, ..Default::default() };
        let results = sweep_deltas(&cfg);
        // floors should be (weakly) increasing in Delta
        for w in results.windows(2) {
            assert!(
                w[0].floor <= w[1].floor * 10.0 + 1e-12,
                "floor not monotone: {} then {}",
                w[0].floor,
                w[1].floor
            );
        }
        // and every floor must satisfy the Cor 2.2 bound
        for r in &results[1..] {
            assert!(
                r.floor <= r.floor_bound,
                "floor {} > bound {} at delta {}",
                r.floor,
                r.floor_bound,
                r.delta
            );
        }
    }

    #[test]
    fn over_relaxation_within_thm41_window_converges() {
        let cfg = RatesConfig { rounds: 300, ..Default::default() };
        for alpha in [0.7, 1.0, 1.5, 1.9] {
            let res = measure(0.0, alpha, &cfg);
            assert!(
                res.measured_rate < 1.0,
                "alpha {alpha}: rate {}",
                res.measured_rate
            );
        }
    }
}

/// App. F (Cor. F.1/F.2) — diminishing thresholds give *exact* convergence.
#[cfg(test)]
mod appf_tests {
    use crate::admm::{ConsensusAdmm, ConsensusConfig};
    use crate::comm::Trigger;
    use crate::rng::Pcg64;
    use crate::solver::{IdentityProx, LocalSolver};

    struct Quad {
        w: Vec<f64>,
        c: Vec<f64>,
    }
    impl LocalSolver<f64> for Quad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _r: &mut Pcg64,
        ) -> Vec<f64> {
            vec![
                (self.w[agent] * self.c[agent] + rho * anchor[0])
                    / (self.w[agent] + rho),
            ]
        }
        fn dim(&self) -> usize {
            1
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    fn run(trigger: Trigger, rounds: usize) -> f64 {
        let w = vec![1.0, 2.0, 0.5, 3.0];
        let c = vec![-1.0, 4.0, 10.0, 0.5];
        let opt = w.iter().zip(&c).map(|(a, b)| a * b).sum::<f64>()
            / w.iter().sum::<f64>();
        let mut solver = Quad { w, c };
        let cfg = ConsensusConfig {
            rounds,
            trigger_d: trigger,
            trigger_z: trigger,
            ..Default::default()
        };
        let mut eng = ConsensusAdmm::new(cfg, 4, vec![0.0]);
        let mut prox = IdentityProx;
        let mut rng = Pcg64::seed(33);
        for _ in 0..rounds {
            eng.round(&mut solver, &mut prox, &mut rng);
        }
        (eng.z[0] - opt).abs()
    }

    #[test]
    fn decaying_threshold_converges_exactly_unlike_fixed() {
        // fixed Δ leaves a floor; Δ_k = Δ0/(k+1)² drives the error to ~0
        // (Cor. F.1) while still saving early communication.
        let err_fixed = run(Trigger::vanilla(0.05), 800);
        let err_decay = run(Trigger::decaying(0.05, 2.0), 800);
        assert!(err_decay < 1e-6, "decaying err {err_decay}");
        assert!(err_decay < err_fixed, "{err_decay} !< {err_fixed}");
    }

    #[test]
    fn faster_decay_converges_faster() {
        // Cor. F.2: error = O(1/k^t) — larger t, smaller error at fixed k.
        let e1 = run(Trigger::decaying(0.5, 1.0), 300);
        let e3 = run(Trigger::decaying(0.5, 3.0), 300);
        assert!(e3 <= e1 + 1e-12, "t=3 err {e3} !<= t=1 err {e1}");
    }
}
