//! Fig. 12 — distributed linear regression over a large agent network
//! (paper: 50 agents / 1762 edges, ρ = 10⁻⁵, Δˣ ∈ [0, 1]).
//!
//! Each agent holds one least-squares block of the App. G.1 data; the
//! decentralized graph engine (Eq. 7) runs with the different
//! communication strategies and we record the comm-load vs suboptimality
//! trade-off.

use crate::admm::{GraphAdmm, GraphConfig};
use crate::data::regress::RegressSpec;
use crate::experiments::fig11::GraphStrategy;
use crate::lasso::{LassoConfig, LassoProblem};
use crate::metrics::Recorder;
use crate::rng::Pcg64;
use crate::solver::ExactQuadratic;
use crate::topology::Graph;

#[derive(Clone, Debug)]
pub struct Fig12Config {
    pub n_agents: usize,
    pub n_edges: usize,
    pub rows_per_agent: usize,
    pub dim: usize,
    pub rounds: usize,
    pub rho: f64,
    pub seed: u64,
    /// Local-solve worker threads (0 = auto; bit-identical results).
    pub workers: usize,
}

impl Default for Fig12Config {
    fn default() -> Self {
        // Tab. 8: N = 50, rho = 1e-5, 17k iterations. The paper's 1762
        // edges exceed the simple-graph max (1225); we use 1100 (dense).
        // Default rounds scaled to 2000 for tractability; --rounds 17000
        // reproduces the paper's horizon.
        Fig12Config {
            n_agents: 50,
            n_edges: 1100,
            rows_per_agent: 12,
            dim: 20,
            rounds: 2000,
            rho: 1e-5,
            seed: 0,
            workers: 0,
        }
    }
}

/// Run one strategy; series: events, suboptimality of the network mean.
pub fn run_strategy(
    prob: &LassoProblem,
    fstar: f64,
    graph: &Graph,
    strategy: GraphStrategy,
    cfg: &Fig12Config,
) -> Recorder {
    let trigger = match strategy {
        GraphStrategy::Vanilla { delta } => crate::comm::Trigger::vanilla(delta),
        GraphStrategy::Randomized { delta, p_trig } => {
            crate::comm::Trigger::randomized(delta, p_trig)
        }
        GraphStrategy::RandomSelection { p } => {
            crate::comm::Trigger::participation(p)
        }
        GraphStrategy::Full => crate::comm::Trigger::Always,
    };
    let gcfg = GraphConfig {
        rho: cfg.rho,
        rounds: cfg.rounds,
        trigger_x: trigger,
        workers: cfg.workers,
        ..Default::default()
    };
    let mut engine: GraphAdmm<f64> =
        GraphAdmm::new(gcfg, graph.clone(), vec![0.0; prob.dim]);
    let mut solver = ExactQuadratic::new(&prob.blocks);
    let mut rng = Pcg64::seed_stream(cfg.seed, 1313);
    let mut rec = Recorder::new();
    let eval_every = (cfg.rounds / 100).max(1);
    for k in 0..cfg.rounds {
        engine.round(&mut solver, &mut rng);
        if (k + 1) % eval_every == 0 || k + 1 == cfg.rounds {
            let sub = (prob.objective(&engine.mean_x()) - fstar).max(1e-16);
            rec.add("subopt", (k + 1) as f64, sub);
            rec.add("events", (k + 1) as f64, engine.total_events() as f64);
            rec.add("disagreement", (k + 1) as f64, engine.disagreement());
        }
    }
    rec
}

/// Full Fig. 12 comparison.
pub fn run(cfg: &Fig12Config) -> Vec<(String, Recorder)> {
    let mut rng = Pcg64::seed_stream(cfg.seed, 1414);
    let prob = LassoProblem::generate(
        &LassoConfig {
            spec: RegressSpec {
                n_agents: cfg.n_agents,
                rows_per_agent: cfg.rows_per_agent,
                dim: cfg.dim,
                ..Default::default()
            },
            lambda: 0.0,
        },
        &mut rng,
    );
    let (_, fstar) = prob.reference_solution(&mut rng);
    let graph = Graph::random_connected(cfg.n_agents, cfg.n_edges, &mut rng);
    [
        GraphStrategy::Full,
        GraphStrategy::Vanilla { delta: 0.01 },
        GraphStrategy::Vanilla { delta: 0.1 },
        GraphStrategy::Randomized { delta: 0.1, p_trig: 0.1 },
        GraphStrategy::RandomSelection { p: 0.5 },
    ]
    .into_iter()
    .map(|s| (s.label(), run_strategy(&prob, fstar, &graph, s, cfg)))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (Fig12Config, LassoProblem, f64, Graph) {
        let cfg = Fig12Config {
            n_agents: 6,
            n_edges: 9,
            rows_per_agent: 10,
            dim: 5,
            rounds: 800,
            rho: 0.05,
            seed: 1,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(2);
        let prob = LassoProblem::generate(
            &LassoConfig {
                spec: RegressSpec {
                    n_agents: cfg.n_agents,
                    rows_per_agent: cfg.rows_per_agent,
                    dim: cfg.dim,
                    ..Default::default()
                },
                lambda: 0.0,
            },
            &mut rng,
        );
        let (_, fstar) = prob.reference_solution(&mut rng);
        let graph =
            Graph::random_connected(cfg.n_agents, cfg.n_edges, &mut rng);
        (cfg, prob, fstar, graph)
    }

    #[test]
    fn full_comm_converges_decentralized() {
        let (cfg, prob, fstar, graph) = small();
        let rec =
            run_strategy(&prob, fstar, &graph, GraphStrategy::Full, &cfg);
        let last = rec.last("subopt").unwrap();
        let first = rec.get("subopt")[0].1;
        assert!(last < 0.05 * first, "subopt {first:.3e} -> {last:.3e}");
        assert!(rec.last("disagreement").unwrap() < 0.1);
    }

    #[test]
    fn event_based_saves_events_at_similar_accuracy() {
        let (cfg, prob, fstar, graph) = small();
        let full =
            run_strategy(&prob, fstar, &graph, GraphStrategy::Full, &cfg);
        let ev = run_strategy(
            &prob,
            fstar,
            &graph,
            GraphStrategy::Vanilla { delta: 1e-3 },
            &cfg,
        );
        assert!(
            ev.last("events").unwrap() < full.last("events").unwrap(),
            "event {} !< full {}",
            ev.last("events").unwrap(),
            full.last("events").unwrap()
        );
        // within an order of magnitude of full-comm accuracy
        assert!(ev.last("subopt").unwrap() < 100.0 * full.last("subopt").unwrap() + 1e-2);
    }
}
