//! Shared neural-network workload harness: Tab. 1, Fig. 3 and Fig. 8.
//!
//! Builds the MNIST-surrogate (N = 10, one class per agent — the paper's
//! most extreme non-iid split) and CIFAR-surrogate (Dirichlet(0.5))
//! federated workloads, runs any of the six algorithms under an identical
//! local-compute budget, and records per-round validation accuracy and
//! cumulative communication events.

use crate::admm::{ConsensusAdmm, ConsensusConfig};
use crate::baselines::{AvgFamily, NativeFed, Scaffold};
use crate::comm::Trigger;
use crate::data::partition::{dirichlet_split, single_class_split};
use crate::data::synth::{self, ClassDataset, SynthSpec};
use crate::metrics::Recorder;
use crate::model::MlpSpec;
use crate::rng::Pcg64;
use crate::runtime::{PjrtRuntime, PjrtSgd, Variant};
use crate::solver::{IdentityProx, NativeSgd};

/// A federated classification workload.
pub struct NnWorkload {
    pub name: String,
    pub spec: MlpSpec,
    pub shards: Vec<ClassDataset>,
    pub test: ClassDataset,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    pub rho: f64,
    /// Artifact config name for the PJRT backend.
    pub artifact_config: String,
}

impl NnWorkload {
    /// MNIST setup (Sec. 5 / Tab. 3): N = 10 agents, each holding a single
    /// class; MLP [400, 200, 10]; 5 SGD steps, lr = 0.1, ρ = 1.
    pub fn mnist(seed: u64) -> NnWorkload {
        let mut rng = Pcg64::seed_stream(seed, 101);
        let (train, test) = synth::generate(&SynthSpec::mnist(), &mut rng);
        let shards = single_class_split(&train, 10);
        NnWorkload {
            name: "mnist".into(),
            spec: MlpSpec::new(vec![64, 400, 200, 10]),
            shards,
            test,
            lr: 0.1,
            steps: 5,
            batch: 64,
            // The paper uses rho = 1 on real MNIST; the surrogate's local
            // landscapes need a stronger proximal pull with only 5 inexact
            // SGD steps (calibration log in EXPERIMENTS.md).
            rho: 5.0,
            artifact_config: "mnist".into(),
        }
    }

    /// CIFAR setup (Tab. 4): Dirichlet(0.5) split, lr = 0.01, ρ = 0.01,
    /// batch 20.  `n_agents` defaults to 20 (paper: 100; scale with
    /// `--agents 100` for the full run).
    pub fn cifar(seed: u64, n_agents: usize) -> NnWorkload {
        let mut rng = Pcg64::seed_stream(seed, 202);
        let (train, test) = synth::generate(&SynthSpec::cifar(), &mut rng);
        let shards = dirichlet_split(&train, n_agents, 0.5, &mut rng);
        NnWorkload {
            name: "cifar".into(),
            spec: MlpSpec::new(vec![192, 512, 256, 10]),
            shards,
            test,
            lr: 0.05,
            steps: 6,
            batch: 20,
            // paper: rho = 0.01, lr = 0.01 on the real CNN; calibrated to
            // the surrogate MLP (see EXPERIMENTS.md)
            rho: 5.0,
            artifact_config: "cifar".into(),
        }
    }

    /// Tiny workload for tests/benches (matches the `tiny` artifacts).
    pub fn tiny(seed: u64) -> NnWorkload {
        let mut rng = Pcg64::seed_stream(seed, 303);
        let (train, test) = synth::generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        NnWorkload {
            name: "tiny".into(),
            spec: MlpSpec::new(vec![8, 16, 4]),
            shards,
            test,
            lr: 0.1,
            steps: 2,
            batch: 4,
            rho: 1.0,
            artifact_config: "tiny".into(),
        }
    }

    pub fn n_agents(&self) -> usize {
        self.shards.len()
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        self.spec.init(&mut Pcg64::seed_stream(seed, 404))
    }

    fn accuracy(&self, params: &[f32]) -> f64 {
        self.spec.accuracy(params, &self.test.xs, &self.test.labels)
    }
}

/// The six algorithms of Sec. 5.
#[derive(Clone, Copy, Debug)]
pub enum Algo {
    /// Alg. 1, vanilla event-based (Δᵈ, Δᶻ).
    Alg1Vanilla { delta_d: f64, delta_z: f64 },
    /// Alg. 1, randomized event-based.
    Alg1Rand { delta_d: f64, delta_z: f64, p_trig: f64 },
    FedAvg { part: f64 },
    FedProx { part: f64, mu: f64 },
    Scaffold { part: f64 },
    FedAdmm { part: f64 },
}

impl Algo {
    pub fn label(&self) -> String {
        match self {
            Algo::Alg1Vanilla { delta_d, .. } => {
                format!("Alg.1-Vanilla(d={delta_d})")
            }
            Algo::Alg1Rand { delta_d, p_trig, .. } => {
                format!("Alg.1-Rand(d={delta_d},p={p_trig})")
            }
            Algo::FedAvg { part } => format!("FedAvg(p={part})"),
            Algo::FedProx { part, mu } => format!("FedProx(p={part},mu={mu})"),
            Algo::Scaffold { part } => format!("SCAFFOLD(p={part})"),
            Algo::FedAdmm { part } => format!("FedADMM(p={part})"),
        }
    }
}

/// Compute backend for the local steps.
pub enum Backend<'a> {
    /// Pure-Rust MLP (fast; differential twin of the artifacts).
    Native,
    /// The production path: AOT JAX/Pallas artifacts through PJRT.
    Pjrt(&'a PjrtRuntime, Variant),
}

/// Run-one-algorithm configuration.
pub struct NnExperimentConfig {
    pub rounds: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// Local-solve worker threads (0 = auto; bit-identical results).
    /// The PJRT backend keeps its sequential `solve_batch` default (the
    /// runtime is single-threaded by design), so the knob only shards
    /// the native backend.
    pub workers: usize,
}

impl Default for NnExperimentConfig {
    fn default() -> Self {
        NnExperimentConfig { rounds: 100, eval_every: 2, seed: 0, workers: 0 }
    }
}

/// Run an algorithm on a workload; returns a [`Recorder`] with series
/// `accuracy(round)`, `events(round)` (cumulative) and `load(round)`.
pub fn run_algo(
    w: &NnWorkload,
    algo: Algo,
    cfg: &NnExperimentConfig,
    backend: &Backend,
) -> Recorder {
    let mut rec = Recorder::new();
    let mut rng = Pcg64::seed_stream(cfg.seed, 777);
    let init = w.init_params(cfg.seed);
    let n = w.n_agents();

    // assemble the event-trigger configuration for the ADMM family
    let admm_cfg = |trigger_d: Trigger, trigger_z: Trigger| ConsensusConfig {
        rho: w.rho,
        alpha: 1.0,
        rounds: cfg.rounds,
        trigger_d,
        trigger_z,
        workers: cfg.workers,
        ..Default::default()
    };

    let record = |rec: &mut Recorder, round: usize, acc: f64, events: u64| {
        rec.add("accuracy", round as f64, acc);
        rec.add("events", round as f64, events as f64);
        rec.add(
            "load",
            round as f64,
            events as f64 / (2.0 * n as f64 * (round.max(1)) as f64),
        );
    };

    match algo {
        Algo::Alg1Vanilla { .. } | Algo::Alg1Rand { .. } | Algo::FedAdmm { .. } => {
            let (td, tz) = match algo {
                Algo::Alg1Vanilla { delta_d, delta_z } => {
                    (Trigger::vanilla(delta_d), Trigger::vanilla(delta_z))
                }
                Algo::Alg1Rand { delta_d, delta_z, p_trig } => (
                    Trigger::randomized(delta_d, p_trig),
                    Trigger::randomized(delta_z, p_trig),
                ),
                Algo::FedAdmm { part } => (
                    Trigger::participation(part),
                    Trigger::participation(part),
                ),
                // lint:allow(panic-in-library): the outer match arm already restricted algo to these three variants
                _ => unreachable!(),
            };
            // FedADMM is Alg. 1 with participation triggers (see
            // baselines::fedadmm) — all three share this engine.
            let mut engine: ConsensusAdmm<f32> =
                ConsensusAdmm::new(admm_cfg(td, tz), n, init.clone());
            let mut prox = IdentityProx;
            match backend {
                Backend::Native => {
                    let mut solver = NativeSgd::new(
                        w.spec.clone(),
                        w.shards.clone(),
                        w.lr,
                        w.steps,
                        w.batch,
                        &init,
                    );
                    for k in 0..cfg.rounds {
                        engine.round(&mut solver, &mut prox, &mut rng);
                        if (k + 1) % cfg.eval_every == 0 || k + 1 == cfg.rounds {
                            record(
                                &mut rec,
                                k + 1,
                                w.accuracy(&engine.z),
                                engine.total_events(),
                            );
                        }
                    }
                }
                Backend::Pjrt(rt, variant) => {
                    let mut solver = PjrtSgd::new(
                        rt,
                        &w.artifact_config,
                        *variant,
                        w.shards.clone(),
                        w.lr,
                        &init,
                    )
                    // lint:allow(panic-in-library): a PJRT solver that fails to build means the artifact set is broken; aborting the experiment is intended
                    .expect("pjrt solver");
                    for k in 0..cfg.rounds {
                        engine.round(&mut solver, &mut prox, &mut rng);
                        if (k + 1) % cfg.eval_every == 0 || k + 1 == cfg.rounds {
                            record(
                                &mut rec,
                                k + 1,
                                w.accuracy(&engine.z),
                                engine.total_events(),
                            );
                        }
                    }
                }
            }
        }
        Algo::FedAvg { part } | Algo::FedProx { part, .. } => {
            let mu = match algo {
                Algo::FedProx { mu, .. } => mu,
                _ => 0.0,
            };
            let mut eng = if mu > 0.0 {
                AvgFamily::fedprox(init.clone(), part, mu)
            } else {
                AvgFamily::fedavg(init.clone(), part)
            }
            .with_workers(cfg.workers);
            run_fed(&mut rec, w, backend, cfg, &mut rng, |local, rng| {
                eng.round(local, rng);
                (eng.z.clone(), eng.events)
            });
        }
        Algo::Scaffold { part } => {
            let mut eng = Scaffold::new(init.clone(), n, part)
                .with_workers(cfg.workers);
            run_fed(&mut rec, w, backend, cfg, &mut rng, |local, rng| {
                eng.round(local, rng);
                (eng.z.clone(), eng.events)
            });
        }
    }
    rec
}

/// Shared driver for the averaging-family baselines.
fn run_fed(
    rec: &mut Recorder,
    w: &NnWorkload,
    backend: &Backend,
    cfg: &NnExperimentConfig,
    rng: &mut Pcg64,
    mut step: impl FnMut(&mut dyn crate::baselines::FedLocal, &mut Pcg64) -> (Vec<f32>, u64),
) {
    let n = w.n_agents();
    let record = |rec: &mut Recorder, round: usize, acc: f64, events: u64| {
        rec.add("accuracy", round as f64, acc);
        rec.add("events", round as f64, events as f64);
        rec.add(
            "load",
            round as f64,
            events as f64 / (2.0 * n as f64 * round.max(1) as f64),
        );
    };
    match backend {
        Backend::Native => {
            let mut local = NativeFed::new(
                w.spec.clone(),
                w.shards.clone(),
                w.lr,
                w.steps,
                w.batch,
            );
            for k in 0..cfg.rounds {
                let (z, events) = step(&mut local, rng);
                if (k + 1) % cfg.eval_every == 0 || k + 1 == cfg.rounds {
                    record(rec, k + 1, w.accuracy(&z), events);
                }
            }
        }
        Backend::Pjrt(rt, variant) => {
            let mut local = crate::runtime::PjrtFed {
                rt,
                config: w.artifact_config.clone(),
                variant: *variant,
                shards: w.shards.clone(),
                lr: w.lr,
            };
            for k in 0..cfg.rounds {
                let (z, events) = step(&mut local, rng);
                if (k + 1) % cfg.eval_every == 0 || k + 1 == cfg.rounds {
                    record(rec, k + 1, w.accuracy(&z), events);
                }
            }
        }
    }
}

/// The Tab. 1 harness: events-to-target-accuracy for every algorithm.
/// Returns (algorithm label, per-target Option<events>) rows.
pub fn events_to_targets(
    w: &NnWorkload,
    algos: &[Algo],
    targets: &[f64],
    cfg: &NnExperimentConfig,
    backend: &Backend,
) -> Vec<(String, Vec<Option<f64>>)> {
    let mut rows = Vec::new();
    for algo in algos {
        let rec = run_algo(w, *algo, cfg, backend);
        let acc = rec.get("accuracy");
        let events = rec.get("events");
        let per_target: Vec<Option<f64>> = targets
            .iter()
            .map(|&t| {
                acc.iter()
                    .position(|&(_, a)| a >= t)
                    .map(|idx| events[idx].1)
            })
            .collect();
        rows.push((algo.label(), per_target));
    }
    rows
}

/// One Tab. 1 row for an algorithm *family*: like the paper (Tab. 2), each
/// target is answered by the best configuration from a per-family grid —
/// the reported number is the fewest events any grid member needed.
pub fn family_events_to_targets(
    w: &NnWorkload,
    family: &[Algo],
    targets: &[f64],
    cfg: &NnExperimentConfig,
    backend: &Backend,
    verbose: bool,
) -> Vec<Option<f64>> {
    let mut best: Vec<Option<f64>> = vec![None; targets.len()];
    for algo in family {
        let rec = run_algo(w, *algo, cfg, backend);
        let acc = rec.get("accuracy");
        let events = rec.get("events");
        if verbose {
            let final_acc = rec.last("accuracy").unwrap_or(0.0);
            let final_ev = rec.last("events").unwrap_or(0.0);
            println!(
                "    {:<36} final acc {final_acc:.3} events {final_ev:.0}",
                algo.label()
            );
        }
        for (ti, &t) in targets.iter().enumerate() {
            if let Some(idx) = acc.iter().position(|&(_, a)| a >= t) {
                let ev = events[idx].1;
                if best[ti].map(|b| ev < b).unwrap_or(true) {
                    best[ti] = Some(ev);
                }
            }
        }
    }
    best
}

/// The per-family configuration grids used for Tab. 1 (the analogue of
/// the paper's Tab. 2).
pub fn tab1_families(cifar: bool) -> Vec<(&'static str, Vec<Algo>)> {
    let deltas: &[f64] = if cifar { &[0.2, 0.5, 1.0] } else { &[0.1, 0.3, 0.6] };
    let parts: &[f64] = &[0.4, 0.6, 1.0];
    vec![
        (
            "Alg. 1 - Randomized",
            deltas
                .iter()
                .map(|&d| Algo::Alg1Rand {
                    delta_d: d,
                    delta_z: d * 0.1,
                    p_trig: 0.1,
                })
                .collect(),
        ),
        (
            "Alg. 1 - Vanilla",
            deltas
                .iter()
                .map(|&d| Algo::Alg1Vanilla { delta_d: d, delta_z: d * 0.1 })
                .collect(),
        ),
        (
            "FedADMM",
            parts.iter().map(|&p| Algo::FedAdmm { part: p }).collect(),
        ),
        (
            "FedAvg",
            parts.iter().map(|&p| Algo::FedAvg { part: p }).collect(),
        ),
        (
            "FedProx",
            parts
                .iter()
                .map(|&p| Algo::FedProx { part: p, mu: 0.1 })
                .collect(),
        ),
        (
            "SCAFFOLD",
            parts.iter().map(|&p| Algo::Scaffold { part: p }).collect(),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_alg1_learns_under_extreme_noniid() {
        let w = NnWorkload::tiny(1);
        let cfg = NnExperimentConfig { rounds: 40, eval_every: 5, seed: 1, ..Default::default() };
        let rec = run_algo(
            &w,
            Algo::Alg1Vanilla { delta_d: 0.05, delta_z: 0.05 },
            &cfg,
            &Backend::Native,
        );
        let acc = rec.last("accuracy").unwrap();
        assert!(acc > 0.6, "final accuracy {acc}");
        let load = rec.last("load").unwrap();
        assert!(load < 1.0);
    }

    #[test]
    fn tiny_fedavg_struggles_under_extreme_noniid() {
        // the paper's core claim: under one-class-per-agent splits,
        // ADMM-family >> FedAvg at equal budgets
        let w = NnWorkload::tiny(1);
        let cfg = NnExperimentConfig { rounds: 40, eval_every: 5, seed: 1, ..Default::default() };
        let rec_admm = run_algo(
            &w,
            Algo::Alg1Vanilla { delta_d: 0.05, delta_z: 0.05 },
            &cfg,
            &Backend::Native,
        );
        let rec_avg =
            run_algo(&w, Algo::FedAvg { part: 1.0 }, &cfg, &Backend::Native);
        let a_admm = rec_admm.last("accuracy").unwrap();
        let a_avg = rec_avg.last("accuracy").unwrap();
        assert!(
            a_admm > a_avg - 0.05,
            "ADMM {a_admm} should not trail FedAvg {a_avg}"
        );
    }

    #[test]
    fn events_to_targets_reports_na_for_unreachable() {
        let w = NnWorkload::tiny(2);
        let cfg = NnExperimentConfig { rounds: 10, eval_every: 2, seed: 2, ..Default::default() };
        let rows = events_to_targets(
            &w,
            &[Algo::FedAvg { part: 0.5 }],
            &[0.2, 1.01],
            &cfg,
            &Backend::Native,
        );
        assert_eq!(rows.len(), 1);
        assert!(rows[0].1[1].is_none(), ">100% must be unreachable");
    }

    #[test]
    fn scaffold_and_fedprox_run() {
        let w = NnWorkload::tiny(3);
        let cfg = NnExperimentConfig { rounds: 10, eval_every: 5, seed: 3, ..Default::default() };
        for algo in [
            Algo::Scaffold { part: 0.8 },
            Algo::FedProx { part: 0.8, mu: 0.1 },
            Algo::FedAdmm { part: 0.8 },
            Algo::Alg1Rand { delta_d: 0.1, delta_z: 0.1, p_trig: 0.1 },
        ] {
            let rec = run_algo(&w, algo, &cfg, &Backend::Native);
            assert!(rec.last("accuracy").is_some(), "{}", algo.label());
        }
    }
}
