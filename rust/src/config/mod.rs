//! Run-level configuration: artifact/result locations, seeds, and JSON
//! config-file loading for the experiment launcher.

use std::path::{Path, PathBuf};

use crate::jsonio::{read_json, Json};
use crate::wire::CompressorCfg;

/// Global run configuration shared by the CLI, examples and benches.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` (built by
    /// `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Output directory for experiment CSV/JSON.
    pub results_dir: PathBuf,
    pub seed: u64,
    /// Wire compressor (`--compressor none|topk:F|randk:F|quant:B|topkq:F:B`).
    pub compressor: CompressorCfg,
    /// Worker threads (`--workers N`) — both the scenario-sweep cells
    /// and every engine's per-agent local-solve pool; 0 = auto (the
    /// `DELUXE_WORKERS` env var if set, else one per core).  Results
    /// are bit-identical for every value.
    pub workers: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: default_artifacts_dir(),
            results_dir: PathBuf::from("results"),
            seed: 0,
            compressor: CompressorCfg::Identity,
            workers: 0,
        }
    }
}

/// Resolve the artifacts dir: `$DELA_ARTIFACTS`, else `./artifacts`, else
/// relative to the crate root (so `cargo test` works from anywhere).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DELA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let local = PathBuf::from("artifacts");
    if local.join("manifest.json").exists() {
        return local;
    }
    let crate_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    crate_root.join("artifacts")
}

impl RunConfig {
    pub fn from_args(args: &crate::cli::Args) -> RunConfig {
        let mut cfg = RunConfig::default();
        if let Some(dir) = args.get("artifacts") {
            cfg.artifacts_dir = PathBuf::from(dir);
        }
        if let Some(dir) = args.get("results") {
            cfg.results_dir = PathBuf::from(dir);
        }
        cfg.seed = args.u64_or("seed", 0);
        cfg.workers = args.usize_or("workers", 0);
        if let Some(spec) = args.get("compressor") {
            // a typo silently measuring the dense baseline would corrupt a
            // whole sweep — malformed values are fatal, same as the JSON
            // config path
            cfg.compressor = CompressorCfg::parse(spec)
                // lint:allow(panic-in-library): a malformed --compressor silently measuring the dense baseline would corrupt a whole sweep; fatal-by-design for CLI input
                .unwrap_or_else(|e| panic!("--compressor: {e}"));
        }
        cfg
    }

    /// Merge overrides from a JSON config file:
    /// `{"artifacts": "...", "results": "...", "seed": 3}`.
    pub fn load_file(&mut self, path: &Path) -> anyhow::Result<()> {
        let j = read_json(path)?;
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("results").and_then(Json::as_str) {
            self.results_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("workers").and_then(Json::as_f64) {
            self.workers = v as usize;
        }
        if let Some(v) = j.get("compressor").and_then(Json::as_str) {
            self.compressor = CompressorCfg::parse(v)
                .map_err(|e| anyhow::anyhow!("config compressor: {e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;
    use crate::jsonio::write_json;

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            ["--artifacts", "/tmp/a", "--seed", "5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.artifacts_dir, PathBuf::from("/tmp/a"));
        assert_eq!(cfg.seed, 5);
        assert_eq!(cfg.compressor, CompressorCfg::Identity);
        assert_eq!(cfg.workers, 0);
    }

    #[test]
    fn from_args_parses_workers() {
        let args =
            Args::parse(["--workers", "6"].iter().map(|s| s.to_string()));
        assert_eq!(RunConfig::from_args(&args).workers, 6);
    }

    #[test]
    fn from_args_parses_compressor_flag() {
        let args = Args::parse(
            ["--compressor", "topkq:0.05:8"].iter().map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(
            cfg.compressor,
            CompressorCfg::TopKQuant { frac: 0.05, bits: 8 }
        );
    }

    #[test]
    fn from_args_rejects_malformed_compressor() {
        // a typo must abort, not silently measure the dense baseline
        let bad = Args::parse(
            ["--compressor", "bogus:9"].iter().map(|s| s.to_string()),
        );
        let res =
            std::panic::catch_unwind(|| RunConfig::from_args(&bad));
        assert!(res.is_err());
    }

    #[test]
    fn load_file_merges() {
        let dir = std::env::temp_dir().join("dela_cfg_test");
        let path = dir.join("cfg.json");
        write_json(
            &path,
            &Json::obj(vec![
                ("results", Json::Str("/tmp/r".into())),
                ("seed", Json::Num(42.0)),
            ]),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.load_file(&path).unwrap();
        assert_eq!(cfg.results_dir, PathBuf::from("/tmp/r"));
        assert_eq!(cfg.seed, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_artifacts_exists_or_crate_relative() {
        let dir = default_artifacts_dir();
        assert!(dir.to_string_lossy().contains("artifacts"));
    }
}
