//! Run-level configuration: artifact/result locations, seeds, and JSON
//! config-file loading for the experiment launcher.

use std::path::{Path, PathBuf};

use crate::jsonio::{read_json, Json};

/// Global run configuration shared by the CLI, examples and benches.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` (built by
    /// `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Output directory for experiment CSV/JSON.
    pub results_dir: PathBuf,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: default_artifacts_dir(),
            results_dir: PathBuf::from("results"),
            seed: 0,
        }
    }
}

/// Resolve the artifacts dir: `$DELA_ARTIFACTS`, else `./artifacts`, else
/// relative to the crate root (so `cargo test` works from anywhere).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DELA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let local = PathBuf::from("artifacts");
    if local.join("manifest.json").exists() {
        return local;
    }
    let crate_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    crate_root.join("artifacts")
}

impl RunConfig {
    pub fn from_args(args: &crate::cli::Args) -> RunConfig {
        let mut cfg = RunConfig::default();
        if let Some(dir) = args.get("artifacts") {
            cfg.artifacts_dir = PathBuf::from(dir);
        }
        if let Some(dir) = args.get("results") {
            cfg.results_dir = PathBuf::from(dir);
        }
        cfg.seed = args.u64_or("seed", 0);
        cfg
    }

    /// Merge overrides from a JSON config file:
    /// `{"artifacts": "...", "results": "...", "seed": 3}`.
    pub fn load_file(&mut self, path: &Path) -> anyhow::Result<()> {
        let j = read_json(path)?;
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("results").and_then(Json::as_str) {
            self.results_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            self.seed = v as u64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;
    use crate::jsonio::write_json;

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            ["--artifacts", "/tmp/a", "--seed", "5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.artifacts_dir, PathBuf::from("/tmp/a"));
        assert_eq!(cfg.seed, 5);
    }

    #[test]
    fn load_file_merges() {
        let dir = std::env::temp_dir().join("dela_cfg_test");
        let path = dir.join("cfg.json");
        write_json(
            &path,
            &Json::obj(vec![
                ("results", Json::Str("/tmp/r".into())),
                ("seed", Json::Num(42.0)),
            ]),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.load_file(&path).unwrap();
        assert_eq!(cfg.results_dir, PathBuf::from("/tmp/r"));
        assert_eq!(cfg.seed, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_artifacts_exists_or_crate_relative() {
        let dir = default_artifacts_dir();
        assert!(dir.to_string_lossy().contains("artifacts"));
    }
}
