//! Run-level configuration: one `RunConfig` shared by the CLI, the
//! threaded service runtime, the sim, examples and benches.
//!
//! The protocol half (ρ, α, triggers, drop rates, reset period) used to
//! live in a separate `CoordinatorConfig`; the transport redesign folded
//! it in here so every entry point — `deluxe train`, `deluxe serve`,
//! `deluxe agent`, the examples — constructs runs through a single
//! builder and a single flag-parsing path.  [`RunConfig::digest`] hashes
//! the protocol fields so a serve/agent pair can refuse to form a cohort
//! on mismatched configuration.

use std::path::{Path, PathBuf};

use crate::comm::Trigger;
use crate::jsonio::{read_json, Json};
use crate::wire::CompressorCfg;

/// Global run configuration shared by the CLI, examples and benches.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` (built by
    /// `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Output directory for experiment CSV/JSON.
    pub results_dir: PathBuf,
    pub seed: u64,
    /// Wire compressor (`--compressor none|topk:F|randk:F|quant:B|topkq:F:B`).
    pub compressor: CompressorCfg,
    /// Worker threads (`--workers N`) — both the scenario-sweep cells
    /// and every engine's per-agent local-solve pool; 0 = auto (the
    /// `DELUXE_WORKERS` env var if set, else one per core).  Results
    /// are bit-identical for every value.
    pub workers: usize,
    /// ADMM penalty ρ.
    pub rho: f32,
    /// Relaxation α (1 = no relaxation).
    pub alpha: f32,
    /// Local prox-SGD learning rate.
    pub lr: f32,
    /// Local prox-SGD steps per round.
    pub steps: usize,
    /// Local prox-SGD batch size.
    pub batch: usize,
    /// Uplink (agent → leader) event trigger.
    pub trigger_d: Trigger,
    /// Downlink (leader → agent) event trigger.
    pub trigger_z: Trigger,
    /// Uplink i.i.d. packet-drop probability.
    pub drop_up: f64,
    /// Downlink i.i.d. packet-drop probability.
    pub drop_down: f64,
    /// Hard-resync `ẑ` every k rounds (0 = never) — the paper's
    /// periodic reset strategy against drop-induced drift.
    pub reset_period: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: default_artifacts_dir(),
            results_dir: PathBuf::from("results"),
            seed: 0,
            compressor: CompressorCfg::Identity,
            workers: 0,
            rho: 1.0,
            alpha: 1.0,
            lr: 0.1,
            steps: 5,
            batch: 32,
            trigger_d: Trigger::Always,
            trigger_z: Trigger::Always,
            drop_up: 0.0,
            drop_down: 0.0,
            reset_period: 0,
        }
    }
}

/// Resolve the artifacts dir: `$DELA_ARTIFACTS`, else `./artifacts`, else
/// relative to the crate root (so `cargo test` works from anywhere).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DELA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let local = PathBuf::from("artifacts");
    if local.join("manifest.json").exists() {
        return local;
    }
    let crate_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    crate_root.join("artifacts")
}

impl RunConfig {
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_compressor(mut self, c: CompressorCfg) -> Self {
        self.compressor = c;
        self
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_rho(mut self, rho: f32) -> Self {
        self.rho = rho;
        self
    }

    pub fn with_alpha(mut self, alpha: f32) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_trigger_d(mut self, t: Trigger) -> Self {
        self.trigger_d = t;
        self
    }

    pub fn with_trigger_z(mut self, t: Trigger) -> Self {
        self.trigger_z = t;
        self
    }

    /// The paper's vanilla trigger pair at threshold δ: uplink fires at
    /// δ, downlink at δ/10 (the `--delta` CLI shorthand).
    pub fn with_delta(mut self, delta: f64) -> Self {
        self.trigger_d = Trigger::vanilla(delta);
        self.trigger_z = Trigger::vanilla(delta * 0.1);
        self
    }

    pub fn with_drop_up(mut self, p: f64) -> Self {
        self.drop_up = p;
        self
    }

    pub fn with_drop_down(mut self, p: f64) -> Self {
        self.drop_down = p;
        self
    }

    pub fn with_reset_period(mut self, k: usize) -> Self {
        self.reset_period = k;
        self
    }

    pub fn from_args(args: &crate::cli::Args) -> RunConfig {
        let mut cfg = RunConfig::default();
        if let Some(dir) = args.get("artifacts") {
            cfg.artifacts_dir = PathBuf::from(dir);
        }
        if let Some(dir) = args.get("results") {
            cfg.results_dir = PathBuf::from(dir);
        }
        cfg.seed = args.u64_or("seed", 0);
        cfg.workers = args.usize_or("workers", 0);
        if let Some(spec) = args.get("compressor") {
            // a typo silently measuring the dense baseline would corrupt a
            // whole sweep — malformed values are fatal, same as the JSON
            // config path
            cfg.compressor = CompressorCfg::parse(spec)
                // lint:allow(panic-in-library): a malformed --compressor silently measuring the dense baseline would corrupt a whole sweep; fatal-by-design for CLI input
                .unwrap_or_else(|e| panic!("--compressor: {e}"));
        }
        cfg.rho = args.f64_or("rho", cfg.rho as f64) as f32;
        cfg.alpha = args.f64_or("alpha", cfg.alpha as f64) as f32;
        cfg.lr = args.f64_or("lr", cfg.lr as f64) as f32;
        cfg.steps = args.usize_or("steps", cfg.steps);
        cfg.batch = args.usize_or("batch", cfg.batch);
        cfg.drop_up = args.f64_or("drop-up", cfg.drop_up);
        cfg.drop_down = args.f64_or("drop-down", cfg.drop_down);
        cfg.reset_period = args.usize_or("reset-period", cfg.reset_period);
        // --delta is shorthand for the vanilla trigger pair; an explicit
        // --trigger-d / --trigger-z wins over it
        match args.get_parse::<f64>("delta") {
            Ok(Some(d)) => cfg = cfg.with_delta(d),
            Ok(None) => {}
            // lint:allow(panic-in-library): a malformed --delta silently running Trigger::Always would corrupt a sweep; fatal-by-design for CLI input
            Err(e) => panic!("--delta: {e}"),
        }
        if let Some(spec) = args.get("trigger-d") {
            cfg.trigger_d = Trigger::parse(spec)
                // lint:allow(panic-in-library): a malformed trigger silently running Trigger::Always would corrupt a sweep; fatal-by-design for CLI input
                .unwrap_or_else(|e| panic!("--trigger-d: {e}"));
        }
        if let Some(spec) = args.get("trigger-z") {
            cfg.trigger_z = Trigger::parse(spec)
                // lint:allow(panic-in-library): a malformed trigger silently running Trigger::Always would corrupt a sweep; fatal-by-design for CLI input
                .unwrap_or_else(|e| panic!("--trigger-z: {e}"));
        }
        cfg
    }

    /// Merge overrides from a JSON config file:
    /// `{"artifacts": "...", "results": "...", "seed": 3}`.
    pub fn load_file(&mut self, path: &Path) -> anyhow::Result<()> {
        let j = read_json(path)?;
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("results").and_then(Json::as_str) {
            self.results_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("workers").and_then(Json::as_f64) {
            self.workers = v as usize;
        }
        if let Some(v) = j.get("compressor").and_then(Json::as_str) {
            self.compressor = CompressorCfg::parse(v)
                .map_err(|e| anyhow::anyhow!("config compressor: {e}"))?;
        }
        Ok(())
    }

    /// FNV-1a hash of every field that must agree between a serving
    /// leader and a connecting agent for the run to be well-defined
    /// (protocol constants, triggers, compressor, seed, model dim,
    /// cohort size).  Carried in the transport handshake: a mismatched
    /// agent is rejected at accept time instead of silently diverging.
    pub fn digest(&self, dim: usize, n_agents: usize) -> u64 {
        let canon = format!(
            "dela-proto-v1|dim={dim}|n={n_agents}|seed={}|rho={}|alpha={}\
             |lr={}|steps={}|batch={}|td={}|tz={}|du={}|dd={}|reset={}\
             |comp={}",
            self.seed,
            self.rho,
            self.alpha,
            self.lr,
            self.steps,
            self.batch,
            self.trigger_d.label(),
            self.trigger_z.label(),
            self.drop_up,
            self.drop_down,
            self.reset_period,
            self.compressor.label(),
        );
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in canon.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;
    use crate::jsonio::write_json;

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            ["--artifacts", "/tmp/a", "--seed", "5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.artifacts_dir, PathBuf::from("/tmp/a"));
        assert_eq!(cfg.seed, 5);
        assert_eq!(cfg.compressor, CompressorCfg::Identity);
        assert_eq!(cfg.workers, 0);
    }

    #[test]
    fn from_args_parses_workers() {
        let args =
            Args::parse(["--workers", "6"].iter().map(|s| s.to_string()));
        assert_eq!(RunConfig::from_args(&args).workers, 6);
    }

    #[test]
    fn from_args_parses_compressor_flag() {
        let args = Args::parse(
            ["--compressor", "topkq:0.05:8"].iter().map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(
            cfg.compressor,
            CompressorCfg::TopKQuant { frac: 0.05, bits: 8 }
        );
    }

    #[test]
    fn from_args_rejects_malformed_compressor() {
        // a typo must abort, not silently measure the dense baseline
        let bad = Args::parse(
            ["--compressor", "bogus:9"].iter().map(|s| s.to_string()),
        );
        let res =
            std::panic::catch_unwind(|| RunConfig::from_args(&bad));
        assert!(res.is_err());
    }

    #[test]
    fn from_args_parses_protocol_fields() {
        let args = Args::parse(
            [
                "--rho", "0.5", "--alpha", "0.9", "--lr", "0.05", "--steps",
                "3", "--batch", "16", "--drop-up", "0.1", "--drop-down",
                "0.2", "--reset-period", "25",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.rho, 0.5);
        assert_eq!(cfg.alpha, 0.9);
        assert_eq!(cfg.lr, 0.05);
        assert_eq!(cfg.steps, 3);
        assert_eq!(cfg.batch, 16);
        assert_eq!(cfg.drop_up, 0.1);
        assert_eq!(cfg.drop_down, 0.2);
        assert_eq!(cfg.reset_period, 25);
    }

    #[test]
    fn delta_shorthand_sets_vanilla_pair_and_explicit_trigger_wins() {
        let args = Args::parse(
            ["--delta", "0.5"].iter().map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.trigger_d, Trigger::vanilla(0.5));
        assert_eq!(cfg.trigger_z, Trigger::vanilla(0.05));

        let args = Args::parse(
            ["--delta", "0.5", "--trigger-d", "never"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.trigger_d, Trigger::Never);
        assert_eq!(cfg.trigger_z, Trigger::vanilla(0.05));
    }

    #[test]
    fn builder_chain_sets_protocol_fields() {
        let cfg = RunConfig::default()
            .with_seed(7)
            .with_rho(2.0)
            .with_lr(0.01)
            .with_steps(9)
            .with_batch(4)
            .with_delta(1.0)
            .with_drop_down(0.3)
            .with_reset_period(10);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.rho, 2.0);
        assert_eq!(cfg.lr, 0.01);
        assert_eq!(cfg.steps, 9);
        assert_eq!(cfg.batch, 4);
        assert_eq!(cfg.trigger_d, Trigger::vanilla(1.0));
        assert_eq!(cfg.drop_down, 0.3);
        assert_eq!(cfg.reset_period, 10);
    }

    #[test]
    fn digest_separates_differing_protocols() {
        let base = RunConfig::default();
        let d0 = base.digest(100, 4);
        // same config, same digest — both ends compute it independently
        assert_eq!(d0, base.clone().digest(100, 4));
        // any protocol-relevant difference must separate
        assert_ne!(d0, base.clone().with_seed(1).digest(100, 4));
        assert_ne!(d0, base.clone().with_rho(2.0).digest(100, 4));
        assert_ne!(d0, base.clone().with_delta(0.5).digest(100, 4));
        assert_ne!(d0, base.digest(101, 4));
        assert_ne!(d0, base.digest(100, 5));
    }

    #[test]
    fn load_file_merges() {
        let dir = std::env::temp_dir().join("dela_cfg_test");
        let path = dir.join("cfg.json");
        write_json(
            &path,
            &Json::obj(vec![
                ("results", Json::Str("/tmp/r".into())),
                ("seed", Json::Num(42.0)),
            ]),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.load_file(&path).unwrap();
        assert_eq!(cfg.results_dir, PathBuf::from("/tmp/r"));
        assert_eq!(cfg.seed, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_artifacts_exists_or_crate_relative() {
        let dir = default_artifacts_dir();
        assert!(dir.to_string_lossy().contains("artifacts"));
    }
}
