//! Minimal JSON substrate (parser + writer).
//!
//! The offline environment has no `serde`; this module supplies the subset
//! DELA needs: parsing `artifacts/manifest.json` / `testvec.json` /
//! experiment configs, and writing experiment results.  It is a complete
//! JSON implementation (objects, arrays, strings with escapes, numbers,
//! bools, null) with line/column error reporting.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with a 1-based source position.  Implements
/// `std::error::Error`, so `?` converts it into `anyhow::Error` at
/// every call site that propagates.
#[derive(Debug)]
pub struct JsonError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------
    // Accessors
    // ---------------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `f64` array -> `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
    }
    /// `f64` array -> `Vec<f32>` (the PJRT parameter ABI).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as f32)).collect())
    }
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
    }

    // ---------------------------------------------------------------
    // Constructors
    // ---------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------------------------------------------------------------
    // Parse / serialize
    // ---------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        let mut line = 1;
        let mut col = 1;
        for &c in &self.b[..self.pos.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        JsonError { line, col, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Parse a JSON file.
pub fn read_json(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
}

/// Write a JSON file (creates parent dirs).
pub fn write_json(path: &std::path::Path, v: &Json) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, v.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → wörld");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"t":true,"s":"q\"uote"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_float_precision() {
        let xs = vec![1.0e-17, 3.14159265358979, -2.5e300, 0.1];
        let v = Json::from_f64s(&xs);
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v2.as_f64_vec().unwrap(), xs);
    }

    #[test]
    fn error_reports_position() {
        let err = Json::parse("{\n  \"a\": tru\n}").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.msg.contains("true"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn f32_vec_accessor() {
        let v = Json::parse("[1.5, 2, -0.25]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.5f32, 2.0, -0.25]);
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dela_json_test");
        let path = dir.join("x.json");
        let v = Json::obj(vec![("k", Json::from_f64s(&[1.0, 2.0]))]);
        write_json(&path, &v).unwrap();
        assert_eq!(read_json(&path).unwrap(), v);
        std::fs::remove_dir_all(&dir).ok();
    }
}
