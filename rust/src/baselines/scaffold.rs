//! SCAFFOLD (Karimireddy et al., 2020) — stochastic controlled averaging.
//!
//! Server keeps `(z, c)`; each agent keeps a control variate `c_i`.
//! Selected agents run K corrected SGD steps `y ← y − lr (∇f_i(y) − c_i + c)`
//! (option II control update), then
//!
//! ```text
//! c_i⁺ = c_i − c + (z − y_i) / (K · lr)
//! z    ← z + (η_g/|S|) Σ (y_i − z)          (η_g = 1)
//! c    ← c + (1/N)     Σ (c_i⁺ − c_i)
//! ```
//!
//! Each participating agent transmits two packages per direction (model +
//! control variate) — the ×2 communication factor the paper charges it.

use super::avg_family::FedLocal;
use crate::admm::core::WorkerPool;
use crate::rng::{Pcg64, Rng};
use crate::wire::{ByteTally, WireMessage};

pub struct Scaffold {
    pub z: Vec<f32>,
    pub c: Vec<f32>,
    pub ci: Vec<Vec<f32>>,
    pub part_rate: f64,
    pub events: u64,
    pub round_idx: usize,
    /// Byte accounting (same codec sizing as the ADMM engines): two dense
    /// packages per direction per participating agent — model + control
    /// variate, the paper's ×2 factor made byte-exact.
    pub wire: ByteTally,
    /// Worker pool for the cohort's local solves (same contract as the
    /// ADMM round core: bit-identical for every worker count).
    pub pool: WorkerPool,
}

impl Scaffold {
    pub fn new(init: Vec<f32>, n_agents: usize, part_rate: f64) -> Self {
        let dim = init.len();
        Scaffold {
            z: init,
            c: vec![0.0; dim],
            ci: vec![vec![0.0; dim]; n_agents],
            part_rate,
            events: 0,
            round_idx: 0,
            wire: ByteTally::default(),
            pool: WorkerPool::new(0),
        }
    }

    /// Set the local-solve worker count (0 = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.pool = WorkerPool::new(workers);
        self
    }

    pub fn round(&mut self, local: &mut dyn FedLocal, rng: &mut Pcg64) {
        let n = local.n_agents();
        let solve_base = rng.clone();
        let selected: Vec<usize> =
            (0..n).filter(|_| rng.bernoulli(self.part_rate)).collect();
        self.round_idx += 1;
        if selected.is_empty() {
            return;
        }
        let k_lr = (local.steps() as f64 * local.lr() as f64).max(1e-12);
        let dim = self.z.len();
        let mut dz = vec![0.0f64; dim];
        let mut dc = vec![0.0f64; dim];
        // corr_i = c − c_i, snapshotted per member before the solves
        let corrs: Vec<Vec<f32>> = selected
            .iter()
            .map(|&i| {
                self.c
                    .iter()
                    .zip(&self.ci[i])
                    .map(|(&c, &ci)| c - ci)
                    .collect()
            })
            .collect();
        let mut rngs: Vec<Pcg64> = selected
            .iter()
            .map(|&i| solve_base.fork(self.round_idx as u64, i as u64))
            .collect();
        let ys = local.sgd_corr_batch(
            &selected,
            &self.z,
            &corrs,
            &mut rngs,
            &self.pool,
        );
        // ordered reduction in cohort order
        for (&i, y) in selected.iter().zip(&ys) {
            for j in 0..dim {
                let ci_new = (self.ci[i][j] - self.c[j]) as f64
                    + (self.z[j] - y[j]) as f64 / k_lr;
                dc[j] += ci_new - self.ci[i][j] as f64;
                self.ci[i][j] = ci_new as f32;
                dz[j] += (y[j] - self.z[j]) as f64;
            }
            // 2 packages down (z, c) + 2 packages up (y, c_i)
            self.events += 4;
            let pkg = WireMessage::<f32>::dense_bytes(dim) as u64;
            self.wire.downlink += 2 * pkg;
            self.wire.uplink += 2 * pkg;
        }
        let inv_s = 1.0 / selected.len() as f64;
        let inv_n = 1.0 / n as f64;
        for j in 0..dim {
            self.z[j] = (self.z[j] as f64 + dz[j] * inv_s) as f32;
            self.c[j] = (self.c[j] as f64 + dc[j] * inv_n) as f32;
        }
    }

    /// Events normalized by full *single-package* communication (2N per
    /// round) — so full-participation SCAFFOLD reports load 2.0, matching
    /// the paper's doubling.
    pub fn comm_load(&self, n: usize) -> f64 {
        if self.round_idx == 0 {
            return 0.0;
        }
        self.events as f64 / (2.0 * n as f64 * self.round_idx as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::avg_family::NativeFed;
    use crate::data::partition::{iid_split, single_class_split};
    use crate::data::synth::{generate, SynthSpec};
    use crate::model::MlpSpec;

    #[test]
    fn learns_iid_tiny() {
        let mut rng = Pcg64::seed(1);
        let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = iid_split(&train, 4, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let mut local = NativeFed::new(spec.clone(), shards, 0.1, 3, 8);
        let init = spec.init(&mut rng);
        let mut eng = Scaffold::new(init, 4, 1.0);
        for _ in 0..60 {
            eng.round(&mut local, &mut rng);
        }
        let acc = spec.accuracy(&eng.z, &test.xs, &test.labels);
        assert!(acc > 0.5, "acc {acc}");
    }

    #[test]
    fn control_variates_sum_tracks_server_c() {
        // invariant (full participation): c = mean(c_i) after each round
        let mut rng = Pcg64::seed(2);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = single_class_split(&train, 4);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let mut local = NativeFed::new(spec.clone(), shards, 0.1, 2, 4);
        let init = spec.init(&mut rng);
        let mut eng = Scaffold::new(init, 4, 1.0);
        for _ in 0..5 {
            eng.round(&mut local, &mut rng);
            let dim = eng.z.len();
            for j in (0..dim).step_by(37) {
                let mean: f64 = eng.ci.iter().map(|ci| ci[j] as f64).sum::<f64>()
                    / 4.0;
                assert!(
                    (mean - eng.c[j] as f64).abs() < 1e-4,
                    "c mismatch at {j}: mean {mean} vs {}",
                    eng.c[j]
                );
            }
        }
    }

    #[test]
    fn comm_load_is_doubled() {
        let mut rng = Pcg64::seed(3);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = iid_split(&train, 4, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        let mut local = NativeFed::new(spec.clone(), shards, 0.1, 1, 4);
        let init = spec.init(&mut rng);
        let mut eng = Scaffold::new(init, 4, 1.0);
        for _ in 0..10 {
            eng.round(&mut local, &mut rng);
        }
        assert!((eng.comm_load(4) - 2.0).abs() < 1e-12);
        // byte-exact x2: two dense packages per direction per event pair
        let dim = eng.z.len();
        let pkg = WireMessage::<f32>::dense_bytes(dim) as u64;
        assert_eq!(eng.wire.total(), eng.events * pkg);
    }
}
