//! FedADMM (Zhou & Li, 2023; Wang et al., 2022; Gong et al., 2022).
//!
//! Architecturally the same primal–dual consensus scheme as Alg. 1, but
//! with *random agent participation* instead of event triggering — which
//! is exactly how the paper frames it ("FedADMM relies on utilizing a
//! random selection of agents that communicate").  We therefore build it
//! as a configuration of the well-tested [`ConsensusAdmm`] engine:
//! `Trigger::Participation{p}` on both the d-line and the z-line.

use crate::admm::consensus::{ConsensusAdmm, ConsensusConfig};
use crate::comm::{Scalar, Trigger};
use crate::rng::Pcg64;
use crate::solver::{LocalSolver, ServerProx};

pub struct FedAdmm<T: Scalar> {
    pub engine: ConsensusAdmm<T>,
}

impl<T: Scalar> FedAdmm<T> {
    pub fn new(
        n: usize,
        init: Vec<T>,
        rho: f64,
        part_rate: f64,
        rounds: usize,
    ) -> Self {
        Self::with_workers(n, init, rho, part_rate, rounds, 0)
    }

    /// Like [`Self::new`] with an explicit local-solve worker count —
    /// FedADMM rides the unified round core through [`ConsensusAdmm`],
    /// so its cohort solves shard across the same pool.
    pub fn with_workers(
        n: usize,
        init: Vec<T>,
        rho: f64,
        part_rate: f64,
        rounds: usize,
        workers: usize,
    ) -> Self {
        let cfg = ConsensusConfig {
            rho,
            alpha: 1.0,
            rounds,
            trigger_d: Trigger::participation(part_rate),
            trigger_z: Trigger::participation(part_rate),
            workers,
            ..Default::default()
        };
        FedAdmm { engine: ConsensusAdmm::new(cfg, n, init) }
    }

    pub fn round(
        &mut self,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
        rng: &mut Pcg64,
    ) {
        self.engine.round(solver, prox, rng);
    }

    pub fn z(&self) -> &[T] {
        &self.engine.z
    }

    pub fn total_events(&self) -> u64 {
        self.engine.total_events()
    }

    pub fn comm_load(&self) -> f64 {
        self.engine.comm_load()
    }

    /// Byte-accurate wire accounting (inherited from the shared engine:
    /// FedADMM rides the same codec/channel path as Alg. 1).
    pub fn wire_stats(&self) -> crate::wire::WireStats {
        self.engine.wire_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::IdentityProx;

    struct ScalarQuad {
        w: Vec<f64>,
        c: Vec<f64>,
    }
    impl LocalSolver<f64> for ScalarQuad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            vec![
                (self.w[agent] * self.c[agent] + rho * anchor[0])
                    / (self.w[agent] + rho),
            ]
        }
        fn dim(&self) -> usize {
            1
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    #[test]
    fn converges_near_optimum_with_partial_participation() {
        let w = vec![1.0, 2.0, 0.5, 3.0];
        let c = vec![-1.0, 4.0, 10.0, 0.5];
        let opt = w.iter().zip(&c).map(|(a, b)| a * b).sum::<f64>()
            / w.iter().sum::<f64>();
        let mut solver = ScalarQuad { w, c };
        let mut eng = FedAdmm::new(4, vec![0.0], 1.0, 0.6, 800);
        let mut prox = IdentityProx;
        let mut rng = Pcg64::seed(1);
        for _ in 0..800 {
            eng.round(&mut solver, &mut prox, &mut rng);
        }
        assert!(
            (eng.z()[0] - opt).abs() < 0.4,
            "z {} vs opt {opt}",
            eng.z()[0]
        );
        let load = eng.comm_load();
        assert!((load - 0.6).abs() < 0.1, "load {load}");
    }

    #[test]
    fn full_participation_matches_standard_admm() {
        let w = vec![1.0, 2.0];
        let c = vec![3.0, -1.0];
        let opt = (1.0 * 3.0 + 2.0 * -1.0) / 3.0;
        let mut solver = ScalarQuad { w, c };
        let mut eng = FedAdmm::new(2, vec![0.0], 1.0, 1.0, 300);
        let mut prox = IdentityProx;
        let mut rng = Pcg64::seed(2);
        for _ in 0..300 {
            eng.round(&mut solver, &mut prox, &mut rng);
        }
        assert!((eng.z()[0] - opt).abs() < 1e-8);
    }
}
