//! Federated-learning baselines (Sec. 5 comparisons): FedAvg, FedProx,
//! SCAFFOLD, FedADMM.
//!
//! All baselines run under the *same local-computation budget* as Alg. 1
//! (S SGD steps per selected agent per round — App. G: "each of the agents
//! are run for the same number of local gradient steps") and the same
//! synthetic non-iid shards; what differs is the aggregation rule and the
//! (random-participation) communication pattern.
//!
//! Communication accounting: each participating agent costs one downlink
//! (model delivery) and one uplink (update) event per round; SCAFFOLD costs
//! two per direction (model + control variate — the paper doubles its
//! counts for the same reason, Tab. 2).

pub mod avg_family;
pub mod fedadmm;
pub mod scaffold;

pub use avg_family::{AvgFamily, FedLocal, NativeFed};
pub use fedadmm::FedAdmm;
pub use scaffold::Scaffold;
