//! FedAvg (McMahan et al., 2017) and FedProx (Li et al., 2020a).
//!
//! One engine covers both: the local objective is
//! `f_i(x) + (μ/2)|x − z|²` with `μ = 0` for FedAvg; the server averages
//! the models of the randomly selected cohort.

use crate::admm::core::WorkerPool;
use crate::data::synth::ClassDataset;
use crate::model::MlpSpec;
use crate::rng::{Pcg64, Rng};
use crate::solver::draw_round_batches;
use crate::wire::{ByteTally, WireMessage};

/// Local-update backend shared by every baseline: runs S (prox-/corrected-)
/// SGD steps *starting from a given point* (baselines restart from the
/// global model each round, unlike ADMM's warm-started agents).
///
/// The `*_batch` methods follow the same determinism contract as
/// `LocalSolver::solve_batch` (see `solver`'s module docs): one forked
/// RNG stream per cohort member, results in cohort order, bit-identical
/// for every worker count.
pub trait FedLocal {
    fn dim(&self) -> usize;
    fn n_agents(&self) -> usize;
    fn lr(&self) -> f32;
    fn steps(&self) -> usize;
    /// S SGD steps on `f_i(x) + (mu/2)|x − anchor|²` from `start`.
    fn sgd_prox(
        &mut self,
        agent: usize,
        start: &[f32],
        anchor: &[f32],
        mu: f64,
        rng: &mut Pcg64,
    ) -> Vec<f32>;
    /// S corrected SGD steps: `x ← x − lr (∇f_i(x) + corr)` from `start`.
    fn sgd_corr(
        &mut self,
        agent: usize,
        start: &[f32],
        corr: &[f32],
        rng: &mut Pcg64,
    ) -> Vec<f32>;

    /// Run [`Self::sgd_prox`] for a whole cohort; `rngs[j]` drives
    /// `cohort[j]`.  Default: sequential on the caller's thread.
    fn sgd_prox_batch(
        &mut self,
        cohort: &[usize],
        start: &[f32],
        anchor: &[f32],
        mu: f64,
        rngs: &mut [Pcg64],
        _pool: &WorkerPool,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(cohort.len(), rngs.len());
        cohort
            .iter()
            .zip(rngs.iter_mut())
            .map(|(&i, rng)| self.sgd_prox(i, start, anchor, mu, rng))
            .collect()
    }

    /// Run [`Self::sgd_corr`] for a whole cohort with per-member
    /// corrections; `rngs[j]` drives `cohort[j]`.  Default: sequential.
    fn sgd_corr_batch(
        &mut self,
        cohort: &[usize],
        start: &[f32],
        corrs: &[Vec<f32>],
        rngs: &mut [Pcg64],
        _pool: &WorkerPool,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(cohort.len(), corrs.len());
        debug_assert_eq!(cohort.len(), rngs.len());
        cohort
            .iter()
            .zip(corrs)
            .zip(rngs.iter_mut())
            .map(|((&i, corr), rng)| self.sgd_corr(i, start, corr, rng))
            .collect()
    }
}

/// Native-MLP backend (the PJRT twin lives in `runtime::PjrtFed`).
pub struct NativeFed {
    pub spec: MlpSpec,
    pub shards: Vec<ClassDataset>,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
}

impl NativeFed {
    pub fn new(
        spec: MlpSpec,
        shards: Vec<ClassDataset>,
        lr: f32,
        steps: usize,
        batch: usize,
    ) -> Self {
        NativeFed { spec, shards, lr, steps, batch }
    }

    fn batches(&self, agent: usize, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>) {
        draw_round_batches(
            &self.spec,
            &self.shards[agent],
            self.steps,
            self.batch,
            rng,
        )
    }
}

impl FedLocal for NativeFed {
    fn dim(&self) -> usize {
        self.spec.param_len()
    }
    fn n_agents(&self) -> usize {
        self.shards.len()
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn steps(&self) -> usize {
        self.steps
    }

    fn sgd_prox(
        &mut self,
        agent: usize,
        start: &[f32],
        anchor: &[f32],
        mu: f64,
        rng: &mut Pcg64,
    ) -> Vec<f32> {
        let (xs, ys) = self.batches(agent, rng);
        // local_admm with (zhat=anchor, u=0, rho=mu) is exactly
        // f_i + (mu/2)|x − anchor|²; the anchor variant folds u = 0 in
        // bit-identically without materializing a zero dual vector.
        self.spec.local_admm_anchor(
            start, anchor, &xs, &ys, self.lr, mu as f32, self.steps,
            self.batch,
        )
    }

    fn sgd_corr(
        &mut self,
        agent: usize,
        start: &[f32],
        corr: &[f32],
        rng: &mut Pcg64,
    ) -> Vec<f32> {
        let (xs, ys) = self.batches(agent, rng);
        self.spec
            .local_scaffold(start, corr, &xs, &ys, self.lr, self.steps, self.batch)
    }

    /// Pool-sharded cohort: the native backend has no per-agent mutable
    /// state (baselines restart from the global model), so workers share
    /// the spec/shards read-only and each member draws from its own
    /// stream.
    fn sgd_prox_batch(
        &mut self,
        cohort: &[usize],
        start: &[f32],
        anchor: &[f32],
        mu: f64,
        rngs: &mut [Pcg64],
        pool: &WorkerPool,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(cohort.len(), rngs.len());
        struct Job<'a> {
            agent: usize,
            rng: &'a mut Pcg64,
            out: Vec<f32>,
        }
        let mut jobs: Vec<Job> = cohort
            .iter()
            .zip(rngs.iter_mut())
            .map(|(&agent, rng)| Job { agent, rng, out: Vec::new() })
            .collect();
        let spec = &self.spec;
        let shards = &self.shards;
        let (lr, steps, batch) = (self.lr, self.steps, self.batch);
        pool.run(&mut jobs, |_, job| {
            let (xs, ys) = draw_round_batches(
                spec,
                &shards[job.agent],
                steps,
                batch,
                job.rng,
            );
            job.out = spec.local_admm_anchor(
                start, anchor, &xs, &ys, lr, mu as f32, steps, batch,
            );
        });
        jobs.into_iter().map(|j| j.out).collect()
    }

    fn sgd_corr_batch(
        &mut self,
        cohort: &[usize],
        start: &[f32],
        corrs: &[Vec<f32>],
        rngs: &mut [Pcg64],
        pool: &WorkerPool,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(cohort.len(), corrs.len());
        debug_assert_eq!(cohort.len(), rngs.len());
        struct Job<'a> {
            agent: usize,
            corr: &'a [f32],
            rng: &'a mut Pcg64,
            out: Vec<f32>,
        }
        let mut jobs: Vec<Job> = cohort
            .iter()
            .zip(corrs)
            .zip(rngs.iter_mut())
            .map(|((&agent, corr), rng)| Job {
                agent,
                corr,
                rng,
                out: Vec::new(),
            })
            .collect();
        let spec = &self.spec;
        let shards = &self.shards;
        let (lr, steps, batch) = (self.lr, self.steps, self.batch);
        pool.run(&mut jobs, |_, job| {
            let (xs, ys) = draw_round_batches(
                spec,
                &shards[job.agent],
                steps,
                batch,
                job.rng,
            );
            job.out = spec.local_scaffold(
                start, job.corr, &xs, &ys, lr, steps, batch,
            );
        });
        jobs.into_iter().map(|j| j.out).collect()
    }
}

/// FedAvg (`mu = 0`) / FedProx (`mu > 0`) engine.
pub struct AvgFamily {
    pub z: Vec<f32>,
    pub mu: f64,
    pub part_rate: f64,
    pub events: u64,
    pub round_idx: usize,
    /// Byte accounting with the same wire codec the ADMM engines use:
    /// each participating agent costs one dense model downlink and one
    /// dense model uplink per round (the family transmits full models,
    /// not deltas, so the dense layout is the honest charge).
    pub wire: ByteTally,
    /// Worker pool for the cohort's local solves (same contract as the
    /// ADMM round core: bit-identical for every worker count).
    pub pool: WorkerPool,
}

impl AvgFamily {
    pub fn fedavg(init: Vec<f32>, part_rate: f64) -> Self {
        AvgFamily {
            z: init,
            mu: 0.0,
            part_rate,
            events: 0,
            round_idx: 0,
            wire: ByteTally::default(),
            pool: WorkerPool::new(0),
        }
    }

    pub fn fedprox(init: Vec<f32>, part_rate: f64, mu: f64) -> Self {
        AvgFamily { mu, ..AvgFamily::fedavg(init, part_rate) }
    }

    /// Set the local-solve worker count (0 = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.pool = WorkerPool::new(workers);
        self
    }

    pub fn round(&mut self, local: &mut dyn FedLocal, rng: &mut Pcg64) {
        let n = local.n_agents();
        // cohort selection stays on the caller's stream; the solves fork
        // per-member streams off the round-entry state
        let solve_base = rng.clone();
        let selected: Vec<usize> =
            (0..n).filter(|_| rng.bernoulli(self.part_rate)).collect();
        self.round_idx += 1;
        if selected.is_empty() {
            return;
        }
        let model_bytes = WireMessage::<f32>::dense_bytes(self.z.len()) as u64;
        let mut acc = vec![0.0f64; self.z.len()];
        let anchor = self.z.clone();
        let mut rngs: Vec<Pcg64> = selected
            .iter()
            .map(|&i| solve_base.fork(self.round_idx as u64, i as u64))
            .collect();
        let ys = local.sgd_prox_batch(
            &selected,
            &self.z,
            &anchor,
            self.mu,
            &mut rngs,
            &self.pool,
        );
        for y in &ys {
            for (a, &v) in acc.iter_mut().zip(y) {
                *a += v as f64;
            }
            self.events += 2; // down (model) + up (update)
            self.wire.downlink += model_bytes;
            self.wire.uplink += model_bytes;
        }
        let inv = 1.0 / selected.len() as f64;
        for (z, a) in self.z.iter_mut().zip(&acc) {
            *z = (a * inv) as f32;
        }
    }

    /// Events normalized by full communication (2N per round).
    pub fn comm_load(&self, n: usize) -> f64 {
        if self.round_idx == 0 {
            return 0.0;
        }
        self.events as f64 / (2.0 * n as f64 * self.round_idx as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_split;
    use crate::data::synth::{generate, SynthSpec};

    fn setup(seed: u64) -> (NativeFed, ClassDataset) {
        let mut rng = Pcg64::seed(seed);
        let (train, test) = generate(&SynthSpec::tiny(), &mut rng);
        let shards = iid_split(&train, 4, &mut rng);
        let spec = MlpSpec::new(vec![8, 16, 4]);
        (NativeFed::new(spec, shards, 0.1, 3, 8), test)
    }

    #[test]
    fn fedavg_learns_iid_tiny() {
        let (mut local, test) = setup(1);
        let mut rng = Pcg64::seed(2);
        let init = local.spec.init(&mut rng);
        let mut eng = AvgFamily::fedavg(init, 1.0);
        let spec = local.spec.clone();
        for _ in 0..60 {
            eng.round(&mut local, &mut rng);
        }
        let acc = spec.accuracy(&eng.z, &test.xs, &test.labels);
        assert!(acc > 0.5, "acc {acc}");
    }

    #[test]
    fn participation_rate_controls_events() {
        let (mut local, _) = setup(3);
        let mut rng = Pcg64::seed(4);
        let init = local.spec.init(&mut rng);
        let mut eng = AvgFamily::fedavg(init, 0.5);
        for _ in 0..100 {
            eng.round(&mut local, &mut rng);
        }
        // expected events = 2 * 0.5 * 4 agents * 100 rounds = 400
        let load = eng.comm_load(4);
        assert!((load - 0.5).abs() < 0.15, "load {load}");
    }

    #[test]
    fn fedprox_stays_closer_to_global_model() {
        let (mut local, _) = setup(5);
        let mut rng = Pcg64::seed(6);
        let init = local.spec.init(&mut rng);
        let z = init.clone();
        let y_avg = local.sgd_prox(0, &z, &z, 0.0, &mut Pcg64::seed(7));
        let y_prox = local.sgd_prox(0, &z, &z, 5.0, &mut Pcg64::seed(7));
        let d_avg = crate::linalg::dist2_f32(&y_avg, &z);
        let d_prox = crate::linalg::dist2_f32(&y_prox, &z);
        assert!(d_prox < d_avg, "prox {d_prox} !< avg {d_avg}");
    }

    #[test]
    fn empty_cohort_is_a_noop() {
        let (mut local, _) = setup(8);
        let mut rng = Pcg64::seed(9);
        let init = local.spec.init(&mut rng);
        let mut eng = AvgFamily::fedavg(init.clone(), 0.0);
        for _ in 0..10 {
            eng.round(&mut local, &mut rng);
        }
        assert_eq!(eng.z, init);
        assert_eq!(eng.events, 0);
        assert_eq!(eng.wire.total(), 0);
    }

    #[test]
    fn byte_tally_matches_event_count() {
        // one dense model per event, by construction
        let (mut local, _) = setup(10);
        let mut rng = Pcg64::seed(11);
        let init = local.spec.init(&mut rng);
        let dim = init.len();
        let mut eng = AvgFamily::fedavg(init, 0.7);
        for _ in 0..20 {
            eng.round(&mut local, &mut rng);
        }
        let dense = WireMessage::<f32>::dense_bytes(dim) as u64;
        assert_eq!(eng.wire.total(), eng.events * dense);
        assert_eq!(eng.wire.uplink, eng.wire.downlink);
    }
}
