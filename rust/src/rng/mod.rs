//! Deterministic random number generation substrate.
//!
//! The offline build environment ships no `rand` crate, so DELA carries its
//! own: a PCG64 (XSL-RR 128/64) generator plus the distributions the
//! experiments need — uniform, Gaussian (Box–Muller), gamma
//! (Marsaglia–Tsang), Dirichlet (normalized gammas, the paper's
//! `Dir_N(0.5)` CIFAR partitioner), Student-t (App. G.1 data generator) and
//! Bernoulli (packet drops, randomized triggers).
//!
//! Every algorithm core takes `&mut impl Rng`, so every experiment is
//! reproducible from a single seed.

/// Minimal RNG interface used across the crate.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)`.
    fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes; bias < 2^-32 for n << 2^32).
    fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean/std.
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); handles shape < 1 by
    /// boosting.
    fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^{1/a}
            let boost = self.gamma(shape + 1.0);
            let u: f64 = self.f64().max(1e-300);
            return boost * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(beta * 1_k): the paper's CIFAR-10 partitioner uses
    /// `Dir_N(0.5)` per class.
    fn dirichlet(&mut self, beta: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(beta)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Student-t with `dof` degrees of freedom (App. G.1 uses dof = 1,
    /// i.e. Cauchy). t = Z / sqrt(ChiSq_v / v), ChiSq_v = 2 * Gamma(v/2).
    fn student_t(&mut self, dof: f64) -> f64 {
        let z = self.normal();
        let chi2 = 2.0 * self.gamma(dof / 2.0);
        z / (chi2 / dof).sqrt()
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// f32 convenience.
    fn f32n(&mut self) -> f32 {
        self.normal() as f32
    }
}

/// PCG64 XSL-RR 128/64 — the same generator family numpy defaults to.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed deterministically; `stream` decorrelates parallel agents.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e39cb94b95bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0)
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Derive an independent child generator (one per agent thread).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::seed_stream(s, stream.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    /// Derive an independent child generator from the *current* state
    /// WITHOUT advancing the parent.  `salt` (e.g. the round index) and
    /// `stream` (e.g. the agent index) decorrelate forks taken from the
    /// same state.
    ///
    /// This is the primitive behind the per-agent solve streams of the
    /// parallel ADMM round core: every agent's local solve draws from
    /// `base.fork(round, agent)`, so the draw sequence is a pure function
    /// of `(base state, round, agent)` — independent of worker count and
    /// of the order in which agents are executed — while leaving the
    /// caller's stream (triggers, channels, compressors) untouched.
    pub fn fork(&self, salt: u64, stream: u64) -> Pcg64 {
        let mix = ((self.state >> 64) as u64)
            ^ (self.state as u64)
            ^ salt.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::seed_stream(mix, stream.wrapping_add(1))
    }
}

impl Rng for Pcg64 {
    fn next_u64(&mut self) -> u64 {
        self.step();
        // XSL-RR output function
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_does_not_advance_parent_and_decorrelates() {
        let parent = Pcg64::seed(42);
        let mut a = parent.clone();
        let mut b = parent.fork(3, 1);
        let mut c = parent.fork(3, 2);
        let mut d = parent.fork(4, 1);
        // parent untouched: a fresh clone continues identically
        let mut e = Pcg64::seed(42);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), e.next_u64());
        }
        // forks are reproducible...
        let mut b2 = Pcg64::seed(42).fork(3, 1);
        for _ in 0..32 {
            assert_eq!(b.next_u64(), b2.next_u64());
        }
        // ...and decorrelated across streams and salts
        let same_cd =
            (0..64).filter(|_| c.next_u64() == d.next_u64()).count();
        assert_eq!(same_cd, 0);
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = Pcg64::seed_stream(7, 1);
        let mut b = Pcg64::seed_stream(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seed(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Pcg64::seed(6);
        for &shape in &[0.5, 1.0, 2.5, 9.0] {
            let n = 100_000;
            let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var =
                xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.1 * shape.max(1.0),
                    "gamma({shape}) mean {mean}");
            assert!((var - shape).abs() < 0.2 * shape.max(1.0),
                    "gamma({shape}) var {var}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_positive() {
        let mut r = Pcg64::seed(7);
        for _ in 0..100 {
            let p = r.dirichlet(0.5, 10);
            assert_eq!(p.len(), 10);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_small_beta_is_skewed() {
        // beta = 0.05 should concentrate mass on few classes most of the
        // time — the non-iid skew the paper relies on.
        let mut r = Pcg64::seed(8);
        let mut max_mass = 0.0f64;
        for _ in 0..50 {
            let p = r.dirichlet(0.05, 10);
            max_mass = max_mass.max(p.iter().cloned().fold(0.0, f64::max));
        }
        assert!(max_mass > 0.8, "max mass {max_mass}");
    }

    #[test]
    fn student_t_heavy_tails() {
        // dof=1 (Cauchy) should produce far more |x| > 10 outliers than a
        // normal would (~0 out of 50k).
        let mut r = Pcg64::seed(9);
        let big = (0..50_000).filter(|_| r.student_t(1.0).abs() > 10.0).count();
        assert!(big > 100, "only {big} tail samples");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::seed(10);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed(11);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::seed(12);
        for _ in 0..50 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 7);
            assert!(s.iter().all(|&i| i < 20));
        }
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }

    #[test]
    fn below_covers_range() {
        let mut r = Pcg64::seed(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
