//! Dense linear-algebra substrate (f64).
//!
//! Supplies what the paper's convex experiments need: row-major dense
//! matrices, matvec/gemm, Cholesky factorization (the cached
//! `(AᵀA + ρI)⁻¹` of the exact LASSO x-update), Gram matrices, norms and
//! power-iteration spectral estimates (for `κ = L σ̄²(A)/(m σ̲²(A))`,
//! Thm. 4.1).

use crate::rng::Rng;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Matrix { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut impl Rng) -> Self {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal()).collect(),
        }
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `y = A x` — register-blocked dot rows
    /// ([`crate::kernels::mat_vec_f64`]; per-row accumulation order
    /// unchanged).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        crate::kernels::mat_vec_f64(&self.data, x, &mut y, self.rows, self.cols);
        y
    }

    /// `y = Aᵀ x`.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "tmatvec dim mismatch");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for (yj, a) in y.iter_mut().zip(row.iter()) {
                *yj += a * xi;
            }
        }
        y
    }

    /// `C = A B` (ikj loop — cache-friendly for row-major), via
    /// [`crate::kernels::gemm_acc_f64`].  The historical `aik == 0.0`
    /// zero-skip branch is gone: it mispredicted on dense data and
    /// blocked vectorization (the same §Perf rationale as the MLP
    /// forward), and skipping changes values only through `±0.0` terms
    /// (DESIGN.md §15).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul dim mismatch");
        let mut c = Matrix::zeros(self.rows, b.cols);
        crate::kernels::gemm_acc_f64(
            &self.data, &b.data, &mut c.data, self.rows, self.cols, b.cols,
        );
        c
    }

    /// Gram matrix `AᵀA` — rank-1 upper-triangle updates per data row
    /// ([`crate::kernels::syrk_upper_acc_f64`], no zero-skip), then
    /// mirrored.
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for i in 0..self.rows {
            crate::kernels::syrk_upper_acc_f64(self.row(i), &mut g.data, n);
        }
        for a in 0..n {
            for b in 0..a {
                g[(a, b)] = g[(b, a)];
            }
        }
        g
    }

    /// Order-sensitive FNV-1a digest of the matrix (shape + element
    /// bits) — the content key of the shared Cholesky cache in
    /// [`crate::solver::ExactQuadratic`].  Bit-exact equality of shape
    /// and every `f64` (including `-0.0` vs `+0.0` and NaN payloads)
    /// gives equal digests; a collision between distinct Gram matrices
    /// would silently share a factorization, at FNV's ~2⁻⁶⁴ odds —
    /// accepted for this non-adversarial, process-local cache.
    pub fn digest(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        mix(self.rows as u64);
        mix(self.cols as u64);
        for &v in &self.data {
            mix(v.to_bits());
        }
        h
    }

    /// Add `c` to the diagonal in place.
    pub fn add_diag(&mut self, c: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += c;
        }
    }

    /// Largest singular value (power iteration on `AᵀA`).
    pub fn sigma_max(&self, iters: usize, rng: &mut impl Rng) -> f64 {
        let n = self.cols;
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        normalize(&mut v);
        let mut lam = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let mut w = self.tmatvec(&av);
            lam = norm2(&w);
            if lam == 0.0 {
                return 0.0;
            }
            normalize(&mut w);
            v = w;
        }
        lam.sqrt()
    }

    /// Smallest singular value via inverse power iteration on
    /// `AᵀA + εI` (requires full column rank for a meaningful answer).
    pub fn sigma_min(&self, iters: usize, rng: &mut impl Rng) -> f64 {
        let mut g = self.gram();
        let eps = 1e-12 * (1.0 + g.data.iter().cloned().fold(0.0, f64::max));
        g.add_diag(eps);
        // lint:allow(panic-in-library): the Gram matrix plus a positive ridge is PD by construction, so the factorization cannot fail
        let chol = Cholesky::factor(&g).expect("gram not PD");
        let n = self.cols;
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        normalize(&mut v);
        let mut mu = 0.0;
        for _ in 0..iters {
            let mut w = chol.solve(&v);
            mu = norm2(&w);
            normalize(&mut w);
            v = w;
        }
        // mu approximates 1/lambda_min(G)
        (1.0 / mu).max(0.0).sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization `M = L Lᵀ` of a symmetric positive-definite
/// matrix; backs the exact quadratic prox solves.
#[derive(Clone, Debug)]
pub struct Cholesky {
    n: usize,
    l: Vec<f64>, // lower triangle, row-major full storage
}

impl Cholesky {
    pub fn factor(m: &Matrix) -> Option<Cholesky> {
        assert_eq!(m.rows, m.cols, "cholesky needs square");
        let n = m.rows;
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = m[(i, j)];
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None; // not PD
                    }
                    l[i * n + i] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Some(Cholesky { n, l })
    }

    /// Solve `M x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve `M x = b` into a reusable output buffer — the
    /// allocation-free twin of [`Self::solve`] for the per-agent prox
    /// hot path (§Perf): identical arithmetic, zero intermediate
    /// allocations.
    pub fn solve_into(&self, b: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(b);
        self.solve_in_place(out);
    }

    /// Solve `M x = b` in place (`x` holds `b` on entry, the solution on
    /// exit).  Both triangular passes run in the buffer itself: the
    /// forward pass reads `x[k < i]` (already `y`) and `x[i]` (still
    /// `b`); the backward pass reads `x[k > i]` (already the solution)
    /// and `x[i]` (still `y`) — bit-identical to the two-buffer form.
    pub fn solve_in_place(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        let n = self.n;
        // forward: L y = b
        for i in 0..n {
            let mut sum = x[i];
            for k in 0..i {
                sum -= self.l[i * n + k] * x[k];
            }
            x[i] = sum / self.l[i * n + i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut sum = x[i];
            for k in i + 1..n {
                sum -= self.l[k * n + i] * x[k];
            }
            x[i] = sum / self.l[i * n + i];
        }
    }
}

// ---------------------------------------------------------------------------
// Vector helpers (used across admm/comm/lasso)
// ---------------------------------------------------------------------------

pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

pub fn norm2_f32(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

pub fn dist2_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
        .sum::<f64>()
        .sqrt()
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    crate::kernels::axpy_f64(y, a, x);
}

pub fn normalize(x: &mut [f64]) {
    let n = norm2(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
}

/// Elementwise soft-threshold — the prox of `tau * |.|_1` (mirrors the L1
/// Pallas kernel; differential-tested against the artifact in
/// `tests/pjrt_roundtrip.rs`).
pub fn soft_threshold(v: &[f64], tau: f64) -> Vec<f64> {
    v.iter()
        .map(|&x| x.signum() * (x.abs() - tau).max(0.0))
        .collect()
}

/// Elementwise soft-threshold into a reusable buffer — the
/// allocation-free twin of [`soft_threshold`] for hot loops (the FISTA
/// reference solver and the per-round z-prox paths).  Identical values.
pub fn soft_threshold_into(v: &[f64], tau: f64, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(v.len());
    out.extend(v.iter().map(|&x| x.signum() * (x.abs() - tau).max(0.0)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matvec_identity() {
        let m = Matrix::eye(4);
        let x = vec![1.0, -2.0, 3.0, 0.5];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn matvec_known() {
        let m = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(m.tmatvec(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn matmul_vs_matvec() {
        let mut rng = Pcg64::seed(1);
        let a = Matrix::randn(5, 7, &mut rng);
        let b = Matrix::randn(7, 3, &mut rng);
        let c = a.matmul(&b);
        for j in 0..3 {
            let col: Vec<f64> = (0..7).map(|k| b[(k, j)]).collect();
            let want = a.matvec(&col);
            for i in 0..5 {
                assert!((c[(i, j)] - want[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matmul_includes_exact_zero_entries() {
        // the zero-skip removal: a matrix with exact zeros multiplies
        // bit-identically to the dense triple loop
        let a = Matrix::from_rows(vec![
            vec![0.0, 1.0, 0.0],
            vec![2.0, 0.0, -3.0],
        ]);
        let mut rng = Pcg64::seed(9);
        let b = Matrix::randn(3, 4, &mut rng);
        let c = a.matmul(&b);
        let mut want = Matrix::zeros(2, 4);
        for i in 0..2 {
            for k in 0..3 {
                for j in 0..4 {
                    want[(i, j)] += a[(i, k)] * b[(k, j)];
                }
            }
        }
        assert_eq!(c.data, want.data);
    }

    #[test]
    fn digest_is_content_keyed() {
        let mut rng = Pcg64::seed(17);
        let a = Matrix::randn(4, 3, &mut rng);
        let b = a.clone();
        assert_eq!(a.digest(), b.digest());
        let mut c = a.clone();
        c[(2, 1)] += 1.0; // any bit flip changes the digest
        assert_ne!(a.digest(), c.digest());
        // shape participates even when the data bits agree
        let flat = Matrix { rows: 3, cols: 4, data: a.data.clone() };
        assert_ne!(a.digest(), flat.digest());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(2);
        let a = Matrix::randn(4, 6, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Pcg64::seed(3);
        let a = Matrix::randn(6, 4, &mut rng);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_solve_roundtrip() {
        let mut rng = Pcg64::seed(4);
        let a = Matrix::randn(8, 5, &mut rng);
        let mut g = a.gram();
        g.add_diag(0.5);
        let chol = Cholesky::factor(&g).unwrap();
        let x_true: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let b = g.matvec(&x_true);
        let x = chol.solve(&b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Matrix::from_rows(vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
        assert!(Cholesky::factor(&m).is_none());
    }

    #[test]
    fn sigma_bounds_on_identity() {
        let mut rng = Pcg64::seed(5);
        let m = Matrix::eye(6);
        assert!((m.sigma_max(50, &mut rng) - 1.0).abs() < 1e-6);
        assert!((m.sigma_min(50, &mut rng) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn sigma_max_dominates_matvec_gain() {
        let mut rng = Pcg64::seed(6);
        let a = Matrix::randn(20, 10, &mut rng);
        let smax = a.sigma_max(100, &mut rng);
        for _ in 0..20 {
            let x: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
            let gain = norm2(&a.matvec(&x)) / norm2(&x);
            assert!(gain <= smax * (1.0 + 1e-6), "gain {gain} > {smax}");
        }
    }

    #[test]
    fn sigma_min_is_lower_bound() {
        let mut rng = Pcg64::seed(7);
        let a = Matrix::randn(30, 8, &mut rng);
        let smin = a.sigma_min(200, &mut rng);
        for _ in 0..20 {
            let x: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
            let gain = norm2(&a.matvec(&x)) / norm2(&x);
            assert!(gain >= smin * (1.0 - 1e-3), "gain {gain} < {smin}");
        }
    }

    #[test]
    fn soft_threshold_known() {
        let out = soft_threshold(&[-0.5, -0.1, 0.0, 0.1, 0.5], 0.2);
        let want = [-0.3, 0.0, 0.0, 0.0, 0.3];
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn soft_threshold_into_matches_and_reuses_capacity() {
        let mut rng = Pcg64::seed(9);
        let v: Vec<f64> = (0..64).map(|_| 3.0 * rng.normal()).collect();
        let mut buf = Vec::with_capacity(64);
        let cap = buf.capacity();
        for tau in [0.0, 0.1, 1.0] {
            soft_threshold_into(&v, tau, &mut buf);
            assert_eq!(buf, soft_threshold(&v, tau), "tau = {tau}");
        }
        assert_eq!(buf.capacity(), cap, "hot path must not reallocate");
    }

    #[test]
    fn cholesky_solve_into_matches_solve() {
        let mut rng = Pcg64::seed(10);
        let a = Matrix::randn(9, 6, &mut rng);
        let mut g = a.gram();
        g.add_diag(0.7);
        let chol = Cholesky::factor(&g).unwrap();
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let want = chol.solve(&b);
        let mut out = Vec::with_capacity(6);
        let cap = out.capacity();
        chol.solve_into(&b, &mut out);
        assert_eq!(out, want, "solve_into must be bit-identical");
        chol.solve_into(&b, &mut out);
        assert_eq!(out, want);
        assert_eq!(out.capacity(), cap, "hot path must not reallocate");
        let mut in_place = b.clone();
        chol.solve_in_place(&mut in_place);
        assert_eq!(in_place, want);
    }

    #[test]
    fn vector_ops() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!((dist2(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-15);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-15);
        let mut y = vec![1.0, 1.0];
        axpy(&mut y, 2.0, &[1.0, -1.0]);
        assert_eq!(y, vec![3.0, -1.0]);
        let mut v = vec![0.0, 3.0, 4.0];
        normalize(&mut v);
        assert!((norm2(&v) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn f32_helpers() {
        assert!((norm2_f32(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((dist2_f32(&[0.0], &[2.0]) - 2.0).abs() < 1e-6);
    }
}
