//! Lossy-link simulation — the paper's packet-drop model.
//!
//! A sent delta is lost with probability `drop_rate`; the *sender does not
//! learn about the loss* (no acknowledgements), which is exactly why the
//! paper needs the periodic reset strategy (App. E, Fig. 10): receiver
//! estimates drift by the accumulated `χ` disturbances until a reset
//! re-synchronizes them.
//!
//! [`LossyLink`] originated under [`crate::comm`]; the loss process is
//! transport-level state, so the transport redesign moved it here
//! (`crate::comm` still re-exports the stats/model types).

use crate::rng::Rng;

/// Per-link transmission counters — messages *and* wire bytes (the byte
/// totals are charged with each message's exact encoded size, see
/// [`crate::wire::WireMessage::wire_bytes`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChannelStats {
    pub sent: u64,
    pub dropped: u64,
    /// Bytes put on the wire (delivered or not).
    pub sent_bytes: u64,
    /// Bytes lost in flight.
    pub dropped_bytes: u64,
}

impl ChannelStats {
    pub fn delivered(&self) -> u64 {
        self.sent - self.dropped
    }
    pub fn delivered_bytes(&self) -> u64 {
        self.sent_bytes - self.dropped_bytes
    }
    pub fn drop_fraction(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.dropped as f64 / self.sent as f64
        }
    }

    /// Charge a message that bypasses the lossy channel (the periodic
    /// resets are full, reliable synchronization messages — they count as
    /// traffic but can never drop).
    pub fn record_reliable(&mut self, bytes: u64) {
        self.sent += 1;
        self.sent_bytes += bytes;
    }
}

/// A packet-loss process for one link.  `Bernoulli` is the paper's i.i.d.
/// drop model; `GilbertElliott` is the standard two-state Markov burst
/// model (a good link that occasionally degrades into a lossy burst),
/// which the discrete-event simulator uses for correlated failures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LossModel {
    /// Never drops.
    None,
    /// i.i.d. drops with probability `p` (the paper's `χ` disturbances).
    Bernoulli { p: f64 },
    /// Two-state burst loss: transition good→bad w.p. `p_gb`, bad→good
    /// w.p. `p_bg` (evaluated per transmission), dropping w.p.
    /// `loss_good` / `loss_bad` in the respective state.
    GilbertElliott { p_gb: f64, p_bg: f64, loss_good: f64, loss_bad: f64 },
}

impl LossModel {
    /// Sample one transmission: evolve the chain state (`bad`) and return
    /// `true` iff the packet is lost.  `None` and `Bernoulli { p: 0 }`
    /// draw nothing from the RNG (the sim's sync-equivalence contract).
    pub fn sample(&self, bad: &mut bool, rng: &mut impl Rng) -> bool {
        match *self {
            LossModel::None => false,
            LossModel::Bernoulli { p } => p > 0.0 && rng.bernoulli(p),
            LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad } => {
                if *bad {
                    if rng.bernoulli(p_bg) {
                        *bad = false;
                    }
                } else if rng.bernoulli(p_gb) {
                    *bad = true;
                }
                let p = if *bad { loss_bad } else { loss_good };
                p > 0.0 && rng.bernoulli(p)
            }
        }
    }

    /// Parse the CLI/scenario syntax:
    /// `none` | `bernoulli:P` | `ge:PGB:PBG:LOSS_GOOD:LOSS_BAD`.
    pub fn parse(s: &str) -> Result<LossModel, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let prob = |i: usize, what: &str| -> Result<f64, String> {
            let p: f64 = parts
                .get(i)
                .ok_or_else(|| format!("{s:?}: missing {what}"))?
                .parse()
                .map_err(|_| format!("{s:?}: bad {what}"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("{s:?}: {what} must be in [0,1]"));
            }
            Ok(p)
        };
        match parts[0] {
            "none" => Ok(LossModel::None),
            "bernoulli" | "bern" => {
                Ok(LossModel::Bernoulli { p: prob(1, "drop probability")? })
            }
            "ge" => Ok(LossModel::GilbertElliott {
                p_gb: prob(1, "p_gb")?,
                p_bg: prob(2, "p_bg")?,
                loss_good: prob(3, "loss_good")?,
                loss_bad: prob(4, "loss_bad")?,
            }),
            other => Err(format!(
                "unknown loss model {other:?} (expected none | bernoulli:P \
                 | ge:PGB:PBG:LG:LB)"
            )),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            LossModel::None => "none".into(),
            LossModel::Bernoulli { p } => format!("bernoulli:{p}"),
            LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad } => {
                format!("ge:{p_gb}:{p_bg}:{loss_good}:{loss_bad}")
            }
        }
    }
}

/// A lossy point-to-point link.
#[derive(Clone, Debug)]
pub struct LossyLink {
    pub drop_rate: f64,
    /// Generalized loss process; `None` uses the i.i.d. `drop_rate`
    /// Bernoulli model (so mutating `drop_rate` keeps working and the
    /// legacy RNG stream is untouched).
    loss: Option<LossModel>,
    /// Gilbert–Elliott chain state.
    bad: bool,
    /// Bytes of a packet dropped at the current round's transmit
    /// opportunity (cleared by [`Self::mark_round`]) — feeds the
    /// reset-supersession accounting rule of [`Self::charge_sync`].
    last_drop: Option<u64>,
    pub stats: ChannelStats,
}

impl LossyLink {
    pub fn new(drop_rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&drop_rate), "drop_rate in [0,1]");
        LossyLink {
            drop_rate,
            loss: None,
            bad: false,
            last_drop: None,
            stats: ChannelStats::default(),
        }
    }

    /// A link with a generalized loss process (burst drops etc.).  The
    /// public `drop_rate` field becomes informational only — it is set
    /// to the process's *stationary average* loss rate (for display)
    /// and mutating it has no effect on a model-driven channel; use a
    /// fresh `with_model` to change the process.
    pub fn with_model(loss: LossModel) -> Self {
        let drop_rate = match loss {
            LossModel::None => 0.0,
            LossModel::Bernoulli { p } => p,
            LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad } => {
                // stationary bad-state mass of the two-state chain
                let pi_bad = if p_gb + p_bg > 0.0 {
                    p_gb / (p_gb + p_bg)
                } else {
                    0.0
                };
                pi_bad * loss_bad + (1.0 - pi_bad) * loss_good
            }
        };
        LossyLink {
            drop_rate,
            loss: Some(loss),
            bad: false,
            last_drop: None,
            stats: ChannelStats::default(),
        }
    }

    /// A perfect link.
    pub fn reliable() -> Self {
        LossyLink::new(0.0)
    }

    /// Transmit a payload; `None` means the packet was dropped in flight.
    pub fn transmit<T>(&mut self, payload: T, rng: &mut impl Rng) -> Option<T> {
        self.transmit_bytes(payload, 0, rng)
    }

    /// Transmit a payload of known wire size, charging the byte counters.
    pub fn transmit_bytes<T>(
        &mut self,
        payload: T,
        bytes: u64,
        rng: &mut impl Rng,
    ) -> Option<T> {
        self.stats.sent += 1;
        self.stats.sent_bytes += bytes;
        let dropped = match self.loss {
            None => self.drop_rate > 0.0 && rng.bernoulli(self.drop_rate),
            Some(m) => m.sample(&mut self.bad, rng),
        };
        if dropped {
            self.stats.dropped += 1;
            self.stats.dropped_bytes += bytes;
            self.last_drop = Some(bytes);
            None
        } else {
            Some(payload)
        }
    }

    /// Open the link's per-round transmit opportunity: forget any drop
    /// recorded in the previous round so [`Self::charge_sync`] only
    /// supersedes a *same-round* loss.  Engines call this once per round
    /// per line, before the trigger is offered.
    pub fn mark_round(&mut self) {
        self.last_drop = None;
    }

    /// Charge a reset's full dense synchronization transfer.  If this
    /// round's triggered packet was dropped, the reset supersedes it: the
    /// lost packet is removed from the counters so the round bills
    /// exactly one dense sync instead of a dropped delta *plus* a sync
    /// (the accounting rule pinned by
    /// `reset_supersedes_same_round_dropped_packet`).
    pub fn charge_sync(&mut self, sync_bytes: u64) {
        if let Some(b) = self.last_drop.take() {
            self.stats.sent -= 1;
            self.stats.sent_bytes -= b;
            self.stats.dropped -= 1;
            self.stats.dropped_bytes -= b;
        }
        self.stats.record_reliable(sync_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn reliable_never_drops() {
        let mut ch = LossyLink::reliable();
        let mut rng = Pcg64::seed(0);
        for i in 0..1000 {
            assert_eq!(ch.transmit(i, &mut rng), Some(i));
        }
        assert_eq!(ch.stats.dropped, 0);
        assert_eq!(ch.stats.sent, 1000);
    }

    #[test]
    fn full_loss_drops_everything() {
        let mut ch = LossyLink::new(1.0);
        let mut rng = Pcg64::seed(1);
        for i in 0..100 {
            assert_eq!(ch.transmit(i, &mut rng), None);
        }
        assert_eq!(ch.stats.dropped, 100);
    }

    #[test]
    fn drop_rate_is_respected() {
        let mut ch = LossyLink::new(0.3);
        let mut rng = Pcg64::seed(2);
        for _ in 0..50_000 {
            ch.transmit((), &mut rng);
        }
        let frac = ch.stats.drop_fraction();
        assert!((frac - 0.3).abs() < 0.01, "drop fraction {frac}");
        assert_eq!(ch.stats.delivered() + ch.stats.dropped, ch.stats.sent);
    }

    #[test]
    fn rejects_bad_rate() {
        let res = std::panic::catch_unwind(|| LossyLink::new(1.5));
        assert!(res.is_err());
    }

    #[test]
    fn byte_counters_track_sent_and_dropped() {
        let mut ch = LossyLink::new(0.5);
        let mut rng = Pcg64::seed(4);
        for _ in 0..10_000 {
            ch.transmit_bytes((), 100, &mut rng);
        }
        assert_eq!(ch.stats.sent_bytes, 1_000_000);
        assert_eq!(ch.stats.dropped_bytes, ch.stats.dropped * 100);
        assert_eq!(
            ch.stats.delivered_bytes(),
            ch.stats.delivered() * 100
        );
    }

    #[test]
    fn reliable_messages_count_traffic_but_never_drop() {
        let mut ch = LossyLink::new(1.0);
        ch.stats.record_reliable(42);
        assert_eq!(ch.stats.sent, 1);
        assert_eq!(ch.stats.sent_bytes, 42);
        assert_eq!(ch.stats.dropped, 0);
    }

    #[test]
    fn charge_sync_supersedes_same_round_drop() {
        // round: triggered packet drops, then a reset syncs the link —
        // the books must show exactly one (dense sync) message.
        let mut ch = LossyLink::new(1.0);
        let mut rng = Pcg64::seed(5);
        ch.mark_round();
        assert_eq!(ch.transmit_bytes((), 100, &mut rng), None);
        ch.charge_sync(800);
        assert_eq!(ch.stats.sent, 1);
        assert_eq!(ch.stats.sent_bytes, 800);
        assert_eq!(ch.stats.dropped, 0);
        assert_eq!(ch.stats.dropped_bytes, 0);
    }

    #[test]
    fn charge_sync_does_not_supersede_earlier_round_drop() {
        let mut ch = LossyLink::new(1.0);
        let mut rng = Pcg64::seed(6);
        // round 1: drop
        ch.mark_round();
        assert_eq!(ch.transmit_bytes((), 100, &mut rng), None);
        // round 2: no transmit, but a reset fires — the round-1 drop is
        // real traffic and must stay on the books
        ch.mark_round();
        ch.charge_sync(800);
        assert_eq!(ch.stats.sent, 2);
        assert_eq!(ch.stats.sent_bytes, 900);
        assert_eq!(ch.stats.dropped, 1);
        assert_eq!(ch.stats.dropped_bytes, 100);
    }

    #[test]
    fn charge_sync_keeps_delivered_packet_on_the_books() {
        // a delivered delta followed by a reset is two real transfers
        let mut ch = LossyLink::new(0.0);
        let mut rng = Pcg64::seed(7);
        ch.mark_round();
        assert!(ch.transmit_bytes((), 100, &mut rng).is_some());
        ch.charge_sync(800);
        assert_eq!(ch.stats.sent, 2);
        assert_eq!(ch.stats.sent_bytes, 900);
        assert_eq!(ch.stats.dropped, 0);
    }

    #[test]
    fn loss_model_none_and_bernoulli_rates() {
        let mut rng = Pcg64::seed(8);
        let mut bad = false;
        assert!(!LossModel::None.sample(&mut bad, &mut rng));
        let m = LossModel::Bernoulli { p: 0.4 };
        let hits =
            (0..50_000).filter(|_| m.sample(&mut bad, &mut rng)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.4).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn gilbert_elliott_bursts() {
        // loss only in the bad state: drops must arrive in runs whose
        // mean length ~ 1/p_bg, far burstier than i.i.d. at the same
        // average rate.
        let m = LossModel::GilbertElliott {
            p_gb: 0.02,
            p_bg: 0.2,
            loss_good: 0.0,
            loss_bad: 1.0,
        };
        let mut rng = Pcg64::seed(9);
        let mut bad = false;
        let outcomes: Vec<bool> =
            (0..100_000).map(|_| m.sample(&mut bad, &mut rng)).collect();
        let drops = outcomes.iter().filter(|&&d| d).count();
        // stationary bad fraction = p_gb / (p_gb + p_bg) ~ 0.09
        let frac = drops as f64 / outcomes.len() as f64;
        assert!((0.03..0.2).contains(&frac), "drop fraction {frac}");
        // burstiness: count drop->drop adjacencies; i.i.d. at `frac`
        // would give ~frac^2 per pair, the chain gives ~frac*(1-p_bg)
        let pairs = outcomes.windows(2).filter(|w| w[0] && w[1]).count();
        let adj = pairs as f64 / (outcomes.len() - 1) as f64;
        assert!(
            adj > 2.0 * frac * frac,
            "adjacency {adj} not bursty vs iid {}",
            frac * frac
        );
    }

    #[test]
    fn gilbert_elliott_all_bad_drops_everything() {
        let mut ch = LossyLink::with_model(LossModel::GilbertElliott {
            p_gb: 1.0,
            p_bg: 0.0,
            loss_good: 0.0,
            loss_bad: 1.0,
        });
        // informational rate = stationary average = pi_bad * loss_bad
        assert!((ch.drop_rate - 1.0).abs() < 1e-12);
        let mut rng = Pcg64::seed(10);
        for _ in 0..100 {
            // first transmit already transitions good->bad (p_gb = 1)
            assert_eq!(ch.transmit((), &mut rng), None);
        }
        assert_eq!(ch.stats.dropped, 100);
    }

    #[test]
    fn with_model_reports_stationary_average_rate() {
        let ch = LossyLink::with_model(LossModel::GilbertElliott {
            p_gb: 0.1,
            p_bg: 0.3,
            loss_good: 0.0,
            loss_bad: 0.8,
        });
        // pi_bad = 0.1/0.4 = 0.25; average = 0.25 * 0.8 = 0.2
        assert!((ch.drop_rate - 0.2).abs() < 1e-12, "{}", ch.drop_rate);
        let b = LossyLink::with_model(LossModel::Bernoulli { p: 0.3 });
        assert_eq!(b.drop_rate, 0.3);
    }

    #[test]
    fn loss_model_parse_roundtrip() {
        for s in ["none", "bernoulli:0.3", "ge:0.02:0.2:0:1"] {
            let m = LossModel::parse(s).unwrap();
            assert_eq!(LossModel::parse(&m.label()).unwrap(), m);
        }
        assert!(LossModel::parse("bernoulli:1.5").is_err());
        assert!(LossModel::parse("bogus").is_err());
        assert!(LossModel::parse("ge:0.1:0.2:0.3").is_err());
    }
}
