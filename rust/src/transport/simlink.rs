//! The discrete-event cost model as a transport: in-process agent
//! threads behind [`crate::sim::link::Link`]s.
//!
//! `SimLink` reuses the [`InProc`] thread fabric but routes every
//! downlink payload through the simulator's per-link latency /
//! bandwidth / burst-loss model, advancing a virtual clock by the
//! slowest link each round (the synchronous round barrier waits for
//! the last delivery).  Uplink replies return at the next barrier —
//! downlink-only delay modeling is the v1 adaptation; the full
//! per-direction async cadence stays in [`crate::sim::engine`].
//!
//! Under [`crate::sim::link::LinkModel::ideal`] links nothing is drawn
//! from the RNG and no virtual time passes, so an ideal `SimLink` run
//! is bit-identical to [`InProc`] (pinned by a coordinator test).

use crate::rng::Pcg64;
use crate::sim::event::{ticks, SimTime};
use crate::sim::link::{Link, LinkModel};
use crate::wire::{LinkStats, WireMessage, WireStats};

use crate::coordinator::AgentEndpoint;

use super::frame::Frame;
use super::inproc::Mesh;
use super::{Transport, TransportEvent, UplinkBooks};

/// In-process transport with the simulator's link cost model on each
/// downlink.
pub struct SimLink {
    mesh: Mesh,
    links: Vec<Link>,
    uplink: UplinkBooks,
    vtime: SimTime,
    round_max: SimTime,
    /// Delay drawn for the most recent send (0 for un-delayed frames),
    /// surfaced per transmit span via `last_send_vtime_us`.
    last_send: SimTime,
}

impl SimLink {
    /// One thread per endpoint, every downlink sharing `model`.
    pub fn spawn(endpoints: Vec<AgentEndpoint>, model: LinkModel) -> SimLink {
        let n = endpoints.len();
        SimLink::spawn_with(endpoints, vec![model; n])
    }

    /// Heterogeneous links: `models[i]` is agent i's downlink.
    pub fn spawn_with(
        endpoints: Vec<AgentEndpoint>,
        models: Vec<LinkModel>,
    ) -> SimLink {
        assert_eq!(endpoints.len(), models.len());
        let n = endpoints.len();
        SimLink {
            mesh: Mesh::spawn(endpoints),
            links: models.into_iter().map(Link::new).collect(),
            uplink: UplinkBooks::new(n),
            vtime: 0,
            round_max: 0,
            last_send: 0,
        }
    }

    /// Virtual clock in integer ticks (µs).
    pub fn vtime_ticks(&self) -> SimTime {
        self.vtime
    }

    /// Virtual clock in seconds.
    pub fn vtime_secs(&self) -> f64 {
        self.vtime as f64 / ticks(1.0) as f64
    }
}

impl Transport for SimLink {
    fn n_agents(&self) -> usize {
        self.mesh.n()
    }

    /// Close the previous round's barrier: the slowest downlink delay
    /// becomes elapsed virtual time.
    fn begin_round(&mut self) {
        self.vtime += self.round_max;
        self.round_max = 0;
    }

    fn send(
        &mut self,
        to: usize,
        frame: Frame,
        rng: &mut Pcg64,
    ) -> anyhow::Result<()> {
        let frame = match frame {
            Frame::Round { zdelta: Some(msg) } => {
                let bytes = msg.wire_bytes() as u64;
                match self.links[to].transmit(bytes, rng) {
                    Some(delay) => {
                        self.round_max = self.round_max.max(delay);
                        self.last_send = delay;
                        Frame::Round { zdelta: Some(msg) }
                    }
                    // lost in flight: the agent still gets its round
                    // tick (pure control latency, no bytes)
                    None => {
                        let d = self.links[to].control_delay(rng);
                        self.round_max = self.round_max.max(d);
                        self.last_send = d;
                        Frame::Round { zdelta: None }
                    }
                }
            }
            Frame::Round { zdelta: None } => {
                let d = self.links[to].control_delay(rng);
                self.round_max = self.round_max.max(d);
                self.last_send = d;
                Frame::Round { zdelta: None }
            }
            Frame::Reset { z } => {
                let sync = WireMessage::<f32>::dense_bytes(z.len()) as u64;
                // same accounting rule as the in-proc coordinator: a
                // reset is reliable charged traffic (no supersession —
                // the leader's reset cadence is round-based, not
                // offer-based)
                self.links[to].stats.record_reliable(sync);
                self.last_send = 0;
                Frame::Reset { z }
            }
            other => {
                self.last_send = 0;
                other
            }
        };
        // lint:allow(unaccounted-send): bytes were charged on the sim link above; the mesh hop is the in-process delivery, not a wire hop
        self.mesh.send(to, frame)
    }

    fn recv(&mut self) -> anyhow::Result<TransportEvent> {
        let (from, frame) = self.mesh.recv()?;
        let ev = TransportEvent::Frame { from, frame };
        self.uplink.observe(&ev);
        Ok(ev)
    }

    fn poll(&mut self) -> Option<TransportEvent> {
        let (from, frame) = self.mesh.try_recv()?;
        let ev = TransportEvent::Frame { from, frame };
        self.uplink.observe(&ev);
        Some(ev)
    }

    fn stats(&self) -> WireStats {
        WireStats {
            uplink: self.uplink.snapshot(),
            downlink: self
                .links
                .iter()
                .map(|l| LinkStats::from(&l.stats))
                .collect(),
        }
    }

    fn label(&self) -> &'static str {
        "simlink"
    }

    /// The virtual clock is deterministic state (ticks are µs), so it
    /// may appear in the journal's deterministic fields.
    fn vtime_us(&self) -> Option<u64> {
        Some(self.vtime)
    }

    /// Per-send delay, drawn deterministically from the caller's RNG —
    /// the transmit spans' virtual-time cost.
    fn last_send_vtime_us(&self) -> Option<u64> {
        Some(self.last_send)
    }

    fn shutdown(&mut self) -> anyhow::Result<()> {
        // account for the final round's deliveries before the books close
        self.vtime += self.round_max;
        self.round_max = 0;
        self.mesh.join_all();
        Ok(())
    }
}
