//! Real sockets: the leader side of the TCP / Unix-domain transport.
//!
//! Wire format is [`super::frame`]'s length-prefixed codec.  A
//! connecting agent opens with [`Frame::Hello`] carrying its agent id,
//! its [`crate::config::RunConfig::digest`] and its model dimension;
//! the acceptor validates all three against the serving run (plus
//! slot-not-taken) and answers [`Frame::Welcome`] — a mismatched or
//! duplicate agent is rejected at accept time instead of silently
//! diverging.  After the initial cohort forms, any further successful
//! handshake surfaces as [`TransportEvent::Joined`], which the
//! coordinator answers with a `Reset` resync (crash recovery rides the
//! existing reset path).
//!
//! Threading: one acceptor thread polls the listener; each accepted
//! link gets a reader thread that turns frames (or EOF/IO errors) into
//! [`TransportEvent`]s on a single mpsc queue.  Writes happen on the
//! caller's thread through a cloned stream handle.  Per-link byte
//! books use the same [`LossyLink`] charging as [`super::InProc`] —
//! with [`LossyLink::reliable`] links that draw nothing, a no-loss TCP
//! run replays the in-proc RNG stream exactly (the bitwise loopback
//! test).

use std::collections::VecDeque;
use std::io;
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::rng::Pcg64;
use crate::wire::{LinkStats, WireMessage, WireStats};

use super::frame::{read_frame, write_frame, Frame};
use super::loss::LossyLink;
use super::{Transport, TransportEvent, UplinkBooks};

/// Socket-level knobs shared by TCP and UDS.
#[derive(Clone, Debug)]
pub struct SocketOpts {
    /// Leader-side gather timeout: how long [`Transport::recv`] blocks
    /// before reporting [`TransportEvent::Timeout`].
    pub read_timeout_ms: u64,
    /// Per-connection handshake deadline (Hello must arrive within it).
    pub handshake_timeout_ms: u64,
    /// Write timeout on every established link.
    pub write_timeout_ms: u64,
    /// Cohort-formation patience: [`SocketTransport::await_cohort`]
    /// fails if no new agent arrives for this long.
    pub accept_wait_ms: u64,
}

impl Default for SocketOpts {
    fn default() -> Self {
        SocketOpts {
            read_timeout_ms: 10_000,
            handshake_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            accept_wait_ms: 30_000,
        }
    }
}

/// A duplex byte stream the socket transport can run over.
pub trait NetStream: io::Read + io::Write + Send + Sized + 'static {
    fn try_clone_stream(&self) -> io::Result<Self>;
    /// Force blocking mode (accepted sockets may inherit the listener's
    /// non-blocking flag on some platforms).
    fn set_blocking(&self) -> io::Result<()>;
    fn set_stream_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> io::Result<()>;
    fn shutdown_both(&self) -> io::Result<()>;
}

impl NetStream for TcpStream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        self.try_clone()
    }

    fn set_blocking(&self) -> io::Result<()> {
        self.set_nonblocking(false)?;
        // small frames, synchronous rounds: Nagle only adds latency
        self.set_nodelay(true)
    }

    fn set_stream_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> io::Result<()> {
        self.set_read_timeout(read)?;
        self.set_write_timeout(write)
    }

    fn shutdown_both(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }
}

#[cfg(unix)]
impl NetStream for UnixStream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        self.try_clone()
    }

    fn set_blocking(&self) -> io::Result<()> {
        self.set_nonblocking(false)
    }

    fn set_stream_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> io::Result<()> {
        self.set_read_timeout(read)?;
        self.set_write_timeout(write)
    }

    fn shutdown_both(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }
}

/// A listener that yields [`NetStream`]s.
pub trait NetListener: Send + Sized + 'static {
    type Stream: NetStream;
    fn bind_to(addr: &str) -> io::Result<Self>;
    fn accept_stream(&self) -> io::Result<Self::Stream>;
    fn set_listener_nonblocking(&self, v: bool) -> io::Result<()>;
    /// The actually-bound address, when meaningful (`127.0.0.1:0`
    /// resolves to a real ephemeral port).
    fn bound_label(&self) -> Option<String>;
    fn kind_label() -> &'static str;
}

impl NetListener for TcpListener {
    type Stream = TcpStream;

    fn bind_to(addr: &str) -> io::Result<Self> {
        TcpListener::bind(addr)
    }

    fn accept_stream(&self) -> io::Result<TcpStream> {
        self.accept().map(|(s, _)| s)
    }

    fn set_listener_nonblocking(&self, v: bool) -> io::Result<()> {
        self.set_nonblocking(v)
    }

    fn bound_label(&self) -> Option<String> {
        self.local_addr().ok().map(|a| a.to_string())
    }

    fn kind_label() -> &'static str {
        "tcp"
    }
}

#[cfg(unix)]
impl NetListener for UnixListener {
    type Stream = UnixStream;

    fn bind_to(addr: &str) -> io::Result<Self> {
        // a stale socket file from a crashed leader would make rebinding
        // fail forever; replacing it is the standard UDS idiom
        let _ = std::fs::remove_file(addr);
        UnixListener::bind(addr)
    }

    fn accept_stream(&self) -> io::Result<UnixStream> {
        self.accept().map(|(s, _)| s)
    }

    fn set_listener_nonblocking(&self, v: bool) -> io::Result<()> {
        self.set_nonblocking(v)
    }

    fn bound_label(&self) -> Option<String> {
        None
    }

    fn kind_label() -> &'static str {
        "uds"
    }
}

/// TCP instantiation of the socket transport.
pub type Tcp = SocketTransport<TcpListener>;

/// Unix-domain-socket instantiation of the socket transport.
#[cfg(unix)]
pub type Uds = SocketTransport<UnixListener>;

/// Leader-side state shared with the acceptor and reader threads.
struct Shared {
    connected: Vec<AtomicBool>,
    stop: AtomicBool,
    /// Current round index, stamped into `Welcome` so a rejoining agent
    /// can log where it re-entered.
    round: AtomicU64,
    rejected: AtomicU64,
    /// Latest coordinator status snapshot (JSON), served to
    /// [`Frame::StatusReq`] probes by the acceptor thread.  Empty until
    /// the coordinator publishes one via `Transport::set_status`.
    status: Mutex<String>,
}

/// The leader end of a process-per-agent cohort over real sockets.
pub struct SocketTransport<L: NetListener> {
    n: usize,
    writers: Vec<Option<L::Stream>>,
    links: Vec<LossyLink>,
    uplink: UplinkBooks,
    pending: VecDeque<TransportEvent>,
    ctl_rx: Receiver<(usize, L::Stream)>,
    ev_rx: Receiver<TransportEvent>,
    ev_tx: Sender<TransportEvent>,
    shared: Arc<Shared>,
    addr: String,
    opts: SocketOpts,
    acceptor: Option<JoinHandle<()>>,
    cleanup_path: Option<PathBuf>,
}

impl<L: NetListener> SocketTransport<L> {
    /// Bind and start accepting.  Returns immediately (so callers can
    /// learn an ephemeral port via [`Self::local_addr`] before any
    /// agent exists); call [`Self::await_cohort`] to block until all
    /// `n_agents` slots completed the handshake.
    pub fn bind(
        addr: &str,
        n_agents: usize,
        digest: u64,
        dim: usize,
        opts: SocketOpts,
    ) -> anyhow::Result<SocketTransport<L>> {
        assert!(n_agents > 0, "cohort must have at least one agent");
        let listener = L::bind_to(addr).map_err(|e| {
            anyhow::anyhow!("bind {} listener on {addr}: {e}", L::kind_label())
        })?;
        let bound = listener.bound_label().unwrap_or_else(|| addr.to_string());
        listener.set_listener_nonblocking(true)?;
        let shared = Arc::new(Shared {
            connected: (0..n_agents).map(|_| AtomicBool::new(false)).collect(),
            stop: AtomicBool::new(false),
            round: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            status: Mutex::new(String::new()),
        });
        let (ctl_tx, ctl_rx) = channel();
        let (ev_tx, ev_rx) = channel();
        let acceptor = {
            let shared = shared.clone();
            let ev_tx = ev_tx.clone();
            let opts = opts.clone();
            std::thread::Builder::new()
                .name("dela-accept".into())
                .spawn(move || {
                    acceptor_loop::<L>(
                        listener, n_agents, digest, dim as u32, shared,
                        ctl_tx, ev_tx, opts,
                    )
                })
                // lint:allow(panic-in-library): thread spawn fails only on OS resource exhaustion; no meaningful recovery exists here
                .expect("spawn acceptor thread")
        };
        let cleanup_path = if L::kind_label() == "uds" {
            Some(PathBuf::from(addr))
        } else {
            None
        };
        Ok(SocketTransport {
            n: n_agents,
            writers: (0..n_agents).map(|_| None).collect(),
            links: (0..n_agents).map(|_| LossyLink::reliable()).collect(),
            uplink: UplinkBooks::new(n_agents),
            pending: VecDeque::new(),
            ctl_rx,
            ev_rx,
            ev_tx,
            shared,
            addr: bound,
            opts,
            acceptor: Some(acceptor),
            cleanup_path,
        })
    }

    /// The bound address (for TCP, the resolved ephemeral port).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    /// Handshakes refused so far (bad digest, bad id, taken slot, …).
    pub fn rejected_handshakes(&self) -> u64 {
        self.shared.rejected.load(Ordering::SeqCst)
    }

    /// Agents currently holding a live connection.
    pub fn connected_count(&self) -> usize {
        self.shared
            .connected
            .iter()
            .filter(|c| c.load(Ordering::SeqCst))
            .count()
    }

    /// Block until every slot has completed the handshake.  Joined /
    /// Left churn during formation is absorbed (the cohort is the
    /// starting state, not a rejoin); fails if no progress happens for
    /// `accept_wait_ms`.
    pub fn await_cohort(&mut self) -> anyhow::Result<()> {
        let patience = Duration::from_millis(self.opts.accept_wait_ms);
        loop {
            self.drain_ctl();
            let have = (0..self.n)
                .filter(|&i| {
                    self.writers[i].is_some()
                        && self.shared.connected[i].load(Ordering::SeqCst)
                })
                .count();
            if have == self.n {
                return Ok(());
            }
            match self.ev_rx.recv_timeout(patience) {
                Ok(TransportEvent::Joined { .. })
                | Ok(TransportEvent::Left { .. }) => {}
                Ok(ev) => self.pending.push_back(ev),
                Err(RecvTimeoutError::Timeout) => anyhow::bail!(
                    "cohort formation timed out ({have}/{} agents connected \
                     on {})",
                    self.n,
                    self.addr
                ),
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("acceptor thread died during formation")
                }
            }
        }
    }

    /// Install any writer handed over by the acceptor.  Must run before
    /// a `Joined` event is surfaced, so the resync `Reset` has a link
    /// to go out on.
    fn drain_ctl(&mut self) {
        while let Ok((agent, w)) = self.ctl_rx.try_recv() {
            self.writers[agent] = Some(w);
        }
    }

    fn deliver(&mut self, ev: TransportEvent) -> TransportEvent {
        self.uplink.observe(&ev);
        ev
    }
}

impl<L: NetListener> Transport for SocketTransport<L> {
    fn n_agents(&self) -> usize {
        self.n
    }

    fn begin_round(&mut self) {
        self.shared.round.fetch_add(1, Ordering::SeqCst);
    }

    fn send(
        &mut self,
        to: usize,
        frame: Frame,
        rng: &mut Pcg64,
    ) -> anyhow::Result<()> {
        self.drain_ctl();
        anyhow::ensure!(to < self.n, "agent index {to} out of range");
        if self.writers[to].is_none() {
            // dead link: drop silently, death was/will be surfaced once
            return Ok(());
        }
        let frame = match frame {
            Frame::Round { zdelta: Some(msg) } => {
                let bytes = msg.wire_bytes() as u64;
                // the link is reliable (TCP/UDS) so nothing is drawn from
                // `rng`, but the charge goes through the same LossyLink
                // path as every other transport — the books cannot be
                // bypassed
                Frame::Round {
                    zdelta: self.links[to].transmit_bytes(msg, bytes, rng),
                }
            }
            Frame::Reset { z } => {
                let sync = WireMessage::<f32>::dense_bytes(z.len()) as u64;
                self.links[to].stats.record_reliable(sync);
                Frame::Reset { z }
            }
            other => other,
        };
        let Some(w) = self.writers[to].as_mut() else {
            return Ok(());
        };
        if write_frame(w, &frame).is_err() {
            self.writers[to] = None;
            self.shared.connected[to].store(false, Ordering::SeqCst);
            // lint:allow(unaccounted-send): link-death notification on the in-process event queue; nothing crosses the modelled wire
            let _ = self.ev_tx.send(TransportEvent::Left { from: to });
        }
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<TransportEvent> {
        self.drain_ctl();
        if let Some(ev) = self.pending.pop_front() {
            return Ok(self.deliver(ev));
        }
        let patience = Duration::from_millis(self.opts.read_timeout_ms);
        match self.ev_rx.recv_timeout(patience) {
            Ok(ev) => {
                // a Joined's writer handover precedes its event
                self.drain_ctl();
                Ok(self.deliver(ev))
            }
            Err(RecvTimeoutError::Timeout) => Ok(TransportEvent::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("socket transport event queue closed")
            }
        }
    }

    fn poll(&mut self) -> Option<TransportEvent> {
        self.drain_ctl();
        if let Some(ev) = self.pending.pop_front() {
            return Some(self.deliver(ev));
        }
        match self.ev_rx.try_recv() {
            Ok(ev) => {
                self.drain_ctl();
                Some(self.deliver(ev))
            }
            Err(_) => None,
        }
    }

    fn stats(&self) -> WireStats {
        WireStats {
            uplink: self.uplink.snapshot(),
            downlink: self
                .links
                .iter()
                .map(|l| LinkStats::from(&l.stats))
                .collect(),
        }
    }

    fn label(&self) -> &'static str {
        L::kind_label()
    }

    fn set_status(&mut self, json: &str) {
        let mut s = self
            .shared
            .status
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        s.clear();
        s.push_str(json);
    }

    fn wants_status(&self) -> bool {
        true
    }

    fn shutdown(&mut self) -> anyhow::Result<()> {
        self.shared.stop.store(true, Ordering::SeqCst);
        for w in self.writers.iter_mut() {
            if let Some(s) = w.take() {
                let _ = s.shutdown_both();
            }
        }
        if let Some(j) = self.acceptor.take() {
            let _ = j.join();
        }
        if let Some(p) = self.cleanup_path.take() {
            let _ = std::fs::remove_file(p);
        }
        Ok(())
    }
}

impl<L: NetListener> Drop for SocketTransport<L> {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// Accept loop: validate handshakes, spawn one reader thread per link,
/// hand the write half to the transport.
fn acceptor_loop<L: NetListener>(
    listener: L,
    n: usize,
    digest: u64,
    dim: u32,
    shared: Arc<Shared>,
    ctl_tx: Sender<(usize, L::Stream)>,
    ev_tx: Sender<TransportEvent>,
    opts: SocketOpts,
) {
    // rejection reasons are counted, not logged (library code)
    let reject = |_why: &str| {
        shared.rejected.fetch_add(1, Ordering::SeqCst);
    };
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let stream = match listener.accept_stream() {
            Ok(s) => s,
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            Err(_) => {
                // transient accept failure (e.g. aborted connection)
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        if stream.set_blocking().is_err() {
            continue;
        }
        if stream
            .set_stream_timeouts(
                Some(Duration::from_millis(opts.handshake_timeout_ms)),
                Some(Duration::from_millis(opts.write_timeout_ms)),
            )
            .is_err()
        {
            continue;
        }
        let mut reader = stream;
        let (agent, their_digest, their_dim) = match read_frame(&mut reader) {
            Ok(Frame::Hello { agent, digest, dim }) => {
                (agent as usize, digest, dim)
            }
            Ok(Frame::StatusReq) => {
                // out-of-band introspection probe (`deluxe status`): a
                // one-shot connection, answered from the published
                // snapshot and closed — not a handshake, not a rejection
                let json = shared
                    .status
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .clone();
                let _ = write_frame(&mut reader, &Frame::Status { json });
                continue;
            }
            _ => {
                reject("no Hello within handshake timeout");
                continue;
            }
        };
        if agent >= n {
            reject("agent id out of range");
            continue;
        }
        if their_digest != digest || their_dim != dim {
            reject("config digest / dimension mismatch");
            continue;
        }
        if shared.connected[agent].swap(true, Ordering::SeqCst) {
            reject("slot already connected");
            continue;
        }
        let ok = (|| -> io::Result<L::Stream> {
            let mut writer = reader.try_clone_stream()?;
            let round = shared.round.load(Ordering::SeqCst);
            write_frame(&mut writer, &Frame::Welcome { round })?;
            // the reader side blocks without deadline: silence between
            // rounds is normal; death is detected as EOF / reset
            reader.set_stream_timeouts(
                None,
                Some(Duration::from_millis(opts.write_timeout_ms)),
            )?;
            Ok(writer)
        })();
        let writer = match ok {
            Ok(w) => w,
            Err(_) => {
                shared.connected[agent].store(false, Ordering::SeqCst);
                reject("handshake write failed");
                continue;
            }
        };
        let reader_ev = ev_tx.clone();
        let reader_shared = shared.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("dela-link-{agent}"))
            .spawn(move || {
                let mut reader = reader;
                loop {
                    match read_frame(&mut reader) {
                        Ok(frame) => {
                            let ev =
                                TransportEvent::Frame { from: agent, frame };
                            // lint:allow(unaccounted-send): handing a received frame to the in-process event queue; its wire bytes were charged sender-side and reported via Reply counters
                            if reader_ev.send(ev).is_err() {
                                return;
                            }
                        }
                        Err(_) => {
                            reader_shared.connected[agent]
                                .store(false, Ordering::SeqCst);
                            let ev = TransportEvent::Left { from: agent };
                            // lint:allow(unaccounted-send): link-death notification on the in-process event queue; nothing crosses the modelled wire
                            let _ = reader_ev.send(ev);
                            return;
                        }
                    }
                }
            });
        if spawned.is_err() {
            shared.connected[agent].store(false, Ordering::SeqCst);
            reject("reader thread spawn failed");
            continue;
        }
        // writer handover MUST precede the Joined event (recv/poll drain
        // the control queue before surfacing events)
        // lint:allow(unaccounted-send): control-plane handover of the write half to the service loop
        if ctl_tx.send((agent, writer)).is_err() {
            return;
        }
        // lint:allow(unaccounted-send): membership notification on the in-process event queue; nothing crosses the modelled wire
        let _ = ev_tx.send(TransportEvent::Joined { from: agent });
    }
}
