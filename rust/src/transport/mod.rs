//! The transport seam: one trait between the ADMM protocol and the
//! bytes that carry it.
//!
//! Every deployment shape moves the same [`Frame`]s and charges the
//! same [`crate::wire::WireStats`] books; only the medium differs:
//!
//! * [`InProc`] — one OS thread per agent over `std::sync::mpsc`
//!   (the original `coordinator` runtime, byte-identical and pinned);
//! * [`SimLink`] — in-process threads with [`crate::sim::link`]'s
//!   latency / bandwidth / burst-loss cost model on the downlink, so
//!   the discrete-event cost model becomes just another transport;
//! * [`Tcp`] / [`Uds`] — real sockets with length-prefixed framing
//!   ([`frame`]), a connect/accept handshake carrying agent id + config
//!   digest, read/write timeouts and crash recovery riding the
//!   reset/rejoin-resync path (DESIGN.md §12).
//!
//! The contract that makes the implementations interchangeable:
//! payload-bearing frames ([`Frame::Round`] with a delta) pass through
//! the link's [`LossyLink`] — bytes charged by the payload's exact
//! [`crate::wire::WireMessage::wire_bytes`], loss sampled from the
//! *caller's* RNG in deterministic per-agent order ([`LossModel::None`]
//! draws nothing, so a no-loss TCP run is bit-identical to `InProc`).
//! [`Frame::Reset`] is a reliable dense sync charged via
//! [`ChannelStats::record_reliable`]; handshake/stop control frames are
//! a few framing bytes the books ignore by design (the same rule as the
//! sim's control ticks, DESIGN.md §9).

pub mod frame;
pub mod loss;

mod inproc;
mod simlink;
mod socket;

pub use frame::{decode_frame, encode_frame, read_frame, write_frame, Frame};
pub use inproc::InProc;
pub use loss::{ChannelStats, LossModel, LossyLink};
pub use simlink::SimLink;
pub use socket::{SocketOpts, SocketTransport, Tcp};
#[cfg(unix)]
pub use socket::Uds;

use crate::rng::Pcg64;
use crate::wire::{LinkStats, WireStats};

/// What [`Transport::recv`] / [`Transport::poll`] deliver to the
/// service loop.
#[derive(Debug)]
pub enum TransportEvent {
    /// A frame arrived from agent `from`.
    Frame { from: usize, frame: Frame },
    /// Agent `from` completed a (re)connect handshake after the initial
    /// cohort was formed — the coordinator answers with a
    /// [`Frame::Reset`] resync.
    Joined { from: usize },
    /// Agent `from`'s link died (EOF, I/O error, write failure).  Its
    /// round reply will never arrive; the coordinator proceeds without
    /// it, exactly as it does for a dropped packet.
    Left { from: usize },
    /// Nothing arrived within the transport's read timeout.  The
    /// coordinator closes the gather; still-pending agents stay live
    /// and their late replies are discarded as stale.
    Timeout,
}

/// An object-safe leader-side message transport for one agent cohort.
///
/// Implementations own the per-agent downlink [`LossyLink`]s (loss
/// process + byte books) and surface uplink books observed from
/// [`Frame::Reply`] counters; the protocol state (triggers, error
/// feedback, `z`) stays with [`crate::coordinator::Coordinator`].
///
/// ### Send semantics
///
/// * `Frame::Round { zdelta: Some(_) }` — charged by the payload's
///   exact wire size, then passed through the link's loss process
///   drawing from `rng` (a no-loss link draws nothing).  A lost payload
///   is delivered as `Round { zdelta: None }`: the agent still runs the
///   round, it just receives no update — the paper's drop semantics.
/// * `Frame::Reset { .. }` — reliable, charged as one dense sync
///   message via [`ChannelStats::record_reliable`].
/// * Control frames (`Welcome`, `Stop`) — reliable, not charged.
/// * Sends to a dead or unknown link are silently dropped; link death
///   is reported once via [`TransportEvent::Left`].
pub trait Transport {
    /// Cohort size (fixed at construction; crashed agents keep their
    /// slot and may rejoin into it).
    fn n_agents(&self) -> usize;

    /// Hook called by the coordinator at the top of each round (e.g.
    /// [`SimLink`] folds the previous round's slowest link delay into
    /// its virtual clock).  Default: no-op.
    fn begin_round(&mut self) {}

    /// Deliver `frame` to agent `to` under the semantics above.
    /// Errors are infrastructure failures (closed in-proc channel),
    /// not per-link conditions.
    fn send(
        &mut self,
        to: usize,
        frame: Frame,
        rng: &mut Pcg64,
    ) -> anyhow::Result<()>;

    /// Send a frame to every agent, in agent order (the deterministic
    /// order every loss draw depends on).
    fn broadcast(
        &mut self,
        frame: &Frame,
        rng: &mut Pcg64,
    ) -> anyhow::Result<()> {
        for i in 0..self.n_agents() {
            // lint:allow(unaccounted-send): Transport::send charges the wire books per frame kind
            self.send(i, frame.clone(), rng)?;
        }
        Ok(())
    }

    /// Block for the next event (frame, membership change, or
    /// [`TransportEvent::Timeout`] on transports with a read timeout).
    fn recv(&mut self) -> anyhow::Result<TransportEvent>;

    /// Non-blocking variant of [`Self::recv`]; `None` if nothing is
    /// queued.  The coordinator drains this between rounds so rejoins
    /// are not stuck waiting for the next gather.
    fn poll(&mut self) -> Option<TransportEvent>;

    /// Per-link byte books: downlink as charged by this transport's
    /// links, uplink as observed from the agents' cumulative
    /// [`Frame::Reply`] counters (uplink drop accounting lives with the
    /// sending endpoint).
    fn stats(&self) -> WireStats;

    /// Human-readable transport kind (for logs and bench labels).
    fn label(&self) -> &'static str;

    /// Publish the coordinator's latest status snapshot (a JSON string)
    /// for out-of-band introspection — the socket transports serve it to
    /// [`Frame::StatusReq`] probes (`deluxe status`).  Default: no-op;
    /// pair with [`Transport::wants_status`] so the coordinator skips
    /// building the snapshot when nobody can read it.
    fn set_status(&mut self, _json: &str) {}

    /// Whether this transport can serve a published status snapshot.
    fn wants_status(&self) -> bool {
        false
    }

    /// Deterministic virtual time in µs, if this transport models one
    /// ([`SimLink`]).  Journaled in `RoundEnd` as a *deterministic*
    /// field — unlike wall-clock, virtual time is part of the seeded
    /// trajectory.
    fn vtime_us(&self) -> Option<u64> {
        None
    }

    /// Virtual-time cost in µs of the most recent [`Transport::send`],
    /// if this transport models one ([`SimLink`]: the delay drawn for
    /// that delivery).  The coordinator journals it on each per-link
    /// transmit span (DESIGN.md §14) — deterministic, like
    /// [`Transport::vtime_us`].
    fn last_send_vtime_us(&self) -> Option<u64> {
        None
    }

    /// Tear down threads/sockets.  Called once, after the coordinator
    /// has drained final replies.
    fn shutdown(&mut self) -> anyhow::Result<()>;
}

/// Uplink books as observable from the leader: cumulative bytes come
/// from each agent's [`Frame::Reply`] counters (charged sender-side by
/// its [`LossyLink`]), message count from payload-bearing replies seen.
#[derive(Clone, Debug)]
pub(crate) struct UplinkBooks {
    links: Vec<LinkStats>,
}

impl UplinkBooks {
    pub(crate) fn new(n: usize) -> UplinkBooks {
        UplinkBooks { links: vec![LinkStats::default(); n] }
    }

    /// Fold one received frame into the books.
    pub(crate) fn observe(&mut self, ev: &TransportEvent) {
        if let TransportEvent::Frame {
            frame: Frame::Reply { agent, sent_bytes, delta, .. },
            ..
        } = ev
        {
            if let Some(l) = self.links.get_mut(*agent as usize) {
                if delta.is_some() {
                    l.msgs += 1;
                }
                l.bytes = *sent_bytes;
            }
        }
    }

    pub(crate) fn snapshot(&self) -> Vec<LinkStats> {
        self.links.clone()
    }
}
