//! The original deployment shape: one OS thread per agent, frames over
//! `std::sync::mpsc`.
//!
//! This is the old `coordinator` runtime rehosted behind
//! [`Transport`]: the thread names, channel topology, per-link
//! [`LossyLink`] draws and byte books are unchanged, so trajectories
//! are bit-identical to the pre-trait code (pinned by the coordinator
//! tests and the TCP-vs-in-proc loopback test).  [`Mesh`] — the thread
//! pool + channel fabric without any link model — is shared with
//! [`crate::transport::SimLink`], which swaps the Bernoulli links for
//! the simulator's latency/bandwidth/burst-loss cost model.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::coordinator::{AgentEndpoint, EndpointStep};
use crate::rng::Pcg64;
use crate::wire::{LinkStats, WireMessage, WireStats};

use super::frame::Frame;
use super::loss::LossyLink;
use super::{Transport, TransportEvent, UplinkBooks};

/// Thread-per-endpoint fabric: spawns one named worker per
/// [`AgentEndpoint`] and moves raw frames over mpsc channels.  No link
/// model lives here — the owning transport decides what a send costs.
pub(crate) struct Mesh {
    tx: Vec<Sender<Frame>>,
    rx: Receiver<(usize, Frame)>,
    joins: Vec<JoinHandle<()>>,
}

impl Mesh {
    pub(crate) fn spawn(endpoints: Vec<AgentEndpoint>) -> Mesh {
        let n = endpoints.len();
        let (from_tx, from_rx) = channel::<(usize, Frame)>();
        let mut tx = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for mut ep in endpoints {
            let i = ep.id();
            let (to_tx, to_rx) = channel::<Frame>();
            let to_leader = from_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("dela-agent-{i}"))
                .spawn(move || {
                    while let Ok(frame) = to_rx.recv() {
                        match ep.handle(frame) {
                            EndpointStep::Reply(r) => {
                                // lint:allow(unaccounted-send): uplink bytes were charged by the endpoint's LossyLink when the payload was produced; this mpsc send is the thread-boundary transfer
                                if to_leader.send((i, r)).is_err() {
                                    break;
                                }
                            }
                            EndpointStep::Idle => {}
                            EndpointStep::Done(r) => {
                                // lint:allow(unaccounted-send): final stats report carries no payload; all wire bytes were charged when transmitted
                                let _ = to_leader.send((i, r));
                                break;
                            }
                        }
                    }
                })
                // lint:allow(panic-in-library): thread spawn fails only on OS resource exhaustion; no meaningful recovery exists here
                .expect("spawn agent thread");
            tx.push(to_tx);
            joins.push(join);
        }
        Mesh { tx, rx: from_rx, joins }
    }

    pub(crate) fn n(&self) -> usize {
        self.tx.len()
    }

    pub(crate) fn send(&self, to: usize, frame: Frame) -> anyhow::Result<()> {
        // lint:allow(unaccounted-send): the owning transport charged the wire books before handing the frame to the fabric; this mpsc send is the thread-boundary transfer, not a wire hop
        let sent = self.tx[to].send(frame);
        sent.map_err(|_| anyhow::anyhow!("agent {to} channel closed"))
    }

    pub(crate) fn recv(&self) -> anyhow::Result<(usize, Frame)> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("all agent threads disconnected"))
    }

    pub(crate) fn try_recv(&self) -> Option<(usize, Frame)> {
        self.rx.try_recv().ok()
    }

    pub(crate) fn join_all(&mut self) {
        // closing the command channels unblocks any thread still in recv
        self.tx.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// In-process transport: each [`AgentEndpoint`] runs on its own thread,
/// the leader talks to it over unbounded mpsc channels, and each
/// downlink is an i.i.d. [`LossyLink`] — exactly the pre-trait
/// `Coordinator` runtime.
pub struct InProc {
    mesh: Mesh,
    links: Vec<LossyLink>,
    uplink: UplinkBooks,
}

impl InProc {
    /// Spawn one named worker thread per endpoint.  `drop_down` is the
    /// i.i.d. downlink loss probability (the endpoints own their uplink
    /// loss processes).
    pub fn spawn(endpoints: Vec<AgentEndpoint>, drop_down: f64) -> InProc {
        let n = endpoints.len();
        InProc {
            mesh: Mesh::spawn(endpoints),
            links: (0..n).map(|_| LossyLink::new(drop_down)).collect(),
            uplink: UplinkBooks::new(n),
        }
    }
}

impl Transport for InProc {
    fn n_agents(&self) -> usize {
        self.mesh.n()
    }

    fn send(
        &mut self,
        to: usize,
        frame: Frame,
        rng: &mut Pcg64,
    ) -> anyhow::Result<()> {
        let frame = match frame {
            Frame::Round { zdelta: Some(msg) } => {
                let bytes = msg.wire_bytes() as u64;
                Frame::Round {
                    zdelta: self.links[to].transmit_bytes(msg, bytes, rng),
                }
            }
            Frame::Reset { z } => {
                let sync = WireMessage::<f32>::dense_bytes(z.len()) as u64;
                self.links[to].stats.record_reliable(sync);
                Frame::Reset { z }
            }
            other => other,
        };
        // lint:allow(unaccounted-send): bytes were charged on the LossyLink above; the mesh hop is the in-process delivery, not a wire hop
        self.mesh.send(to, frame)
    }

    fn recv(&mut self) -> anyhow::Result<TransportEvent> {
        let (from, frame) = self.mesh.recv()?;
        let ev = TransportEvent::Frame { from, frame };
        self.uplink.observe(&ev);
        Ok(ev)
    }

    fn poll(&mut self) -> Option<TransportEvent> {
        let (from, frame) = self.mesh.try_recv()?;
        let ev = TransportEvent::Frame { from, frame };
        self.uplink.observe(&ev);
        Some(ev)
    }

    fn stats(&self) -> WireStats {
        WireStats {
            uplink: self.uplink.snapshot(),
            downlink: self
                .links
                .iter()
                .map(|l| LinkStats::from(&l.stats))
                .collect(),
        }
    }

    fn label(&self) -> &'static str {
        "inproc"
    }

    fn shutdown(&mut self) -> anyhow::Result<()> {
        self.mesh.join_all();
        Ok(())
    }
}
