//! The transport frame protocol and its length-prefixed byte framing.
//!
//! Every transport moves the same eight [`Frame`] kinds; the socket
//! transports serialize them as
//!
//! ```text
//! frame := tag(u8) . len(u32 LE) . body(len bytes)
//! ```
//!
//! Vector payloads reuse [`WireMessage`]'s exact codec, so a frame's
//! payload bytes on a real socket are bit-identical to the bytes the
//! in-process accounting charges.  The codec is strict: unknown tags,
//! truncated bodies and trailing garbage are errors, never silently
//! skipped (DESIGN.md §12).

use std::io::{Read, Write};

use crate::wire::WireMessage;

/// Hard upper bound on a frame body (64 MiB) — a corrupted length
/// prefix must not translate into an unbounded allocation.
pub const MAX_FRAME_BODY: u32 = 64 << 20;

/// Frame tags on the wire, in catalogue order (DESIGN.md §12).
const TAG_HELLO: u8 = 0;
const TAG_WELCOME: u8 = 1;
const TAG_ROUND: u8 = 2;
const TAG_RESET: u8 = 3;
const TAG_STOP: u8 = 4;
const TAG_REPLY: u8 = 5;
const TAG_STATUS_REQ: u8 = 6;
const TAG_STATUS: u8 = 7;

/// One protocol message between the leader and an agent.  The deployed
/// runtime speaks the f32 PJRT parameter ABI, so frames are concrete
/// over `f32` (keeping [`super::Transport`] object-safe).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Agent -> leader connect handshake: who I am and a digest of my
    /// run configuration (seed, triggers, compressor, dim, cohort size).
    /// The leader rejects a digest mismatch — two processes silently
    /// disagreeing on the protocol parameters would diverge without any
    /// error signal.
    Hello { agent: u32, digest: u64, dim: u32 },
    /// Leader -> agent handshake ack; `round` tells a rejoining agent
    /// where the cohort is.  Carries no model state: the initial `z` is
    /// derived from the shared seed on both sides, and a rejoin resync
    /// arrives as an explicit [`Frame::Reset`] so its dense bytes are
    /// charged on the books.
    Welcome { round: u64 },
    /// Start one round; `zdelta` is the event-based downlink payload
    /// (`None` = no z-event fired, or the packet was lost in flight).
    Round { zdelta: Option<WireMessage<f32>> },
    /// Reliable resynchronization of the agent's `ẑ` to the true `z`
    /// (periodic resets and rejoin resyncs).
    Reset { z: Vec<f32> },
    /// Terminate; the agent answers with one final [`Frame::Reply`].
    Stop,
    /// Agent -> leader round reply: the event-based uplink payload plus
    /// the agent's cumulative event/byte counters.
    Reply {
        agent: u32,
        /// d-events triggered so far (for load accounting).
        events: u64,
        /// Cumulative uplink bytes this agent has put on the wire.
        sent_bytes: u64,
        /// `Some(msg)` iff the d-trigger fired AND the packet survived.
        delta: Option<WireMessage<f32>>,
    },
    /// Out-of-band introspection probe (`deluxe status`): a one-shot
    /// connection sends this instead of [`Frame::Hello`] and gets a
    /// [`Frame::Status`] back.  Never enters the round protocol and is
    /// not charged to the books (a control frame, DESIGN.md §13).
    StatusReq,
    /// The leader's latest status snapshot, as a JSON document (the
    /// coordinator's metrics/liveness view, published per round via
    /// `Transport::set_status`).
    Status { json: String },
}

impl Frame {
    /// Display name of the frame kind (for counters and errors).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::Welcome { .. } => "welcome",
            Frame::Round { .. } => "round",
            Frame::Reset { .. } => "reset",
            Frame::Stop => "stop",
            Frame::Reply { .. } => "reply",
            Frame::StatusReq => "status_req",
            Frame::Status { .. } => "status",
        }
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(buf: &[u8], pos: &mut usize) -> anyhow::Result<u32> {
    if buf.len() < *pos + 4 {
        anyhow::bail!("truncated u32 at offset {}", *pos);
    }
    let v = u32::from_le_bytes([
        buf[*pos],
        buf[*pos + 1],
        buf[*pos + 2],
        buf[*pos + 3],
    ]);
    *pos += 4;
    Ok(v)
}

fn get_u64(buf: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    if buf.len() < *pos + 8 {
        anyhow::bail!("truncated u64 at offset {}", *pos);
    }
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[*pos..*pos + 8]);
    *pos += 8;
    Ok(u64::from_le_bytes(b))
}

fn put_opt_msg(out: &mut Vec<u8>, msg: &Option<WireMessage<f32>>) {
    match msg {
        None => out.push(0),
        Some(m) => {
            out.push(1);
            out.extend_from_slice(&m.encode());
        }
    }
}

fn get_opt_msg(
    buf: &[u8],
    pos: &mut usize,
) -> anyhow::Result<Option<WireMessage<f32>>> {
    let flag = *buf
        .get(*pos)
        .ok_or_else(|| anyhow::anyhow!("truncated payload flag"))?;
    *pos += 1;
    match flag {
        0 => Ok(None),
        1 => {
            let msg = WireMessage::<f32>::decode(&buf[*pos..])?;
            *pos += msg.wire_bytes();
            Ok(Some(msg))
        }
        other => anyhow::bail!("bad payload flag {other}"),
    }
}

/// Encode a frame to its full on-wire form (tag + length + body).
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut body = Vec::new();
    let tag = match f {
        Frame::Hello { agent, digest, dim } => {
            put_u32(&mut body, *agent);
            put_u64(&mut body, *digest);
            put_u32(&mut body, *dim);
            TAG_HELLO
        }
        Frame::Welcome { round } => {
            put_u64(&mut body, *round);
            TAG_WELCOME
        }
        Frame::Round { zdelta } => {
            put_opt_msg(&mut body, zdelta);
            TAG_ROUND
        }
        Frame::Reset { z } => {
            body.extend_from_slice(&WireMessage::dense(z).encode());
            TAG_RESET
        }
        Frame::Stop => TAG_STOP,
        Frame::Reply { agent, events, sent_bytes, delta } => {
            put_u32(&mut body, *agent);
            put_u64(&mut body, *events);
            put_u64(&mut body, *sent_bytes);
            put_opt_msg(&mut body, delta);
            TAG_REPLY
        }
        Frame::StatusReq => TAG_STATUS_REQ,
        Frame::Status { json } => {
            put_u32(&mut body, json.len() as u32);
            body.extend_from_slice(json.as_bytes());
            TAG_STATUS
        }
    };
    let mut out = Vec::with_capacity(5 + body.len());
    out.push(tag);
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Decode one frame body given its tag.  The body must be consumed
/// exactly — trailing bytes are a framing error.
fn decode_body(tag: u8, body: &[u8]) -> anyhow::Result<Frame> {
    let mut pos = 0usize;
    let frame = match tag {
        TAG_HELLO => Frame::Hello {
            agent: get_u32(body, &mut pos)?,
            digest: get_u64(body, &mut pos)?,
            dim: get_u32(body, &mut pos)?,
        },
        TAG_WELCOME => Frame::Welcome { round: get_u64(body, &mut pos)? },
        TAG_ROUND => Frame::Round { zdelta: get_opt_msg(body, &mut pos)? },
        TAG_RESET => {
            let msg = WireMessage::<f32>::decode(body)?;
            pos += msg.wire_bytes();
            match msg {
                WireMessage::Dense(z) => Frame::Reset { z },
                other => anyhow::bail!(
                    "reset payload must be dense, got {} values in a \
                     non-dense message",
                    other.dim()
                ),
            }
        }
        TAG_STOP => Frame::Stop,
        TAG_REPLY => Frame::Reply {
            agent: get_u32(body, &mut pos)?,
            events: get_u64(body, &mut pos)?,
            sent_bytes: get_u64(body, &mut pos)?,
            delta: get_opt_msg(body, &mut pos)?,
        },
        TAG_STATUS_REQ => Frame::StatusReq,
        TAG_STATUS => {
            let len = get_u32(body, &mut pos)? as usize;
            if body.len() < pos + len {
                anyhow::bail!("truncated status payload at offset {pos}");
            }
            let json = match std::str::from_utf8(&body[pos..pos + len]) {
                Ok(s) => s.to_string(),
                Err(e) => anyhow::bail!("status payload is not UTF-8: {e}"),
            };
            pos += len;
            Frame::Status { json }
        }
        other => anyhow::bail!("unknown frame tag {other}"),
    };
    if pos != body.len() {
        anyhow::bail!(
            "frame body has {} trailing byte(s) after a {} frame",
            body.len() - pos,
            frame.kind()
        );
    }
    Ok(frame)
}

/// Decode a full framed buffer (as produced by [`encode_frame`]); the
/// buffer must contain exactly one frame.
pub fn decode_frame(buf: &[u8]) -> anyhow::Result<Frame> {
    if buf.len() < 5 {
        anyhow::bail!("framed buffer shorter than the 5-byte header");
    }
    let tag = buf[0];
    let mut pos = 1usize;
    let len = get_u32(buf, &mut pos)? as usize;
    if buf.len() != 5 + len {
        anyhow::bail!(
            "frame length prefix {len} disagrees with buffer ({} body \
             bytes)",
            buf.len() - 5
        );
    }
    decode_body(tag, &buf[5..])
}

/// Write one frame to a byte sink (the socket transports' single write
/// path — `Tcp`/`Uds` charge wire bytes *before* calling this, in
/// `SocketTransport::send`, so the framing layer never touches the
/// books).
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> std::io::Result<()> {
    let buf = encode_frame(f);
    // lint:allow(unaccounted-send): wire bytes are charged by the caller (SocketTransport::send / AgentEndpoint uplink) before framing; this is the one socket write path
    w.write_all(&buf)?;
    w.flush()
}

/// Read one frame from a byte source.  Decode failures surface as
/// `InvalidData` I/O errors so socket readers treat a corrupt peer the
/// same as a broken connection.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Frame> {
    let mut header = [0u8; 5];
    r.read_exact(&mut header)?;
    let tag = header[0];
    let len = u32::from_le_bytes([header[1], header[2], header[3], header[4]]);
    if len > MAX_FRAME_BODY {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame body length {len} exceeds {MAX_FRAME_BODY}"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    decode_body(tag, &body).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let buf = encode_frame(&f);
        assert_eq!(decode_frame(&buf).unwrap(), f, "roundtrip {}", f.kind());
        // the io path must agree with the buffer path
        let mut sink = Vec::new();
        write_frame(&mut sink, &f).unwrap();
        assert_eq!(sink, buf);
        let mut cur = std::io::Cursor::new(&buf);
        assert_eq!(read_frame(&mut cur).unwrap(), f);
    }

    #[test]
    fn all_frame_kinds_roundtrip() {
        roundtrip(Frame::Hello { agent: 3, digest: 0xDEAD_BEEF, dim: 44 });
        roundtrip(Frame::Welcome { round: 17 });
        roundtrip(Frame::Round { zdelta: None });
        roundtrip(Frame::Round {
            zdelta: Some(WireMessage::dense(&[1.0f32, -2.5, 3.25])),
        });
        roundtrip(Frame::Reset { z: vec![0.5, -0.25, 8.0, 0.0] });
        roundtrip(Frame::Stop);
        roundtrip(Frame::Reply {
            agent: 9,
            events: 41,
            sent_bytes: 12345,
            delta: None,
        });
        roundtrip(Frame::Reply {
            agent: 0,
            events: 0,
            sent_bytes: 0,
            delta: Some(WireMessage::dense(&[42.0f32])),
        });
        roundtrip(Frame::StatusReq);
        roundtrip(Frame::Status { json: String::new() });
        roundtrip(Frame::Status {
            json: "{\"round\":7,\"live\":[true,false]}".to_string(),
        });
    }

    #[test]
    fn corrupt_status_frames_are_rejected() {
        // truncated payload: declared string length exceeds the body
        let mut buf = encode_frame(&Frame::Status { json: "abcd".into() });
        let body_len = (buf.len() - 5) as u32;
        buf[5..9].copy_from_slice(&100u32.to_le_bytes());
        buf[1..5].copy_from_slice(&body_len.to_le_bytes());
        assert!(decode_frame(&buf).is_err());
        // non-UTF-8 payload
        let mut bad = encode_frame(&Frame::Status { json: "ab".into() });
        let n = bad.len();
        bad[n - 1] = 0xFF;
        bad[n - 2] = 0xC0;
        assert!(decode_frame(&bad).is_err());
        // status_req with trailing bytes
        let mut req = encode_frame(&Frame::StatusReq);
        req[1] = 1;
        req.push(0);
        assert!(decode_frame(&req).is_err());
    }

    #[test]
    fn round_payload_bytes_match_wire_message_codec() {
        // the framing must embed the WireMessage codec verbatim: body =
        // flag byte + exact encode() bytes
        let msg = WireMessage::dense(&[1.0f32, 2.0, 3.0]);
        let buf = encode_frame(&Frame::Round { zdelta: Some(msg.clone()) });
        assert_eq!(&buf[6..], &msg.encode()[..]);
        assert_eq!(buf[5], 1); // payload flag
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        assert!(decode_frame(&[]).is_err());
        assert!(decode_frame(&[99, 0, 0, 0, 0]).is_err()); // unknown tag
        let mut buf = encode_frame(&Frame::Welcome { round: 1 });
        buf.push(0); // trailing garbage: length prefix now disagrees
        assert!(decode_frame(&buf).is_err());
        // trailing bytes inside the declared body
        let mut long = encode_frame(&Frame::Stop);
        long[1] = 1; // declare a 1-byte body
        long.push(7);
        assert!(decode_frame(&long).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocating() {
        let hdr = [TAG_STOP, 0xFF, 0xFF, 0xFF, 0xFF];
        let mut cur = std::io::Cursor::new(&hdr[..]);
        let err = read_frame(&mut cur).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }
}
