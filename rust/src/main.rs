//! `deluxe` — launcher/CLI for the DELA reproduction.
//!
//! ```text
//! deluxe exp <id> [flags]     regenerate a paper table/figure
//! deluxe train [flags]        e2e federated training (threaded runtime)
//! deluxe info                 show artifact manifest + configs
//! deluxe help
//! ```

use anyhow::Result;
use deluxe::cli::Args;
use deluxe::config::RunConfig;
use deluxe::experiments::{
    faults, fig10, fig11, fig12, fig9, nn, pareto, rates,
};
use deluxe::jsonio::Json;
use deluxe::metrics::{fmt_bytes, fmt_duration, fmt_opt, Recorder, Table};
use deluxe::runtime::{PjrtRuntime, Variant};
use deluxe::sim::Scenario;

const USAGE: &str = "\
deluxe — Distributed Event-based Learning via ADMM (ICML 2025 reproduction)

USAGE:
  deluxe exp <id> [--rounds N] [--agents N] [--seed S] [--backend native|pjrt|pjrt-ref]
             [--results DIR] [--artifacts DIR] [--workers N]
             [--compressor none|topk:F|randk:F|quant:B|topkq:F:B]
             (--workers N shards every engine's per-agent local solves;
              0 = one per core, env DELUXE_WORKERS overrides the default;
              results are bit-identical for every worker count)
  deluxe train [--rounds N] [--delta D] [--seed S] [--compressor C]
             [--journal PATH]                          threaded e2e run
  deluxe serve [--listen HOST:PORT | --uds PATH] [--rounds N] [--seed S]
             [--delta D] [--compressor C] [--drop-down P] [--reset-period T]
             [--journal PATH]
             leader service over real sockets: waits for the full agent
             cohort, drives rounds, resyncs crashed agents on rejoin;
             --journal writes the JSONL event journal (DESIGN.md §13)
  deluxe agent (--connect HOST:PORT | --uds PATH) --shard K [--seed S]
             [--delta D] [--compressor C] [--journal PATH]
             one agent process holding shard K; protocol flags must match
             the leader's (enforced by the handshake config digest)
  deluxe status (--connect HOST:PORT | --uds PATH) [--json]
             probe a running leader: per-agent liveness, trigger rates
             and wire bytes from its live Status snapshot
  deluxe trace PATH [PATH2] [--check]
             summarize a JSONL event journal (comm savings vs dense,
             trigger rates, straggler histogram); with PATH2, diff the
             deterministic fields of two journals; --check reconciles
             journal sums against the round-end books (exits 1 on
             mismatch)
  deluxe profile PATH [--json] [--flame] [--check] [--strip]
             aggregate a journal's hierarchical spans (DESIGN.md §14):
             per-round phase breakdown, per-agent solve histograms and
             critical-path attribution (which agent/link bounded each
             round); --flame emits folded flame stacks, --strip drops
             wall-clock first (deterministic output), --check verifies
             phase durations and bytes reconcile with the round span
             and the wire books (exits 1 on mismatch)
  deluxe perfdiff BASE HEAD [--tol-pct P] [--budget-pct B]
             compare two BENCH_*.json microbench trajectories: exits 1
             when HEAD regresses a matching case's per-round time by
             more than P% (default 50) or any journal/span overhead
             case exceeds B% (default 5) — the CI regression gate
  deluxe sim --scenario NAME|file.json [--agents N] [--rounds N] [--seed S]
             [--workers N]
             discrete-event network simulation (builtins: ideal | lossy |
             stragglers | churn); scenario JSON schema in DESIGN.md §9
  deluxe lint [--json] [--root DIR]
             house-invariant static analysis: nondeterministic
             iteration, wall-clock reads, ambient RNG, library panics,
             unaccounted sends (rule catalogue in DESIGN.md §11);
             exits 1 on findings
  deluxe info                                          artifact manifest
  deluxe help

EXPERIMENT IDS (DESIGN.md §6):
  tab1-mnist tab1-cifar   Tab. 1  events-to-target-accuracy
  fig3                    Fig. 3  accuracy + comm load per round (CIFAR)
  fig8-mnist fig8-cifar   Fig. 8  Δ-sweep trade-off curves
  fig9                    Fig. 9  linreg + LASSO comm/suboptimality
  fig10                   Fig.10  packet drops & reset period
  fig11                   Fig.11  MNIST over a graph
  fig12                   Fig.12  linreg over a 50-agent graph
  rates                   Thm 4.1/Cor 2.2 rate + floor validation
  pareto                  trigger-Δ x compression frontier (bytes-accurate)
  faults                  latency x participation frontier on the sim
                          backend (drops, stragglers, staleness; --nn adds
                          the NN-surrogate panel; --workers N)
";

fn main() -> Result<()> {
    let (cmd, args) = Args::from_env();
    match cmd.as_deref() {
        Some("exp") => run_exp(&args),
        Some("train") => run_train(&args),
        Some("serve") => run_serve(&args),
        Some("agent") => run_agent(&args),
        Some("status") => run_status(&args),
        Some("trace") => run_trace(&args),
        Some("profile") => run_profile(&args),
        Some("perfdiff") => run_perfdiff(&args),
        Some("sim") => run_sim(&args),
        Some("lint") => run_lint(&args),
        Some("info") => run_info(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn save(rc: &RunConfig, name: &str, rec: &Recorder) -> Result<()> {
    let csv = rc.results_dir.join(format!("{name}.csv"));
    rec.to_csv(&csv)?;
    deluxe::jsonio::write_json(
        &rc.results_dir.join(format!("{name}.json")),
        &rec.to_json(),
    )?;
    println!("  -> {}", csv.display());
    Ok(())
}

/// Resolve the compute backend from `--backend`.
enum BackendChoice {
    Native,
    Pjrt(Variant),
}

fn backend_choice(args: &Args) -> BackendChoice {
    match args.str_or("backend", "native") {
        "pjrt" => BackendChoice::Pjrt(Variant::Pallas),
        "pjrt-ref" => BackendChoice::Pjrt(Variant::Ref),
        _ => BackendChoice::Native,
    }
}

fn run_exp(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args);
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    match id {
        "tab1-mnist" | "tab1-cifar" => exp_tab1(id, args, &rc),
        "fig3" => exp_fig3(args, &rc),
        "fig8-mnist" | "fig8-cifar" => exp_fig8(id, args, &rc),
        "fig9" => exp_fig9(args, &rc),
        "fig10" => exp_fig10(args, &rc),
        "fig11" => exp_fig11(args, &rc),
        "fig12" => exp_fig12(args, &rc),
        "rates" => exp_rates(args, &rc),
        "pareto" => exp_pareto(args, &rc),
        "faults" => exp_faults(args, &rc),
        other => {
            eprintln!("unknown experiment {other:?}\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn workload(id: &str, args: &Args, rc: &RunConfig) -> nn::NnWorkload {
    if id.contains("cifar") {
        nn::NnWorkload::cifar(rc.seed, args.usize_or("agents", 20))
    } else {
        nn::NnWorkload::mnist(rc.seed)
    }
}

/// Tab. 2's per-algorithm communication configurations, adapted to the
/// surrogate workloads.
fn tab_algos(id: &str) -> Vec<nn::Algo> {
    use nn::Algo;
    if id.contains("cifar") {
        vec![
            Algo::Alg1Rand { delta_d: 0.5, delta_z: 0.05, p_trig: 0.1 },
            Algo::Alg1Vanilla { delta_d: 0.5, delta_z: 0.05 },
            Algo::FedAdmm { part: 0.5 },
            Algo::FedAvg { part: 0.4 },
            Algo::FedProx { part: 0.4, mu: 0.1 },
            Algo::Scaffold { part: 0.4 },
        ]
    } else {
        vec![
            Algo::Alg1Rand { delta_d: 0.3, delta_z: 0.03, p_trig: 0.1 },
            Algo::Alg1Vanilla { delta_d: 0.3, delta_z: 0.03 },
            Algo::FedAdmm { part: 0.6 },
            Algo::FedAvg { part: 0.6 },
            Algo::FedProx { part: 0.6, mu: 0.1 },
            Algo::Scaffold { part: 0.5 },
        ]
    }
}

fn with_backend<R>(
    args: &Args,
    f: impl FnOnce(&nn::Backend) -> R,
) -> Result<R> {
    match backend_choice(args) {
        BackendChoice::Native => Ok(f(&nn::Backend::Native)),
        BackendChoice::Pjrt(variant) => {
            let rc = RunConfig::from_args(args);
            let rt = PjrtRuntime::load(&rc.artifacts_dir)?;
            Ok(f(&nn::Backend::Pjrt(&rt, variant)))
        }
    }
}

fn exp_tab1(id: &str, args: &Args, rc: &RunConfig) -> Result<()> {
    let w = workload(id, args, rc);
    let default_rounds = if id.contains("cifar") { 150 } else { 200 };
    let cfg = nn::NnExperimentConfig {
        rounds: args.usize_or("rounds", default_rounds),
        eval_every: 2,
        seed: rc.seed,
        workers: rc.workers,
    };
    let targets: Vec<f64> = if id.contains("cifar") {
        vec![0.60, 0.70, 0.75]
    } else {
        vec![0.85, 0.90, 0.95]
    };
    println!(
        "== Tab. 1 ({id}): fewest events to reach target accuracy ==\n\
         workload: {} agents, {} rounds, backend {}; per-family config\n\
         grids as in the paper's Tab. 2 (each cell = best grid member)\n",
        w.n_agents(),
        cfg.rounds,
        args.str_or("backend", "native"),
    );
    let verbose = args.has("verbose");
    let rows = with_backend(args, |b| {
        nn::tab1_families(id.contains("cifar"))
            .into_iter()
            .map(|(name, family)| {
                if verbose {
                    println!("  {name}:");
                }
                let best = nn::family_events_to_targets(
                    &w, &family, &targets, &cfg, b, verbose,
                );
                (name.to_string(), best)
            })
            .collect::<Vec<_>>()
    })?;
    let mut headers: Vec<String> = vec!["Algorithm".into()];
    headers.extend(targets.iter().map(|t| format!("{:.0}%", t * 100.0)));
    let mut table =
        Table::new(&headers.iter().map(String::as_str).collect::<Vec<_>>());
    let mut json_rows = Vec::new();
    for (label, per_target) in &rows {
        let mut cells = vec![label.clone()];
        cells.extend(per_target.iter().map(|v| fmt_opt(*v)));
        table.row(cells);
        json_rows.push(Json::obj(vec![
            ("algorithm", Json::Str(label.clone())),
            (
                "events",
                Json::Arr(
                    per_target
                        .iter()
                        .map(|v| v.map(Json::Num).unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
        ]));
    }
    println!("{}", table.render());
    deluxe::jsonio::write_json(
        &rc.results_dir.join(format!("{id}.json")),
        &Json::Arr(json_rows),
    )?;
    Ok(())
}

fn exp_fig3(args: &Args, rc: &RunConfig) -> Result<()> {
    let w = workload("cifar", args, rc);
    let cfg = nn::NnExperimentConfig {
        rounds: args.usize_or("rounds", 150),
        eval_every: 2,
        seed: rc.seed,
        workers: rc.workers,
    };
    println!("== Fig. 3: accuracy + smoothed comm load per round ==");
    for algo in tab_algos("cifar") {
        let rec = with_backend(args, |b| nn::run_algo(&w, algo, &cfg, b))?;
        let smooth = rec.smoothed("load", 3);
        let mut out = rec.clone();
        out.series.insert(
            "load_smooth3".into(),
            smooth,
        );
        println!(
            "{:<34} final acc {:.3}  load {:.3}",
            algo.label(),
            rec.last("accuracy").unwrap_or(0.0),
            rec.last("load").unwrap_or(0.0)
        );
        save(rc, &format!("fig3_{}", sanitize(&algo.label())), &out)?;
    }
    Ok(())
}

fn exp_fig8(id: &str, args: &Args, rc: &RunConfig) -> Result<()> {
    let w = workload(id, args, rc);
    let default_rounds = if id.contains("cifar") { 150 } else { 100 };
    let cfg = nn::NnExperimentConfig {
        rounds: args.usize_or("rounds", default_rounds),
        eval_every: 5,
        seed: rc.seed,
        workers: rc.workers,
    };
    println!("== Fig. 8 ({id}): Δ-sweep trade-off (events vs final accuracy) ==");
    let deltas: Vec<f64> = if id.contains("cifar") {
        vec![0.0, 0.5, 1.0, 2.0, 3.0, 4.0]
    } else {
        vec![0.0, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0]
    };
    let parts = [0.2, 0.4, 0.6, 0.8, 1.0];
    let mut rec = Recorder::new();
    with_backend(args, |b| -> Result<()> {
        for &d in &deltas {
            for (name, algo) in [
                ("alg1_vanilla", nn::Algo::Alg1Vanilla { delta_d: d, delta_z: d * 0.1 }),
                (
                    "alg1_rand",
                    nn::Algo::Alg1Rand { delta_d: d, delta_z: d * 0.1, p_trig: 0.1 },
                ),
            ] {
                let r = nn::run_algo(&w, algo, &cfg, b);
                let ev = r.last("events").unwrap_or(0.0);
                let acc = r.last("accuracy").unwrap_or(0.0);
                rec.add(name, ev, acc);
                println!("  {name:<13} Δ={d:<5} events {ev:>8.0}  acc {acc:.3}");
            }
        }
        for &p in &parts {
            for (name, algo) in [
                ("fedadmm", nn::Algo::FedAdmm { part: p }),
                ("fedavg", nn::Algo::FedAvg { part: p }),
                ("fedprox", nn::Algo::FedProx { part: p, mu: 0.1 }),
                ("scaffold", nn::Algo::Scaffold { part: p }),
            ] {
                let r = nn::run_algo(&w, algo, &cfg, b);
                let ev = r.last("events").unwrap_or(0.0);
                let acc = r.last("accuracy").unwrap_or(0.0);
                rec.add(name, ev, acc);
                println!("  {name:<13} p={p:<5} events {ev:>8.0}  acc {acc:.3}");
            }
        }
        Ok(())
    })??;
    save(rc, id, &rec)?;
    Ok(())
}

fn exp_fig9(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = fig9::Fig9Config {
        n_agents: args.usize_or("agents", 50),
        rounds: args.usize_or("rounds", 50),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!("== Fig. 9: comm load vs |f − f*| (linreg α=1.5, LASSO λ=0.1) ==");
    for (panel, label, rec) in fig9::run(&cfg) {
        println!(
            "{panel:<7} {label:<28} events {:>8.0}  subopt {:.3e}",
            rec.last("events").unwrap_or(0.0),
            rec.last("subopt").unwrap_or(f64::NAN),
        );
        save(rc, &format!("fig9_{panel}_{}", sanitize(&label)), &rec)?;
    }
    Ok(())
}

fn exp_fig10(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = fig10::Fig10Config {
        n_agents: args.usize_or("agents", 50),
        rounds: args.usize_or("rounds", 50),
        drop_rate: args.f64_or("drop", 0.3),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!(
        "== Fig. 10: drops (rate {}) and reset period ==",
        cfg.drop_rate
    );
    for (label, rec) in fig10::run(&cfg) {
        println!(
            "{label:<7} subopt {:.3e}  events {:>8.0}",
            rec.last("subopt").unwrap_or(f64::NAN),
            rec.last("events").unwrap_or(0.0),
        );
        save(rc, &format!("fig10_{}", sanitize(&label)), &rec)?;
    }
    Ok(())
}

fn exp_fig11(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = fig11::Fig11Config {
        rounds: args.usize_or("rounds", 300),
        n_agents: args.usize_or("agents", 10),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!("== Fig. 11: MNIST over a graph ({} agents) ==", cfg.n_agents);
    for (label, rec) in fig11::run(&cfg) {
        println!(
            "{label:<28} acc {:.3} [{:.3},{:.3}]  events {:>8.0}",
            rec.last("acc_mean").unwrap_or(0.0),
            rec.last("acc_min").unwrap_or(0.0),
            rec.last("acc_max").unwrap_or(0.0),
            rec.last("events").unwrap_or(0.0),
        );
        save(rc, &format!("fig11_{}", sanitize(&label)), &rec)?;
    }
    Ok(())
}

fn exp_fig12(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = fig12::Fig12Config {
        rounds: args.usize_or("rounds", 2000),
        n_agents: args.usize_or("agents", 50),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!(
        "== Fig. 12: linreg over a {}-agent graph ==",
        cfg.n_agents
    );
    for (label, rec) in fig12::run(&cfg) {
        println!(
            "{label:<28} subopt {:.3e}  events {:>9.0}",
            rec.last("subopt").unwrap_or(f64::NAN),
            rec.last("events").unwrap_or(0.0),
        );
        save(rc, &format!("fig12_{}", sanitize(&label)), &rec)?;
    }
    Ok(())
}

fn exp_rates(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = rates::RatesConfig {
        rounds: args.usize_or("rounds", 400),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!("== Thm 4.1 / Cor 2.2 validation ==");
    let mut table = Table::new(&[
        "Δ", "κ", "measured rate", "bound rate", "floor", "floor bound",
    ]);
    for r in rates::sweep_deltas(&cfg) {
        table.row(vec![
            format!("{:.0e}", r.delta),
            format!("{:.1}", r.kappa),
            format!("{:.5}", r.measured_rate),
            format!("{:.5}", r.bound_rate),
            format!("{:.3e}", r.floor),
            format!("{:.3e}", r.floor_bound),
        ]);
        save(rc, &format!("rates_delta{:.0e}", r.delta), &r.recorder)?;
    }
    println!("{}", table.render());
    Ok(())
}

fn exp_pareto(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = pareto::ParetoConfig {
        n_agents: args.usize_or("agents", 20),
        rounds: args.usize_or("rounds", 400),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!(
        "== Pareto: trigger-Δ x compression (lasso + consensus, \
         byte-accurate) =="
    );
    let points = pareto::run(&cfg);
    let mut table = Table::new(&[
        "panel",
        "Δ",
        "compressor",
        "events",
        "uplink",
        "downlink",
        "subopt",
    ]);
    let mut json_rows = Vec::new();
    for p in &points {
        table.row(vec![
            p.panel.clone(),
            format!("{:.0e}", p.delta),
            p.compressor.clone(),
            format!("{}", p.events),
            fmt_bytes(p.up_bytes),
            fmt_bytes(p.down_bytes),
            format!("{:.3e}", p.subopt),
        ]);
        json_rows.push(Json::obj(vec![
            ("panel", Json::Str(p.panel.clone())),
            ("delta", Json::Num(p.delta)),
            ("compressor", Json::Str(p.compressor.clone())),
            ("events", Json::Num(p.events as f64)),
            ("up_bytes", Json::Num(p.up_bytes as f64)),
            ("down_bytes", Json::Num(p.down_bytes as f64)),
            ("objective", Json::Num(p.objective)),
            ("subopt", Json::Num(p.subopt)),
        ]));
        save(
            rc,
            &format!(
                "pareto_{}_d{:.0e}_{}",
                p.panel,
                p.delta,
                sanitize(&p.compressor)
            ),
            &p.recorder,
        )?;
    }
    println!("{}", table.render());
    // headline: byte reduction vs dense at matched objective per panel/Δ
    for p in &points {
        if p.compressor == "identity" {
            continue;
        }
        if let Some((ratio, gap)) = pareto::uplink_reduction(
            &points,
            &p.panel,
            p.delta,
            &p.compressor,
        ) {
            println!(
                "{:<10} Δ={:<8.0e} {:<14} uplink reduction {ratio:6.1}x \
                 (objective gap {:.3}%)",
                p.panel,
                p.delta,
                p.compressor,
                gap * 100.0
            );
        }
    }
    deluxe::jsonio::write_json(
        &rc.results_dir.join("pareto.json"),
        &Json::Arr(json_rows),
    )?;
    Ok(())
}

fn exp_faults(args: &Args, rc: &RunConfig) -> Result<()> {
    let cfg = faults::FaultsConfig {
        n_agents: args.usize_or("agents", 64),
        rounds: args.usize_or("rounds", 240),
        delta: args.f64_or("delta", 1e-3),
        drop_rate: args.f64_or("drop", 0.05),
        seed: rc.seed,
        workers: rc.workers,
        ..Default::default()
    };
    println!(
        "== faults: latency x participation frontier on the sim backend \
         ({} agents, {} rounds, drop {}, stragglers {:.0}% x{}) ==",
        cfg.n_agents,
        cfg.rounds,
        cfg.drop_rate,
        cfg.straggler_frac * 100.0,
        cfg.straggler_mult,
    );
    let points = faults::run(&cfg);
    let mut table = Table::new(&[
        "latency",
        "quorum",
        "subopt",
        "rel gap",
        "vtime",
        "events",
        "uplink",
        "stale",
    ]);
    let mut json_rows = Vec::new();
    for p in &points {
        table.row(vec![
            fmt_duration(p.latency),
            format!("{:.0}%", p.participation * 100.0),
            format!("{:.3e}", p.subopt),
            format!("{:.2}%", p.rel_gap * 100.0),
            fmt_duration(p.vtime_secs),
            format!("{}", p.events),
            fmt_bytes(p.up_bytes),
            format!("{}", p.stale_discarded),
        ]);
        json_rows.push(Json::obj(vec![
            ("latency", Json::Num(p.latency)),
            ("participation", Json::Num(p.participation)),
            ("objective", Json::Num(p.objective)),
            ("subopt", Json::Num(p.subopt)),
            ("vtime_secs", Json::Num(p.vtime_secs)),
            ("events", Json::Num(p.events as f64)),
            ("up_bytes", Json::Num(p.up_bytes as f64)),
            ("stale_discarded", Json::Num(p.stale_discarded as f64)),
        ]));
        save(
            rc,
            &format!(
                "faults_l{}_q{}",
                sanitize(&format!("{}", p.latency)),
                sanitize(&format!("{}", p.participation))
            ),
            &p.recorder,
        )?;
    }
    println!("{}", table.render());
    deluxe::jsonio::write_json(
        &rc.results_dir.join("faults.json"),
        &Json::Arr(json_rows),
    )?;
    if args.has("nn") {
        println!("\n-- NN-surrogate panel (inexact SGD local solves) --");
        let w = nn::NnWorkload::mnist(rc.seed);
        let nn_cfg = faults::FaultsConfig {
            n_agents: w.n_agents(),
            rounds: args.usize_or("rounds", 100),
            delta: args.f64_or("delta", 0.3),
            ..cfg
        };
        for p in faults::run_nn(&w, &nn_cfg) {
            println!(
                "latency {:<9} quorum {:>4.0}%  acc {:.3}  vtime {:<10} \
                 events {:>7}  uplink {}",
                fmt_duration(p.latency),
                p.participation * 100.0,
                p.accuracy,
                fmt_duration(p.vtime_secs),
                p.events,
                fmt_bytes(p.up_bytes),
            );
        }
    }
    Ok(())
}

fn run_sim(args: &Args) -> Result<()> {
    use deluxe::lasso::{LassoConfig, LassoProblem};
    use deluxe::rng::Pcg64;
    use deluxe::sim::AsyncConsensus;
    use deluxe::solver::{ExactQuadratic, L1Prox};

    let rc = RunConfig::from_args(args);
    let spec = args.str_or("scenario", "ideal");
    let path = std::path::Path::new(spec);
    let mut scn = if path.exists() {
        Scenario::load(path)?
    } else {
        Scenario::builtin(
            spec,
            args.usize_or("agents", 16),
            args.usize_or("rounds", 200),
            rc.seed,
        )
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario {spec:?} (builtins: ideal | lossy | \
                 stragglers | churn; or a path to a scenario JSON file)"
            )
        })?
    };
    if let Some(n) = args.get_parse::<usize>("agents")? {
        scn.n_agents = n;
    }
    if let Some(r) = args.get_parse::<usize>("rounds")? {
        scn.rounds = r;
    }
    if args.get("seed").is_some() {
        scn.seed = rc.seed;
    }
    // flag overrides can invalidate a scenario that parsed fine (e.g.
    // --agents below a fault's agent id): fail as a CLI error, not a
    // panic inside the engine
    scn.validate()
        .map_err(|e| anyhow::anyhow!("scenario {:?}: {e}", scn.name))?;
    println!("scenario {}", scn.summary());

    // LASSO workload sized to the scenario
    let mut rng = Pcg64::seed_stream(scn.seed, 4242);
    let prob = LassoProblem::generate(
        &LassoConfig {
            spec: deluxe::data::regress::RegressSpec {
                n_agents: scn.n_agents,
                rows_per_agent: 8,
                dim: 20,
                ..Default::default()
            },
            lambda: 0.1,
        },
        &mut rng,
    );
    let (_, fstar) = prob.reference_solution(&mut rng);
    let mut engine = AsyncConsensus::<f64>::new(scn, vec![0.0; prob.dim])
        .with_workers(rc.workers);
    let mut solver = ExactQuadratic::new(&prob.blocks);
    let mut prox = L1Prox { lambda: prob.lambda };
    let rounds = engine.scn.rounds as u64;
    let mut rec = Recorder::new();
    for r in 1..=rounds {
        engine.run_until(r, &mut solver, &mut prox);
        let subopt = (prob.objective(&engine.z) - fstar).max(1e-16);
        rec.add("subopt", r as f64, subopt);
        rec.add("vtime", r as f64, engine.now_secs());
        rec.add("subopt_vs_vtime", engine.now_secs(), subopt);
    }
    let (up, down) = engine.bytes_split();
    let (du, dd) = engine.drops_split();
    println!(
        "completed {} / {} leader rounds in {} virtual time \
         ({} events processed)",
        engine.leader_round,
        rounds,
        fmt_duration(engine.now_secs()),
        engine.events_processed(),
    );
    println!(
        "subopt {:.3e}  events {}  uplink {} (dropped {du})  \
         downlink {} (dropped {dd})  stale discarded {}  rejoins {}",
        (prob.objective(&engine.z) - fstar).max(1e-16),
        engine.total_events(),
        fmt_bytes(up),
        fmt_bytes(down),
        engine.stale_discarded,
        engine.rejoin_resyncs,
    );
    println!("trace hash {:016x} (same scenario + seed => same hash)",
        engine.trace_hash());
    save(&rc, &format!("sim_{}", sanitize(&engine.scn.name)), &rec)?;
    Ok(())
}

/// Workload-derived protocol defaults shared by `train`, `serve` and
/// `agent`, so all three build the identical [`RunConfig`] from the same
/// flags — and therefore the identical handshake digest.  Explicit flags
/// always win; the vanilla Δ=0.5 trigger pair applies only when no
/// trigger flag was given at all.
fn apply_train_defaults(
    mut rc: RunConfig,
    w: &nn::NnWorkload,
    args: &Args,
) -> RunConfig {
    if args.get("rho").is_none() {
        rc.rho = w.rho as f32;
    }
    if args.get("lr").is_none() {
        rc.lr = w.lr;
    }
    if args.get("steps").is_none() {
        rc.steps = w.steps;
    }
    if args.get("batch").is_none() {
        rc.batch = w.batch;
    }
    if args.get("delta").is_none()
        && args.get("trigger-d").is_none()
        && args.get("trigger-z").is_none()
    {
        rc = rc.with_delta(0.5);
    }
    rc
}

fn run_train(args: &Args) -> Result<()> {
    use deluxe::coordinator::Coordinator;
    let rc = RunConfig::from_args(args);
    let rounds = args.usize_or("rounds", 60);
    let w = nn::NnWorkload::mnist(rc.seed);
    let rc = apply_train_defaults(rc, &w, args);
    println!(
        "threaded e2e training: {} agents (single-class shards), {} rounds, \
         trigger {}, compressor {}",
        w.n_agents(),
        rounds,
        rc.trigger_d.label(),
        rc.compressor.label()
    );
    let init = w.spec.init(&mut deluxe::rng::Pcg64::seed(rc.seed));
    let mut coord =
        Coordinator::spawn(rc, w.spec.clone(), w.shards.clone(), init);
    coord.obs = journal_obs(args, false)?;
    drive_leader(coord, &w, rounds)
}

/// Resolve `--journal PATH` into an [`deluxe::obs::Obs`] handle.  With
/// no flag: a journal-less live handle when `default_on` (serve keeps
/// metrics warm for `deluxe status`), else fully off.  The flag never
/// enters the handshake digest — observability is per-process.
fn journal_obs(args: &Args, default_on: bool) -> Result<deluxe::obs::Obs> {
    use deluxe::obs::Obs;
    match args.get("journal") {
        Some(path) => Obs::to_path(std::path::Path::new(path)),
        None if default_on => Ok(Obs::new()),
        None => Ok(Obs::off()),
    }
}

/// Round loop + final report shared by `train` (in-proc transport) and
/// `serve` (socket transport).
fn drive_leader<TP: deluxe::transport::Transport>(
    mut coord: deluxe::coordinator::Coordinator<TP>,
    w: &nn::NnWorkload,
    rounds: usize,
) -> Result<()> {
    for k in 0..rounds {
        coord.round();
        if (k + 1) % 10 == 0 {
            let acc = w.spec.accuracy(&coord.z, &w.test.xs, &w.test.labels);
            println!(
                "round {:>4}: accuracy {:.3}  (live {}/{}, rejoins {}, \
                 stale {})",
                k + 1,
                acc,
                coord.live_count(),
                w.n_agents(),
                coord.rejoin_resyncs,
                coord.stale_replies,
            );
        }
    }
    let acc = w.spec.accuracy(&coord.z, &w.test.xs, &w.test.labels);
    let down = coord.downlink_events();
    let up_bytes = coord.uplink_bytes();
    let down_bytes = coord.downlink_bytes();
    coord.obs.flush();
    let up = coord.shutdown();
    let dense = deluxe::wire::WireMessage::<f32>::dense_bytes(
        w.spec.param_len(),
    ) as u64;
    println!(
        "final accuracy {acc:.3}; events up {up} down {down} (full would be {})",
        rounds * w.n_agents() * 2
    );
    println!(
        "wire: uplink {} downlink {} (full-dense would be {} per direction)",
        fmt_bytes(up_bytes),
        fmt_bytes(down_bytes),
        fmt_bytes(rounds as u64 * w.n_agents() as u64 * dense),
    );
    Ok(())
}

fn run_serve(args: &Args) -> Result<()> {
    use deluxe::coordinator::Coordinator;
    use deluxe::transport::{SocketOpts, Tcp};

    let rc = RunConfig::from_args(args);
    let rounds = args.usize_or("rounds", 60);
    let w = nn::NnWorkload::mnist(rc.seed);
    let rc = apply_train_defaults(rc, &w, args);
    let n = w.n_agents();
    let init = w.spec.init(&mut deluxe::rng::Pcg64::seed(rc.seed));
    let digest = rc.digest(init.len(), n);

    #[cfg(unix)]
    {
        if let Some(path) = args.get("uds") {
            use deluxe::transport::Uds;
            let mut tp = <Uds>::bind(
                path,
                n,
                digest,
                init.len(),
                SocketOpts::default(),
            )?;
            println!(
                "serving {n} agents on uds:{path} (config digest \
                 {digest:016x}); waiting for cohort…"
            );
            tp.await_cohort()?;
            println!("cohort complete; starting rounds");
            let mut coord = Coordinator::over(tp, rc, w.spec.clone(), init);
            coord.obs = journal_obs(args, true)?;
            return drive_leader(coord, &w, rounds);
        }
    }
    let listen = args.str_or("listen", "127.0.0.1:46700");
    let mut tp =
        <Tcp>::bind(listen, n, digest, init.len(), SocketOpts::default())?;
    println!(
        "serving {n} agents on tcp:{} (config digest {digest:016x}); \
         waiting for cohort…",
        tp.local_addr()
    );
    tp.await_cohort()?;
    println!("cohort complete; starting rounds");
    let mut coord = Coordinator::over(tp, rc, w.spec.clone(), init);
    coord.obs = journal_obs(args, true)?;
    drive_leader(coord, &w, rounds)
}

fn run_agent(args: &Args) -> Result<()> {
    use deluxe::coordinator::{make_endpoints, run_tcp_agent_obs, AgentOpts};

    let rc = RunConfig::from_args(args);
    let w = nn::NnWorkload::mnist(rc.seed);
    let rc = apply_train_defaults(rc, &w, args);
    let n = w.n_agents();
    let shard = match args.get_parse::<usize>("shard")? {
        Some(k) => k,
        None => anyhow::bail!("deluxe agent requires --shard K"),
    };
    anyhow::ensure!(
        shard < n,
        "--shard {shard} out of range (workload has {n} shards)"
    );
    let init = w.spec.init(&mut deluxe::rng::Pcg64::seed(rc.seed));
    let digest = rc.digest(init.len(), n);
    // every agent derives the full deterministic endpoint set and keeps
    // its own shard's — no leader round-trip needed for RNG streams
    let mut endpoints =
        make_endpoints(&rc, &w.spec, w.shards.clone(), &init);
    let mut ep = endpoints.remove(shard);
    drop(endpoints);
    let opts = AgentOpts::default();
    let mut obs = journal_obs(args, false)?;

    #[cfg(unix)]
    {
        if let Some(path) = args.get("uds") {
            use deluxe::coordinator::run_uds_agent_obs;
            println!(
                "agent {shard}/{n} connecting to uds:{path} (config digest \
                 {digest:016x})"
            );
            let end = run_uds_agent_obs(path, &mut ep, digest, &opts, &mut obs)?;
            obs.flush();
            println!(
                "agent {shard}: session ended ({end:?}); {} uplink events, \
                 {} sent",
                ep.events(),
                fmt_bytes(ep.sent_bytes()),
            );
            return Ok(());
        }
    }
    let addr = args.str_or("connect", "127.0.0.1:46700");
    println!(
        "agent {shard}/{n} connecting to tcp:{addr} (config digest \
         {digest:016x})"
    );
    let end = run_tcp_agent_obs(addr, &mut ep, digest, &opts, &mut obs)?;
    obs.flush();
    println!(
        "agent {shard}: session ended ({end:?}); {} uplink events, {} sent",
        ep.events(),
        fmt_bytes(ep.sent_bytes()),
    );
    Ok(())
}

/// One-shot status probe: a bare connection that sends `StatusReq`
/// instead of `Hello` and reads back the leader's `Status` snapshot.
fn fetch_status<S: std::io::Read + std::io::Write>(
    s: &mut S,
) -> Result<String> {
    use deluxe::transport::frame::{read_frame, write_frame, Frame};
    write_frame(s, &Frame::StatusReq)?;
    match read_frame(s)? {
        Frame::Status { json } => Ok(json),
        other => anyhow::bail!("expected Status, got {}", other.kind()),
    }
}

fn run_status(args: &Args) -> Result<()> {
    #[cfg(unix)]
    let json = if let Some(path) = args.get("uds") {
        let mut s = std::os::unix::net::UnixStream::connect(path)?;
        fetch_status(&mut s)?
    } else {
        let addr = args.str_or("connect", "127.0.0.1:46700");
        let mut s = std::net::TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        fetch_status(&mut s)?
    };
    #[cfg(not(unix))]
    let json = {
        let addr = args.str_or("connect", "127.0.0.1:46700");
        let mut s = std::net::TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        fetch_status(&mut s)?
    };
    anyhow::ensure!(
        !json.is_empty(),
        "leader is up but has not completed a round yet (empty status)"
    );
    let st = Json::parse(&json)
        .map_err(|e| anyhow::anyhow!("malformed status JSON: {e:?}"))?;
    if args.has("json") {
        println!("{}", st.to_string());
        return Ok(());
    }
    let num =
        |k: &str| st.get(k).and_then(|j| j.as_f64()).unwrap_or(0.0) as u64;
    let arr = |k: &str| -> Vec<u64> {
        st.get(k)
            .and_then(|j| j.as_arr())
            .map(|a| {
                a.iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as u64)
                    .collect()
            })
            .unwrap_or_default()
    };
    let live: Vec<bool> = st
        .get("live")
        .and_then(|j| j.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_bool()).collect())
        .unwrap_or_default();
    let round = num("round");
    println!(
        "round {round}  agents {}  live {}/{}  rejoin resyncs {}  stale \
         replies {}",
        num("agents"),
        live.iter().filter(|&&l| l).count(),
        live.len(),
        num("rejoin_resyncs"),
        num("stale_replies"),
    );
    let up_ev = arr("uplink_events");
    let up_b = arr("uplink_bytes");
    let down_ev = arr("downlink_events");
    let down_b = arr("downlink_bytes");
    let mut table = Table::new(&[
        "agent", "live", "up events", "up rate", "up bytes", "down events",
        "down bytes",
    ]);
    for (i, &l) in live.iter().enumerate() {
        let ev = up_ev.get(i).copied().unwrap_or(0);
        let rate = if round > 0 { ev as f64 / round as f64 } else { 0.0 };
        table.row(vec![
            format!("{i}"),
            if l { "yes".into() } else { "NO".into() },
            format!("{ev}"),
            format!("{rate:.2}"),
            fmt_bytes(up_b.get(i).copied().unwrap_or(0)),
            format!("{}", down_ev.get(i).copied().unwrap_or(0)),
            fmt_bytes(down_b.get(i).copied().unwrap_or(0)),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn run_trace(args: &Args) -> Result<()> {
    let paths = &args.positional;
    anyhow::ensure!(
        !paths.is_empty(),
        "deluxe trace needs a journal path (see `deluxe help`)"
    );
    if paths.len() >= 2 {
        return trace_diff(&paths[0], &paths[1]);
    }
    let src = std::fs::read_to_string(&paths[0])?;
    let parsed = deluxe::obs::parse_journal_lossy(&src)?;
    if parsed.truncated > 0 {
        eprintln!(
            "warning: final journal line truncated (crashed writer?); \
             recovered {} complete events",
            parsed.events.len()
        );
    }
    trace_summary(&parsed.events, args.has("check"))
}

fn bump(v: &mut Vec<u64>, i: usize, by: u64) {
    if v.len() <= i {
        v.resize(i + 1, 0);
    }
    v[i] += by;
}

/// Journal summary: comm savings vs the dense baseline in exact bytes,
/// per-agent trigger rates, straggler histogram; `--check` reconciles
/// the per-event sums against the final `round_end` cumulative books.
fn trace_summary(events: &[deluxe::jsonio::Json], check: bool) -> Result<()> {
    let kind = |j: &Json| j.get("ev").and_then(|v| v.as_str()).unwrap_or("");
    let num = |j: &Json, k: &str| {
        j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
    };
    let line_up =
        |j: &Json| j.get("line").and_then(|v| v.as_str()) == Some("up");
    let mut agents = 0usize;
    let mut dense = 0u64;
    let mut rounds = 0u64;
    let mut trig_count = 0u64;
    let mut up_trig: Vec<u64> = Vec::new();
    let mut down_trig: Vec<u64> = Vec::new();
    let (mut up_sent, mut down_sent) = (0u64, 0u64);
    let (mut resets, mut reset_bytes) = (0u64, 0u64);
    let (mut drops, mut dropped_bytes) = (0u64, 0u64);
    let mut last_end: Option<(u64, u64, u64)> = None;
    let mut solve_hist = deluxe::obs::Histogram::default();
    for j in events {
        match kind(j) {
            "meta" => {
                agents = num(j, "agents") as usize;
                dense = num(j, "dense_bytes");
            }
            "round_end" => {
                rounds += 1;
                last_end = Some((
                    num(j, "events"),
                    num(j, "up_bytes"),
                    num(j, "down_bytes"),
                ));
            }
            "trigger_fired" => {
                trig_count += 1;
                let a = num(j, "agent") as usize;
                if line_up(j) {
                    bump(&mut up_trig, a, 1);
                } else {
                    bump(&mut down_trig, a, 1);
                }
            }
            "msg_sent" => {
                let b = num(j, "bytes");
                if line_up(j) {
                    up_sent += b;
                } else {
                    down_sent += b;
                }
            }
            "pkt_dropped" => {
                drops += 1;
                dropped_bytes += num(j, "bytes");
            }
            "reset_sync" => {
                resets += 1;
                reset_bytes += num(j, "bytes");
            }
            "solve_done" => solve_hist.observe(num(j, "wall_us")),
            _ => {}
        }
    }
    println!(
        "journal: {} events, {rounds} rounds, {agents} agents",
        events.len()
    );
    let actual = up_sent + down_sent + reset_bytes;
    println!(
        "wire: uplink {} + downlink {} + resets {} = {} ({actual} bytes); \
         {drops} packets dropped ({})",
        fmt_bytes(up_sent),
        fmt_bytes(down_sent),
        fmt_bytes(reset_bytes),
        fmt_bytes(actual),
        fmt_bytes(dropped_bytes),
    );
    let baseline = 2 * dense * agents as u64 * rounds;
    if baseline > 0 {
        println!(
            "dense baseline: {} ({dense} bytes x {agents} agents x \
             {rounds} rounds x 2 directions = {baseline} bytes); comm \
             savings {:.1}%",
            fmt_bytes(baseline),
            100.0 * (1.0 - actual as f64 / baseline as f64),
        );
    }
    let n = agents.max(up_trig.len()).max(down_trig.len());
    let r = rounds.max(1) as f64;
    let mut table =
        Table::new(&["agent", "up trig", "up rate", "down trig", "down rate"]);
    for i in 0..n {
        let u = up_trig.get(i).copied().unwrap_or(0);
        let d = down_trig.get(i).copied().unwrap_or(0);
        table.row(vec![
            format!("{i}"),
            format!("{u}"),
            format!("{:.2}", u as f64 / r),
            format!("{d}"),
            format!("{:.2}", d as f64 / r),
        ]);
    }
    println!("{}", table.render());
    if solve_hist.count() > 0 {
        println!(
            "solve-time straggler histogram (µs, log2 buckets; mean {:.0}):",
            solve_hist.mean()
        );
        let hj = solve_hist.to_json();
        if let Some(bs) = hj.get("buckets").and_then(|b| b.as_arr()) {
            for b in bs {
                if let Some(t) = b.as_arr() {
                    println!(
                        "  [{:>10} .. {:>10}]  {}",
                        t[0].as_f64().unwrap_or(0.0) as u64,
                        t[1].as_f64().unwrap_or(0.0) as u64,
                        t[2].as_f64().unwrap_or(0.0) as u64,
                    );
                }
            }
        }
    }
    if check {
        let (ev, upb, downb) = last_end.ok_or_else(|| {
            anyhow::anyhow!("--check needs at least one round_end event")
        })?;
        let mut bad = false;
        // a reset counts one trigger event in the books but journals as
        // reset_sync, so the event reconciliation is the sum of both
        if trig_count + resets != ev {
            eprintln!(
                "check: trigger_fired {trig_count} + reset_sync {resets} \
                 != round_end events {ev}"
            );
            bad = true;
        }
        if up_sent != upb {
            eprintln!(
                "check: sum(msg_sent up) {up_sent} != round_end up_bytes \
                 {upb}"
            );
            bad = true;
        }
        if down_sent + reset_bytes != downb {
            eprintln!(
                "check: sum(msg_sent down) {down_sent} + sum(reset_sync) \
                 {reset_bytes} != round_end down_bytes {downb}"
            );
            bad = true;
        }
        if bad {
            std::process::exit(1);
        }
        println!(
            "check: journal sums match the round_end books (events {ev}, \
             up {}, down {})",
            fmt_bytes(upb),
            fmt_bytes(downb),
        );
    }
    Ok(())
}

/// Diff the deterministic fields of two journals (wall-clock stripped).
fn trace_diff(a: &str, b: &str) -> Result<()> {
    let pa = deluxe::obs::parse_journal_lossy(&std::fs::read_to_string(a)?)?;
    let pb = deluxe::obs::parse_journal_lossy(&std::fs::read_to_string(b)?)?;
    for (path, p) in [(a, &pa), (b, &pb)] {
        if p.truncated > 0 {
            eprintln!("warning: {path}: final journal line truncated");
        }
    }
    let (ja, jb) = (pa.events, pb.events);
    let strip = |v: &[Json]| -> Vec<String> {
        v.iter()
            .map(|j| deluxe::obs::strip_wall(j).to_string())
            .collect()
    };
    let (sa, sb) = (strip(&ja), strip(&jb));
    if sa == sb {
        println!(
            "journals identical over deterministic fields ({} events)",
            sa.len()
        );
        return Ok(());
    }
    let mut i = 0;
    while i < sa.len().min(sb.len()) && sa[i] == sb[i] {
        i += 1;
    }
    println!(
        "journals diverge at event {} ({} vs {} events total)",
        i + 1,
        sa.len(),
        sb.len()
    );
    if let Some(l) = sa.get(i) {
        println!("  a: {l}");
    }
    if let Some(l) = sb.get(i) {
        println!("  b: {l}");
    }
    let mut by_kind: std::collections::BTreeMap<String, (i64, i64)> =
        std::collections::BTreeMap::new();
    for j in &ja {
        let k = j.get("ev").and_then(|v| v.as_str()).unwrap_or("?");
        by_kind.entry(k.to_string()).or_default().0 += 1;
    }
    for j in &jb {
        let k = j.get("ev").and_then(|v| v.as_str()).unwrap_or("?");
        by_kind.entry(k.to_string()).or_default().1 += 1;
    }
    for (k, (ca, cb)) in &by_kind {
        if ca != cb {
            println!("  {k}: {ca} vs {cb}");
        }
    }
    std::process::exit(1);
}

/// `deluxe profile` — span-level performance digest of one journal
/// (DESIGN.md §14): per-round phase breakdown, per-agent solve-time
/// histograms, folded flame stacks and critical-path attribution.
fn run_profile(args: &Args) -> Result<()> {
    let paths = &args.positional;
    anyhow::ensure!(
        paths.len() == 1,
        "deluxe profile needs exactly one journal path (see `deluxe help`)"
    );
    let src = std::fs::read_to_string(&paths[0])?;
    let parsed = deluxe::obs::parse_journal_lossy(&src)?;
    if parsed.truncated > 0 {
        eprintln!(
            "warning: final journal line truncated (crashed writer?); \
             recovered {} complete events",
            parsed.events.len()
        );
    }
    let events: Vec<Json> = if args.has("strip") {
        parsed.events.iter().map(|j| deluxe::obs::strip_wall(j)).collect()
    } else {
        parsed.events
    };
    let mut profile = deluxe::obs::profile::analyze(&events);
    profile.truncated = parsed.truncated;
    if args.has("json") {
        println!("{}", profile.to_json().to_string());
    } else if args.has("flame") {
        eprintln!("# folded flame stacks; self cost in {}", profile.flame_unit);
        for (path, v) in &profile.folded {
            println!("{path} {v}");
        }
    } else {
        print_profile(&profile);
    }
    if args.has("check") {
        if profile.rounds.is_empty() {
            eprintln!(
                "check: journal has no closed round spans to reconcile \
                 (run the leader with the journal enabled)"
            );
            std::process::exit(1);
        }
        if !profile.violations.is_empty() {
            for v in &profile.violations {
                eprintln!("check: {v}");
            }
            std::process::exit(1);
        }
        println!(
            "check: {} rounds reconcile with the round spans and wire books \
             ({} spans opened, {} closed)",
            profile.rounds.len(),
            profile.spans_opened,
            profile.spans_closed,
        );
    }
    Ok(())
}

/// One phase cell for the per-round table: wall µs when the journal
/// carries wall-clock, else bytes (the deterministic fallback).
fn phase_cell(agg: Option<&deluxe::obs::profile::PhaseAgg>) -> String {
    match agg {
        None => "-".to_string(),
        Some(a) if a.wall_known => format!("{}µs", a.wall_us),
        Some(a) if a.bytes > 0 => fmt_bytes(a.bytes),
        Some(a) if a.vtime_us > 0 => format!("{}vµs", a.vtime_us),
        Some(_) => "0".to_string(),
    }
}

fn critical_cell(c: Option<&deluxe::obs::profile::Critical>) -> String {
    match c {
        None => "-".to_string(),
        Some(c) => {
            let who = match c.agent {
                Some(a) => format!("a{a}"),
                None => "?".to_string(),
            };
            let cost = match c.unit {
                "wall_us" => format!("{}µs", c.cost),
                "vtime_us" => format!("{}vµs", c.cost),
                _ => fmt_bytes(c.cost),
            };
            format!("{who} {} {cost}", c.kind.as_str())
        }
    }
}

fn print_profile(p: &deluxe::obs::profile::Profile) {
    println!(
        "profile: {} rounds, {} spans opened / {} closed, {} violation(s)",
        p.rounds.len(),
        p.spans_opened,
        p.spans_closed,
        p.violations.len()
    );
    let mut rounds = Table::new(&[
        "round", "wall", "broadcast", "local_solve", "gather", "apply",
        "critical path",
    ]);
    for r in &p.rounds {
        rounds.row(vec![
            format!("{}", r.round),
            r.wall_us.map_or("-".to_string(), |w| format!("{w}µs")),
            phase_cell(r.phases.get("broadcast")),
            phase_cell(r.phases.get("local_solve")),
            phase_cell(r.phases.get("gather")),
            phase_cell(r.phases.get("apply")),
            critical_cell(r.critical.as_ref()),
        ]);
    }
    println!("{}", rounds.render());
    let mut totals =
        Table::new(&["phase", "count", "wall", "bytes", "vtime"]);
    for (k, a) in &p.phase_totals {
        totals.row(vec![
            k.to_string(),
            format!("{}", a.count),
            if a.wall_known { format!("{}µs", a.wall_us) } else { "-".to_string() },
            fmt_bytes(a.bytes),
            format!("{}µs", a.vtime_us),
        ]);
    }
    println!("{}", totals.render());
    if !p.solve_hist.is_empty() {
        let mut solves =
            Table::new(&["agent", "solves", "mean µs", "min µs", "max µs"]);
        for (a, h) in &p.solve_hist {
            solves.row(vec![
                format!("{a}"),
                format!("{}", h.count()),
                format!("{:.0}", h.mean()),
                format!("{}", h.min()),
                format!("{}", h.max()),
            ]);
        }
        println!("per-agent solve wall time:\n{}", solves.render());
    }
    for v in &p.violations {
        println!("violation: {v}");
    }
}

/// Identity key for matching trajectory cases across two BENCH files:
/// the stable knob fields, in fixed order, skipping absent ones.
fn case_key(c: &Json) -> String {
    let mut parts = Vec::new();
    for k in ["workers", "transport", "journal", "spans", "kernel", "solver"] {
        if let Some(v) = c.get(k) {
            parts.push(format!("{k}={}", v.to_string()));
        }
    }
    parts.join(",")
}

/// `deluxe perfdiff` — the CI perf-regression gate: compare a HEAD
/// microbench trajectory against the previous PR's BASE file.  Fails
/// (exit 1) when HEAD is not measured, any journal/span overhead case
/// exceeds the budget, a BASE case disappeared, or a matching case's
/// per-round time regressed beyond the tolerance.
fn run_perfdiff(args: &Args) -> Result<()> {
    let paths = &args.positional;
    anyhow::ensure!(
        paths.len() == 2,
        "deluxe perfdiff needs BASE and HEAD paths (see `deluxe help`)"
    );
    let tol = args.f64_or("tol-pct", 50.0);
    let budget = args.f64_or("budget-pct", 5.0);
    let base = deluxe::jsonio::read_json(std::path::Path::new(&paths[0]))?;
    let head = deluxe::jsonio::read_json(std::path::Path::new(&paths[1]))?;
    let measured = |j: &Json| {
        j.get("measured").and_then(Json::as_bool).unwrap_or(false)
    };
    let cases = |j: &Json| -> Vec<Json> {
        j.get("cases")
            .and_then(Json::as_arr)
            .map(|a| a.to_vec())
            .unwrap_or_default()
    };
    let head_cases = cases(&head);
    let mut bad = false;
    if !measured(&head) || head_cases.is_empty() {
        eprintln!(
            "perfdiff: HEAD {} is not a measured trajectory \
             (measured:true with non-empty cases required)",
            paths[1]
        );
        std::process::exit(1);
    }
    // budget gate: every overhead case must stay within budget
    for c in &head_cases {
        if let Some(pct) = c.get("overhead_vs_off_pct").and_then(Json::as_f64) {
            if pct > budget {
                eprintln!(
                    "perfdiff: case [{}] overhead {pct:.2}% exceeds the \
                     {budget}% budget",
                    case_key(c)
                );
                bad = true;
            }
        }
    }
    // regression gate: compare per-round time per matching case
    let base_cases = cases(&base);
    if measured(&base) && !base_cases.is_empty() {
        for bc in &base_cases {
            let key = case_key(bc);
            let b_us = bc.get("per_round_us").and_then(Json::as_f64);
            let hc = head_cases.iter().find(|c| case_key(c) == key);
            match (hc, b_us) {
                (None, _) => {
                    eprintln!(
                        "perfdiff: case [{key}] present in BASE but missing \
                         from HEAD"
                    );
                    bad = true;
                }
                (Some(hc), Some(b_us)) if b_us > 0.0 => {
                    let h_us = hc
                        .get("per_round_us")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0);
                    let ratio = 100.0 * (h_us / b_us - 1.0);
                    if ratio > tol {
                        eprintln!(
                            "perfdiff: case [{key}] per-round time regressed \
                             {ratio:.1}% ({b_us:.1}µs -> {h_us:.1}µs, \
                             tolerance {tol}%)"
                        );
                        bad = true;
                    } else {
                        println!(
                            "perfdiff: case [{key}] {b_us:.1}µs -> \
                             {h_us:.1}µs ({ratio:+.1}%)"
                        );
                    }
                }
                _ => {}
            }
        }
    } else {
        println!(
            "perfdiff: BASE {} is a placeholder (unmeasured); structural \
             and budget checks only",
            paths[0]
        );
    }
    if bad {
        std::process::exit(1);
    }
    println!(
        "perfdiff: {} HEAD case(s) within budget {budget}% and tolerance \
         {tol}%",
        head_cases.len()
    );
    Ok(())
}

fn run_lint(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.str_or("root", "."));
    let findings = deluxe::analysis::run_on_tree(&root)?;
    if args.has("json") {
        println!(
            "{}",
            deluxe::analysis::findings_to_json(&findings).to_string()
        );
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!("-- {} finding(s)", findings.len());
    }
    if !findings.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

fn run_info(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args);
    let rt = PjrtRuntime::load(&rc.artifacts_dir)?;
    println!("artifacts: {}", rc.artifacts_dir.display());
    let mut names: Vec<&String> = rt.manifest.configs.keys().collect();
    names.sort();
    for name in names {
        let c = &rt.manifest.configs[name];
        println!(
            "  {name}: layers {:?}, P={}, batch={}, steps={}, {} artifacts",
            c.layers,
            c.param_len,
            c.batch,
            c.steps,
            c.artifacts.len()
        );
    }
    Ok(())
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_alphanumeric() { c } else { '_' })
        .collect()
}
