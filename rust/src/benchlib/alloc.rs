//! Thread-local allocation counting for zero-alloc hot-path assertions.
//!
//! [`CountingAlloc`] is a [`GlobalAlloc`] wrapper around the system
//! allocator that counts allocations (and allocated bytes) on the
//! *current thread* while counting is [`enable`]d.  It is NOT installed
//! by the library — a test binary opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: deluxe::benchlib::alloc::CountingAlloc = CountingAlloc;
//! ```
//!
//! so the crate's normal builds keep the plain system allocator.
//! `rust/tests/alloc.rs` uses it to pin the DESIGN.md §15 contract: the
//! fused solve phase performs **zero allocations per round after
//! warmup**.
//!
//! Implementation constraints (an allocator must never allocate or
//! panic while serving a request):
//!
//! * the counters are `const`-initialized `thread_local!` cells — no
//!   lazy initialization, so reading them never allocates;
//! * all cell access goes through `try_with`, so a request landing
//!   during thread teardown is simply not counted instead of aborting;
//! * only `alloc` / `alloc_zeroed` / `realloc` count; `dealloc` is
//!   free-of-charge (the contract is about acquiring memory).
//!
//! Counting is per-thread by design: the pooled solve path's worker
//! threads are *supposed* to allocate during warmup, and the assertion
//! runs on the driving thread with `WorkerPool::sequential()` where the
//! whole hot path executes inline.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static COUNT: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

/// System-allocator wrapper that bumps the current thread's counters
/// while counting is enabled.  Zero-sized; install via
/// `#[global_allocator]` in the binary that wants accounting.
pub struct CountingAlloc;

fn note(size: usize) {
    let _ = ENABLED.try_with(|e| {
        if e.get() {
            let _ = COUNT.try_with(|c| c.set(c.get() + 1));
            let _ = BYTES.try_with(|b| b.set(b.get() + size as u64));
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        note(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Start counting on the current thread (counters keep their values;
/// call [`reset`] first for a fresh measurement).
pub fn enable() {
    let _ = ENABLED.try_with(|e| e.set(true));
}

/// Stop counting on the current thread.
pub fn disable() {
    let _ = ENABLED.try_with(|e| e.set(false));
}

/// Zero the current thread's counters.
pub fn reset() {
    let _ = COUNT.try_with(|c| c.set(0));
    let _ = BYTES.try_with(|b| b.set(0));
}

/// `(allocations, bytes)` counted on the current thread since the last
/// [`reset`].
pub fn counts() -> (u64, u64) {
    let count = COUNT.try_with(Cell::get).unwrap_or(0);
    let bytes = BYTES.try_with(Cell::get).unwrap_or(0);
    (count, bytes)
}

/// Run `f` with counting enabled and return `(result, allocations,
/// bytes)` attributed to it.  Counting state is reset on entry and
/// disabled on exit; the measurement machinery itself performs no heap
/// allocation between enable and disable.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, u64, u64) {
    reset();
    enable();
    let out = f();
    let (count, bytes) = counts();
    disable();
    (out, count, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: CountingAlloc is not installed as the global allocator in
    // unit tests (that happens only in `rust/tests/alloc.rs`), so these
    // tests exercise the counter plumbing, not actual interception.

    #[test]
    fn counters_accumulate_and_reset() {
        reset();
        enable();
        note(16);
        note(8);
        let (count, bytes) = counts();
        assert_eq!((count, bytes), (2, 24));
        disable();
        note(100); // not counted while disabled
        assert_eq!(counts(), (2, 24));
        reset();
        assert_eq!(counts(), (0, 0));
    }

    #[test]
    fn measure_scopes_the_counting() {
        note(999); // stray note before: wiped by measure's reset
        let (out, count, bytes) = measure(|| {
            note(32);
            7
        });
        assert_eq!(out, 7);
        assert_eq!((count, bytes), (1, 32));
        // counting is off afterwards
        note(5);
        assert_eq!(counts(), (1, 32));
    }
}
