//! Criterion-style micro/endtoend benchmark harness (the offline
//! environment has no `criterion`).
//!
//! Benches under `benches/` use `harness = false` and drive this module:
//! adaptive warmup, fixed-duration sampling, robust statistics and a
//! plain-text report compatible with `cargo bench` output scraping.

pub mod alloc;

use std::time::{Duration, Instant};

/// One benchmark's collected samples and statistics.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    fn per_iter_ns(&self) -> Vec<f64> {
        self.samples
            .iter()
            .map(|d| d.as_nanos() as f64 / self.iters_per_sample as f64)
            .collect()
    }

    pub fn mean_ns(&self) -> f64 {
        let xs = self.per_iter_ns();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    }

    pub fn median_ns(&self) -> f64 {
        let mut xs = self.per_iter_ns();
        xs.sort_by(f64::total_cmp);
        if xs.is_empty() {
            return 0.0;
        }
        xs[xs.len() / 2]
    }

    pub fn stddev_ns(&self) -> f64 {
        let xs = self.per_iter_ns();
        if xs.len() < 2 {
            return 0.0;
        }
        let mean = self.mean_ns();
        (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>14}/iter  (median {:>14}, sd {:>12}, {} samples x {} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.median_ns()),
            fmt_ns(self.stddev_ns()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }

    /// JSON row for the `BENCH_*.json` perf-trajectory series (see
    /// `rust/benches/microbench.rs --trajectory`).
    pub fn to_json(&self) -> crate::jsonio::Json {
        use crate::jsonio::Json;
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("median_ns", Json::Num(self.median_ns())),
            ("stddev_ns", Json::Num(self.stddev_ns())),
            ("samples", Json::Num(self.samples.len() as f64)),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
        ])
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: warm up ~`warmup`, then take `samples` timed samples
/// whose iteration count is sized so each sample runs >= `sample_time`.
pub struct Bench {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            sample_time: Duration::from_millis(100),
            samples: 12,
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// Fast harness for end-to-end benches that are themselves slow.
    pub fn endtoend() -> Self {
        Bench {
            warmup: Duration::from_millis(0),
            sample_time: Duration::from_millis(1),
            samples: 3,
            ..Default::default()
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + calibration
        let mut iters: u64 = 1;
        let cal_start = Instant::now();
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.sample_time || iters > 1 << 30 {
                break;
            }
            if cal_start.elapsed() > self.warmup + Duration::from_secs(2) {
                break;
            }
            let scale = (self.sample_time.as_secs_f64()
                / dt.as_secs_f64().max(1e-9))
            .ceil() as u64;
            iters = (iters * scale.clamp(2, 16)).min(1 << 30);
        }
        while cal_start.elapsed() < self.warmup {
            f();
        }
        // sampling
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed());
        }
        let res = BenchResult {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        };
        println!("{}", res.report());
        self.results.push(res);
        let n = self.results.len();
        &self.results[n - 1]
    }

    /// Time a single invocation (for long end-to-end drivers).
    pub fn once<F: FnOnce()>(&mut self, name: &str, f: F) -> &BenchResult {
        let t0 = Instant::now();
        f();
        let res = BenchResult {
            name: name.to_string(),
            samples: vec![t0.elapsed()],
            iters_per_sample: 1,
        };
        println!("{}", res.report());
        self.results.push(res);
        let n = self.results.len();
        &self.results[n - 1]
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept for call-site clarity).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Relative overhead of `on_ns` over `off_ns` in percent — the number
/// the `BENCH_*.json` trajectory publishes as `overhead_vs_off_pct` and
/// `deluxe perfdiff` gates against its budget.  A non-positive baseline
/// yields 0 rather than a nonsense ratio.
pub fn overhead_pct(off_ns: f64, on_ns: f64) -> f64 {
    if off_ns <= 0.0 {
        return 0.0;
    }
    (on_ns / off_ns - 1.0) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_samples() {
        let r = BenchResult {
            name: "t".into(),
            samples: vec![
                Duration::from_nanos(100),
                Duration::from_nanos(200),
                Duration::from_nanos(300),
            ],
            iters_per_sample: 1,
        };
        assert!((r.mean_ns() - 200.0).abs() < 1e-9);
        assert!((r.median_ns() - 200.0).abs() < 1e-9);
        assert!((r.stddev_ns() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn per_iter_normalization() {
        let r = BenchResult {
            name: "t".into(),
            samples: vec![Duration::from_micros(10)],
            iters_per_sample: 10,
        };
        assert!((r.mean_ns() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            sample_time: Duration::from_micros(50),
            samples: 3,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean_ns() > 0.0);
    }

    #[test]
    fn overhead_pct_is_relative_and_guards_zero_baseline() {
        assert!((overhead_pct(100.0, 105.0) - 5.0).abs() < 1e-9);
        assert!((overhead_pct(200.0, 100.0) + 50.0).abs() < 1e-9);
        assert_eq!(overhead_pct(0.0, 100.0), 0.0);
        assert_eq!(overhead_pct(-1.0, 100.0), 0.0);
    }

    #[test]
    fn once_records_single_sample() {
        let mut b = Bench::default();
        b.once("single", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(b.results[0].samples.len(), 1);
        assert!(b.results[0].mean_ns() >= 2e6);
    }
}
