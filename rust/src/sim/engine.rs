//! `AsyncConsensus` — the asynchronous variant of Alg. 1 running on the
//! discrete-event queue.
//!
//! The synchronous engine ([`crate::admm::ConsensusAdmm`]) assumes a
//! round barrier: every agent computes and every message (or its loss)
//! resolves before `z` advances.  Here the barrier is gone:
//!
//! * the leader **broadcasts** `z` (per-link event trigger + compressed
//!   codec + lossy, delayed link) and go-ticks every active agent;
//! * each agent, on its tick, runs the Alg. 1 dual update + local prox
//!   solve (taking modeled compute time — stragglers take longer), then
//!   offers its `d`-delta uplink;
//! * every completed solve sends a reliable control-plane **completion
//!   report** (zero bytes; the async analogue of the sync round
//!   barrier), carrying the event-triggered delta when one fired and
//!   survived the link; the leader integrates payloads **as they
//!   arrive**, and once a quorum (`participation` fraction of active
//!   agents) has reported since the last update it advances `z` and
//!   broadcasts again.  Payloads older than the `staleness` bound (in
//!   leader rounds) are discarded — a controlled disturbance the
//!   periodic resets absorb, exactly like packet drops (Prop. 2.1);
//! * agents **leave and rejoin** per the fault schedule; a rejoining
//!   agent is resynchronized through the reset path (one reliable dense
//!   `z` transfer).
//!
//! **Sync-equivalence contract** (pinned by tests): under an ideal
//! scenario — zero latency, infinite bandwidth, no drops, instant
//! compute, full participation, no churn, and draw-free uplink triggers
//! — the event ordering reduces to the synchronous schedule and the
//! trajectory matches `ConsensusAdmm` bit-for-bit, including the RNG
//! stream consumed by the local solvers.
//!
//! **Determinism contract**: the queue is keyed by `(time, seq)` with a
//! monotone sequence number, all randomness flows through one seeded
//! `Pcg64`, and virtual time is integer microseconds — same `Scenario` +
//! seed ⇒ identical iterates, counters and event-trace hash.

use crate::admm::core::WorkerPool;
use crate::comm::{Estimate, Scalar, TriggerState};
use crate::rng::Pcg64;
use crate::solver::{LocalSolver, ServerProx};
use crate::wire::{
    Compressor, ErrorFeedback, LinkStats, WireMessage, WireStats,
};

use super::event::{secs, ticks, EventQueue, SimTime, TraceHash};
use super::link::Link;
use super::scenario::{FaultKind, Scenario, TopologySpec};

/// Events of the async Alg. 1 simulation.
///
/// Stateful agent events carry the agent's `epoch` (incarnation
/// counter, bumped on every leave and join): an event scheduled before
/// a churn fault must not act on the state of a later incarnation — a
/// delta sent to an agent that left and rejoined would otherwise land
/// on the freshly resynced estimate and permanently desynchronize it
/// from the leader's per-link trigger reference.  `Tick` carries no
/// epoch: it is a pure control signal that only ever acts on whatever
/// the agent's current state is.
enum SimEvent<T: Scalar> {
    /// Leader offers `z` on every active downlink and ticks the agents.
    Broadcast,
    /// A downlink payload arrives at an agent.
    DeliverDown { agent: usize, epoch: u64, msg: WireMessage<T> },
    /// Control-plane go-tick: the agent may start its next local solve.
    Tick { agent: usize },
    /// The agent's local solve completes; it offers its delta uplink.
    Finish { agent: usize, epoch: u64 },
    /// An agent's round-completion report arrives at the leader: always
    /// sent (control-plane, reliable — the async analogue of the sync
    /// round barrier, so quorum progress never depends on a trigger
    /// firing), carrying the triggered delta payload when one fired and
    /// survived the link.  Tagged with the leader round the compute
    /// started from (the staleness bound's clock).
    DeliverUp {
        agent: usize,
        epoch: u64,
        msg: Option<WireMessage<T>>,
        tag: u64,
    },
    /// Apply the next fault-schedule entry.
    Fault { idx: usize },
}

struct AsyncAgent<T: Scalar> {
    x: Vec<T>,
    u: Vec<T>,
    zhat: Estimate<T>,
    /// `ẑ` as of this agent's previous dual update (the sync engine's
    /// pre-downlink snapshot, maintained incrementally here).
    zhat_prev: Vec<T>,
    d: Vec<T>,
    d_trig: TriggerState<T>,
    /// Leader-side per-link downlink trigger.
    z_trig: TriggerState<T>,
    ef_up: ErrorFeedback<T>,
    ef_down: ErrorFeedback<T>,
    up: Link,
    down: Link,
    active: bool,
    busy: bool,
    /// A broadcast arrived while this agent was computing; start again
    /// as soon as the current solve finishes.
    tick_pending: bool,
    /// Leader round at the start of the current compute.
    tag: u64,
    /// Incarnation counter (bumped on leave and join); in-flight events
    /// from an earlier incarnation are discarded on arrival.
    epoch: u64,
    straggler: bool,
}

/// A local solve whose *virtual* start already happened (the tick ran
/// the dual update, captured the anchor and forked the solver stream)
/// but whose numeric result is not needed until the agent's `Finish`
/// event.  Deferring the numeric work lets the engine batch every
/// overlapping compute window into one `solve_batch` on the worker pool
/// — the async engine's compute-phase parallelism.  Results are a pure
/// function of the captured `(anchor, rng)`, so flush timing and worker
/// count cannot change the trajectory.
struct PendingSolve<T: Scalar> {
    agent: usize,
    epoch: u64,
    anchor: Vec<T>,
    rng: Pcg64,
}

/// Asynchronous event-based consensus ADMM on the discrete-event queue.
/// Generic over the scalar type like the synchronous engine.
pub struct AsyncConsensus<T: Scalar> {
    pub scn: Scenario,
    pub n: usize,
    pub dim: usize,
    pub z: Vec<T>,
    zeta_hat: Estimate<T>,
    agents: Vec<AsyncAgent<T>>,
    queue: EventQueue<SimEvent<T>>,
    comp: Box<dyn Compressor<T>>,
    scratch: Vec<T>,
    rng: Pcg64,
    /// RNG state snapshotted at each broadcast — the fork base for the
    /// per-agent solver streams, mirroring the synchronous engine's
    /// round-entry snapshot so the sync-equivalence contract extends to
    /// RNG-consuming solvers.
    solve_base: Pcg64,
    /// Solves started (virtually) but not yet materialized; batched onto
    /// the pool at the first event that needs a result.
    pending: Vec<PendingSolve<T>>,
    /// Worker pool for the batched compute phase (default sequential —
    /// sweeps parallelize over cells; `with_workers` enables per-agent
    /// sharding for single-scenario runs).
    pool: WorkerPool,
    /// Number of `z` updates performed so far.
    pub leader_round: u64,
    /// Distinct agents heard from since the last `z` update.
    arrived: Vec<bool>,
    arrival_count: usize,
    /// Uplink deltas discarded by the staleness bound.
    pub stale_discarded: u64,
    /// Rejoin resynchronizations performed.
    pub rejoin_resyncs: u64,
    trace: TraceHash,
}

impl<T: Scalar> AsyncConsensus<T> {
    /// All state starts synchronized at `z0`, mirroring the synchronous
    /// engine's initialization contract.
    pub fn new(scn: Scenario, z0: Vec<T>) -> Self {
        scn.validate()
            // lint:allow(panic-in-library): an invalid scenario is a constructor contract violation; running it would produce meaningless sweep results
            .unwrap_or_else(|e| panic!("invalid scenario {:?}: {e}", scn.name));
        assert!(
            matches!(scn.topology, TopologySpec::Star),
            "the async sim engine models the leader/agent (star) pattern; \
             decentralized topologies run on the synchronous GraphAdmm \
             engine"
        );
        let n = scn.n_agents;
        let dim = z0.len();
        let stragglers =
            (scn.compute.straggler_frac * n as f64).ceil() as usize;
        let agents: Vec<AsyncAgent<T>> = (0..n)
            .map(|i| AsyncAgent {
                x: z0.clone(),
                u: vec![T::zero(); dim],
                zhat: Estimate::new(z0.clone()),
                zhat_prev: z0.clone(),
                d: z0.clone(),
                d_trig: TriggerState::new(scn.trigger_d, z0.clone()),
                z_trig: TriggerState::new(scn.trigger_z, z0.clone()),
                ef_up: ErrorFeedback::new(),
                ef_down: ErrorFeedback::new(),
                up: Link::new(scn.link_up),
                down: Link::new(scn.link_down),
                active: true,
                busy: false,
                tick_pending: false,
                tag: 0,
                epoch: 0,
                straggler: i < stragglers,
            })
            .collect();
        let comp = scn.compressor.build::<T>();
        let rng = Pcg64::seed(scn.seed);
        let mut queue = EventQueue::new();
        for (idx, f) in scn.faults.iter().enumerate() {
            queue.push(ticks(f.at_secs), SimEvent::Fault { idx });
        }
        queue.push(0, SimEvent::Broadcast);
        AsyncConsensus {
            n,
            dim,
            zeta_hat: Estimate::new(z0.clone()),
            z: z0,
            agents,
            queue,
            comp,
            scratch: Vec::with_capacity(dim),
            solve_base: rng.clone(),
            pending: Vec::new(),
            pool: WorkerPool::sequential(),
            rng,
            leader_round: 0,
            arrived: vec![false; n],
            arrival_count: 0,
            stale_discarded: 0,
            rejoin_resyncs: 0,
            trace: TraceHash::new(),
            scn,
        }
    }

    /// Set the compute-phase worker count (0 = auto): overlapping local
    /// solves batch onto the pool.  Bit-identical for every value.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.pool = WorkerPool::new(workers);
        self
    }

    /// Run the simulation to the scenario horizon.
    pub fn run(
        &mut self,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
    ) {
        self.run_until(self.scn.rounds as u64, solver, prox);
    }

    /// Process events until `target` leader rounds have completed (or the
    /// queue drains — e.g. the quorum became unreachable after churn).
    /// Incremental: callers may step round-by-round to record metrics
    /// against the virtual clock.
    pub fn run_until(
        &mut self,
        target: u64,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
    ) {
        let target = target.min(self.scn.rounds as u64);
        while self.leader_round < target {
            let (t, ev) = match self.queue.pop() {
                Some(e) => e,
                None => {
                    // queue drained (e.g. quorum unreachable): leave no
                    // stale iterates behind
                    self.flush_solves(solver);
                    return;
                }
            };
            self.trace_event(t, &ev);
            match ev {
                SimEvent::Broadcast => self.on_broadcast(),
                SimEvent::DeliverDown { agent, epoch, msg } => {
                    self.on_deliver_down(agent, epoch, &msg)
                }
                SimEvent::Tick { agent } => self.on_tick(agent),
                SimEvent::Finish { agent, epoch } => {
                    self.on_finish(agent, epoch, solver)
                }
                SimEvent::DeliverUp { agent, epoch, msg, tag } => {
                    self.on_deliver_up(agent, epoch, &msg, tag, solver, prox);
                }
                SimEvent::Fault { idx } => {
                    self.on_fault(idx, solver, prox)
                }
            }
        }
        // materialize any solves still in flight so external observers
        // (metrics, tests) see the post-round iterates
        self.flush_solves(solver);
    }

    /// Materialize every pending local solve in one `solve_batch` on the
    /// worker pool.  Called lazily at the first point a result can be
    /// observed (an agent's `Finish`, a reset, a fault, or run exit), so
    /// every compute window that overlaps in virtual time lands in the
    /// same batch.  Each result is a pure function of its captured
    /// `(anchor, rng)` — flush timing and worker count cannot change it.
    fn flush_solves(&mut self, solver: &mut dyn LocalSolver<T>) {
        if self.pending.is_empty() {
            return;
        }
        let alpha = self.scn.alpha;
        let rho = self.scn.rho;
        let pending = std::mem::take(&mut self.pending);
        let mut ids = Vec::with_capacity(pending.len());
        let mut epochs = Vec::with_capacity(pending.len());
        let mut anchors = Vec::with_capacity(pending.len());
        let mut rngs = Vec::with_capacity(pending.len());
        for p in pending {
            ids.push(p.agent);
            epochs.push(p.epoch);
            anchors.push(p.anchor);
            rngs.push(p.rng);
        }
        let xs = solver.solve_batch(&ids, &anchors, rho, &mut rngs, &self.pool);
        for ((i, epoch), x) in ids.into_iter().zip(epochs).zip(xs) {
            let a = &mut self.agents[i];
            if epoch != a.epoch {
                // the incarnation that started this solve has left
                continue;
            }
            debug_assert_eq!(x.len(), self.dim);
            a.x = x;
            a.d = a
                .x
                .iter()
                .zip(&a.u)
                .map(|(&x, &u)| T::from_f64(alpha * x.to_f64() + u.to_f64()))
                .collect();
        }
    }

    fn trace_event(&mut self, t: SimTime, ev: &SimEvent<T>) {
        let (kind, who) = match ev {
            SimEvent::Broadcast => (1u64, u64::MAX),
            SimEvent::DeliverDown { agent, .. } => (2, *agent as u64),
            SimEvent::Tick { agent } => (3, *agent as u64),
            SimEvent::Finish { agent, .. } => (4, *agent as u64),
            SimEvent::DeliverUp { agent, .. } => (5, *agent as u64),
            SimEvent::Fault { idx } => (6, *idx as u64),
        };
        self.trace.mix(t);
        self.trace.mix(kind);
        self.trace.mix(who);
    }

    /// Leader side of a round: per-link event-based `z` offer plus the
    /// go-tick that lets each active agent start its next local solve.
    /// Mirrors the synchronous step 1 agent-by-agent, so the ideal
    /// scenario consumes the RNG in the same order.
    fn on_broadcast(&mut self) {
        // fork base for this round's solver streams: the pre-broadcast
        // state, matching the sync engine's round-entry snapshot
        self.solve_base = self.rng.clone();
        let now = self.queue.now();
        for i in 0..self.n {
            if !self.agents[i].active {
                continue;
            }
            let a = &mut self.agents[i];
            a.down.mark_round();
            if a.z_trig.offer_into(&self.z, &mut self.rng, &mut self.scratch)
            {
                let msg = a.ef_down.compress(
                    &self.scratch,
                    self.comp.as_ref(),
                    &mut self.rng,
                );
                let bytes = msg.wire_bytes() as u64;
                if let Some(delay) = a.down.transmit(bytes, &mut self.rng) {
                    let epoch = a.epoch;
                    self.queue.push(
                        now.saturating_add(delay),
                        SimEvent::DeliverDown { agent: i, epoch, msg },
                    );
                }
            }
            let tick_delay = a.down.control_delay(&mut self.rng);
            self.queue.push(
                now.saturating_add(tick_delay),
                SimEvent::Tick { agent: i },
            );
        }
    }

    fn on_deliver_down(
        &mut self,
        agent: usize,
        epoch: u64,
        msg: &WireMessage<T>,
    ) {
        let a = &mut self.agents[agent];
        if !a.active || epoch != a.epoch {
            // left while the packet was in flight (possibly rejoining
            // since): a stale delta must not land on the resynced state
            return;
        }
        a.zhat.apply_msg(msg);
    }

    fn on_tick(&mut self, agent: usize) {
        if !self.agents[agent].active {
            return;
        }
        if self.agents[agent].busy {
            self.agents[agent].tick_pending = true;
            return;
        }
        self.start_compute(agent);
    }

    /// Alg. 1 step 2, agent side: dual update against the current `ẑ`,
    /// then the local prox solve is *deferred* — its anchor and forked
    /// RNG stream are captured here and the numeric work batches onto
    /// the pool at the first event that needs the result (see
    /// [`PendingSolve`]).  The uplink offer is scheduled after the
    /// modeled compute time.  The arithmetic mirrors
    /// `ConsensusAdmm::round` expression-for-expression — the
    /// sync-equivalence test pins this bit-for-bit.
    fn start_compute(&mut self, i: usize) {
        let alpha = self.scn.alpha;
        let a = &mut self.agents[i];
        a.busy = true;
        a.tick_pending = false;
        a.tag = self.leader_round;
        // u^i = u^i + α x^i − ẑ^i + (1−α) ẑ^i_prev
        for j in 0..self.dim {
            let u = a.u[j].to_f64()
                + alpha * a.x[j].to_f64()
                - a.zhat.get()[j].to_f64()
                + (1.0 - alpha) * a.zhat_prev[j].to_f64();
            a.u[j] = T::from_f64(u);
        }
        // the ẑ used in this dual update becomes the next one's ẑ_prev
        a.zhat_prev.clear();
        a.zhat_prev.extend_from_slice(a.zhat.get());
        let anchor: Vec<T> = a
            .zhat
            .get()
            .iter()
            .zip(&a.u)
            .map(|(&z, &u)| T::from_f64(z.to_f64() - u.to_f64()))
            .collect();
        let straggler = a.straggler;
        let epoch = a.epoch;
        self.pending.push(PendingSolve {
            agent: i,
            epoch,
            anchor,
            rng: self.solve_base.fork(self.leader_round, i as u64),
        });
        let dt = self.scn.compute.sample(straggler, &mut self.rng);
        self.queue
            .push_after(ticks(dt), SimEvent::Finish { agent: i, epoch });
    }

    fn on_finish(
        &mut self,
        i: usize,
        epoch: u64,
        solver: &mut dyn LocalSolver<T>,
    ) {
        // the agent's d is read below: materialize every pending solve
        // (one pooled batch across all overlapping compute windows)
        self.flush_solves(solver);
        let now = self.queue.now();
        let a = &mut self.agents[i];
        if epoch != a.epoch {
            // the compute belongs to an incarnation that has since left
            // (and possibly rejoined): its result was discarded by the
            // fault handler, so neither report nor payload goes out
            return;
        }
        a.busy = false;
        if !a.active {
            return; // left mid-compute: the result is discarded
        }
        // The completion report always goes out (control-plane,
        // reliable): without it, a converged network whose triggers all
        // stay silent would starve the quorum and stall the leader —
        // whereas the sync engine's round barrier always advances.
        let mut delay = a.up.control_delay(&mut self.rng);
        let mut payload: Option<WireMessage<T>> = None;
        a.up.mark_round();
        if a.d_trig.offer_into(&a.d, &mut self.rng, &mut self.scratch) {
            let msg = a.ef_up.compress(
                &self.scratch,
                self.comp.as_ref(),
                &mut self.rng,
            );
            let bytes = msg.wire_bytes() as u64;
            // on loss the payload vanishes (the sender's trigger
            // reference already advanced — the paper's χ disturbance)
            // but the bare report below still arrives
            if let Some(d) = a.up.transmit(bytes, &mut self.rng) {
                // the report rides with the payload
                delay = d;
                payload = Some(msg);
            }
        }
        let tag = a.tag;
        let up_epoch = a.epoch;
        self.queue.push(
            now.saturating_add(delay),
            SimEvent::DeliverUp {
                agent: i,
                epoch: up_epoch,
                msg: payload,
                tag,
            },
        );
        if a.tick_pending {
            a.tick_pending = false;
            self.queue.push(now, SimEvent::Tick { agent: i });
        }
    }

    fn on_deliver_up(
        &mut self,
        i: usize,
        epoch: u64,
        msg: &Option<WireMessage<T>>,
        tag: u64,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
    ) {
        if !self.agents[i].active || epoch != self.agents[i].epoch {
            // the sender has since left (and possibly rejoined with a
            // fresh state): the leader ignores the stale report
            return;
        }
        if let Some(msg) = msg {
            if self.leader_round.saturating_sub(tag) > self.scn.staleness {
                // Too stale: discard the payload.  The sender's trigger
                // already advanced its reference, so this acts exactly
                // like a packet drop (a χ disturbance) — the periodic
                // resets absorb the drift.
                self.stale_discarded += 1;
            } else {
                let invn = 1.0 / self.n as f64;
                self.zeta_hat.apply_scaled_msg(msg, invn);
            }
        }
        // the completion itself counts toward the participation quorum
        if !self.arrived[i] {
            self.arrived[i] = true;
            self.arrival_count += 1;
        }
        self.maybe_update(solver, prox);
    }

    fn active_count(&self) -> usize {
        self.agents.iter().filter(|a| a.active).count()
    }

    /// Quorum size: `ceil(participation * active)`, at least 1.
    fn quorum_size(&self) -> usize {
        let active = self.active_count();
        ((self.scn.participation * active as f64).ceil() as usize)
            .clamp(1, active.max(1))
    }

    fn maybe_update(
        &mut self,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
    ) {
        if self.arrival_count >= self.quorum_size() {
            self.leader_update(solver, prox);
        }
    }

    /// Alg. 1 step 3: `z ← prox_g(ζ̂ + (1−α) z; Nρ)`, then the next
    /// broadcast (and a periodic reset when due).
    fn leader_update(
        &mut self,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
    ) {
        let alpha = self.scn.alpha;
        let v: Vec<T> = self
            .zeta_hat
            .get()
            .iter()
            .zip(&self.z)
            .map(|(&zh, &z)| {
                T::from_f64(zh.to_f64() + (1.0 - alpha) * z.to_f64())
            })
            .collect();
        self.z = prox.prox(&v, self.n as f64 * self.scn.rho);
        debug_assert_eq!(self.z.len(), self.dim);
        self.leader_round += 1;
        self.arrived.fill(false);
        self.arrival_count = 0;
        if self.scn.reset_period > 0
            && self.leader_round as usize % self.scn.reset_period == 0
        {
            self.resync(solver);
        }
        if self.leader_round < self.scn.rounds as u64 {
            let now = self.queue.now();
            self.queue.push(now, SimEvent::Broadcast);
        }
    }

    /// Full resynchronization — the synchronous engine's periodic reset
    /// (App. E) transplanted to the event world: `ζ̂` snaps to the true
    /// mean of the `d^i`, and every active agent receives the exact `z`
    /// out-of-band (reliable, instantaneous, charged as one dense sync
    /// per direction; see DESIGN.md §9 for why the sync transfer is
    /// modeled as out-of-band).
    fn resync(&mut self, solver: &mut dyn LocalSolver<T>) {
        // ζ̂ snaps to the true mean of the d^i: every d must be current
        self.flush_solves(solver);
        let mut zeta = vec![0.0f64; self.dim];
        for a in &self.agents {
            for (s, &d) in zeta.iter_mut().zip(&a.d) {
                *s += d.to_f64();
            }
        }
        let invn = 1.0 / self.n as f64;
        let zeta: Vec<T> =
            zeta.into_iter().map(|v| T::from_f64(v * invn)).collect();
        self.zeta_hat.reset_to(&zeta);
        let sync_bytes = WireMessage::<T>::dense_bytes(self.dim) as u64;
        for a in &mut self.agents {
            if !a.active {
                continue;
            }
            a.zhat.reset_to(&self.z);
            // the sync engine snapshots ẑ_prev each round, so a reset
            // there propagates into the next dual update; replicate by
            // overwriting the incremental snapshot too
            a.zhat_prev.clear();
            a.zhat_prev.extend_from_slice(&self.z);
            a.d_trig.reset(&a.d);
            a.z_trig.reset(&self.z);
            a.ef_up.clear();
            a.ef_down.clear();
            a.up.charge_sync(sync_bytes);
            a.down.charge_sync(sync_bytes);
        }
    }

    fn on_fault(
        &mut self,
        idx: usize,
        solver: &mut dyn LocalSolver<T>,
        prox: &mut dyn ServerProx<T>,
    ) {
        // epoch bumps below invalidate captured solves: materialize them
        // first so the leaving incarnation's state matches the
        // solve-at-tick semantics
        self.flush_solves(solver);
        let f = self.scn.faults[idx];
        match f.kind {
            FaultKind::Leave => {
                let a = &mut self.agents[f.agent];
                if !a.active {
                    return;
                }
                a.active = false;
                a.busy = false; // an in-progress compute dies with it
                a.tick_pending = false;
                a.epoch += 1; // in-flight events to/from it are now stale
                if self.arrived[f.agent] {
                    self.arrived[f.agent] = false;
                    self.arrival_count -= 1;
                }
                // a shrinking quorum may already be satisfied
                if self.active_count() > 0 {
                    self.maybe_update(solver, prox);
                }
            }
            FaultKind::Join => {
                if self.agents[f.agent].active {
                    return;
                }
                // stale-state resync through the reset path: the leader
                // ships the exact current z (one reliable dense sync) and
                // the agent restarts from the common initialization
                let sync_bytes =
                    WireMessage::<T>::dense_bytes(self.dim) as u64;
                let z = self.z.clone();
                let a = &mut self.agents[f.agent];
                a.active = true;
                a.epoch += 1;
                a.zhat.reset_to(&z);
                a.zhat_prev.clear();
                a.zhat_prev.extend_from_slice(&z);
                for v in &mut a.u {
                    *v = T::zero();
                }
                a.x.clear();
                a.x.extend_from_slice(&z);
                a.d.clear();
                a.d.extend_from_slice(&z);
                a.d_trig.reset(&z);
                a.z_trig.reset(&z);
                a.ef_up.clear();
                a.ef_down.clear();
                a.down.charge_sync(sync_bytes);
                self.rejoin_resyncs += 1;
                let now = self.queue.now();
                self.queue.push(now, SimEvent::Tick { agent: f.agent });
            }
        }
    }

    // ---------------------------------------------------------------
    // Observers (mirroring the synchronous engine's accessors)
    // ---------------------------------------------------------------

    /// Virtual clock, in seconds.
    pub fn now_secs(&self) -> f64 {
        secs(self.queue.now())
    }

    /// Virtual clock, in ticks (integer microseconds).
    pub fn now_ticks(&self) -> SimTime {
        self.queue.now()
    }

    /// Events processed / scheduled so far.
    pub fn events_processed(&self) -> u64 {
        self.queue.popped
    }

    pub fn events_scheduled(&self) -> u64 {
        self.queue.pushed
    }

    /// The determinism witness: FNV-1a hash over `(time, kind, agent)`
    /// of every processed event.
    pub fn trace_hash(&self) -> u64 {
        self.trace.value()
    }

    pub fn agent_x(&self, i: usize) -> &[T] {
        &self.agents[i].x
    }

    pub fn agent_u(&self, i: usize) -> &[T] {
        &self.agents[i].u
    }

    pub fn agent_active(&self, i: usize) -> bool {
        self.agents[i].active
    }

    /// Total triggered communication events (up + down lines).
    pub fn total_events(&self) -> u64 {
        self.agents
            .iter()
            .map(|a| a.d_trig.events + a.z_trig.events)
            .sum()
    }

    /// Per-direction event counts `(uplink, downlink)`.
    pub fn events_split(&self) -> (u64, u64) {
        let up = self.agents.iter().map(|a| a.d_trig.events).sum();
        let down = self.agents.iter().map(|a| a.z_trig.events).sum();
        (up, down)
    }

    /// Dropped-packet counts `(uplink, downlink)`.
    pub fn drops_split(&self) -> (u64, u64) {
        let up = self.agents.iter().map(|a| a.up.stats.dropped).sum();
        let down = self.agents.iter().map(|a| a.down.stats.dropped).sum();
        (up, down)
    }

    /// Total sent bytes `(uplink, downlink)`.
    pub fn bytes_split(&self) -> (u64, u64) {
        let up = self.agents.iter().map(|a| a.up.stats.sent_bytes).sum();
        let down =
            self.agents.iter().map(|a| a.down.stats.sent_bytes).sum();
        (up, down)
    }

    /// Byte-accurate per-agent wire accounting (both directions).
    pub fn wire_stats(&self) -> WireStats {
        WireStats {
            uplink: self
                .agents
                .iter()
                .map(|a| LinkStats::from(&a.up.stats))
                .collect(),
            downlink: self
                .agents
                .iter()
                .map(|a| LinkStats::from(&a.down.stats))
                .collect(),
        }
    }

    /// Mean residual `(1/N) Σ |x^i − z|` over active agents.
    pub fn mean_residual(&self) -> f64 {
        let active = self.active_count().max(1);
        self.agents
            .iter()
            .filter(|a| a.active)
            .map(|a| {
                a.x.iter()
                    .zip(&self.z)
                    .map(|(&x, &z)| {
                        let d = x.to_f64() - z.to_f64();
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / active as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::{ConsensusAdmm, ConsensusConfig};
    use crate::comm::Trigger;
    use crate::transport::loss::LossModel;
    use crate::sim::link::{LatencyModel, LinkModel};
    use crate::sim::scenario::{ComputeModel, FaultEvent};
    use crate::solver::IdentityProx;

    /// Scalar quadratic agents f_i(x) = 0.5 w_i (x - c_i)^2 — the same
    /// workload the synchronous engine's tests use, so the equivalence
    /// test compares like for like.
    struct ScalarQuad {
        w: Vec<f64>,
        c: Vec<f64>,
    }

    impl LocalSolver<f64> for ScalarQuad {
        fn solve(
            &mut self,
            agent: usize,
            anchor: &[f64],
            rho: f64,
            _rng: &mut Pcg64,
        ) -> Vec<f64> {
            let (w, c) = (self.w[agent], self.c[agent]);
            vec![(w * c + rho * anchor[0]) / (w + rho)]
        }
        fn dim(&self) -> usize {
            1
        }
        fn n_agents(&self) -> usize {
            self.w.len()
        }
    }

    fn quad(n: usize) -> (ScalarQuad, f64) {
        use crate::rng::Rng;
        let mut rng = Pcg64::seed(9000);
        let w: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64() * 2.0).collect();
        let c: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
        let opt = w.iter().zip(&c).map(|(a, b)| a * b).sum::<f64>()
            / w.iter().sum::<f64>();
        (ScalarQuad { w, c }, opt)
    }

    fn gnarly_scenario(seed: u64) -> Scenario {
        let mut s = Scenario::ideal("gnarly", 8, 60);
        s.seed = seed;
        s.trigger_d = Trigger::vanilla(1e-3);
        s.trigger_z = Trigger::vanilla(1e-4);
        s.link_up = LinkModel {
            latency: LatencyModel::lognormal_median(0.010, 0.6),
            bandwidth: 1e6,
            loss: LossModel::GilbertElliott {
                p_gb: 0.05,
                p_bg: 0.3,
                loss_good: 0.02,
                loss_bad: 0.7,
            },
        };
        s.link_down = LinkModel {
            latency: LatencyModel::Uniform { lo: 0.002, hi: 0.02 },
            bandwidth: 2e6,
            loss: LossModel::Bernoulli { p: 0.1 },
        };
        s.compute = ComputeModel {
            time: LatencyModel::Uniform { lo: 0.005, hi: 0.02 },
            straggler_frac: 0.25,
            straggler_mult: 8.0,
        };
        s.participation = 0.5;
        s.staleness = 3;
        s.reset_period = 10;
        s.faults = vec![
            FaultEvent { at_secs: 0.3, agent: 2, kind: FaultKind::Leave },
            FaultEvent { at_secs: 0.9, agent: 2, kind: FaultKind::Join },
        ];
        s
    }

    #[test]
    fn ideal_scenario_reproduces_sync_engine_bit_for_bit() {
        // zero latency, infinite bandwidth, no drops, instant compute,
        // full participation: the async engine must be indistinguishable
        // from ConsensusAdmm — identical z, x, u, event counts and bytes.
        let n = 6;
        let rounds = 150;
        let mut scn = Scenario::ideal("equiv", n, rounds);
        scn.seed = 11;
        scn.alpha = 1.5;
        scn.rho = 0.7;
        scn.trigger_d = Trigger::vanilla(1e-3);
        scn.trigger_z = Trigger::vanilla(1e-4);
        scn.reset_period = 17;

        let (mut solver_a, _) = quad(n);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox_a = IdentityProx;
        sim.run(&mut solver_a, &mut prox_a);

        let cfg = ConsensusConfig {
            rho: 0.7,
            alpha: 1.5,
            rounds,
            trigger_d: Trigger::vanilla(1e-3),
            trigger_z: Trigger::vanilla(1e-4),
            reset_period: 17,
            ..Default::default()
        };
        let (mut solver_b, _) = quad(n);
        let mut sync = ConsensusAdmm::new(cfg, n, vec![0.0]);
        let mut prox_b = IdentityProx;
        let mut rng = Pcg64::seed(11);
        for _ in 0..rounds {
            sync.round(&mut solver_b, &mut prox_b, &mut rng);
        }

        assert_eq!(sim.leader_round, rounds as u64);
        assert_eq!(sim.z[0], sync.z[0], "z diverged");
        for i in 0..n {
            assert_eq!(sim.agent_x(i)[0], sync.agent_x(i)[0], "x[{i}]");
            assert_eq!(sim.agent_u(i)[0], sync.agent_u(i)[0], "u[{i}]");
        }
        assert_eq!(sim.total_events(), sync.total_events());
        assert_eq!(sim.events_split(), sync.events_split());
        assert_eq!(sim.bytes_split(), sync.bytes_split());
        // everything happened at virtual time zero
        assert_eq!(sim.now_ticks(), 0);
    }

    #[test]
    fn ideal_scenario_converges_to_optimum() {
        let n = 8;
        let mut scn = Scenario::ideal("opt", n, 300);
        scn.trigger_d = Trigger::vanilla(1e-5);
        scn.trigger_z = Trigger::vanilla(1e-6);
        let (mut solver, opt) = quad(n);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        assert!(
            (sim.z[0] - opt).abs() < 1e-4,
            "z {} vs opt {opt}",
            sim.z[0]
        );
        assert!(sim.mean_residual() < 1e-3);
    }

    #[test]
    fn determinism_same_seed_identical_trace_and_iterates() {
        // the acceptance contract: two runs of the same Scenario + seed
        // produce identical final iterates, event counts, byte counts
        // and event-trace hash
        let run = || {
            let scn = gnarly_scenario(77);
            let (mut solver, _) = quad(scn.n_agents);
            let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
            let mut prox = IdentityProx;
            sim.run(&mut solver, &mut prox);
            (
                sim.z[0].to_bits(),
                sim.trace_hash(),
                sim.events_processed(),
                sim.events_scheduled(),
                sim.total_events(),
                sim.bytes_split(),
                sim.drops_split(),
                sim.stale_discarded,
                sim.now_ticks(),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same scenario + seed must be bit-identical");
    }

    #[test]
    fn different_seed_changes_the_trace() {
        let run = |seed| {
            let scn = gnarly_scenario(seed);
            let (mut solver, _) = quad(scn.n_agents);
            let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
            let mut prox = IdentityProx;
            sim.run(&mut solver, &mut prox);
            sim.trace_hash()
        };
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn gnarly_scenario_completes_and_stays_finite() {
        let scn = gnarly_scenario(5);
        let rounds = scn.rounds as u64;
        let (mut solver, opt) = quad(scn.n_agents);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        assert_eq!(sim.leader_round, rounds);
        assert!(sim.z[0].is_finite());
        // lossy links + staleness bound + churn must all have fired
        let (du, dd) = sim.drops_split();
        assert!(du + dd > 0, "lossy links never dropped");
        assert_eq!(sim.rejoin_resyncs, 1);
        assert!(sim.now_ticks() > 0, "virtual time must advance");
        // with resets every 10 rounds the error stays bounded
        assert!(
            (sim.z[0] - opt).abs() < 1.5,
            "z {} too far from {opt}",
            sim.z[0]
        );
    }

    #[test]
    fn churn_quorum_shrinks_and_recovers() {
        // all-but-one agents leave; the quorum shrinks to the survivor
        // and the run still completes all rounds
        let mut scn = Scenario::ideal("churn", 4, 40);
        scn.trigger_d = Trigger::vanilla(1e-4);
        scn.trigger_z = Trigger::vanilla(1e-5);
        scn.compute = ComputeModel {
            time: LatencyModel::Fixed { secs: 0.001 },
            straggler_frac: 0.0,
            straggler_mult: 1.0,
        };
        scn.faults = vec![
            FaultEvent { at_secs: 0.005, agent: 1, kind: FaultKind::Leave },
            FaultEvent { at_secs: 0.005, agent: 2, kind: FaultKind::Leave },
            FaultEvent { at_secs: 0.005, agent: 3, kind: FaultKind::Leave },
            FaultEvent { at_secs: 0.020, agent: 1, kind: FaultKind::Join },
            FaultEvent { at_secs: 0.025, agent: 2, kind: FaultKind::Join },
        ];
        let (mut solver, _) = quad(4);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        assert_eq!(sim.leader_round, 40);
        assert_eq!(sim.rejoin_resyncs, 2);
        assert!(sim.agent_active(1));
        assert!(sim.agent_active(2));
        assert!(!sim.agent_active(3));
        assert!(sim.z[0].is_finite());
    }

    #[test]
    fn in_flight_downlink_across_rejoin_is_discarded() {
        // a delta broadcast before an agent leaves must not land on the
        // rejoined agent's freshly resynced estimate: without the epoch
        // guard the stale delta permanently desynchronizes the link
        let mut scn = Scenario::ideal("inflight", 4, 120);
        scn.trigger_d = Trigger::vanilla(1e-6);
        scn.trigger_z = Trigger::vanilla(1e-6);
        scn.link_down = LinkModel {
            latency: LatencyModel::Fixed { secs: 0.010 },
            bandwidth: 0.0,
            loss: LossModel::None,
        };
        scn.compute = ComputeModel {
            time: LatencyModel::Fixed { secs: 0.005 },
            straggler_frac: 0.0,
            straggler_mult: 1.0,
        };
        // broadcasts land every ~15 ms; agent 1 leaves right after one
        // with its delta still in flight and rejoins before delivery
        scn.faults = vec![
            FaultEvent { at_secs: 0.017, agent: 1, kind: FaultKind::Leave },
            FaultEvent { at_secs: 0.019, agent: 1, kind: FaultKind::Join },
        ];
        let (mut solver, opt) = quad(4);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        assert_eq!(sim.rejoin_resyncs, 1);
        assert_eq!(sim.leader_round, 120);
        // reliable links + no resets: only a stale in-flight delta could
        // leave a permanent estimate offset here
        assert!(
            (sim.z[0] - opt).abs() < 1e-3,
            "z {} vs opt {opt}: stale in-flight delta corrupted the link",
            sim.z[0]
        );
        assert!(sim.mean_residual() < 1e-2);
    }

    #[test]
    fn staleness_bound_discards_straggler_deltas() {
        // one extreme straggler with a tight staleness bound: its deltas
        // arrive many leader rounds late and must be discarded
        let mut scn = Scenario::ideal("stale", 5, 60);
        scn.trigger_d = Trigger::vanilla(1e-6);
        scn.trigger_z = Trigger::vanilla(1e-6);
        scn.compute = ComputeModel {
            time: LatencyModel::Fixed { secs: 0.001 },
            straggler_frac: 0.2, // agent 0
            straggler_mult: 50.0,
        };
        scn.participation = 0.6; // quorum of 3: the fast agents carry it
        scn.staleness = 2;
        let (mut solver, _) = quad(5);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        assert_eq!(sim.leader_round, 60);
        assert!(
            sim.stale_discarded > 0,
            "straggler deltas should exceed the staleness bound"
        );
    }

    #[test]
    fn bandwidth_makes_virtual_time_advance() {
        // finite bandwidth: each dense message takes dim*8 bytes / bw
        // seconds, so the horizon's virtual time is bounded below
        let mut scn = Scenario::ideal("bw", 3, 10);
        scn.link_up.bandwidth = 1e6;
        scn.link_down.bandwidth = 1e6;
        let (mut solver, _) = quad(3);
        let mut sim = AsyncConsensus::<f64>::new(scn, vec![0.0]);
        let mut prox = IdentityProx;
        sim.run(&mut solver, &mut prox);
        assert_eq!(sim.leader_round, 10);
        assert!(
            sim.now_secs() > 0.0,
            "serialization delay must advance the clock"
        );
    }

    #[test]
    #[should_panic(expected = "star")]
    fn non_star_topology_is_rejected() {
        let mut scn = Scenario::ideal("ring", 4, 10);
        scn.topology = TopologySpec::Ring;
        let _ = AsyncConsensus::<f64>::new(scn, vec![0.0]);
    }
}
