//! Deterministic discrete-event network simulator (DESIGN.md §9).
//!
//! The synchronous engines in [`crate::admm`] model exactly one failure
//! mode — i.i.d. packet drops inside a round barrier.  This subsystem
//! removes the barrier and makes the network a first-class object:
//!
//! * [`event`] — virtual clock + binary-heap event queue keyed by
//!   `(time, tie-break seq)`, plus the FNV-1a trace hash that witnesses
//!   the determinism contract (same `Scenario` + seed ⇒ bit-identical
//!   event trace, iterates and counters).
//! * [`link`] — per-link delivery models: seeded latency distributions,
//!   bandwidth that converts [`crate::wire::WireMessage`] bytes into
//!   serialization time, and Bernoulli / Gilbert–Elliott loss via the
//!   shared [`crate::transport::loss::LossModel`].
//! * [`scenario`] — the declarative [`Scenario`] (topology, links,
//!   compute/straggler model, quorum, staleness, resets, fault
//!   schedule), parseable from JSON and from named CLI builtins.
//! * [`engine`] — [`AsyncConsensus`]: the asynchronous variant of
//!   Alg. 1 (delta-as-they-arrive aggregation with a participation
//!   quorum and a staleness bound, agent churn with resync through the
//!   reset path).  Under an ideal scenario it reproduces the
//!   synchronous [`crate::admm::ConsensusAdmm`] bit-for-bit.
//! * [`sweep`] — the multi-threaded scenario × seed sweep runner used
//!   by [`crate::experiments::faults`].
//!
//! No wall-clock time and no OS threads inside a simulation: a run is a
//! pure function of `(Scenario, seed)`.

pub mod engine;
pub mod event;
pub mod link;
pub mod scenario;
pub mod sweep;

pub use engine::AsyncConsensus;
pub use event::{secs, ticks, EventQueue, SimTime, TraceHash};
pub use link::{LatencyModel, Link, LinkModel};
pub use scenario::{
    ComputeModel, FaultEvent, FaultKind, Scenario, TopologySpec,
};
pub use sweep::{default_workers, run_parallel};
