//! `Scenario` — the declarative description of one simulated run: who
//! talks (topology), over what (per-direction link models), how agents
//! compute (stragglers), how the leader aggregates (quorum, staleness),
//! and what goes wrong when (the fault schedule).
//!
//! Scenarios parse from JSON (`deluxe sim --scenario path.json`) with
//! the same colon syntaxes the CLI flags use, and a few named builtins
//! cover the common cases.  Same `Scenario` + seed ⇒ bit-identical run
//! (the determinism contract, DESIGN.md §9).

use std::path::Path;

use crate::comm::Trigger;
use crate::transport::loss::LossModel;
use crate::jsonio::{read_json, Json};
use crate::rng::{Pcg64, Rng};
use crate::topology::Graph;
use crate::wire::CompressorCfg;

use super::link::{LatencyModel, LinkModel};

/// Agent churn: a scheduled leave or (re)join.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    Leave,
    Join,
}

/// One entry of the fault schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Virtual time of the fault, in seconds.
    pub at_secs: f64,
    pub agent: usize,
    pub kind: FaultKind,
}

/// Per-agent local-compute time model.  The first
/// `ceil(straggler_frac * n)` agents are stragglers whose compute time
/// is multiplied by `straggler_mult` (deterministic membership keeps the
/// scenario self-describing).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeModel {
    pub time: LatencyModel,
    pub straggler_frac: f64,
    pub straggler_mult: f64,
}

impl ComputeModel {
    /// Zero-time computation (the sync-equivalence configuration).
    pub fn instant() -> Self {
        ComputeModel {
            time: LatencyModel::zero(),
            straggler_frac: 0.0,
            straggler_mult: 1.0,
        }
    }

    /// Sample one local-solve duration in seconds.
    pub fn sample(&self, straggler: bool, rng: &mut Pcg64) -> f64 {
        let base = self.time.sample(rng);
        if straggler {
            base * self.straggler_mult
        } else {
            base
        }
    }

    pub fn from_json(j: &Json) -> Result<ComputeModel, String> {
        reject_unknown_keys(
            j,
            &["time", "straggler_frac", "straggler_mult"],
            "compute",
        )?;
        let mut m = ComputeModel::instant();
        if let Some(s) = j.get("time").and_then(Json::as_str) {
            m.time = LatencyModel::parse(s)?;
        }
        if let Some(v) = j.get("straggler_frac").and_then(Json::as_f64) {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("straggler_frac {v} not in [0,1]"));
            }
            m.straggler_frac = v;
        }
        if let Some(v) = j.get("straggler_mult").and_then(Json::as_f64) {
            if v < 1.0 {
                return Err(format!("straggler_mult {v} must be >= 1"));
            }
            m.straggler_mult = v;
        }
        Ok(m)
    }
}

/// A typo in a scenario key silently running the ideal default would
/// corrupt a whole sweep (the same reasoning that makes a malformed
/// `--compressor` fatal), so every object is checked against its schema.
fn reject_unknown_keys(
    j: &Json,
    known: &[&str],
    what: &str,
) -> Result<(), String> {
    if let Some(obj) = j.as_obj() {
        for key in obj.keys() {
            if !known.contains(&key.as_str()) {
                return Err(format!(
                    "unknown {what} key {key:?} (known: {})",
                    known.join(", ")
                ));
            }
        }
    }
    Ok(())
}

/// Named communication topology.  The async engine models the paper's
/// leader/agent (star) pattern; the other shapes drive the decentralized
/// [`crate::admm::GraphAdmm`] engine and are validated here so a
/// scenario can never name a disconnected network.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TopologySpec {
    Star,
    Complete,
    Ring,
    Grid2d { rows: usize, cols: usize },
    /// `G(n, p)` resampled until connected.
    ErdosRenyi { p: f64 },
}

impl TopologySpec {
    /// Parse `star` | `complete` | `ring` | `grid2d:R:C` | `er:P`.
    pub fn parse(s: &str) -> Result<TopologySpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "star" => Ok(TopologySpec::Star),
            "complete" => Ok(TopologySpec::Complete),
            "ring" => Ok(TopologySpec::Ring),
            "grid2d" => {
                let dim = |i: usize| -> Result<usize, String> {
                    parts
                        .get(i)
                        .ok_or_else(|| format!("{s:?}: missing extent"))?
                        .parse::<usize>()
                        .map_err(|_| format!("{s:?}: bad extent"))
                };
                Ok(TopologySpec::Grid2d { rows: dim(1)?, cols: dim(2)? })
            }
            "er" => {
                let p: f64 = parts
                    .get(1)
                    .ok_or_else(|| format!("{s:?}: missing p"))?
                    .parse()
                    .map_err(|_| format!("{s:?}: bad p"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("{s:?}: p must be in [0,1]"));
                }
                Ok(TopologySpec::ErdosRenyi { p })
            }
            other => Err(format!(
                "unknown topology {other:?} (expected star | complete | \
                 ring | grid2d:R:C | er:P)"
            )),
        }
    }

    /// Materialize a connected graph on `n` vertices (for the star, the
    /// hub is vertex 0 = the leader).
    pub fn build(&self, n: usize, rng: &mut impl Rng) -> Graph {
        match *self {
            TopologySpec::Star => Graph::star(n),
            TopologySpec::Complete => Graph::complete(n),
            TopologySpec::Ring => Graph::ring(n),
            TopologySpec::Grid2d { rows, cols } => Graph::grid2d(rows, cols),
            TopologySpec::ErdosRenyi { p } => {
                Graph::erdos_renyi_connected(n, p, rng)
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            TopologySpec::Star => "star".into(),
            TopologySpec::Complete => "complete".into(),
            TopologySpec::Ring => "ring".into(),
            TopologySpec::Grid2d { rows, cols } => {
                format!("grid2d:{rows}:{cols}")
            }
            TopologySpec::ErdosRenyi { p } => format!("er:{p}"),
        }
    }
}

/// Full description of one simulated run.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub n_agents: usize,
    /// Leader z-updates to simulate (the virtual-time horizon follows
    /// from the link/compute models).
    pub rounds: usize,
    pub seed: u64,
    pub rho: f64,
    pub alpha: f64,
    pub topology: TopologySpec,
    pub trigger_d: Trigger,
    pub trigger_z: Trigger,
    pub compressor: CompressorCfg,
    pub link_up: LinkModel,
    pub link_down: LinkModel,
    pub compute: ComputeModel,
    /// Quorum: fraction of *active* agents whose deltas must arrive
    /// before the leader updates `z` (1.0 = full participation).
    pub participation: f64,
    /// Max leader rounds an uplink delta may lag before the leader
    /// discards it (`u64::MAX` = unbounded).  A discarded delta acts
    /// like a packet drop: the periodic resets absorb the drift.
    pub staleness: u64,
    /// Reset period in leader rounds; 0 disables.
    pub reset_period: usize,
    pub faults: Vec<FaultEvent>,
}

impl Scenario {
    /// The sync-equivalent configuration: ideal links, instant compute,
    /// full participation — the sim reproduces `ConsensusAdmm`
    /// bit-for-bit under this scenario.
    pub fn ideal(name: &str, n_agents: usize, rounds: usize) -> Scenario {
        Scenario {
            name: name.to_string(),
            n_agents,
            rounds,
            seed: 0,
            rho: 1.0,
            alpha: 1.0,
            topology: TopologySpec::Star,
            trigger_d: Trigger::Always,
            trigger_z: Trigger::Always,
            compressor: CompressorCfg::Identity,
            link_up: LinkModel::ideal(),
            link_down: LinkModel::ideal(),
            compute: ComputeModel::instant(),
            participation: 1.0,
            staleness: u64::MAX,
            reset_period: 0,
            faults: Vec::new(),
        }
    }

    /// Named builtin scenarios for the CLI (`deluxe sim --scenario NAME`).
    pub fn builtin(
        name: &str,
        n_agents: usize,
        rounds: usize,
        seed: u64,
    ) -> Option<Scenario> {
        let mut s = Scenario::ideal(name, n_agents, rounds);
        s.seed = seed;
        s.trigger_d = Trigger::vanilla(1e-3);
        s.trigger_z = Trigger::vanilla(1e-4);
        match name {
            "ideal" => {}
            "lossy" => {
                // bursty WAN: ~10 ms median latency, Gilbert–Elliott
                // bursts, periodic resets to absorb the drift
                let link = LinkModel {
                    latency: LatencyModel::lognormal_median(0.010, 0.5),
                    bandwidth: 10e6,
                    loss: LossModel::GilbertElliott {
                        p_gb: 0.05,
                        p_bg: 0.3,
                        loss_good: 0.01,
                        loss_bad: 0.8,
                    },
                };
                s.link_up = link;
                s.link_down = link;
                s.reset_period = 10;
            }
            "stragglers" => {
                let link = LinkModel {
                    latency: LatencyModel::Uniform { lo: 0.005, hi: 0.015 },
                    bandwidth: 0.0,
                    loss: LossModel::Bernoulli { p: 0.05 },
                };
                s.link_up = link;
                s.link_down = link;
                s.compute = ComputeModel {
                    time: LatencyModel::Uniform { lo: 0.005, hi: 0.020 },
                    straggler_frac: 0.25,
                    straggler_mult: 10.0,
                };
                s.participation = 0.5;
                s.staleness = 4;
                s.reset_period = 20;
            }
            "churn" => {
                let link = LinkModel {
                    latency: LatencyModel::Uniform { lo: 0.005, hi: 0.015 },
                    bandwidth: 0.0,
                    loss: LossModel::Bernoulli { p: 0.1 },
                };
                s.link_up = link;
                s.link_down = link;
                s.compute = ComputeModel {
                    time: LatencyModel::Fixed { secs: 0.010 },
                    straggler_frac: 0.0,
                    straggler_mult: 1.0,
                };
                s.participation = 0.75;
                s.staleness = 8;
                s.reset_period = 10;
                // a round-trip is ~40 ms; park two agents for the middle
                // half of the horizon
                let horizon = rounds as f64 * 0.040;
                s.faults = vec![
                    FaultEvent {
                        at_secs: 0.25 * horizon,
                        agent: 0,
                        kind: FaultKind::Leave,
                    },
                    FaultEvent {
                        at_secs: 0.30 * horizon,
                        agent: 1,
                        kind: FaultKind::Leave,
                    },
                    FaultEvent {
                        at_secs: 0.60 * horizon,
                        agent: 0,
                        kind: FaultKind::Join,
                    },
                    FaultEvent {
                        at_secs: 0.75 * horizon,
                        agent: 1,
                        kind: FaultKind::Join,
                    },
                ];
            }
            _ => return None,
        }
        Some(s)
    }

    /// Parse a scenario from a JSON object.  Missing keys keep the
    /// [`Self::ideal`] defaults; unknown keys are fatal (a typoed field
    /// silently running the ideal default would corrupt a sweep).
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        reject_unknown_keys(
            j,
            &[
                "name",
                "agents",
                "rounds",
                "seed",
                "rho",
                "alpha",
                "topology",
                "trigger_d",
                "trigger_z",
                "compressor",
                "link_up",
                "link_down",
                "compute",
                "participation",
                "staleness",
                "reset_period",
                "faults",
            ],
            "scenario",
        )?;
        let mut s = Scenario::ideal("scenario", 16, 100);
        if let Some(v) = j.get("name").and_then(Json::as_str) {
            s.name = v.to_string();
        }
        if let Some(v) = j.get("agents").and_then(Json::as_usize) {
            s.n_agents = v;
        }
        if let Some(v) = j.get("rounds").and_then(Json::as_usize) {
            s.rounds = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            s.seed = v as u64;
        }
        if let Some(v) = j.get("rho").and_then(Json::as_f64) {
            s.rho = v;
        }
        if let Some(v) = j.get("alpha").and_then(Json::as_f64) {
            s.alpha = v;
        }
        if let Some(v) = j.get("topology").and_then(Json::as_str) {
            s.topology = TopologySpec::parse(v)?;
        }
        if let Some(v) = j.get("trigger_d").and_then(Json::as_str) {
            s.trigger_d = Trigger::parse(v)?;
        }
        if let Some(v) = j.get("trigger_z").and_then(Json::as_str) {
            s.trigger_z = Trigger::parse(v)?;
        }
        if let Some(v) = j.get("compressor").and_then(Json::as_str) {
            s.compressor = CompressorCfg::parse(v)?;
        }
        if let Some(v) = j.get("link_up") {
            s.link_up = LinkModel::from_json(v)?;
        }
        if let Some(v) = j.get("link_down") {
            s.link_down = LinkModel::from_json(v)?;
        }
        if let Some(v) = j.get("compute") {
            s.compute = ComputeModel::from_json(v)?;
        }
        if let Some(v) = j.get("participation").and_then(Json::as_f64) {
            s.participation = v;
        }
        if let Some(v) = j.get("staleness").and_then(Json::as_f64) {
            s.staleness = v as u64;
        }
        if let Some(v) = j.get("reset_period").and_then(Json::as_usize) {
            s.reset_period = v;
        }
        if let Some(arr) = j.get("faults").and_then(Json::as_arr) {
            s.faults.clear();
            for f in arr {
                let at_secs = f
                    .get("at")
                    .and_then(Json::as_f64)
                    .ok_or("fault: missing \"at\" (seconds)")?;
                let agent = f
                    .get("agent")
                    .and_then(Json::as_usize)
                    .ok_or("fault: missing \"agent\"")?;
                let kind = match f.get("kind").and_then(Json::as_str) {
                    Some("leave") => FaultKind::Leave,
                    Some("join") => FaultKind::Join,
                    other => {
                        return Err(format!(
                            "fault: kind must be \"leave\" or \"join\", \
                             got {other:?}"
                        ))
                    }
                };
                s.faults.push(FaultEvent { at_secs, agent, kind });
            }
        }
        s.validate()?;
        Ok(s)
    }

    /// Load a scenario JSON file.
    pub fn load(path: &Path) -> anyhow::Result<Scenario> {
        let j = read_json(path)?;
        Scenario::from_json(&j).map_err(|e| {
            anyhow::anyhow!("scenario {}: {e}", path.display())
        })
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_agents == 0 {
            return Err("need at least one agent".into());
        }
        if self.rounds == 0 {
            return Err("need at least one round".into());
        }
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            return Err(format!(
                "participation {} not in (0, 1]",
                self.participation
            ));
        }
        if !(self.alpha > 0.0 && self.alpha < 2.0) {
            return Err(format!("alpha {} not in (0, 2)", self.alpha));
        }
        if self.rho <= 0.0 {
            return Err(format!("rho {} must be positive", self.rho));
        }
        if !(0.0..=1.0).contains(&self.compute.straggler_frac) {
            return Err("straggler_frac not in [0,1]".into());
        }
        for f in &self.faults {
            if f.agent >= self.n_agents {
                return Err(format!(
                    "fault agent {} out of range (n = {})",
                    f.agent, self.n_agents
                ));
            }
            if f.at_secs.is_nan() || f.at_secs < 0.0 {
                return Err(format!("fault time {} invalid", f.at_secs));
            }
        }
        Ok(())
    }

    /// One-line summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} agents over {}, {} rounds, trigger d={} z={}, comp={}, \
             up[{}], down[{}], quorum {:.0}%, staleness {}, reset {}, \
             {} faults",
            self.name,
            self.n_agents,
            self.topology.label(),
            self.rounds,
            self.trigger_d.label(),
            self.trigger_z.label(),
            self.compressor.label(),
            self.link_up.label(),
            self.link_down.label(),
            self.participation * 100.0,
            if self.staleness == u64::MAX {
                "inf".to_string()
            } else {
                self.staleness.to_string()
            },
            self.reset_period,
            self.faults.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_scenario_validates() {
        let s = Scenario::ideal("t", 8, 50);
        assert!(s.validate().is_ok());
        assert_eq!(s.link_up, LinkModel::ideal());
        assert_eq!(s.compute, ComputeModel::instant());
    }

    #[test]
    fn builtins_exist_and_validate() {
        for name in ["ideal", "lossy", "stragglers", "churn"] {
            let s = Scenario::builtin(name, 16, 100, 7)
                .unwrap_or_else(|| panic!("builtin {name}"));
            s.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.seed, 7);
        }
        assert!(Scenario::builtin("nope", 4, 10, 0).is_none());
    }

    #[test]
    fn from_json_full_roundtrip() {
        let j = Json::parse(
            r#"{
              "name": "wan",
              "agents": 32,
              "rounds": 200,
              "seed": 3,
              "rho": 0.5,
              "alpha": 1.5,
              "topology": "star",
              "trigger_d": "vanilla:0.001",
              "trigger_z": "randomized:0.0001:0.05",
              "compressor": "topk:0.05",
              "link_up": {"latency": "uniform:0.005:0.02",
                          "bandwidth": 1000000.0,
                          "drop": "bernoulli:0.1"},
              "link_down": {"latency": "fixed:0.002"},
              "compute": {"time": "fixed:0.01",
                          "straggler_frac": 0.25,
                          "straggler_mult": 8.0},
              "participation": 0.5,
              "staleness": 4,
              "reset_period": 20,
              "faults": [{"at": 1.5, "agent": 3, "kind": "leave"},
                         {"at": 3.0, "agent": 3, "kind": "join"}]
            }"#,
        )
        .unwrap();
        let s = Scenario::from_json(&j).unwrap();
        assert_eq!(s.name, "wan");
        assert_eq!(s.n_agents, 32);
        assert_eq!(s.rounds, 200);
        assert_eq!(s.seed, 3);
        assert_eq!(s.alpha, 1.5);
        assert_eq!(s.trigger_d, Trigger::vanilla(0.001));
        assert_eq!(s.compressor, CompressorCfg::TopK { frac: 0.05 });
        assert_eq!(s.link_up.bandwidth, 1e6);
        assert_eq!(
            s.link_down.latency,
            LatencyModel::Fixed { secs: 0.002 }
        );
        assert_eq!(s.compute.straggler_mult, 8.0);
        assert_eq!(s.participation, 0.5);
        assert_eq!(s.staleness, 4);
        assert_eq!(s.faults.len(), 2);
        assert_eq!(s.faults[0].kind, FaultKind::Leave);
        assert_eq!(s.faults[1].kind, FaultKind::Join);
    }

    #[test]
    fn from_json_rejects_bad_configs() {
        for bad in [
            r#"{"agents": 0}"#,
            r#"{"agents": 4, "participation": 0.0}"#,
            r#"{"agents": 4, "alpha": 2.5}"#,
            r#"{"agents": 4, "trigger_d": "warp:9"}"#,
            r#"{"agents": 4, "faults": [{"at": 1, "agent": 9,
                                         "kind": "leave"}]}"#,
            r#"{"agents": 4, "faults": [{"at": 1, "agent": 0,
                                         "kind": "explode"}]}"#,
            // typoed keys must be fatal, not silently ideal
            r#"{"agents": 4, "particiaption": 0.3}"#,
            r#"{"agents": 4, "link_up": {"latncy": "fixed:0.01"}}"#,
            r#"{"agents": 4, "compute": {"stragglers": 0.2}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Scenario::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn topology_spec_parse_and_build() {
        let mut rng = Pcg64::seed(5);
        for (s, n) in [
            ("star", 9),
            ("complete", 6),
            ("ring", 7),
            ("grid2d:3:4", 12),
            ("er:0.4", 14),
        ] {
            let spec = TopologySpec::parse(s).unwrap();
            assert_eq!(TopologySpec::parse(&spec.label()).unwrap(), spec);
            let g = spec.build(n, &mut rng);
            assert_eq!(g.n, n);
            assert!(g.is_connected(), "{s} disconnected");
        }
        assert!(TopologySpec::parse("er:1.5").is_err());
        assert!(TopologySpec::parse("moebius").is_err());
    }

    #[test]
    fn compute_model_straggler_multiplier() {
        let m = ComputeModel {
            time: LatencyModel::Fixed { secs: 0.01 },
            straggler_frac: 0.5,
            straggler_mult: 10.0,
        };
        let mut rng = Pcg64::seed(6);
        assert_eq!(m.sample(false, &mut rng), 0.01);
        assert_eq!(m.sample(true, &mut rng), 0.1);
    }
}
